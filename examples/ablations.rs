//! Ablation driver — the paper's Table 5, Figure 4, and Tables 6/7 at a
//! user-chosen scale (the `benches/` targets run the same studies at the
//! fixed bench scale).
//!
//! ```bash
//! make artifacts && cargo run --release --example ablations -- --study all
//! ```
//!
//! Studies:
//!
//! * `n`       — candidate-count sweep (Table 5, top block)
//! * `parts`   — drop L_t / L_kd / L_r / PNC (Table 5, middle block)
//! * `index`   — optimal-assignment index histogram (Table 5, bottom)
//! * `alpha`   — PNC threshold sweep (Figure 4)
//! * `codebook`— KDE source-combination study (Table 6)
//! * `init`    — assignment-init study: random/cosine/euclid/+ratio (Table 7)
//! * `stages`  — residual-stage sweep at matched total bits (universal
//!   codebook, prefix-restricted stages; `exp::stages`)
//! * `all`     — everything above

use std::path::PathBuf;

use vq4all::coordinator::Campaign;
use vq4all::exp::{fig4, stages, table5, table6_7};
use vq4all::util::cli::Cli;
use vq4all::util::config::CampaignConfig;

fn main() -> anyhow::Result<()> {
    vq4all::util::logging::init_from_env();
    let args = Cli::new("ablations", "VQ4ALL ablation studies (Table 5, Fig 4, Tables 6/7)")
        .opt("study", "all", "n | parts | index | alpha | codebook | init | stages | all")
        .opt("net", "mini_resnet18", "network under ablation")
        .opt("steps", "100", "construction steps per run")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse()?;

    let cfg = CampaignConfig {
        steps: args.usize_or("steps", 100)?,
        eval_interval: 0,
        ..CampaignConfig::default()
    };
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let campaign = Campaign::load(&dir, cfg)?;
    let net = args.get_or("net", "mini_resnet18").to_string();
    let study = args.get_or("study", "all").to_string();
    let run = |s: &str| study == "all" || study == s;

    if run("n") {
        let n_max = campaign.manifest.config.n;
        let mut ns = vec![1usize, 2, 4, 8];
        ns.retain(|&v| v <= n_max);
        if !ns.contains(&n_max) {
            ns.push(n_max);
        }
        println!("== candidate-count sweep (Table 5 'n' block, net={net}) ==");
        for r in table5::candidate_count(&campaign, &net, &ns)? {
            println!("  {:<8} metric {:.4}", r.label, r.metric);
        }
    }

    if run("parts") {
        println!("\n== pipeline-part ablation (Table 5 'Part' block, net={net}) ==");
        for r in table5::components(&campaign, &net)? {
            if r.converged {
                println!("  {:<8} metric {:.4}", r.label, r.metric);
            } else {
                println!("  {:<8} nc (diverged)", r.label);
            }
        }
    }

    if run("index") {
        println!("\n== optimal-assignment index distribution (Table 5 'Index' block) ==");
        let mass = table5::index_distribution(&campaign, &net)?;
        for (i, m) in mass.iter().enumerate() {
            println!("  bucket {i}: {:>5.1}%", m * 100.0);
        }
    }

    if run("alpha") {
        println!("\n== PNC threshold sweep (Figure 4, net={net}) ==");
        let pts = fig4::sweep(&campaign, &net, &[0.9, 0.95, 0.99, 0.995, 0.999])?;
        print!("{}", fig4::render(&net, &pts));
    }

    if run("codebook") {
        println!("\n== codebook source-combination study (Table 6) ==");
        let all: Vec<String> = campaign
            .manifest
            .networks
            .iter()
            .map(|n| n.name.clone())
            .collect();
        let combos: Vec<Vec<&str>> = (1..=all.len())
            .map(|k| all[..k].iter().map(|s| s.as_str()).collect())
            .collect();
        let rows = table6_7::codebook_sources(&campaign, &net, &combos)?;
        table6_7::render(&format!("Table 6 — codebook sources ({net})"), &rows).print();
    }

    if run("init") {
        println!("\n== assignment-initialization study (Table 7) ==");
        use vq4all::vq::assign::AssignInit;
        let variants = [
            (AssignInit::Random, false, "random"),
            (AssignInit::Cosine, true, "cosine"),
            (AssignInit::Euclid, false, "euclid (equal ratios)"),
            (AssignInit::Euclid, true, "euclid + ratio init (Eq. 7)"),
        ];
        let rows = table6_7::assign_init(&campaign, &net, &variants)?;
        table6_7::render(&format!("Table 7 — assignment init ({net})"), &rows).print();
    }

    if run("stages") {
        println!("\n== residual-stage sweep at matched total bits (exp::stages) ==");
        let rows = stages::run(&campaign.manifest, &stages::default_splits())?;
        stages::render(&rows).print();
    }

    Ok(())
}
