//! Load + compile every HLO artifact in the manifest — the fastest way
//! to catch ops the xla_extension 0.5.1 text parser rejects (e.g. the
//! `topk` attribute newer jax emits) before a campaign trips over them.
//!
//! ```bash
//! cargo run --release --example check_artifacts
//! ```

use vq4all::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu()?;
    let mut ok = 0usize;
    let mut failed = Vec::new();
    for net in &manifest.networks {
        for (name, spec) in &net.executables {
            let path = manifest.path(&spec.hlo);
            match rt.load(&path, spec) {
                Ok(_) => {
                    println!("OK   {}::{name}  ({} in / {} out)", net.name, spec.inputs.len(), spec.outputs.len());
                    ok += 1;
                }
                Err(e) => {
                    let msg = format!("{e}");
                    let first = msg.lines().take(3).collect::<Vec<_>>().join(" | ");
                    println!("FAIL {}::{name}: {first}", net.name);
                    failed.push(format!("{}::{name}", net.name));
                }
            }
        }
    }
    println!("\n{ok} artifacts compiled, {} failed", failed.len());
    if !failed.is_empty() {
        anyhow::bail!("failed artifacts: {failed:?}");
    }
    Ok(())
}
