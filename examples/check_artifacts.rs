//! Artifact health check: validate the manifest's code-stream integrity
//! block, then load + compile every HLO artifact — the fastest way to
//! catch ops the xla_extension 0.5.1 text parser rejects (e.g. the
//! `topk` attribute newer jax emits) before a campaign trips over them.
//!
//! ```bash
//! cargo run --release --example check_artifacts
//! # manifest integrity only (no PJRT needed — what CI runs):
//! cargo run --release --example check_artifacts -- --manifest-only
//! ```
//!
//! The integrity pass runs first and needs no runtime: a manifest whose
//! `code_checksums` block is malformed (non-hex entries fail the load
//! itself) or inconsistent (checksum count != the manifest's residual
//! stage count) fails the check before a single HLO is compiled.  The
//! checksums' *values* are verified against the live packed streams at
//! hosting time (`Engine::verify_hosted`), where the streams exist.

use vq4all::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let manifest_only = std::env::args().any(|a| a == "--manifest-only");
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)?;
    let mut failed = Vec::new();

    // Pass 1 — manifest integrity: the code_checksums block, when
    // present, must agree with the manifest's own stage count.  (Hex
    // parsing already happened inside Manifest::load — a corrupted
    // entry never reaches this point.)
    let mut stamped = 0usize;
    for net in &manifest.networks {
        if net.code_checksums.is_empty() {
            println!("--   {}: no code checksums (legacy manifest; hosting verifies vacuously)", net.name);
            continue;
        }
        if net.code_checksums.len() == manifest.config.stages {
            println!("OK   {}: {} code-stream checksum(s) match the manifest's {} stage(s)",
                net.name, net.code_checksums.len(), manifest.config.stages);
            stamped += 1;
        } else {
            println!("FAIL {}: {} code-stream checksum(s) but the manifest declares {} stage(s)",
                net.name, net.code_checksums.len(), manifest.config.stages);
            failed.push(format!("{}::code_checksums", net.name));
        }
    }
    println!("integrity: {stamped} net(s) carry checksums, {} inconsistent", failed.len());
    if manifest_only {
        if !failed.is_empty() {
            anyhow::bail!("manifest integrity failures: {failed:?}");
        }
        println!("manifest-only mode: skipping HLO compilation");
        return Ok(());
    }

    // Pass 2 — compile every HLO artifact against the live runtime.
    let rt = Runtime::cpu()?;
    let mut ok = 0usize;
    for net in &manifest.networks {
        for (name, spec) in &net.executables {
            let path = manifest.path(&spec.hlo);
            match rt.load(&path, spec) {
                Ok(_) => {
                    println!("OK   {}::{name}  ({} in / {} out)", net.name, spec.inputs.len(), spec.outputs.len());
                    ok += 1;
                }
                Err(e) => {
                    let msg = format!("{e}");
                    let first = msg.lines().take(3).collect::<Vec<_>>().join(" | ");
                    println!("FAIL {}::{name}: {first}", net.name);
                    failed.push(format!("{}::{name}", net.name));
                }
            }
        }
    }
    println!("\n{ok} artifacts compiled, {} failed", failed.len());
    if !failed.is_empty() {
        anyhow::bail!("failed artifacts: {failed:?}");
    }
    Ok(())
}
