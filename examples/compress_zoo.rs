//! End-to-end driver (DESIGN.md §7): the full VQ4ALL system on the whole
//! zoo — the run recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example compress_zoo
//! ```
//!
//! Stages, all on the Rust/PJRT request path (python never runs here):
//!
//! 1. **Universal codebook** — rebuilt natively from the float zoo's
//!    sub-vectors (KDE sample, §4.1) and cross-checked against the
//!    python-exported codebook shipped in the artifacts.
//! 2. **Campaign** — for every network: device-side candidate init
//!    (Pallas distance kernel inside `init_assign`), the differentiable
//!    construction loop (`train_step`, hundreds of AOT executions), the
//!    PNC scheduler freezing assignments past alpha (Eq. 14), the hard
//!    collapse, and `eval_hard`.
//! 3. **Packing** — `log2 k`-bit codes to disk, whole-model size
//!    accounting with the codebook amortized into ROM.
//! 4. **Hardware story** — codebook I/O for this zoo under per-layer
//!    DRAM vs universal ROM placement (Table 1's I/O column).

use std::path::{Path, PathBuf};

use vq4all::coordinator::{report, Campaign};
use vq4all::rom::memsim::{switch_storm, CodebookPlacement, MemSim, NetCodebooks};
use vq4all::tensor::io;
use vq4all::util::cli::Cli;
use vq4all::util::config::CampaignConfig;
use vq4all::vq::Utilization;

fn main() -> anyhow::Result<()> {
    vq4all::util::logging::init_from_env();
    let args = Cli::new("compress_zoo", "construct the whole zoo from one universal codebook")
        .opt("steps", "200", "construction steps per network")
        .opt("alpha", "0.99", "PNC freeze threshold (schedule-scaled; paper 0.9999)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("out", "", "optional output directory for packed codes + report")
        .opt("seed", "2024", "codebook sampling seed")
        .threads_opt()
        .flag("rust-codebook", "rebuild the codebook natively instead of using the python export")
        .parse()?;

    let cfg = CampaignConfig {
        steps: args.usize_or("steps", 200)?,
        alpha: args.f64_or("alpha", 0.99)?,
        eval_interval: 0,
        threads: args.parallelism()?.threads,
        ..CampaignConfig::default()
    };
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut campaign = Campaign::load(&dir, cfg)?;
    let nets: Vec<String> = campaign
        .manifest
        .networks
        .iter()
        .map(|n| n.name.clone())
        .collect();

    println!(
        "platform: {} | zoo: {nets:?}",
        campaign.rt.platform()
    );
    println!(
        "universal codebook: {}x{} = {} KiB, frozen (ROM-resident)",
        campaign.manifest.config.k,
        campaign.manifest.config.d,
        campaign.manifest.config.k * campaign.manifest.config.d * 4 / 1024
    );

    // Stage 1 — the codebook. Default: the python-exported sample (so the
    // artifacts' candidate tables match). `--rust-codebook` rebuilds it
    // natively and reports the distribution shift vs the export.
    let refs: Vec<&str> = nets.iter().map(|s| s.as_str()).collect();
    let pool = args.parallelism()?.pool();
    let native = Campaign::build_codebook_from_with(
        &campaign.manifest,
        &refs,
        args.usize_or("seed", 2024)? as u64,
        pool.as_ref(),
    )?;
    {
        let a = campaign.codebook.as_f32()?;
        let b = native.as_f32()?;
        let (ma, mb) = (mean(a), mean(b));
        let (sa, sb) = (std_dev(a, ma), std_dev(b, mb));
        println!(
            "codebook cross-check: python-export mean/std {ma:.4}/{sa:.4} vs rust-KDE {mb:.4}/{sb:.4}"
        );
    }
    if args.has("rust-codebook") {
        println!("using the natively rebuilt codebook for construction");
        campaign.codebook = native;
    }

    // Stage 2+3 — the campaign.
    let result = campaign.run(&refs)?;
    report::table(&result).print();

    // Codeword-utilization audit (the collapse/under-use diagnostics of
    // arXiv 2309.17361): what fraction of the universal codebook each
    // constructed network actually addresses, and how far its empirical
    // code entropy sits below the log2(k) budget the packed width pays.
    println!("\ncodeword utilization (k = {}):", campaign.manifest.config.k);
    for n in &result.nets {
        let u = Utilization::from_codes(&n.codes, campaign.manifest.config.k);
        println!(
            "  {}: {}/{} codewords used ({:.1}%), code entropy {:.2} of {:.1} bits",
            n.name,
            u.used,
            u.k,
            u.used_fraction() * 100.0,
            u.entropy_bits,
            (u.k as f64).log2()
        );
    }

    let mut total_float = 0usize;
    let mut total_packed = 0usize;
    for n in &result.nets {
        total_float += n.sizes.float_bytes + n.sizes.other_bytes;
        total_packed += n.sizes.assign_bytes + n.sizes.other_bytes;
    }
    // The single ROM codebook is charged once for the whole zoo.
    let zoo_ratio =
        total_float as f64 / (total_packed + result.codebook_bytes) as f64;
    println!(
        "\nzoo totals: float {:.2} MiB -> packed {:.2} MiB + one {:.2} MiB ROM codebook = {zoo_ratio:.1}x whole-zoo compression",
        total_float as f64 / (1 << 20) as f64,
        total_packed as f64 / (1 << 20) as f64,
        result.codebook_bytes as f64 / (1 << 20) as f64
    );

    // Stage 4 — codebook I/O under a task-switch storm for THIS zoo's
    // geometry (what Table 1's I/O column abstracts).
    let zoo_books: Vec<NetCodebooks> = result
        .nets
        .iter()
        .map(|n| NetCodebooks {
            name: n.name.clone(),
            // per-layer VQ would need one codebook per compressed layer;
            // approximate layers from group count (one book / 4096 groups).
            layer_codebooks: vec![
                campaign.manifest.config.k.min(256) * campaign.manifest.config.d * 4;
                (n.codes.len() / 4096).max(2)
            ],
        })
        .collect();
    let sram = zoo_books
        .iter()
        .map(|b| b.layer_codebooks.iter().sum::<usize>())
        .max()
        .unwrap_or(0)
        * 3
        / 2;
    let mut per_layer = MemSim::new(CodebookPlacement::PerLayerDram { sram_bytes: sram }, zoo_books.clone());
    switch_storm(&mut per_layer, zoo_books.len(), 10, 5);
    let mut rom = MemSim::new(CodebookPlacement::UniversalRom, zoo_books);
    switch_storm(&mut rom, result.nets.len(), 10, 5);
    println!(
        "task-switch storm (10 rounds x 5 inferences): per-layer codebook loads {} ({:.1} MiB moved) vs universal-ROM loads {} — {}x vs 1x",
        per_layer.report.codebook_loads,
        per_layer.report.codebook_bytes_loaded as f64 / (1 << 20) as f64,
        rom.report.codebook_loads,
        per_layer.report.codebook_loads.max(1)
    );

    // Persist the deliverables.
    let out = args.get_or("out", "");
    if !out.is_empty() {
        let out = Path::new(out);
        std::fs::create_dir_all(out)?;
        std::fs::write(out.join("report.json"), report::to_json(&result).to_string())?;
        for n in &result.nets {
            io::write_tensor(
                &out.join(format!("{}.codes.vqt", n.name)),
                &vq4all::tensor::Tensor::from_i32(
                    &[n.codes.len()],
                    n.codes.iter().map(|&c| c as i32).collect(),
                ),
            )?;
        }
        println!("report + packed codes written to {}", out.display());
    }
    Ok(())
}

fn mean(v: &[f32]) -> f64 {
    v.iter().map(|&x| x as f64).sum::<f64>() / v.len().max(1) as f64
}

fn std_dev(v: &[f32], m: f64) -> f64 {
    (v.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / v.len().max(1) as f64).sqrt()
}
