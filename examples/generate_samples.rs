//! Image-generation demo (the paper's §5.3 / Figures 6-7 analogue):
//! construct the 2-bit denoiser from the universal codebook, run the
//! reverse-diffusion chain through the AOT `denoise_eps` artifact, and
//! write generated vs real samples as CSV for plotting.
//!
//! ```bash
//! cargo run --release --example generate_samples -- --out runs/samples
//! ```
//!
//! Prints the Table-4 metrics (FID-proxy vs the test split, IS-proxy
//! mode coverage) for the float teacher, the VQ4ALL construction, and a
//! crushed-codebook baseline — the qualitative story of Figure 7 (other
//! methods lose the ring; VQ4ALL keeps it) as numbers plus plottable
//! points.

use std::io::Write;
use std::path::PathBuf;

use vq4all::coordinator::{Campaign, NetSession};
use vq4all::exp::table4;
use vq4all::tensor::io;
use vq4all::util::cli::Cli;
use vq4all::util::config::CampaignConfig;
use vq4all::vq::kmeans::{kmeans, KmeansOpts};

fn write_csv(path: &PathBuf, pts: &[f32]) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "x,y")?;
    for p in pts.chunks(2) {
        writeln!(f, "{},{}", p[0], p[1])?;
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    vq4all::util::logging::init_from_env();
    let args = Cli::new("generate_samples", "sample the compressed denoiser (Table 4 / Fig 6-7)")
        .opt("steps", "200", "construction steps")
        .opt("rounds", "4", "sampling batches (eval_batch each)")
        .opt("out", "runs/samples", "output directory for CSVs")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse()?;

    let cfg = CampaignConfig {
        steps: args.usize_or("steps", 200)?,
        eval_interval: 0,
        ..CampaignConfig::default()
    };
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let campaign = Campaign::load(&dir, cfg)?;
    let nm = campaign.manifest.network("mini_denoiser")?;
    let rounds = args.usize_or("rounds", 4)?;
    let out = PathBuf::from(args.get_or("out", "runs/samples"));
    std::fs::create_dir_all(&out)?;

    let test = io::read_tensor(&campaign.manifest.path(nm.data_file("test_x")?))?;
    let real = test.as_f32()?;
    write_csv(&out.join("real.csv"), &real[..2048.min(real.len())])?;

    println!("constructing the 2-bit denoiser from the universal codebook...");
    let vq = campaign.construct("mini_denoiser")?;
    let mut sess =
        NetSession::new(&campaign.rt, &campaign.manifest, "mini_denoiser", &campaign.codebook)?;
    sess.set_others(&vq.final_others)?;
    let codes_t = sess.codes_tensor(&vq.codes);
    let gen = table4::generate(&mut sess, &codes_t, rounds, 0x5A)?;
    write_csv(&out.join("vq4all.csv"), &gen)?;
    println!(
        "VQ4ALL ({:.1}x):   FID-proxy {:.3}  IS-proxy {:.2}/8",
        vq.sizes.ratio(),
        table4::fid_proxy(&gen, real),
        table4::is_proxy(&gen, 8, 2.0)
    );

    // Crushed baseline (the Q-diffusion/PCR 2-bit failure mode).
    let flat_t = io::read_tensor(&campaign.manifest.path(nm.data_file("teacher_flat")?))?;
    let flat = flat_t.as_f32()?;
    let cfgm = &campaign.manifest.config;
    let km = kmeans(flat, cfgm.d, 8, &KmeansOpts::default());
    let mut words = km.codebook.words.clone();
    words.resize(cfgm.k * cfgm.d, 0.0);
    let cb = vq4all::tensor::Tensor::from_f32(&[cfgm.k, cfgm.d], words);
    let mut s2 = NetSession::new(&campaign.rt, &campaign.manifest, "mini_denoiser", &cb)?;
    let codes2 = s2.codes_tensor(&km.codes);
    let gen2 = table4::generate(&mut s2, &codes2, rounds, 0x5B)?;
    write_csv(&out.join("crushed.csv"), &gen2)?;
    println!(
        "crushed k=8:      FID-proxy {:.3}  IS-proxy {:.2}/8",
        table4::fid_proxy(&gen2, real),
        table4::is_proxy(&gen2, 8, 2.0)
    );

    println!(
        "CSVs in {} — plot real.csv vs vq4all.csv vs crushed.csv to see \
         the ring survive 16x compression (Figure 7's story)",
        out.display()
    );
    Ok(())
}
