//! Quickstart: construct one low-bit network (mini_mlp) from the frozen
//! universal codebook and report accuracy + compression.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! This runs the full VQ4ALL pipeline end to end: device-side candidate
//! initialization (Pallas distance kernel inside the `init_assign`
//! artifact), the differentiable construction loop (`train_step`), the
//! PNC scheduler freezing assignments past alpha, the hard collapse, and
//! the packed-size accounting.

use vq4all::coordinator::{report, Campaign};
use vq4all::util::cli::Cli;
use vq4all::util::config::CampaignConfig;

fn main() -> anyhow::Result<()> {
    vq4all::util::logging::init_from_env();
    let args = Cli::new("quickstart", "construct mini_mlp with the universal codebook")
        .opt("steps", "120", "construction steps")
        .opt("alpha", "0.99", "PNC freeze threshold (schedule-scaled; paper 0.9999)")
        .opt("net", "mini_mlp", "zoo network to construct")
        .opt("artifacts", "artifacts", "artifacts directory")
        .threads_opt()
        .parse()?;

    let cfg = CampaignConfig {
        steps: args.usize_or("steps", 120)?,
        alpha: args.f64_or("alpha", 0.99)?,
        threads: args.parallelism()?.threads,
        ..CampaignConfig::default()
    };
    let campaign = Campaign::load(std::path::Path::new(args.get_or("artifacts", "artifacts")), cfg)?;
    println!(
        "platform: {} | codebook: {}x{} ({} bytes, ROM-resident)",
        campaign.rt.platform(),
        campaign.manifest.config.k,
        campaign.manifest.config.d,
        campaign.manifest.config.k * campaign.manifest.config.d * 4
    );

    let net = args.get_or("net", "mini_mlp").to_string();
    let result = campaign.run(&[&net])?;
    report::table(&result).print();

    let n = &result.nets[0];
    println!(
        "\n{}: float {:.3} -> VQ4ALL {:.3} at {:.1}x whole-model compression \
         ({} packed assignment bytes, codebook amortized in ROM)",
        n.name,
        n.float_metric,
        n.hard_metric,
        n.sizes.ratio(),
        n.sizes.assign_bytes
    );
    Ok(())
}
