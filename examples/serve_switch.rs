//! Multi-network serving with zero-reload task switching (§3.2).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_switch
//! ```
//!
//! Constructs several networks from the one universal codebook, then
//! serves an interleaved request stream against their `infer_hard`
//! artifacts through the router + dynamic batcher.  Because every
//! network decodes from the same ROM-resident codebook, switching the
//! active network costs zero codebook I/O — the storm at the end
//! quantifies what per-layer codebooks would have paid instead.

use std::path::PathBuf;
use std::sync::Arc;

use vq4all::coordinator::{Campaign, NetSession};
use vq4all::serving::batcher::BatcherConfig;
use vq4all::serving::obs::expose;
use vq4all::serving::server::Server;
use vq4all::serving::switchsim::{compare, SwitchWorkload};
use vq4all::serving::faults::ALL_SITES;
use vq4all::serving::{Admission, Engine, EngineConfig, FaultPlan, FaultSite, HostedNet};
use vq4all::util::cli::Cli;
use vq4all::util::config::CampaignConfig;
use vq4all::util::rng::Rng;
use vq4all::vq::{Codebook, StagedCodes};

fn main() -> anyhow::Result<()> {
    vq4all::util::logging::init_from_env();
    let args = Cli::new("serve_switch", "serve many compressed nets from one ROM codebook")
        .opt("steps", "80", "construction steps per network")
        .opt("requests", "400", "total requests in the stream")
        .opt("nets", "mini_mlp,mini_resnet18,mini_mobilenet", "networks to serve")
        .opt("max-batch", "8", "batcher max batch")
        .opt("linger-us", "200", "batcher max linger (virtual microseconds)")
        .opt("deadline-us", "0", "per-request deadline on the virtual clock (us, 0 = none)")
        .opt("chaos", "0", "arm latency faults (slow-op + shard-wedge) at this permille rate")
        .opt("chaos-seed", "42", "fault-plan seed for --chaos")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "", "config TOML ([engine] shards / cache_kb / max_queue)")
        .engine_opts()
        .threads_opt()
        .parse()?;

    let cfg = CampaignConfig {
        steps: args.usize_or("steps", 80)?,
        eval_interval: 0,
        ..CampaignConfig::default()
    };
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let campaign = Campaign::load(&dir, cfg)?;
    let nets: Vec<String> = args
        .get_or("nets", "mini_mlp,mini_resnet18,mini_mobilenet")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();

    let bc = BatcherConfig {
        max_batch: args.usize_or("max-batch", 8)?,
        max_linger_ns: args.usize_or("linger-us", 200)? as u64 * 1_000,
    };

    // Phase 1 — construct each network (once, offline) and keep the
    // packed codes + a live session for serving.  Progress diagnostics
    // go through util::logging so VQ4ALL_LOG governs their verbosity;
    // only the end-of-run report prints unconditionally.
    vq4all::log_info!(
        "serve_switch",
        "constructing {} networks from the universal codebook...",
        nets.len()
    );
    let universal = Arc::new(Codebook::new(
        campaign.manifest.config.k,
        campaign.manifest.config.d,
        campaign.codebook.as_f32()?.to_vec(),
    ));
    let mut sessions: Vec<(NetSession, vq4all::tensor::Tensor)> = Vec::new();
    let mut hosted: Vec<HostedNet> = Vec::new();
    for name in &nets {
        let res = campaign.construct(name)?;
        let mut sess = NetSession::new(&campaign.rt, &campaign.manifest, name, &campaign.codebook)?;
        sess.set_others(&res.final_others)?; // codes pair with trained norms
        let codes = sess.codes_tensor(&res.codes);
        vq4all::log_info!(
            "serve_switch",
            "{name}: float {:.3} -> hard {:.3} at {:.1}x",
            res.float_metric,
            res.hard_metric,
            res.sizes.ratio()
        );
        // Host the packed stream on the decode plane, segmented so the
        // request-row space (0..64) maps onto real stream rows.  The
        // plane forms the batches now, so the hosted geometry carries
        // the artifact's fixed eval batch.
        hosted.push(HostedNet {
            name: name.clone(),
            codes: StagedCodes::single(res.packed.clone()),
            codebook: universal.clone(),
            codes_per_row: (res.packed.count / 64).max(1),
            device_batch: sess.net.eval_batch,
        });
        sessions.push((sess, codes));
    }

    // Phase 2 — serve an interleaved stream (bursty per-network arrivals
    // force constant task switching) through the sharded plane: the one
    // routing path (admission -> shard queues -> fire-selection ->
    // cached decode -> infer_hard).  Precedence for the knobs:
    // --shards/--cache-kb/--max-queue > [engine] config > defaults; the
    // --threads pool parallelizes the plane's cache-miss decodes.
    let knobs = args.engine_knobs_from_config(args.get("config"))?;
    let plane = Engine::new(
        EngineConfig {
            shards: knobs.shards,
            cache_bytes: knobs.cache_bytes(),
            max_queue_depth: knobs.max_queue,
            batcher: bc,
            obs: Default::default(),
        },
        hosted,
    )?;
    let sess_refs: Vec<(&mut NetSession, vq4all::tensor::Tensor)> = sessions
        .iter_mut()
        .map(|(s, c)| (s, c.clone()))
        .collect();
    let mut server = Server::new(sess_refs, plane, args.parallelism()?.pool())?;

    // Optional deterministic chaos: latency faults only (slow-op stalls
    // the virtual clock, shard-wedge holds fires back a round), so the
    // storm still serves every admitted request — the point is watching
    // the conservation identity hold under injected turbulence.  The
    // destructive sites (decode panic, corrupt window) are exercised by
    // the chaos test suite, not this demo.
    let chaos = args.usize_or("chaos", 0)?.min(1000) as u16;
    if chaos > 0 {
        let seed = args.usize_or("chaos-seed", 42)? as u64;
        let plan = FaultPlan::new(seed)
            .with_rate(FaultSite::SlowOp, chaos)
            .with_rate(FaultSite::ShardWedge, chaos);
        server.plane.arm_faults(&plan);
        if cfg!(feature = "fault-inject") {
            println!("chaos armed: slow-op + shard-wedge at {chaos}/1000, seed {seed}");
        } else {
            println!("--chaos set but the `fault-inject` feature is off; probes are no-ops");
        }
    }

    let total = args.usize_or("requests", 400)?;
    let deadline_us = args.usize_or("deadline-us", 0)? as u64;
    let mut rng = Rng::new(7);
    let mut submitted = 0usize;
    while submitted < total {
        // bursts of 1..=6 requests to one network, then switch
        let net = &nets[rng.below(nets.len())];
        let burst = 1 + rng.below(6);
        for _ in 0..burst.min(total - submitted) {
            let row = rng.below(64);
            // Deadlines live on the same virtual clock the batcher fires
            // on; an expired request is shed at fire time, before decode.
            let deadline = if deadline_us == 0 {
                0
            } else {
                server.now_ns() + deadline_us * 1_000
            };
            // Typed admission: over-budget bursts are shed (--max-queue)
            // instead of queueing without bound; the plane ledgers the
            // shed, so the report reads it back from `totals()`.
            let _admission: Admission = server.submit_with_deadline(net, row, deadline)?;
            submitted += 1;
        }
        server.tick(20_000); // 20us virtual inter-burst gap
        while server.dispatch_one()? > 0 {}
    }
    let drained = server.drain_all()?;
    let totals = server.plane.totals();
    println!(
        "\nserved {} of {submitted} requests ({} shed at admission, {} expired, {} failed, {drained} drained at shutdown) across {} networks",
        totals.served,
        totals.shed,
        totals.expired,
        totals.failed,
        nets.len()
    );

    // Virtual-clock latencies (engine clock, ns → reported in us) —
    // the same unit+clock labeling the `/stats` verb uses.
    println!(
        "\n  network            served  batches  avg-batch  p50 lat(us)  p90 lat(us)  p99 lat(us)   [clock: engine]"
    );
    for (name, st) in &server.stats {
        // Bounded latency summary: percentiles come from the reservoir,
        // not an unbounded per-request log.
        println!(
            "  {name:<18} {:>6}  {:>7}  {:>9.2}  {:>11.1}  {:>11.1}  {:>11.1}",
            st.served,
            st.batches,
            st.served as f64 / st.batches.max(1) as f64,
            st.latency_ns.percentile(50.0) / 1_000.0,
            st.latency_ns.percentile(90.0) / 1_000.0,
            st.latency_ns.percentile(99.0) / 1_000.0,
        );
    }
    println!(
        "  mean device execute: {:.1} us over {} batches (virtual clock driven by measured execs)",
        server.exec_ns.mean() / 1_000.0,
        server.exec_ns.count()
    );
    let cs = server.plane.cache_stats();
    let t = server.plane.totals();
    println!(
        "  decode plane: {} shards, {} weight-row lookups, hit_rate {:.3}, {} evictions",
        server.plane.shard_count(),
        cs.lookups,
        cs.hit_rate(),
        cs.evictions
    );
    println!(
        "  admission: accepted {} = dispatched {} + shed {} + expired {} + failed {} (peak shard depth {}, budget {})",
        t.accepted,
        t.served,
        t.shed,
        t.expired,
        t.failed,
        t.peak_depth,
        server.plane.cfg.max_queue_depth
    );
    if chaos > 0 {
        let fired: u64 = server
            .plane
            .shards()
            .iter()
            .filter_map(|s| s.faults.as_ref())
            .map(|p| ALL_SITES.iter().map(|&site| p.fired(site)).sum::<u64>())
            .sum();
        println!("  chaos: {fired} fault(s) fired across {} shard(s)", server.plane.shard_count());
    }

    // Final unified metrics snapshot — the same object the TCP
    // front-end serves as `/metrics` `"format": "json"`, dumped so
    // headless runs leave a machine-readable observability record.
    let snap = server.plane.metrics_snapshot();
    println!(
        "  stage split: decode {:.1} us / infer {:.1} us per batch, decode-hidden ratio {:.3}",
        snap.decode_ns_total as f64 / snap.batches.max(1) as f64 / 1_000.0,
        snap.infer_ns_total as f64 / snap.batches.max(1) as f64 / 1_000.0,
        snap.decode_hidden_ratio()
    );
    println!("\nfinal metrics snapshot:\n{}", expose::snapshot_json(&snap));

    // Phase 3 — what the same switch pattern costs with per-layer
    // codebooks in DRAM vs the universal codebook in ROM.
    let w = SwitchWorkload {
        nets: nets.len(),
        layers_per_net: 12,
        codebook_bytes_per_layer: 64 * 1024,
        rounds: 10,
        inferences_per_activation: 5,
        sram_bytes: 18 * 64 * 1024,
    };
    let (pl, rom) = compare(&w);
    println!(
        "\ntask-switch storm: per-layer DRAM {} codebook loads ({:.1} MiB) vs universal ROM {} loads — {}x vs 1x (Table 1 I/O column)",
        pl.codebook_loads,
        pl.codebook_bytes_loaded as f64 / (1 << 20) as f64,
        rom.codebook_loads,
        pl.codebook_loads.max(1)
    );
    Ok(())
}
