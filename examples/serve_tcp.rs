//! TCP serving demo: construct networks from the universal codebook,
//! expose them over a newline-JSON TCP endpoint, and (in `--client`
//! mode) fire a request storm against it.
//!
//! ```bash
//! # terminal 1 — server on :7878
//! cargo run --release --example serve_tcp -- --listen 127.0.0.1:7878
//! # terminal 2 — client storm
//! cargo run --release --example serve_tcp -- --client 127.0.0.1:7878 --requests 50
//! # or self-contained (spawns the server in-process, then the storm):
//! cargo run --release --example serve_tcp -- --self-test
//! ```

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use vq4all::coordinator::{Campaign, NetSession};
use vq4all::serving::batcher::BatcherConfig;
use vq4all::serving::obs::expose;
use vq4all::serving::tcp::{
    client_metrics, client_request_deadline, client_trace, Shutdown, TcpServer,
};
use vq4all::serving::{Engine, EngineConfig, HostedNet};
use vq4all::util::cli::Cli;
use vq4all::util::config::CampaignConfig;
use vq4all::util::rng::Rng;
use vq4all::vq::{Codebook, StagedCodes};

fn build_server(args: &vq4all::util::cli::Args) -> anyhow::Result<TcpServer> {
    let cfg = CampaignConfig {
        steps: args.usize_or("steps", 60)?,
        eval_interval: 0,
        ..CampaignConfig::default()
    };
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let campaign = Campaign::load(&dir, cfg)?;
    let nets: Vec<String> = args
        .get_or("nets", "mini_mlp,mini_resnet18")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let universal = Arc::new(Codebook::new(
        campaign.manifest.config.k,
        campaign.manifest.config.d,
        campaign.codebook.as_f32()?.to_vec(),
    ));
    let bc = BatcherConfig {
        max_batch: args.usize_or("max-batch", 16)?,
        max_linger_ns: args.usize_or("linger-us", 500)? as u64 * 1_000,
    };
    let mut sessions = Vec::new();
    let mut hosted = Vec::new();
    for name in &nets {
        let res = campaign.construct(name)?;
        let mut sess = NetSession::new(&campaign.rt, &campaign.manifest, name, &campaign.codebook)?;
        sess.set_others(&res.final_others)?; // codes pair with trained norms
        let codes = sess.codes_tensor(&res.codes);
        // Construction progress rides util::logging so VQ4ALL_LOG
        // governs its verbosity; the serve reports stay on stdout.
        vq4all::log_info!(
            "serve_tcp",
            "{name}: float {:.3} -> hard {:.3} at {:.1}x",
            res.float_metric,
            res.hard_metric,
            res.sizes.ratio()
        );
        hosted.push(HostedNet {
            name: name.clone(),
            codes: StagedCodes::single(res.packed.clone()),
            codebook: universal.clone(),
            codes_per_row: (res.packed.count / 64).max(1),
            device_batch: sess.net.eval_batch,
        });
        sessions.push((sess, codes));
    }
    // The plane is the one routing path (wall clock on this front-end):
    // admission -> shard queues -> fire-selection -> cached decode ->
    // infer_hard.  Precedence: --shards/--cache-kb/--max-queue >
    // [engine] config > defaults; the --threads pool parallelizes the
    // plane's cache-miss decodes.  With --max-queue set, over-budget
    // requests backpressure the readers instead of queueing unbounded.
    let knobs = args.engine_knobs_from_config(args.get("config"))?;
    let mut plane = Engine::new(
        EngineConfig {
            shards: knobs.shards,
            cache_bytes: knobs.cache_bytes(),
            max_queue_depth: knobs.max_queue,
            batcher: bc,
            obs: Default::default(),
        },
        hosted,
    )?;
    // Hosting-time integrity: every packed code stream must still match
    // the checksum captured when it was hosted, before a single request
    // is served against it.
    plane.verify_hosted()?;
    TcpServer::new(sessions, plane, args.parallelism()?.pool())
}

fn storm(addr: &str, nets: &[&str], n: usize, deadline_ms: u64) -> anyhow::Result<()> {
    let mut rng = Rng::new(23);
    let mut conn = TcpStream::connect(addr)?;
    let mut ok = 0usize;
    let mut expired = 0usize;
    let mut lat = Vec::new();
    for _ in 0..n {
        let net = nets[rng.below(nets.len())];
        let resp = client_request_deadline(&mut conn, net, rng.below(64), deadline_ms)?;
        if resp.req_bool("ok").unwrap_or(false) {
            ok += 1;
            if let Ok(l) = resp.req_f64("latency_us") {
                lat.push(l);
            }
        } else if resp
            .get("error")
            .and_then(|e| e.as_str())
            .is_some_and(|e| e.contains("deadline expired"))
        {
            expired += 1;
        }
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat.get(((lat.len() - 1) as f64 * p) as usize).copied().unwrap_or(0.0);
    println!(
        "client: {ok}/{n} ok ({expired} deadline-expired) | wall latency p50 {:.0}us p90 {:.0}us p99 {:.0}us",
        pct(0.5),
        pct(0.9),
        pct(0.99)
    );
    // Exercise the observability verbs over the same connection: the
    // Prometheus exposition must parse under the repo's own checker,
    // and /trace reports how much flight-recorder history survives.
    let m = client_metrics(&mut conn, false)?;
    let body = m.req_str("body")?;
    let samples = expose::check_exposition(body)?;
    let tr = client_trace(&mut conn)?;
    println!(
        "client: /metrics exposition ok ({samples} samples) | /trace {} events retained, {} dropped",
        tr.req("events")?.as_arr().map(|e| e.len()).unwrap_or(0),
        tr.req_usize("dropped")?
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    vq4all::util::logging::init_from_env();
    let args = Cli::new("serve_tcp", "TCP front-end over the compressed zoo")
        .opt("listen", "", "serve on this address (e.g. 127.0.0.1:7878)")
        .opt("client", "", "run a client storm against this address")
        .opt("requests", "50", "requests in client/self-test mode")
        .opt("nets", "mini_mlp,mini_resnet18", "networks to host")
        .opt("steps", "60", "construction steps per network")
        .opt("max-batch", "16", "batcher max batch")
        .opt("linger-us", "500", "batcher linger (us)")
        .opt("deadline-ms", "0", "per-request deadline sent by the client (ms, 0 = none)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "", "config TOML ([engine] shards / cache_kb / max_queue)")
        .flag("self-test", "spawn server in-process and storm it")
        .engine_opts()
        .threads_opt()
        .parse()?;

    let nets: Vec<String> = args
        .get_or("nets", "mini_mlp,mini_resnet18")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let net_refs: Vec<&str> = nets.iter().map(|s| s.as_str()).collect();
    let requests = args.usize_or("requests", 50)?;
    let deadline_ms = args.usize_or("deadline-ms", 0)? as u64;

    if let Some(addr) = args.get("client").filter(|s| !s.is_empty()) {
        return storm(addr, &net_refs, requests, deadline_ms);
    }

    if args.has("self-test") {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        println!("self-test: constructing {} nets, serving on {addr}", nets.len());
        let mut server = build_server(&args)?;
        let shutdown = Shutdown::new();
        let sd = shutdown.clone();
        let addr2 = addr.clone();
        let nets2: Vec<String> = nets.clone();
        let client = std::thread::spawn(move || {
            let refs: Vec<&str> = nets2.iter().map(|s| s.as_str()).collect();
            let r = storm(&addr2, &refs, requests, deadline_ms);
            sd.trigger();
            // Poke the acceptor so the dispatch loop notices shutdown.
            let _ = TcpStream::connect(&addr2);
            r
        });
        let served = server.serve(listener, shutdown, 0)?;
        client.join().unwrap()?;
        println!("server: {served} requests served");
        for (name, st) in &server.stats {
            // Wall-clock percentiles from the bounded reservoir — the
            // same labeled family the `/stats` verb reports.
            println!(
                "  {name}: served {} in {} batches (avg {:.2}/batch, wall p50 {:.0}us p90 {:.0}us p99 {:.0}us)",
                st.served,
                st.batches,
                st.served as f64 / st.batches.max(1) as f64,
                st.latency_us.percentile(50.0),
                st.latency_us.percentile(90.0),
                st.latency_us.percentile(99.0)
            );
        }
        let cs = server.plane.cache_stats();
        let t = server.plane.totals();
        println!(
            "  decode plane: {} shards, {} weight-row lookups, hit_rate {:.3}",
            server.plane.shard_count(),
            cs.lookups,
            cs.hit_rate()
        );
        println!(
            "  admission: accepted {} = dispatched {} + shed {} + expired {} + failed {} ({} deferrals, peak depth {}, budget {})",
            t.accepted,
            t.served,
            t.shed,
            t.expired,
            t.failed,
            t.deferred,
            t.peak_depth,
            server.plane.cfg.max_queue_depth
        );
        // Final unified metrics snapshot — identical in shape to the
        // `/metrics` `"format": "json"` response, for headless capture.
        let snap = server.plane.metrics_snapshot();
        println!(
            "  stage split: decode {:.1} us / infer {:.1} us per batch, decode-hidden ratio {:.3}",
            snap.decode_ns_total as f64 / snap.batches.max(1) as f64 / 1_000.0,
            snap.infer_ns_total as f64 / snap.batches.max(1) as f64 / 1_000.0,
            snap.decode_hidden_ratio()
        );
        println!("\nfinal metrics snapshot:\n{}", expose::snapshot_json(&snap));
        return Ok(());
    }

    let addr = args.get_or("listen", "127.0.0.1:7878").to_string();
    let listener = TcpListener::bind(&addr)?;
    vq4all::log_info!("serve_tcp", "constructing {} networks...", nets.len());
    let mut server = build_server(&args)?;
    println!("serving on {addr} (newline JSON: {{\"net\": ..., \"row\": ...}}; ctrl-c to stop)");
    server.serve(listener, Shutdown::new(), 0)?;
    Ok(())
}
