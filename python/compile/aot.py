"""AOT build driver: pretrain the float zoo, build datasets, lower every
VQ4ALL step function to HLO text, and emit ``artifacts/manifest.json``.

This is the only python entry point in the system and it runs exactly
once (``make artifacts``); the Rust coordinator is self-contained
afterwards.  See DESIGN.md §5 for the interchange contract.

HLO **text** is the interchange format — the image's xla_extension 0.5.1
rejects jax>=0.5 serialized protos (64-bit instruction ids), while the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts            # full zoo
    python -m compile.aot --out-dir ../artifacts --nets mini_mlp
    VQ4ALL_PROFILE=large python -m compile.aot ...          # paper-ish scale
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from jax._src.lib import xla_client as xc

from . import codebook as cb_mod
from . import data as data_mod
from . import tensorio, train, vqlayers
from .zoo import ZOO, VqConfig, get_net, vq_config, zoo_names

_DT = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, in_specs) -> tuple[str, list[dict]]:
    """Lower ``fn(*args)`` at the given (name, shape, dtype) specs.

    Returns (hlo_text, output_specs).
    """
    shaped = [jax.ShapeDtypeStruct(shape, _DT[dt]) for _, shape, dt in in_specs]
    out_shapes = jax.eval_shape(fn, *shaped)
    if not isinstance(out_shapes, (tuple, list)):
        out_shapes = (out_shapes,)
    out_specs = [
        {
            "name": f"out{i}",
            "shape": list(o.shape),
            "dtype": "i32" if np.issubdtype(o.dtype, np.integer) else "f32",
        }
        for i, o in enumerate(out_shapes)
    ]
    # keep_unused=True: the Rust caller feeds every manifest input, so the
    # compiled parameter list must match the signature even if a tensor is
    # unused in some configuration (jit would otherwise DCE it).
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*shaped))
    return text, out_specs


def specs_json(specs) -> list[dict]:
    return [{"name": nm, "shape": list(sh), "dtype": dt} for nm, sh, dt in specs]


def build_network(spec, cfg: VqConfig, out: Path, manifest: dict) -> np.ndarray:
    """Pretrain + export one zoo member.  Returns its float sub-vectors
    (for the universal-codebook pool)."""
    t0 = time.time()
    fns = train.make_step_fns(spec, cfg)
    net = fns.net
    print(f"[{spec.name}] pretraining ({spec.pretrain_steps} steps)...", flush=True)

    cx, cy = data_mod.make_dataset(spec, 0, spec.calib_size)
    tx, ty = data_mod.make_dataset(spec, 1, spec.test_size)
    # Pretrain on a dedicated, larger split (seed offset 2) — the paper's
    # float checkpoints are trained on the full dataset, not the small
    # calibration set VQ4ALL later streams.
    px, py = data_mod.make_dataset(spec, 2, max(8 * spec.calib_size, 4000))
    params, last_loss = train.pretrain(net, spec, px, py)
    fl, fm = train.eval_float(net, spec, params, tx, ty)
    print(f"[{spec.name}] float: loss={fl:.4f} metric={fm:.4f} ({time.time()-t0:.1f}s)")

    flat = np.asarray(vqlayers.extract_subvectors(params, fns.layout))

    # ---- data + teacher tensors
    files: dict[str, str] = {}

    def save(tag: str, arr: np.ndarray):
        fname = f"{spec.name}__{tag}.vqt"
        tensorio.write_tensor(out / fname, arr)
        files[tag] = fname

    save("calib_x", cx)
    save("calib_y", cy if cy.dtype != np.float32 else cy.astype(np.float32))
    save("test_x", tx)
    save("test_y", ty if ty.dtype != np.float32 else ty.astype(np.float32))
    save("teacher_flat", flat.astype(np.float32))
    for i, nm in enumerate(fns.other_names):
        save(f"teacher_other_{i}", np.asarray(params[nm], np.float32))

    # ---- executables
    execs: dict[str, dict] = {}

    def lower(tag: str, fn, in_specs):
        t1 = time.time()
        text, out_specs = lower_fn(fn, in_specs)
        fname = f"{spec.name}__{tag}.hlo.txt"
        (out / fname).write_text(text)
        execs[tag] = {
            "hlo": fname,
            "inputs": specs_json(in_specs),
            "outputs": out_specs,
        }
        print(f"[{spec.name}] lowered {tag}: {len(in_specs)} in, "
              f"{len(out_specs)} out, {len(text)//1024} KiB ({time.time()-t1:.1f}s)")

    s, n, k, d = fns.s_total, cfg.n, cfg.k, cfg.d
    lower(
        "init_assign",
        fns.init_assign,
        [("wsub", (s, d), "f32"), ("codebook", (k, d), "f32")],
    )
    lower(
        "train_step",
        fns.train_step,
        fns.state_specs() + fns.static_specs() + train.batch_specs(spec),
    )
    eval_soft_specs = (
        [("z", (s, n), "f32")]
        + [(f"other:{nm}", tuple(net.params[nm].shape), "f32") for nm in fns.other_names]
        + [
            ("assign", (s, n), "i32"),
            ("frozen", (s,), "f32"),
            ("frozen_idx", (s,), "i32"),
            ("codebook", (k, d), "f32"),
        ]
        + train.eval_batch_specs(spec)
    )
    lower("eval_soft", fns.eval_soft, eval_soft_specs)
    hard_prefix = (
        [("codes", (s,), "i32")]
        + [(f"other:{nm}", tuple(net.params[nm].shape), "f32") for nm in fns.other_names]
        + [("codebook", (k, d), "f32")]
    )
    lower("eval_hard", fns.eval_hard, hard_prefix + train.eval_batch_specs(spec))
    infer_x = train.eval_batch_specs(spec)[0]
    lower("infer_hard", fns.infer_hard, hard_prefix + [infer_x])
    if spec.task == "denoise":
        b = spec.eval_batch
        lower(
            "sample_step",
            fns.sample_step,
            hard_prefix
            + [("xt", (b, 2), "f32"), ("tdiff", (b,), "i32"), ("eps", (b, 2), "f32")],
        )
        # Pure eps forward — the Rust coordinator owns the DDPM posterior
        # loop (see train.StepFns.denoise_eps).
        lower(
            "denoise_eps",
            fns.denoise_eps,
            hard_prefix + [("xt", (b, 2), "f32"), ("tdiff", (b,), "i32")],
        )

    manifest["networks"].append(
        {
            "name": spec.name,
            "task": spec.task,
            "arch": spec.arch,
            "input_shape": list(spec.input_shape),
            "num_classes": spec.num_classes,
            "batch": spec.batch,
            "eval_batch": spec.eval_batch,
            "calib_size": spec.calib_size,
            "test_size": spec.test_size,
            "s_total": s,
            "float_loss": fl,
            "float_metric": fm,
            "pretrain_final_loss": last_loss,
            "layers": [
                {
                    "name": sl.layer.name,
                    "kind": sl.layer.kind,
                    "shape": list(sl.layer.shape),
                    "offset": sl.offset,
                    "groups": sl.groups,
                }
                for sl in fns.layout.slices
            ],
            "excluded_layers": [
                {"name": l.name, "kind": l.kind, "shape": list(l.shape)}
                for l in net.weight_layers
                if not l.compress
            ],
            "others": [
                {"name": nm, "shape": list(net.params[nm].shape)} for nm in fns.other_names
            ],
            "state_specs": specs_json(fns.state_specs()),
            "static_specs": specs_json(fns.static_specs()),
            "batch_specs": specs_json(train.batch_specs(spec)),
            "eval_batch_specs": specs_json(train.eval_batch_specs(spec)),
            "executables": execs,
            "data": files,
        }
    )
    return flat


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(legacy) ignored, use --out-dir")
    ap.add_argument("--nets", default=None, help="comma-separated zoo subset")
    ap.add_argument(
        "--merge",
        action="store_true",
        help="update only --nets inside an existing manifest (keeps the "
        "other networks and the existing universal codebook untouched)",
    )
    args = ap.parse_args()

    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    cfg = vq_config()
    names = zoo_names(args.nets.split(",") if args.nets else None)

    prior: dict | None = None
    if args.merge:
        prior = json.loads((out / "manifest.json").read_text())
        assert prior["config"]["k"] == cfg.k and prior["config"]["d"] == cfg.d, (
            "merge requires the same VQ profile as the existing manifest"
        )

    manifest: dict = {
        "version": 1,
        "config": {
            "k": cfg.k,
            "d": cfg.d,
            "n": cfg.n,
            "alpha": cfg.alpha,
            "bandwidth": cfg.bandwidth,
            "lr_ratios": cfg.lr_ratios,
            "lr_other": cfg.lr_other,
            "samples_per_net": cfg.samples_per_net,
            "effective_bit": cfg.effective_bit,
        },
        "networks": [],
    }

    flats = []
    for name in names:
        flats.append(build_network(get_net(name), cfg, out, manifest))

    if prior is not None:
        # Splice the rebuilt networks into the prior manifest, preserving
        # order and the existing codebook (the codebook must stay frozen —
        # §4.1 — or every other network's candidate tables go stale).
        rebuilt = {n["name"]: n for n in manifest["networks"]}
        merged = [rebuilt.pop(n["name"], n) for n in prior["networks"]]
        merged.extend(rebuilt.values())
        prior["networks"] = merged
        (out / "manifest.json").write_text(json.dumps(prior, indent=1))
        print(f"merged {len(names)} network(s) into {out}/manifest.json")
        return

    # Universal codebook (§4.1): equal-count pool over the zoo, KDE sample.
    print("building universal codebook...")
    ucb, pool = cb_mod.build_universal_codebook(
        flats, cfg.k, cfg.d, cfg.bandwidth, cfg.samples_per_net, seed=2024
    )
    tensorio.write_tensor(out / "zoo__codebook.vqt", ucb)
    tensorio.write_tensor(out / "zoo__kde_pool.vqt", pool)
    manifest["codebook"] = "zoo__codebook.vqt"
    manifest["kde_pool"] = "zoo__kde_pool.vqt"

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out}/manifest.json ({len(manifest['networks'])} networks)")


if __name__ == "__main__":
    main()
