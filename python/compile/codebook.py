"""Universal-codebook initialization (§4.1, Eq. 3-4) — python side.

The production sampler lives in Rust (``rust/src/vq/kde.rs``, the
coordinator owns codebook creation); this module provides the same
algorithm for (a) the default codebook shipped in ``artifacts/`` so the
Rust side can cross-check its sampler, and (b) the python test-suite.

KDE sampling for a Gaussian kernel is exact and cheap: drawing from
``f(w) = 1/n sum_i N(w; w_i, h^2 I)`` is "pick a data sub-vector
uniformly, add N(0, h^2 I) noise" — no density grid needed.  The paper
samples ``10 * k * d`` sub-vectors per network, concatenates them
(equal count per network so the codebook is unbiased), and draws ``k``
codewords.
"""

from __future__ import annotations

import numpy as np


def sample_subvectors(
    flats: list[np.ndarray], per_net: int, seed: int = 0
) -> np.ndarray:
    """Equal-count sub-vector sample across networks (unbiased, §4.1).

    Args:
      flats: per-network ``(S_i, d)`` float sub-vector arrays.
      per_net: how many sub-vectors to draw from each network.

    Returns:
      ``(len(flats) * per_net, d)`` concatenated sample.
    """
    rng = np.random.default_rng(seed)
    parts = []
    for f in flats:
        if f.shape[0] >= per_net:
            idx = rng.choice(f.shape[0], size=per_net, replace=False)
        else:  # small net: sample with replacement to keep counts equal
            idx = rng.choice(f.shape[0], size=per_net, replace=True)
        parts.append(f[idx])
    return np.concatenate(parts, axis=0).astype(np.float32)


def kde_sample_codebook(
    samples: np.ndarray, k: int, bandwidth: float, seed: int = 0
) -> np.ndarray:
    """Draw ``k`` codewords from the Gaussian KDE of ``samples`` (Eq. 4)."""
    rng = np.random.default_rng(seed)
    n, d = samples.shape
    picks = rng.integers(0, n, size=k)
    noise = rng.normal(0.0, bandwidth, size=(k, d)).astype(np.float32)
    return samples[picks] + noise


def build_universal_codebook(
    flats: list[np.ndarray], k: int, d: int, bandwidth: float, per_net: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Full §4.1 pipeline; returns ``(codebook (k, d), sample pool)``."""
    pool = sample_subvectors(flats, per_net, seed=seed)
    assert pool.shape[1] == d, f"sub-vector dim {pool.shape[1]} != d={d}"
    cb = kde_sample_codebook(pool, k, bandwidth, seed=seed + 1)
    return cb, pool
