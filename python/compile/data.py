"""Synthetic datasets substituting the paper's gated data (DESIGN.md §2).

Three generators, one per task family in the zoo:

* :func:`synth_imagenet`  — substitutes ImageNet for the classification
  nets.  Ten classes, each a fixed random spatial template; samples are
  the class template under a random circular shift, per-pixel Gaussian
  noise, and a random brightness scale.  The task is learnable to >90%
  Top-1 by the mini networks yet not linearly trivial (shift invariance
  is required), so compression-induced accuracy drops are visible —
  which is all VQ4ALL's losses ever see of a dataset.
* :func:`synth_shapes`    — substitutes COCO detection.  Each image holds
  one shape (square / circle / cross) at a random position and scale on a
  textured background; targets are a per-cell objectness grid plus a box
  and a class, Mask-RCNN's loss structure in miniature.
* :func:`gmm2d`           — substitutes the diffusion training corpus: an
  8-mode 2-D Gaussian mixture on a circle, the standard toy target for
  denoising-diffusion models.

All generators are deterministic in ``seed`` and return float32 numpy
arrays; ``aot.py`` writes them into ``artifacts/`` as ``.vqt`` tensors so
the Rust coordinator streams the *identical* bytes at run time.
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 10


def synth_imagenet(
    n: int, hw: int = 16, num_classes: int = NUM_CLASSES, seed: int = 0,
    template_seed: int = 7, share: float = 0.5, noise: float = 0.5,
) -> tuple[np.ndarray, np.ndarray]:
    """Procedural image classification set.

    ``template_seed`` fixes the class templates *independently* of the
    sample seed, so train/calibration/test splits (different ``seed``)
    share one class structure — the train/test relationship of a real
    dataset.

    Difficulty calibration (tools/tune_probe.py): class templates blend a
    **shared** component (weight ``share``) with a class-unique one, so
    classes differ in fine detail that weight-quantization noise can
    destroy, and per-pixel noise is high enough that the mini networks
    land at ~0.92-0.96 float Top-1 instead of saturating at 1.0 —
    without this every compression method ties at 100% and none of the
    paper's accuracy orderings (Tables 3/5, Figures 2/3) is visible.

    Returns:
      ``(x, y)`` with ``x`` of shape ``(n, hw, hw, 3)`` in roughly
      ``[-1, 1]`` and int32 labels ``y`` of shape ``(n,)``.
    """
    trng = np.random.default_rng(template_seed)
    common = trng.normal(0.0, 1.0, size=(1, hw, hw, 3)).astype(np.float32)
    uniq = trng.normal(0.0, 1.0, size=(num_classes, hw, hw, 3)).astype(np.float32)
    templates = share * common + (1.0 - share) * uniq
    rng = np.random.default_rng(seed)
    # Low-pass the templates a little so shifts stay recognizable.
    for _ in range(2):
        templates = 0.5 * templates + 0.25 * (
            np.roll(templates, 1, axis=1) + np.roll(templates, 1, axis=2)
        )
    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    max_shift = max(hw // 8, 1)  # small jitter: learnable from ~500 samples
    sx = rng.integers(-max_shift, max_shift + 1, size=n)
    sy = rng.integers(-max_shift, max_shift + 1, size=n)
    scale = rng.uniform(0.7, 1.3, size=n).astype(np.float32)
    nz = rng.normal(0.0, noise, size=(n, hw, hw, 3)).astype(np.float32)
    x = np.empty((n, hw, hw, 3), np.float32)
    for i in range(n):
        img = np.roll(templates[y[i]], (sx[i], sy[i]), axis=(0, 1))
        x[i] = img * scale[i] + nz[i]
    return x, y


def synth_shapes(
    n: int, hw: int = 24, grid: int = 4, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Procedural single-object detection set.

    Targets pack, per grid cell, ``[objectness, cx, cy, size, class]``
    (cx/cy are offsets within the cell in [0,1], size is the half-width
    relative to the image).  Output shape ``(n, grid, grid, 5)``.
    """
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 0.15, size=(n, hw, hw, 3)).astype(np.float32)
    t = np.zeros((n, grid, grid, 5), np.float32)
    cell = hw // grid
    yy, xx = np.mgrid[0:hw, 0:hw]
    for i in range(n):
        cls = rng.integers(0, 3)
        half = rng.uniform(2.0, 4.5)
        cx = rng.uniform(half, hw - half)
        cy = rng.uniform(half, hw - half)
        color = rng.uniform(0.6, 1.4, size=3).astype(np.float32)
        if cls == 0:  # square
            mask = (np.abs(xx - cx) <= half) & (np.abs(yy - cy) <= half)
        elif cls == 1:  # circle
            mask = (xx - cx) ** 2 + (yy - cy) ** 2 <= half**2
        else:  # cross
            mask = (np.abs(xx - cx) <= half / 2.5) | (np.abs(yy - cy) <= half / 2.5)
            mask &= (np.abs(xx - cx) <= half) & (np.abs(yy - cy) <= half)
        x[i][mask] += color
        gx = min(int(cx / cell), grid - 1)
        gy = min(int(cy / cell), grid - 1)
        t[i, gy, gx] = [
            1.0,
            (cx - gx * cell) / cell,
            (cy - gy * cell) / cell,
            half / hw,
            float(cls),
        ]
    return x, t


def gmm2d(n: int, modes: int = 8, radius: float = 2.0, std: float = 0.15, seed: int = 0) -> np.ndarray:
    """8-mode Gaussian mixture on a circle — the diffusion toy target."""
    rng = np.random.default_rng(seed)
    which = rng.integers(0, modes, size=n)
    angles = 2.0 * np.pi * which / modes
    centers = np.stack([radius * np.cos(angles), radius * np.sin(angles)], axis=1)
    return (centers + rng.normal(0.0, std, size=(n, 2))).astype(np.float32)


def diffusion_schedule(timesteps: int = 50) -> dict[str, np.ndarray]:
    """Linear-beta DDPM schedule; returns the constants the denoiser needs."""
    betas = np.linspace(1e-4, 0.25, timesteps, dtype=np.float32)
    alphas = 1.0 - betas
    abar = np.cumprod(alphas).astype(np.float32)
    return {
        "betas": betas,
        "alphas": alphas,
        "alpha_bars": abar,
        "sqrt_abar": np.sqrt(abar).astype(np.float32),
        "sqrt_1m_abar": np.sqrt(1.0 - abar).astype(np.float32),
    }


def make_dataset(spec, split_seed_offset: int, size: int):
    """Dispatch on a zoo :class:`~compile.zoo.NetSpec`'s task.

    For ``denoise`` the "labels" are unused (zeros) — the diffusion loss
    draws its own noise inside the train step from a counter-seeded PRNG.
    """
    seed = spec.seed + split_seed_offset
    if spec.task == "classify":
        hw = spec.input_shape[0]
        return synth_imagenet(size, hw=hw, num_classes=spec.num_classes, seed=seed)
    if spec.task == "detect":
        hw = spec.input_shape[0]
        return synth_shapes(size, hw=hw, grid=6, seed=seed)
    if spec.task == "denoise":
        x = gmm2d(size, seed=seed)
        return x, np.zeros((size,), np.int32)
    raise ValueError(f"unknown task {spec.task!r}")
