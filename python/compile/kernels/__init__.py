"""Layer-1 Pallas kernels for VQ4ALL.

Four kernels cover the system's compute hot-spots (see DESIGN.md section 3/4):

* distance     -- pairwise ||w - c||^2 + top-n candidates (Eq. 5)
* reconstruct  -- differentiable decode W_hat = R * C[A_c] (Eq. 8)
* vq_matmul    -- fused codebook-decode + matmul (serving hot path)
* kde          -- Gaussian KDE evaluation (Eq. 3)

``ref`` holds the pure-jnp oracles each kernel is tested against.
All kernels run under ``interpret=True`` (see ``pallas_util``).
"""

from . import distance, kde, pallas_util, reconstruct, ref, vq_matmul  # noqa: F401

__all__ = ["distance", "kde", "pallas_util", "reconstruct", "ref", "vq_matmul"]
