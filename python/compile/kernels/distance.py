"""Pallas kernel: tiled pairwise squared-Euclidean distance (Eq. 5).

Computes ``D[s, k] = ||w_s - c_k||^2`` for ``S`` weight sub-vectors
against ``K`` codewords using the expanded form

    D = ||w||^2 - 2 w @ c^T + ||c||^2

so the dominant cost is a single ``(S_tile, d) @ (d, K_tile)`` matmul per
grid step — on a real TPU that is an MXU op; the two norm terms are VPU
reductions.

HBM <-> VMEM schedule (BlockSpec):

* grid = ``(S / bs, K / bk)`` with the codeword axis **innermost**, so a
  sub-vector tile ``w[i]`` is loaded into VMEM once and reused across all
  codeword tiles (codebook tiles stream).
* VMEM footprint per step: ``bs*d + bk*d + bs*bk`` floats.  With the
  defaults (bs=128, bk=512, d<=32) that is < 0.5 MB — far under the
  ~16 MB VMEM budget, leaving room for double buffering.

This kernel runs twice in the system: once per network at campaign start
(candidate-assignment initialization, the ``init_assign`` artifact) and
inside Table-1/Table-7 style analyses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_util as pu


def _distance_kernel(w_ref, c_ref, out_ref):
    """One (S_tile, K_tile) block of the distance matrix."""
    w = w_ref[...].astype(jnp.float32)  # (bs, d)
    c = c_ref[...].astype(jnp.float32)  # (bk, d)
    w2 = jnp.sum(w * w, axis=1, keepdims=True)  # (bs, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, bk)
    # MXU: (bs, d) @ (d, bk). preferred_element_type pins f32 accumulation.
    cross = jax.lax.dot_general(
        w,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = jnp.maximum(w2 - 2.0 * cross + c2, 0.0)


@functools.partial(jax.jit, static_argnames=("block_s", "block_k"))
def pairwise_sq_dist(
    w: jax.Array,
    c: jax.Array,
    *,
    block_s: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """Tiled pairwise squared distances; drop-in for ``ref.pairwise_sq_dist``.

    Args:
      w: ``(S, d)`` sub-vectors (any float dtype; accumulates in f32).
      c: ``(K, d)`` codebook.
      block_s / block_k: tile sizes along the sub-vector / codeword axes.

    Returns:
      ``(S, K)`` float32 squared distances.
    """
    pu.static_check(w.ndim == 2 and c.ndim == 2, "w and c must be rank-2")
    pu.static_check(w.shape[1] == c.shape[1], f"dim mismatch {w.shape} vs {c.shape}")
    s, d = w.shape
    k, _ = c.shape

    bs = pu.pick_tile(s, block_s)
    bk = pu.pick_tile(k, block_k)
    sp = pu.round_up(s, bs)
    kp = pu.round_up(k, bk)
    # Zero padding is safe: padded rows/cols produce distances that are
    # sliced away below and can never affect real entries.
    wp = pu.pad_axis(pu.as_f32(w), 0, sp)
    cp = pu.pad_axis(pu.as_f32(c), 0, kp)

    out = pl.pallas_call(
        _distance_kernel,
        grid=(sp // bs, kp // bk),
        in_specs=[
            pl.BlockSpec((bs, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bs, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((sp, kp), jnp.float32),
        interpret=pu.INTERPRET,
    )(wp, cp)
    return out[:s, :k]


def topn_candidates(
    w: jax.Array,
    c: jax.Array,
    n: int,
    *,
    block_s: int = 128,
    block_k: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Candidate assignments (Eq. 5) on top of the Pallas distance kernel.

    The top-n selection is an **iterative argmin scan** (n rounds of
    argmin + mask-out) rather than ``jax.lax.top_k``: the xla_extension
    0.5.1 HLO-text parser used by the Rust runtime predates the ``topk``
    custom attribute jax emits, while argmin/scatter lower to classic
    reduce/scatter HLO that round-trips cleanly (DESIGN.md §5).  For
    n <= 64 the scan costs n vectorized passes over the (S, K) distance
    matrix — negligible next to the distance matmul itself.

    Returns:
      ``(assignments, sq_dists)`` — ``(S, n)`` int32 indices and their
      squared distances, nearest first.
    """
    pu.static_check(0 < n <= c.shape[0], f"n={n} out of range for K={c.shape[0]}")
    dist = pairwise_sq_dist(w, c, block_s=block_s, block_k=block_k)
    s = dist.shape[0]
    rows = jnp.arange(s)

    def body(d, _):
        idx = jnp.argmin(d, axis=1).astype(jnp.int32)  # (S,)
        dd = jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]
        d = d.at[rows, idx].set(jnp.inf)
        return d, (idx, dd)

    _, (idxs, dds) = jax.lax.scan(body, dist, None, length=n)
    return jnp.transpose(idxs).astype(jnp.int32), jnp.transpose(dds)
