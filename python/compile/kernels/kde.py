"""Pallas kernel: Gaussian kernel-density evaluation (Eq. 3).

Evaluates the KDE fitted to ``N`` weight sub-vector samples at ``Q``
query points:

    f(q) = 1 / (N h^d (2 pi)^{d/2}) * sum_i exp(-||q - s_i||^2 / (2 h^2))

Used by the codebook-quality analyses (Table 6: which weight combinations
the universal codebook is sampled from) and by the python-side validation
of the Rust KDE sampler.

Kernel structure:

* grid = ``(Q / bq, N / bn)`` — the sample axis is innermost and
  **accumulated across grid steps**: the output block index_map ignores
  the sample-axis index, so Pallas revisits the same output tile and the
  kernel adds each sample tile's partial sum (initializing at the first
  step).  This is the canonical Pallas reduction-across-grid pattern and
  keeps VMEM at ``bq*d + bn*d + bq`` floats.
* the distance part reuses the expanded ``||q||^2 - 2 q s^T + ||s||^2``
  MXU form; ``exp`` runs on the VPU.

Padding: padded samples sit at the origin, which would contribute
spurious density, so the wrapper weights every sample with a 0/1 validity
mask instead of relying on slicing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_util as pu


def _kde_kernel(q_ref, s_ref, mask_ref, out_ref, *, inv_2h2: float, log_norm: float):
    """Accumulate one sample tile's contribution to one query tile."""
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)  # (bq, d)
    s = s_ref[...].astype(jnp.float32)  # (bn, d)
    m = mask_ref[...].astype(jnp.float32)  # (bn,)
    q2 = jnp.sum(q * q, axis=1, keepdims=True)
    s2 = jnp.sum(s * s, axis=1)[None, :]
    cross = jax.lax.dot_general(
        q, s, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    sq = jnp.maximum(q2 - 2.0 * cross + s2, 0.0)  # (bq, bn)
    part = jnp.sum(jnp.exp(-sq * inv_2h2 + log_norm) * m[None, :], axis=1)  # (bq,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("bandwidth", "block_q", "block_n"))
def kde_density(
    queries: jax.Array,
    samples: jax.Array,
    bandwidth: float,
    *,
    block_q: int = 256,
    block_n: int = 1024,
) -> jax.Array:
    """Tiled KDE evaluation; drop-in for ``ref.kde_density``.

    Args:
      queries: ``(Q, d)`` evaluation points.
      samples: ``(N, d)`` data the KDE was fitted to.
      bandwidth: Gaussian bandwidth ``h`` (static; paper uses 0.01).

    Returns:
      ``(Q,)`` float32 densities.
    """
    pu.static_check(queries.ndim == 2 and samples.ndim == 2, "rank-2 inputs required")
    pu.static_check(queries.shape[1] == samples.shape[1], "dim mismatch")
    pu.static_check(bandwidth > 0.0, "bandwidth must be positive")
    qn, d = queries.shape
    n, _ = samples.shape

    bq = pu.pick_tile(qn, block_q)
    bn = pu.pick_tile(n, block_n)
    qp = pu.round_up(qn, bq)
    np_ = pu.round_up(n, bn)
    qpad = pu.pad_axis(pu.as_f32(queries), 0, qp)
    spad = pu.pad_axis(pu.as_f32(samples), 0, np_)
    mask = pu.pad_axis(jnp.ones((n,), jnp.float32), 0, np_, value=0.0)

    h2 = float(bandwidth) ** 2
    import math

    log_norm = -0.5 * d * math.log(2.0 * math.pi * h2)
    kern = functools.partial(_kde_kernel, inv_2h2=0.5 / h2, log_norm=log_norm)

    out = pl.pallas_call(
        kern,
        grid=(qp // bq, np_ // bn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((qp,), jnp.float32),
        interpret=pu.INTERPRET,
    )(qpad, spad, mask)
    return out[:qn] / jnp.float32(n)
