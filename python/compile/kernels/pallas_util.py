"""Shared helpers for the VQ4ALL Pallas kernels.

All kernels in this package follow the same conventions:

* **interpret mode** — the CPU PJRT plugin cannot execute Mosaic
  custom-calls, so every ``pallas_call`` here is built with
  ``interpret=True``.  Interpret mode lowers the kernel body to plain HLO
  ops, which means the kernels run (and AOT-export) on any backend while
  keeping the BlockSpec structure that a real TPU build would use.
* **padding** — wrappers pad inputs up to tile multiples, run the tiled
  kernel, and slice the result back.  Padding values are chosen so padded
  lanes can never contaminate real outputs (zeros for matmul operands,
  ``+inf``-style large distances for codeword padding).
* **tile sizes** — default tiles are multiples of (8, 128) where the
  axis semantics allow, matching the TPU VREG layout; on small problems
  the wrappers clamp tiles to the array size.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

# Flip to False to compile kernels for a real TPU (Mosaic). Everything in
# this repository assumes the CPU interpret path; see DESIGN.md §4.
INTERPRET = True


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the next multiple of ``b``."""
    return cdiv(a, b) * b


def pad_axis(x, axis: int, target: int, value=0.0):
    """Pad ``x`` with ``value`` along ``axis`` until its size is ``target``."""
    cur = x.shape[axis]
    if cur == target:
        return x
    if cur > target:
        raise ValueError(f"pad_axis: axis {axis} already {cur} > {target}")
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(x, widths, constant_values=value)


def pick_tile(size: int, preferred: int) -> int:
    """Choose a tile size: the preferred tile, clamped to the array size.

    Guarantees the returned tile is >= 1.  When ``size`` is smaller than
    ``preferred`` the whole axis becomes a single block (the wrapper pads
    the axis up to the tile).
    """
    if size <= 0:
        raise ValueError(f"pick_tile: non-positive size {size}")
    return min(preferred, max(1, size))


def as_f32(x):
    """Promote to float32 (kernels accumulate in f32 regardless of input)."""
    return x.astype(jnp.float32)


def static_check(cond: bool, msg: str) -> None:
    """Shape/static-argument validation with a uniform error type."""
    if not cond:
        raise ValueError(msg)
