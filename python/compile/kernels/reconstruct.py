"""Pallas kernel: differentiable weighted decode ``W_hat = R * C[A_c]`` (Eq. 8).

Reconstructs every weight sub-vector as the ratio-weighted average of its
``n`` candidate codewords.  This is the training-path hot spot: it runs
inside every VQ4ALL train step, once per compressed layer.

Kernel structure:

* grid = ``(S / bs,)`` over sub-vector tiles; the **entire codebook is
  pinned in VMEM** (`index_map` returns block (0, 0) for every grid step,
  the VMEM analogue of the paper's ROM-resident codebook).  For the
  paper's largest training codebook (2^12 x 4 f32 = 64 KB) this is
  trivially resident; the serving-size codebooks (2 MB at 2^16 x 8) also
  fit comfortably in 16 MB VMEM.
* per tile, the gather ``C[A]`` is a ``jnp.take`` along the codeword axis
  followed by an ``einsum('sn,snd->sd')`` weighted sum — on TPU the take
  lowers to a dynamic-gather and the contraction to a VPU multiply-add
  tree (n <= 64 keeps the candidate axis fully in registers/VMEM).

``pallas_call`` has no built-in reverse-mode rule, so :func:`reconstruct`
carries a ``custom_vjp``: the forward pass is the tiled kernel; the
backward pass w.r.t. the ratios is the matching contraction
``g_r[s, m] = <g[s], C[A[s, m]]>`` (the codebook is frozen by
construction — §4.1 — and assignments are integers, so neither needs a
gradient).  ``python/tests/test_kernels.py`` checks the VJP against the
reference implementation's autodiff.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_util as pu


def _reconstruct_kernel(cb_ref, assign_ref, ratio_ref, out_ref):
    """One S-tile of the weighted decode."""
    cb = cb_ref[...].astype(jnp.float32)  # (K, d) — pinned
    a = assign_ref[...]  # (bs, n) int32
    r = ratio_ref[...].astype(jnp.float32)  # (bs, n)
    gathered = jnp.take(cb, a, axis=0)  # (bs, n, d)
    out_ref[...] = jnp.einsum("sn,snd->sd", r, gathered)


def _reconstruct_impl(
    codebook: jax.Array,
    assign: jax.Array,
    ratios: jax.Array,
    block_s: int,
) -> jax.Array:
    """Tiled weighted decode; drop-in for ``ref.reconstruct``.

    Args:
      codebook: ``(K, d)`` frozen universal codebook.
      assign: ``(S, n)`` int32 candidate indices into the codebook.
      ratios: ``(S, n)`` candidate ratios (rows sum to 1 after softmax).
      block_s: sub-vector tile size.

    Returns:
      ``(S, d)`` float32 reconstructed sub-vectors.
    """
    pu.static_check(codebook.ndim == 2, "codebook must be (K, d)")
    pu.static_check(assign.shape == ratios.shape, "assign/ratios shape mismatch")
    pu.static_check(assign.ndim == 2, "assign must be (S, n)")
    s, n = assign.shape
    k, d = codebook.shape

    bs = pu.pick_tile(s, block_s)
    sp = pu.round_up(s, bs)
    # Padded groups point at codeword 0 with ratio 0 — decode to zeros and
    # are sliced away.
    ap = pu.pad_axis(assign.astype(jnp.int32), 0, sp, value=0)
    rp = pu.pad_axis(pu.as_f32(ratios), 0, sp, value=0.0)

    out = pl.pallas_call(
        _reconstruct_kernel,
        grid=(sp // bs,),
        in_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # codebook pinned
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), jnp.float32),
        interpret=pu.INTERPRET,
    )(pu.as_f32(codebook), ap, rp)
    return out[:s]


def _grad_ratios_kernel(cb_ref, assign_ref, g_ref, out_ref):
    """Backward tile: g_r[s, m] = <g[s], C[A[s, m]]>."""
    cb = cb_ref[...].astype(jnp.float32)  # (K, d) pinned
    a = assign_ref[...]  # (bs, n)
    g = g_ref[...].astype(jnp.float32)  # (bs, d)
    gathered = jnp.take(cb, a, axis=0)  # (bs, n, d)
    out_ref[...] = jnp.einsum("sd,snd->sn", g, gathered)


def _grad_ratios(
    codebook: jax.Array, assign: jax.Array, g: jax.Array, block_s: int
) -> jax.Array:
    """Tiled VJP w.r.t. ratios (same schedule as the forward kernel)."""
    s, n = assign.shape
    k, d = codebook.shape
    bs = pu.pick_tile(s, block_s)
    sp = pu.round_up(s, bs)
    ap = pu.pad_axis(assign.astype(jnp.int32), 0, sp, value=0)
    gp = pu.pad_axis(pu.as_f32(g), 0, sp, value=0.0)
    out = pl.pallas_call(
        _grad_ratios_kernel,
        grid=(sp // bs,),
        in_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, n), jnp.float32),
        interpret=pu.INTERPRET,
    )(pu.as_f32(codebook), ap, gp)
    return out[:s]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _reconstruct_vjp(codebook, assign, ratios, block_s):
    return _reconstruct_impl(codebook, assign, ratios, block_s)


def _reconstruct_fwd(codebook, assign, ratios, block_s):
    return _reconstruct_impl(codebook, assign, ratios, block_s), (codebook, assign)


def _reconstruct_bwd(block_s, res, g):
    codebook, assign = res
    # The universal codebook is frozen (§4.1) and assignments are integer
    # indices — only the ratios receive a gradient.
    return (None, None, _grad_ratios(codebook, assign, g, block_s))


_reconstruct_vjp.defvjp(_reconstruct_fwd, _reconstruct_bwd)


def reconstruct(
    codebook: jax.Array,
    assign: jax.Array,
    ratios: jax.Array,
    *,
    block_s: int = 256,
) -> jax.Array:
    """Tiled weighted decode; drop-in for ``ref.reconstruct``.

    Differentiable w.r.t. ``ratios`` (custom VJP; see module docstring).

    Args:
      codebook: ``(K, d)`` frozen universal codebook.
      assign: ``(S, n)`` int32 candidate indices into the codebook.
      ratios: ``(S, n)`` candidate ratios (rows sum to 1 after softmax).
      block_s: sub-vector tile size.

    Returns:
      ``(S, d)`` float32 reconstructed sub-vectors.
    """
    pu.static_check(codebook.ndim == 2, "codebook must be (K, d)")
    pu.static_check(assign.shape == ratios.shape, "assign/ratios shape mismatch")
    pu.static_check(assign.ndim == 2, "assign must be (S, n)")
    return _reconstruct_vjp(codebook, assign, ratios, block_s)


def hard_reconstruct(
    codebook: jax.Array,
    codes: jax.Array,
    *,
    block_s: int = 512,
) -> jax.Array:
    """Hard decode ``C[A]`` (Eq. 2) as the n=1, ratio=1 special case."""
    pu.static_check(codes.ndim == 1, "codes must be (S,)")
    ones = jnp.ones((codes.shape[0], 1), jnp.float32)
    return reconstruct(codebook, codes[:, None], ones, block_s=block_s)
