"""Pure-jnp reference oracles for the VQ4ALL Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops and no tiling, padding, or kernel
machinery.  The pytest suite (``python/tests/test_kernels.py``) asserts
``assert_allclose(kernel(...), ref(...))`` over randomized shape/dtype
sweeps; these functions are the single source of truth for kernel
numerics.

The functions mirror the paper's equations:

* :func:`pairwise_sq_dist`  — Eq. 5's distance computation,
  ``D[s, k] = ||w_s - c_k||^2``.
* :func:`topn_candidates`   — Eq. 5's ``argmin^n`` candidate selection.
* :func:`init_ratio_logits` — Eq. 7's inverse-distance logit init.
* :func:`reconstruct`       — Eq. 8's ratio-weighted decode
  ``W_hat = R * C[A_c]``.
* :func:`vq_matmul`         — the serving hot path ``y = x @ W_hat^T``
  with ``W_hat`` decoded from (codes, codebook) — i.e. hard one-hot
  assignments, the post-PNC inference form.
* :func:`kde_density`       — Eq. 3's Gaussian kernel density estimate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_dist(w: jax.Array, c: jax.Array) -> jax.Array:
    """Squared Euclidean distance between every sub-vector and codeword.

    Args:
      w: ``(S, d)`` weight sub-vectors.
      c: ``(K, d)`` codebook.

    Returns:
      ``(S, K)`` matrix with ``out[s, k] = ||w[s] - c[k]||_2^2``.

    Computed in the numerically expanded form
    ``||w||^2 - 2 w c^T + ||c||^2`` to match the MXU-friendly kernel;
    clamped at zero because the expansion can go slightly negative in
    float32.
    """
    w = w.astype(jnp.float32)
    c = c.astype(jnp.float32)
    w2 = jnp.sum(w * w, axis=1, keepdims=True)  # (S, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, K)
    cross = w @ c.T  # (S, K)
    return jnp.maximum(w2 - 2.0 * cross + c2, 0.0)


def topn_candidates(w: jax.Array, c: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """Top-``n`` nearest codewords per sub-vector (Eq. 5).

    Returns:
      ``(assignments, sq_dists)`` of shapes ``(S, n)``; column 0 is the
      nearest codeword, column ``n-1`` the farthest of the candidates.
    """
    d = pairwise_sq_dist(w, c)
    neg, idx = jax.lax.top_k(-d, n)
    return idx.astype(jnp.int32), -neg


def init_ratio_logits(sq_dists: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Inverse-distance logit initialization (Eq. 7).

    ``z_m = ln( d_{n-1} / d_m )`` where ``d_m`` is the squared distance of
    candidate ``m`` and ``d_{n-1}`` the *last* (farthest) candidate, so the
    nearest candidate receives the largest logit and the farthest gets 0.
    After softmax the ratios are proportional to ``1 / d_m``.
    """
    sq = jnp.maximum(sq_dists.astype(jnp.float32), eps)
    last = sq[..., -1:]
    return jnp.log(last / sq)


def ratios_from_logits(z: jax.Array) -> jax.Array:
    """Softmax over the candidate axis (Eq. 6)."""
    return jax.nn.softmax(z.astype(jnp.float32), axis=-1)


def reconstruct(codebook: jax.Array, assign: jax.Array, ratios: jax.Array) -> jax.Array:
    """Differentiable weighted decode ``W_hat = R * C[A_c]`` (Eq. 8).

    Args:
      codebook: ``(K, d)`` frozen universal codebook.
      assign: ``(S, n)`` int32 candidate codeword indices.
      ratios: ``(S, n)`` softmax ratios (rows sum to 1).

    Returns:
      ``(S, d)`` reconstructed sub-vectors
      ``out[s] = sum_m ratios[s, m] * codebook[assign[s, m]]``.
    """
    gathered = codebook.astype(jnp.float32)[assign]  # (S, n, d)
    return jnp.einsum("sn,snd->sd", ratios.astype(jnp.float32), gathered)


def hard_reconstruct(codebook: jax.Array, codes: jax.Array) -> jax.Array:
    """Hard decode ``W_hat = C[A]`` (Eq. 2) — post-PNC inference form."""
    return codebook.astype(jnp.float32)[codes]


def vq_matmul(x: jax.Array, codes: jax.Array, codebook: jax.Array) -> jax.Array:
    """Serving hot path: ``y = x @ W_hat^T`` with VQ-encoded weights.

    Args:
      x: ``(B, I)`` activations.
      codes: ``(O, I // d)`` int32 codeword indices; row ``o`` encodes
        output neuron ``o``'s weight vector as ``I // d`` codewords.
      codebook: ``(K, d)`` universal codebook.

    Returns:
      ``(B, O)`` output ``y = x @ decode(codes)^T``.
    """
    o, g = codes.shape
    k, d = codebook.shape
    w = codebook.astype(jnp.float32)[codes].reshape(o, g * d)  # (O, I)
    return x.astype(jnp.float32) @ w.T


def kde_density(queries: jax.Array, samples: jax.Array, bandwidth: float) -> jax.Array:
    """Gaussian kernel density estimate (Eq. 3), product kernel over dims.

    ``f(q) = 1 / (N h^d (2 pi)^{d/2}) * sum_i exp(-||q - s_i||^2 / (2 h^2))``

    Args:
      queries: ``(Q, d)`` evaluation points.
      samples: ``(N, d)`` data points the KDE is fit to.
      bandwidth: scalar ``h`` (paper uses 0.01).

    Returns:
      ``(Q,)`` density estimates.
    """
    q = queries.astype(jnp.float32)
    s = samples.astype(jnp.float32)
    n, d = s.shape
    sq = pairwise_sq_dist(q, s)  # (Q, N)
    h2 = jnp.float32(bandwidth) ** 2
    log_norm = -0.5 * d * jnp.log(2.0 * jnp.pi * h2)
    kernels = jnp.exp(-0.5 * sq / h2 + log_norm)
    return jnp.sum(kernels, axis=1) / jnp.float32(n)


def ratio_regularizer(ratios: jax.Array, unset_mask: jax.Array | None = None) -> jax.Array:
    """Eq. 11's regularizer pushing ratios towards {0, 1}.

    ``L_r = n * sum_{s,m} r_{s,m} (1 - r_{s,m}) / S`` computed only over
    groups where ``unset_mask`` is 1 (PNC-frozen groups are excluded,
    §4.3).
    """
    r = ratios.astype(jnp.float32)
    s, n = r.shape
    per_group = jnp.sum(r * (1.0 - r), axis=-1)  # (S,)
    if unset_mask is not None:
        per_group = per_group * unset_mask.astype(jnp.float32)
    return jnp.float32(n) * jnp.sum(per_group) / jnp.float32(s)
