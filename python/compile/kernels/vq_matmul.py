"""Pallas kernel: fused decode-and-matmul for VQ-encoded weights.

The serving hot path of the paper's hardware story: weights never exist
in HBM as floats — only the ``(O, I/d)`` uint32 code matrix is streamed,
and weight tiles are decoded **inside the kernel** from the universal
codebook pinned in VMEM (the on-chip-ROM analogue), then fed straight to
the MXU:

    y[b, o] = sum_i x[b, i] * C[codes[o, i // d]][i % d]

HBM traffic per output tile is therefore ``bo * g * 4`` bytes of codes
instead of ``bo * I * 4`` bytes of weights — a ``d``-fold reduction, which
is exactly the compression-rate column of Table 1 realized as bandwidth.

Kernel structure:

* grid = ``(B / bb, O / bo)``; codes tile ``(bo, g)`` and the full
  codebook are resident per step; activations tile ``(bb, I)`` is reused
  across the O axis (innermost grid dim is O).
* decode = ``jnp.take`` -> reshape ``(bo, g, d)`` -> ``(bo, I)``; matmul =
  MXU ``(bb, I) @ (I, bo)``.
* VMEM per step (defaults bb=64, bo=128, I<=4096, K*d codebook): codes
  4*bo*g + weights 4*bo*I + acts 4*bb*I + codebook 4*K*d — for the 2-bit
  config (K=2^16, d=8, I=1024) about 3.3 MB, within budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_util as pu


def _vq_matmul_kernel(x_ref, codes_ref, cb_ref, out_ref):
    """One (B_tile, O_tile) output block: decode codes, matmul on MXU."""
    x = x_ref[...].astype(jnp.float32)  # (bb, I)
    codes = codes_ref[...]  # (bo, g) int32
    cb = cb_ref[...].astype(jnp.float32)  # (K, d) pinned
    bo, g = codes.shape
    k, d = cb.shape
    w = jnp.take(cb, codes.reshape(-1), axis=0).reshape(bo, g * d)  # (bo, I)
    out_ref[...] = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_b", "block_o"))
def vq_matmul(
    x: jax.Array,
    codes: jax.Array,
    codebook: jax.Array,
    *,
    block_b: int = 64,
    block_o: int = 128,
) -> jax.Array:
    """Fused decode + matmul; drop-in for ``ref.vq_matmul``.

    Args:
      x: ``(B, I)`` activations.
      codes: ``(O, g)`` int32 codeword indices with ``g = I // d``.
      codebook: ``(K, d)`` universal codebook.

    Returns:
      ``(B, O)`` float32 output ``x @ decode(codes)^T``.
    """
    pu.static_check(x.ndim == 2 and codes.ndim == 2, "x and codes must be rank-2")
    b, i = x.shape
    o, g = codes.shape
    k, d = codebook.shape
    pu.static_check(g * d == i, f"codes encode {g * d} inputs but x has {i}")

    bb = pu.pick_tile(b, block_b)
    bo = pu.pick_tile(o, block_o)
    bp = pu.round_up(b, bb)
    op = pu.round_up(o, bo)
    xp = pu.pad_axis(pu.as_f32(x), 0, bp)
    # Padded output rows decode codeword 0; they are sliced away below.
    cp = pu.pad_axis(codes.astype(jnp.int32), 0, op, value=0)

    out = pl.pallas_call(
        _vq_matmul_kernel,
        grid=(bp // bb, op // bo),
        in_specs=[
            pl.BlockSpec((bb, i), lambda bi, oi: (bi, 0)),
            pl.BlockSpec((bo, g), lambda bi, oi: (oi, 0)),
            pl.BlockSpec((k, d), lambda bi, oi: (0, 0)),  # codebook pinned
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda bi, oi: (bi, oi)),
        out_shape=jax.ShapeDtypeStruct((bp, op), jnp.float32),
        interpret=pu.INTERPRET,
    )(xp, cp, pu.as_f32(codebook))
    return out[:b, :o]
