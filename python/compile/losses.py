"""Objective functions (§4.2): task loss, block-wise KD, ratio regularizer.

``L = L_t + L_kd + L_r`` (Eq. 12).  The task loss dispatches on the zoo
task; the KD loss compares the student's main-block features against the
float teacher's (Eq. 10); the regularizer pushes unfrozen ratios towards
one-hot (Eq. 11).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .nets import DETECT_CLASSES


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy (classification task loss)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)
    return -jnp.mean(picked)


def classify_correct(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Top-1 correct count (summed, not averaged — Rust aggregates)."""
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def detect_loss(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    """Mini Mask-RCNN-style multi-task loss.

    ``pred``: (B, G, G, 4+C) = [obj_logit, cx, cy, size, class_logits].
    ``target``: (B, G, G, 5) = [objectness, cx, cy, size, class_id].
    Objectness BCE everywhere; box L2 and class CE only on object cells.
    """
    obj_t = target[..., 0]
    obj_l = pred[..., 0]
    bce = jnp.mean(
        jnp.maximum(obj_l, 0.0) - obj_l * obj_t + jnp.log1p(jnp.exp(-jnp.abs(obj_l)))
    )
    box_err = jnp.sum((pred[..., 1:4] - target[..., 1:4]) ** 2, axis=-1)
    box = jnp.sum(box_err * obj_t) / jnp.maximum(jnp.sum(obj_t), 1.0)
    cls_logits = pred[..., 4:]
    cls_t = target[..., 4].astype(jnp.int32)
    logp = jax.nn.log_softmax(cls_logits, axis=-1)
    picked = jnp.take_along_axis(logp, cls_t[..., None], axis=-1)[..., 0]
    ce = -jnp.sum(picked * obj_t) / jnp.maximum(jnp.sum(obj_t), 1.0)
    return bce + box + ce


def detect_hits(pred: jnp.ndarray, target: jnp.ndarray, tol: float = 0.35) -> jnp.ndarray:
    """mAP@0.5 proxy: count images whose argmax-objectness cell matches
    the ground-truth cell, with the right class and box error under
    ``tol`` (see DESIGN.md §2 — Mask-RCNN AP substitution)."""
    b, g, _, _ = pred.shape
    obj = pred[..., 0].reshape(b, -1)
    pred_cell = jnp.argmax(obj, axis=-1)
    true_cell = jnp.argmax(target[..., 0].reshape(b, -1), axis=-1)
    cell_ok = pred_cell == true_cell

    idx = true_cell  # evaluate box/class at the true cell
    flat_pred = pred.reshape(b, g * g, -1)
    flat_t = target.reshape(b, g * g, -1)
    at_p = jnp.take_along_axis(flat_pred, idx[:, None, None], axis=1)[:, 0]
    at_t = jnp.take_along_axis(flat_t, idx[:, None, None], axis=1)[:, 0]
    cls_ok = jnp.argmax(at_p[:, 4:], axis=-1) == at_t[:, 4].astype(jnp.int32)
    box_ok = jnp.sum(jnp.abs(at_p[:, 1:4] - at_t[:, 1:4]), axis=-1) < tol
    return jnp.sum((cell_ok & cls_ok & box_ok).astype(jnp.float32))


def denoise_loss(pred_eps: jnp.ndarray, eps: jnp.ndarray) -> jnp.ndarray:
    """DDPM epsilon-prediction MSE (Eq. 9 with y = true noise)."""
    return jnp.mean(jnp.sum((pred_eps - eps) ** 2, axis=-1))


def kd_loss(student_feats, teacher_feats) -> jnp.ndarray:
    """Block-wise KD (Eq. 10): sum over main blocks of feature MSE."""
    total = jnp.float32(0.0)
    for s, t in zip(student_feats, teacher_feats):
        total = total + jnp.mean((s - t) ** 2)
    return total


def ratio_regularizer(
    ratios: jnp.ndarray, unset_mask: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Eq. 11 over unfrozen groups only (§4.3)."""
    s, n = ratios.shape
    per_group = jnp.sum(ratios * (1.0 - ratios), axis=-1)
    if unset_mask is not None:
        per_group = per_group * unset_mask
    return jnp.float32(n) * jnp.sum(per_group) / jnp.float32(s)
