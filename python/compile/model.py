"""Layer-2 public surface (compat shim).

The model code is organized across :mod:`compile.nets` (architectures),
:mod:`compile.vqlayers` (VQ reconstruction), :mod:`compile.losses`,
:mod:`compile.optim`, and :mod:`compile.train` (step factory).  This
module re-exports the main entry points under the path the repo scaffold
documents (``python/compile/model.py``).
"""

from .nets import BUILDERS, Net, WeightLayer, build_net  # noqa: F401
from .train import StepFns, make_step_fns, pretrain  # noqa: F401
from .vqlayers import (  # noqa: F401
    Layout,
    effective_ratios,
    extract_subvectors,
    hard_codes,
    make_layout,
    student_params,
    weights_from_flat,
)
