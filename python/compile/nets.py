"""The functional network zoo (Layer 2).

Every architecture is expressed as a pure function over an explicit,
ordered parameter dict — no framework modules — so the same forward code
runs with float weights (teacher / pretraining) and with VQ-reconstructed
weights (the differentiable construction path), and so the full parameter
list can be flattened into a stable calling convention for the AOT
artifacts.

Zoo members (substitutes per DESIGN.md §2):

* ``mlp``        — quickstart target.
* ``resnet18`` / ``resnet50`` — basic-block / bottleneck residual CNNs
  (the paper's ResNet-18/50 stand-ins).
* ``mobilenet``  — depthwise-separable inverted-residual CNN
  (MobileNet-V2 stand-in; depthwise kernels are excluded from VQ just
  like the paper excludes layers whose geometry fights the sub-vector
  grid — documented in DESIGN.md).
* ``detector``   — conv backbone + dense detection head over a cell grid
  (Mask-RCNN stand-in).
* ``denoiser``   — conditional MLP epsilon-predictor for a 2-D DDPM
  (Stable-Diffusion stand-in).

Normalization is running-stat-free channel normalization (per-sample,
per-channel standardization over spatial positions with learned
scale/shift).  This keeps the AOT state machine free of BN running-stat
plumbing while still giving VQ4ALL its "other parameters" (§4.2) to
fine-tune — the substitution is recorded in DESIGN.md §2.

Block features: every ``forward`` returns ``(output, feats)`` where
``feats`` is the list of main-block outputs used by the block-wise
knowledge-distillation loss (Eq. 10); block boundaries follow the paper's
supplementary §11 (residual blocks / inverted residuals / backbone stages
/ hidden blocks).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

DETECT_GRID = 6
DETECT_CLASSES = 3
TIME_EMBED = 14  # denoiser time-embedding dims (x:2 + emb:14 = 16, d | 16)


@dataclasses.dataclass(frozen=True)
class WeightLayer:
    """One VQ-compressible (or explicitly excluded) weight tensor."""

    name: str  # param key
    kind: str  # dense | conv | depthwise
    shape: tuple[int, ...]  # stored param shape (dense: (I, O); conv: HWIO)
    compress: bool  # False for input/output/depthwise exclusions

    @property
    def row_major_out_first(self) -> tuple[int, int]:
        """(O, fan_in) of the (O, I') matrix the paper sub-divides (Eq. 1)."""
        if self.kind == "dense":
            i, o = self.shape
            return o, i
        if self.kind in ("conv", "depthwise"):
            h, w, i, o = self.shape
            return o, h * w * i
        raise ValueError(f"unknown kind {self.kind}")


@dataclasses.dataclass
class Net:
    """A zoo member: init params + forward + layer table."""

    name: str
    forward: Callable  # (params: dict[str, Array], x) -> (out, feats)
    params: dict[str, jnp.ndarray]
    weight_layers: list[WeightLayer]

    def param_names(self) -> list[str]:
        return list(self.params.keys())

    def compressed_layers(self) -> list[WeightLayer]:
        return [l for l in self.weight_layers if l.compress]

    def other_names(self) -> list[str]:
        comp = {l.name for l in self.compressed_layers()}
        return [k for k in self.params if k not in comp]


# --------------------------------------------------------------- helpers


def _split_key(key, num):
    return jax.random.split(key, num)


def _he_conv(key, h, w, i, o):
    std = float(np.sqrt(2.0 / (h * w * i)))
    return jax.random.normal(key, (h, w, i, o), jnp.float32) * std


def _he_dense(key, i, o):
    std = float(np.sqrt(2.0 / i))
    return jax.random.normal(key, (i, o), jnp.float32) * std


def conv2d(x, w, stride: int = 1, groups: int = 1):
    """NHWC x HWIO convolution with SAME padding."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def channel_norm(x, gamma, beta, eps: float = 1e-5):
    """Per-sample, per-channel standardization over spatial dims."""
    if x.ndim == 4:
        mean = jnp.mean(x, axis=(1, 2), keepdims=True)
        var = jnp.var(x, axis=(1, 2), keepdims=True)
    else:  # dense activations: normalize over features
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * gamma + beta


def time_embedding(t, dims: int = TIME_EMBED, max_t: float = 50.0):
    """Sinusoidal timestep embedding for the denoiser."""
    half = dims // 2
    freqs = jnp.exp(jnp.linspace(0.0, 4.0, half))
    ang = (t.astype(jnp.float32) / max_t)[:, None] * freqs[None, :] * 2.0 * jnp.pi
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


class _Builder:
    """Accumulates params + layer table in deterministic order."""

    def __init__(self, key):
        self.params: dict[str, jnp.ndarray] = {}
        self.layers: list[WeightLayer] = []
        self._key = key

    def key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def conv(self, name, h, w, i, o, compress=True, kind="conv"):
        self.params[f"{name}.w"] = _he_conv(self.key(), h, w, i, o)
        self.layers.append(WeightLayer(f"{name}.w", kind, (h, w, i, o), compress))
        self.params[f"{name}.g"] = jnp.ones((o,), jnp.float32)
        self.params[f"{name}.b"] = jnp.zeros((o,), jnp.float32)

    def dense(self, name, i, o, compress=True, norm=True):
        self.params[f"{name}.w"] = _he_dense(self.key(), i, o)
        self.layers.append(WeightLayer(f"{name}.w", "dense", (i, o), compress))
        self.params[f"{name}.b"] = jnp.zeros((o,), jnp.float32)
        if norm:
            self.params[f"{name}.g"] = jnp.ones((o,), jnp.float32)
            self.params[f"{name}.nb"] = jnp.zeros((o,), jnp.float32)


def _conv_block(p, name, x, stride=1, groups=1, relu=True):
    y = conv2d(x, p[f"{name}.w"], stride=stride, groups=groups)
    y = channel_norm(y, p[f"{name}.g"], p[f"{name}.b"])
    return jax.nn.relu(y) if relu else y


def _dense_block(p, name, x, relu=True, norm=True):
    y = x @ p[f"{name}.w"] + p[f"{name}.b"]
    if norm:
        y = channel_norm(y, p[f"{name}.g"], p[f"{name}.nb"])
    return jax.nn.relu(y) if relu else y


# ------------------------------------------------------------------- MLP


def build_mlp(key, input_shape=(16, 16, 3), num_classes=10) -> Net:
    b = _Builder(key)
    in_dim = int(np.prod(input_shape))
    b.dense("fc1", in_dim, 256)
    b.dense("fc2", 256, 128)
    b.dense("out", 128, num_classes, compress=False, norm=False)

    def forward(p, x):
        h = x.reshape(x.shape[0], -1)
        feats = []
        h = _dense_block(p, "fc1", h)
        feats.append(h)
        h = _dense_block(p, "fc2", h)
        feats.append(h)
        return h @ p["out.w"] + p["out.b"], feats

    return Net("mini_mlp", forward, b.params, b.layers)


# ---------------------------------------------------------------- ResNets


def _basic_block(p, name, x, cin, cout, stride):
    y = _conv_block(p, f"{name}.c1", x, stride=stride)
    y = _conv_block(p, f"{name}.c2", y, relu=False)
    if stride != 1 or cin != cout:
        x = conv2d(x, p[f"{name}.proj.w"], stride=stride)
        x = channel_norm(x, p[f"{name}.proj.g"], p[f"{name}.proj.b"])
    return jax.nn.relu(x + y)


def _bottleneck(p, name, x, cin, cmid, cout, stride):
    y = _conv_block(p, f"{name}.c1", x)
    y = _conv_block(p, f"{name}.c2", y, stride=stride)
    y = _conv_block(p, f"{name}.c3", y, relu=False)
    if stride != 1 or cin != cout:
        x = conv2d(x, p[f"{name}.proj.w"], stride=stride)
        x = channel_norm(x, p[f"{name}.proj.g"], p[f"{name}.proj.b"])
    return jax.nn.relu(x + y)


def build_resnet18(key, input_shape=(16, 16, 3), num_classes=10) -> Net:
    """2-stage basic-block residual net (ResNet-18 stand-in)."""
    b = _Builder(key)
    b.conv("stem", 3, 3, 3, 16, compress=False)  # input layer: excluded (§5.1)
    cfg = [("s1b1", 16, 16, 1), ("s1b2", 16, 16, 1), ("s2b1", 16, 32, 2), ("s2b2", 32, 32, 1)]
    for name, cin, cout, stride in cfg:
        b.conv(f"{name}.c1", 3, 3, cin, cout)
        b.conv(f"{name}.c2", 3, 3, cout, cout)
        if stride != 1 or cin != cout:
            b.conv(f"{name}.proj", 1, 1, cin, cout)
    b.dense("head", 32, num_classes, compress=False, norm=False)  # output layer: excluded

    def forward(p, x):
        h = _conv_block(p, "stem", x)
        feats = []
        for name, cin, cout, stride in cfg:
            h = _basic_block(p, name, h, cin, cout, stride)
            feats.append(h)
        h = jnp.mean(h, axis=(1, 2))
        return h @ p["head.w"] + p["head.b"], feats

    return Net("mini_resnet18", forward, b.params, b.layers)


def build_resnet50(key, input_shape=(16, 16, 3), num_classes=10) -> Net:
    """2-stage bottleneck residual net (ResNet-50 stand-in)."""
    b = _Builder(key)
    b.conv("stem", 3, 3, 3, 32, compress=False)
    cfg = [
        ("s1b1", 32, 16, 64, 1),
        ("s1b2", 64, 16, 64, 1),
        ("s2b1", 64, 32, 128, 2),
        ("s2b2", 128, 32, 128, 1),
    ]
    for name, cin, cmid, cout, stride in cfg:
        b.conv(f"{name}.c1", 1, 1, cin, cmid)
        b.conv(f"{name}.c2", 3, 3, cmid, cmid)
        b.conv(f"{name}.c3", 1, 1, cmid, cout)
        if stride != 1 or cin != cout:
            b.conv(f"{name}.proj", 1, 1, cin, cout)
    b.dense("head", 128, num_classes, compress=False, norm=False)

    def forward(p, x):
        h = _conv_block(p, "stem", x)
        feats = []
        for name, cin, cmid, cout, stride in cfg:
            h = _bottleneck(p, name, h, cin, cmid, cout, stride)
            feats.append(h)
        h = jnp.mean(h, axis=(1, 2))
        return h @ p["head.w"] + p["head.b"], feats

    return Net("mini_resnet50", forward, b.params, b.layers)


# -------------------------------------------------------------- MobileNet


def build_mobilenet(key, input_shape=(16, 16, 3), num_classes=10) -> Net:
    """Inverted-residual depthwise-separable net (MobileNet-V2 stand-in).

    Depthwise kernels have fan-in 9 per output channel, which does not
    divide the paper's d ∈ {4, 8, ...}; like the paper's special-case
    layers they are left uncompressed (DESIGN.md §2).
    """
    b = _Builder(key)
    b.conv("stem", 3, 3, 3, 16, compress=False)
    cfg = [("ir1", 16, 48, 24, 1), ("ir2", 24, 72, 32, 2), ("ir3", 32, 96, 32, 1)]
    for name, cin, cexp, cout, stride in cfg:
        b.conv(f"{name}.expand", 1, 1, cin, cexp)
        b.conv(f"{name}.dw", 3, 3, 1, cexp, compress=False, kind="depthwise")
        b.conv(f"{name}.project", 1, 1, cexp, cout)
    b.dense("head", 32, num_classes, compress=False, norm=False)

    def forward(p, x):
        h = _conv_block(p, "stem", x)
        feats = []
        for name, cin, cexp, cout, stride in cfg:
            y = _conv_block(p, f"{name}.expand", h)
            y = conv2d(y, p[f"{name}.dw.w"], stride=stride, groups=cexp)
            y = channel_norm(y, p[f"{name}.dw.g"], p[f"{name}.dw.b"])
            y = jax.nn.relu(y)
            y = _conv_block(p, f"{name}.project", y, relu=False)
            if stride == 1 and cin == cout:
                y = y + h
            h = y
            feats.append(h)
        # channel_norm makes each channel zero-mean over space, which a
        # plain GAP would collapse to ~0; ReLU first keeps the pooled
        # representation informative (MobileNet-V2 ends with a ReLU6 conv
        # before pooling for the same reason).
        h = jnp.mean(jax.nn.relu(h), axis=(1, 2))
        return h @ p["head.w"] + p["head.b"], feats

    return Net("mini_mobilenet", forward, b.params, b.layers)


# --------------------------------------------------------------- Detector


def build_detector(key, input_shape=(24, 24, 3), num_classes=DETECT_CLASSES) -> Net:
    """Conv backbone + per-cell detection head (Mask-RCNN stand-in).

    Head output per cell: [obj_logit, cx, cy, size, class_logits...].
    """
    b = _Builder(key)
    b.conv("stem", 3, 3, 3, 16, compress=False)
    b.conv("c1", 3, 3, 16, 32)
    b.conv("c2", 3, 3, 32, 32)
    b.conv("c3", 3, 3, 32, 48)
    out_ch = 4 + num_classes
    b.conv("head", 1, 1, 48, out_ch, compress=False)

    def forward(p, x):
        h = _conv_block(p, "stem", x)  # 24x24x16
        feats = []
        h = _conv_block(p, "c1", h, stride=2)  # 12x12x32
        feats.append(h)
        h = _conv_block(p, "c2", h)  # 12x12x32
        feats.append(h)
        h = _conv_block(p, "c3", h, stride=2)  # 6x6x48
        feats.append(h)
        out = conv2d(h, p["head.w"]) + p["head.b"]  # 6x6x(4+C)
        return out, feats

    return Net("mini_detector", forward, b.params, b.layers)


# --------------------------------------------------------------- Denoiser


def build_denoiser(key, input_shape=(2,), num_classes=0) -> Net:
    """Conditional epsilon-predictor for 2-D DDPM (Stable-Diffusion stand-in).

    Input is ``concat(x_t, time_embedding(t))``; output is predicted noise.
    """
    b = _Builder(key)
    in_dim = 2 + TIME_EMBED  # 16
    b.dense("fc1", in_dim, 128)
    b.dense("fc2", 128, 128)
    b.dense("fc3", 128, 128)
    b.dense("out", 128, 2, compress=False, norm=False)

    def forward(p, xt):
        # xt packs (x_t, t) as (B, 3): columns 0..1 = x, column 2 = t.
        x = xt[:, :2]
        t = xt[:, 2]
        h = jnp.concatenate([x, time_embedding(t)], axis=1)
        feats = []
        h = _dense_block(p, "fc1", h)
        feats.append(h)
        h = _dense_block(p, "fc2", h)
        feats.append(h)
        h = _dense_block(p, "fc3", h)
        feats.append(h)
        return h @ p["out.w"] + p["out.b"], feats

    return Net("mini_denoiser", forward, b.params, b.layers)


BUILDERS = {
    "mlp": build_mlp,
    "resnet18": build_resnet18,
    "resnet50": build_resnet50,
    "mobilenet": build_mobilenet,
    "detector": build_detector,
    "denoiser": build_denoiser,
}


def build_net(spec) -> Net:
    """Construct a zoo member from its :class:`~compile.zoo.NetSpec`."""
    key = jax.random.PRNGKey(spec.seed)
    net = BUILDERS[spec.arch](key, input_shape=spec.input_shape, num_classes=max(spec.num_classes, 1))
    net.name = spec.name
    return net
