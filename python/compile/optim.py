"""Hand-rolled optimizers (optax is not part of the build image).

Two optimizers, matching §5's hyper-parameters:

* :func:`adamax_update` — Adamax for the ratio logits ``z``
  (lr 3e-1; infinity-norm second moment, as in Kingma & Ba §7.1).
* :func:`adam_update`   — Adam for the other parameters (bias / norm),
  lr 1e-3.

State is carried as explicit tensors so the whole optimizer threads
through the AOT artifact interface: the Rust coordinator owns the state
buffers and feeds them back every step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

B1 = 0.9
B2 = 0.999
EPS = 1e-8


def adamax_update(p, g, m, u, t, lr):
    """One Adamax step.  ``t`` is the 1-based step count (f32 scalar).

    Returns ``(p_new, m_new, u_new)``.
    """
    m_new = B1 * m + (1.0 - B1) * g
    u_new = jnp.maximum(B2 * u, jnp.abs(g))
    # Bias correction only on the first moment (Adamax has none on u).
    m_hat = m_new / (1.0 - B1**t)
    return p - lr * m_hat / (u_new + EPS), m_new, u_new


def adam_update(p, g, m, v, t, lr):
    """One Adam step.  Returns ``(p_new, m_new, v_new)``."""
    m_new = B1 * m + (1.0 - B1) * g
    v_new = B2 * v + (1.0 - B2) * g * g
    m_hat = m_new / (1.0 - B1**t)
    v_hat = v_new / (1.0 - B2**t)
    return p - lr * m_hat / (jnp.sqrt(v_hat) + EPS), m_new, v_new


def adam_update_tree(params, grads, ms, vs, t, lr):
    """Adam over a dict of tensors; returns (params, ms, vs) dicts."""
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        out_p[k], out_m[k], out_v[k] = adam_update(params[k], grads[k], ms[k], vs[k], t, lr)
    return out_p, out_m, out_v


def cosine_lr(base_lr: float, step, total_steps: int):
    """Cosine annealing (§5.1 uses a cosine scheduler for 'other' params)."""
    frac = jnp.clip(step / float(max(total_steps, 1)), 0.0, 1.0)
    return base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
