"""``.vqt`` tensor-file codec — the python half of the interchange format.

Layout (little-endian throughout), mirrored by ``rust/src/tensor/io.rs``:

    magic   4 bytes   b"VQT1"
    dtype   u32       0 = f32, 1 = i32, 2 = u32, 3 = f64, 4 = i64, 5 = u8
    ndim    u32
    dims    ndim * u64
    data    raw row-major payload

Kept deliberately trivial: no compression, no alignment games — the Rust
reader memory-maps nothing and simply reads the stream, so the format is
portable and diff-able.
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"VQT1"

_DTYPES: list[tuple[int, np.dtype]] = [
    (0, np.dtype("<f4")),
    (1, np.dtype("<i4")),
    (2, np.dtype("<u4")),
    (3, np.dtype("<f8")),
    (4, np.dtype("<i8")),
    (5, np.dtype("u1")),
]
_TO_TAG = {dt: tag for tag, dt in _DTYPES}
_FROM_TAG = {tag: dt for tag, dt in _DTYPES}


def write_tensor(path: str | Path, arr: np.ndarray) -> None:
    """Write ``arr`` as a .vqt file (canonicalizing to LE, C-order)."""
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype.newbyteorder("<")
    if dt not in _TO_TAG:
        raise TypeError(f"unsupported dtype {arr.dtype}")
    arr = arr.astype(dt, copy=False)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", _TO_TAG[dt], arr.ndim))
        f.write(struct.pack(f"<{arr.ndim}Q", *arr.shape))
        f.write(arr.tobytes())


def read_tensor(path: str | Path) -> np.ndarray:
    """Read a .vqt file back into a numpy array."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        tag, ndim = struct.unpack("<II", f.read(8))
        if tag not in _FROM_TAG:
            raise ValueError(f"{path}: unknown dtype tag {tag}")
        dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
        dt = _FROM_TAG[tag]
        count = int(np.prod(dims)) if ndim else 1
        payload = f.read(count * dt.itemsize)
        if len(payload) != count * dt.itemsize:
            raise ValueError(f"{path}: truncated payload")
        arr = np.frombuffer(payload, dtype=dt, count=count)
        return arr.reshape(dims).copy()
