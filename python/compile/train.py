"""Step-function factory (Layer 2): pretraining + the VQ4ALL construction
steps that get AOT-lowered for the Rust coordinator.

Calling convention (mirrored in artifacts/manifest.json, consumed by
``rust/src/runtime/artifact.rs``):

``train_step`` inputs, in order::

    z (S,n) f32 | m_z (S,n) | u_z (S,n)          ratio logits + Adamax state
    other_0..other_{P-1}                          trainable bias/norm/excluded
    m_0..m_{P-1} | v_0..v_{P-1}                   Adam state for the others
    t (1,) f32                                    1-based step counter
    assign (S,n) i32                              candidate table (static)
    frozen (S,) f32 | frozen_idx (S,) i32         PNC state (Rust-owned)
    codebook (K,d) f32                            frozen universal codebook
    teacher_flat (S,d) f32                        float sub-vectors (L_kd)
    teacher_other_0..teacher_other_{P-1}          float other params (L_kd)
    <batch>                                       task-specific, see below

outputs, in order::

    z | m_z | u_z | other_* | m_* | v_* | t      updated state (same order)
    metrics (4,) f32                              [L, L_t, L_kd, L_r]

Batch per task: ``classify`` -> ``x (B,H,W,C) f32, y (B,) i32``;
``detect`` -> ``x (B,H,W,C) f32, y (B,G,G,5) f32``; ``denoise`` ->
``x0 (B,2) f32, tdiff (B,) i32, eps (B,2) f32`` (Rust draws tdiff/eps).

The PNC freeze decision itself lives in Rust (`coordinator/pnc.rs`): the
step only *consumes* ``frozen``/``frozen_idx``.  That split is the paper's
Algorithm 1 — line 10 (gradient update) is this module, lines 11-14
(threshold & freeze) are the coordinator.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import losses, optim, vqlayers
from .kernels import distance as pk_distance
from .kernels import ref as pk_ref
from .kernels import vq_matmul as pk_vq_matmul
from .nets import DETECT_GRID, Net, build_net
from .zoo import NetSpec, VqConfig

TOTAL_VQ_STEPS = 400  # cosine-anneal horizon for the 'other' lr (§5.1)


# ------------------------------------------------------------ task batches


def batch_specs(spec: NetSpec) -> list[tuple[str, tuple[int, ...], str]]:
    """(name, shape, dtype) of the train batch inputs for one network."""
    b = spec.batch
    if spec.task == "classify":
        return [("x", (b, *spec.input_shape), "f32"), ("y", (b,), "i32")]
    if spec.task == "detect":
        g = DETECT_GRID
        return [("x", (b, *spec.input_shape), "f32"), ("y", (b, g, g, 5), "f32")]
    if spec.task == "denoise":
        return [
            ("x0", (b, 2), "f32"),
            ("tdiff", (b,), "i32"),
            ("eps", (b, 2), "f32"),
        ]
    raise ValueError(spec.task)


def eval_batch_specs(spec: NetSpec) -> list[tuple[str, tuple[int, ...], str]]:
    out = []
    for name, shape, dt in batch_specs(spec):
        out.append((name, (spec.eval_batch, *shape[1:]), dt))
    return out


def _task_forward_loss(spec: NetSpec, net: Net, params, batch, schedule):
    """Forward + task loss; returns (loss_t, feats, aux_for_metric)."""
    if spec.task == "classify":
        x, y = batch
        logits, feats = net.forward(params, x)
        return losses.cross_entropy(logits, y), feats, logits
    if spec.task == "detect":
        x, y = batch
        pred, feats = net.forward(params, x)
        return losses.detect_loss(pred, y), feats, pred
    if spec.task == "denoise":
        x0, tdiff, eps = batch
        sa = jnp.take(schedule["sqrt_abar"], tdiff)[:, None]
        sb = jnp.take(schedule["sqrt_1m_abar"], tdiff)[:, None]
        xt = sa * x0 + sb * eps
        pack = jnp.concatenate([xt, tdiff.astype(jnp.float32)[:, None]], axis=1)
        pred, feats = net.forward(params, pack)
        return losses.denoise_loss(pred, eps), feats, pred
    raise ValueError(spec.task)


def _task_metrics(spec: NetSpec, aux, batch) -> jnp.ndarray:
    """(2,) f32 = [loss-like sum, hit count] — Rust aggregates over batches."""
    if spec.task == "classify":
        _, y = batch
        ce = losses.cross_entropy(aux, y) * aux.shape[0]
        return jnp.stack([ce, losses.classify_correct(aux, y)])
    if spec.task == "detect":
        _, y = batch
        ls = losses.detect_loss(aux, y) * aux.shape[0]
        return jnp.stack([ls, losses.detect_hits(aux, y)])
    if spec.task == "denoise":
        x0, tdiff, eps = batch
        mse = losses.denoise_loss(aux, eps) * aux.shape[0]
        return jnp.stack([mse, jnp.float32(0.0)])
    raise ValueError(spec.task)


# ------------------------------------------------------------- pretraining


def pretrain(net: Net, spec: NetSpec, x: np.ndarray, y: np.ndarray) -> tuple[dict, float]:
    """Float pretraining (build-time substitute for the paper's official
    pretrained checkpoints — DESIGN.md §2).  Plain Adam + task loss."""
    schedule = {k: jnp.asarray(v) for k, v in data_mod.diffusion_schedule().items()}
    params = dict(net.params)
    ms = {k: jnp.zeros_like(v) for k, v in params.items()}
    vs = {k: jnp.zeros_like(v) for k, v in params.items()}
    key = jax.random.PRNGKey(spec.seed + 77)

    def loss_fn(p, batch):
        l, _, aux = _task_forward_loss(spec, net, p, batch, schedule)
        return l, aux

    @jax.jit
    def step(params, ms, vs, t, batch):
        (l, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, ms, vs = optim.adam_update_tree(params, grads, ms, vs, t, spec.pretrain_lr)
        return params, ms, vs, l

    n = x.shape[0]
    for i in range(spec.pretrain_steps):
        key, k1, k2, k3 = jax.random.split(key, 4)
        idx = jax.random.randint(k1, (spec.batch,), 0, n)
        if spec.task == "denoise":
            batch = (
                jnp.asarray(x)[idx],
                jax.random.randint(k2, (spec.batch,), 0, len(data_mod.diffusion_schedule()["betas"])),
                jax.random.normal(k3, (spec.batch, 2)),
            )
        else:
            batch = (jnp.asarray(x)[idx], jnp.asarray(y)[idx])
        params, ms, vs, l = step(params, ms, vs, jnp.float32(i + 1), batch)
    return params, float(l)


def eval_float(net: Net, spec: NetSpec, params, x, y, seed: int = 0) -> tuple[float, float]:
    """Float metric over a full split: (mean loss, accuracy-or-hit-rate)."""
    schedule = {k: jnp.asarray(v) for k, v in data_mod.diffusion_schedule().items()}
    key = jax.random.PRNGKey(seed)
    bs = spec.eval_batch
    total = np.zeros(2)
    count = 0
    for off in range(0, (x.shape[0] // bs) * bs, bs):
        if spec.task == "denoise":
            key, k1, k2 = jax.random.split(key, 3)
            batch = (
                jnp.asarray(x[off : off + bs]),
                jax.random.randint(k1, (bs,), 0, 50),
                jax.random.normal(k2, (bs, 2)),
            )
        else:
            batch = (jnp.asarray(x[off : off + bs]), jnp.asarray(y[off : off + bs]))
        _, _, aux = _task_forward_loss(spec, net, params, batch, schedule)
        m = np.asarray(_task_metrics(spec, aux, batch))
        total += m
        count += bs
    return float(total[0] / count), float(total[1] / count)


# --------------------------------------------------------- VQ step factory


class StepFns:
    """Bundle of lowering-ready functions + their input specs for one net."""

    def __init__(self, net: Net, spec: NetSpec, cfg: VqConfig):
        self.net = net
        self.spec = spec
        self.cfg = cfg
        self.layout = vqlayers.make_layout(net, cfg.d)
        self.other_names = net.other_names()
        self.schedule = {
            k: jnp.asarray(v) for k, v in data_mod.diffusion_schedule().items()
        }

    # -- signature helpers -------------------------------------------------

    @property
    def s_total(self) -> int:
        return self.layout.s_total

    def state_specs(self) -> list[tuple[str, tuple[int, ...], str]]:
        s, n = self.s_total, self.cfg.n
        specs = [("z", (s, n), "f32"), ("m_z", (s, n), "f32"), ("u_z", (s, n), "f32")]
        for prefix in ("other", "m_other", "v_other"):
            for name in self.other_names:
                shape = tuple(self.net.params[name].shape)
                specs.append((f"{prefix}:{name}", shape, "f32"))
        specs.append(("t", (1,), "f32"))
        return specs

    def static_specs(self) -> list[tuple[str, tuple[int, ...], str]]:
        s, n = self.s_total, self.cfg.n
        k, d = self.cfg.k, self.cfg.d
        specs = [
            ("assign", (s, n), "i32"),
            ("frozen", (s,), "f32"),
            ("frozen_idx", (s,), "i32"),
            ("codebook", (k, d), "f32"),
            ("teacher_flat", (s, d), "f32"),
            # Per-term loss weights [w_t, w_kd, w_r] — 1.0 in the paper's
            # Eq. 12; zeroing a term is Table 5's component ablation.
            ("loss_w", (3,), "f32"),
        ]
        for name in self.other_names:
            specs.append((f"teacher:{name}", tuple(self.net.params[name].shape), "f32"))
        return specs

    def _unpack(self, args, specs):
        assert len(args) == len(specs), f"{len(args)} args vs {len(specs)} specs"
        return {name: a for a, (name, _, _) in zip(args, specs)}

    def _others_from(self, st, prefix="other") -> dict:
        return {name: st[f"{prefix}:{name}"] for name in self.other_names}

    # -- the functions to lower ---------------------------------------------

    def init_assign(self, wsub, codebook):
        """Candidate table + initial logits (Eq. 5 + Eq. 7).

        Runs the Pallas distance kernel over the network's sub-vectors.
        """
        a, sq = pk_distance.topn_candidates(wsub, codebook, self.cfg.n)
        z0 = pk_ref.init_ratio_logits(sq)
        return a, z0

    def train_step(self, *args):
        sspecs = self.state_specs()
        tspecs = self.static_specs()
        bspecs = batch_specs(self.spec)
        ns, nt = len(sspecs), len(tspecs)
        st = self._unpack(args[:ns], sspecs)
        static = self._unpack(args[ns : ns + nt], tspecs)
        batch = args[ns + nt :]
        assert len(batch) == len(bspecs)

        teacher_params = dict(self._teacher_params(static))
        t_now = st["t"][0] + 1.0

        def loss_fn(z, others):
            params = vqlayers.student_params(
                z,
                static["frozen"],
                static["frozen_idx"],
                static["assign"],
                static["codebook"],
                others,
                self.layout,
            )
            l_t, feats, _aux = _task_forward_loss(self.spec, self.net, params, batch, self.schedule)
            _, t_feats, _ = _task_forward_loss(
                self.spec, self.net, teacher_params, batch, self.schedule
            )
            l_kd = losses.kd_loss(feats, t_feats)
            r = vqlayers.effective_ratios(z, static["frozen"], static["frozen_idx"])
            l_r = losses.ratio_regularizer(r, 1.0 - static["frozen"])
            w = static["loss_w"]
            total = w[0] * l_t + w[1] * l_kd + w[2] * l_r
            return total, (l_t, l_kd, l_r)

        others = self._others_from(st)
        (l, (l_t, l_kd, l_r)), grads = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
            st["z"], others
        )
        gz, gothers = grads

        z_new, mz, uz = optim.adamax_update(
            st["z"], gz, st["m_z"], st["u_z"], t_now, self.cfg.lr_ratios
        )
        lr_o = optim.cosine_lr(self.cfg.lr_other, t_now, TOTAL_VQ_STEPS)
        o_new, m_new, v_new = optim.adam_update_tree(
            others,
            gothers,
            self._others_from(st, "m_other"),
            self._others_from(st, "v_other"),
            t_now,
            lr_o,
        )

        outs = [z_new, mz, uz]
        outs += [o_new[nm] for nm in self.other_names]
        outs += [m_new[nm] for nm in self.other_names]
        outs += [v_new[nm] for nm in self.other_names]
        outs.append(st["t"] + 1.0)
        outs.append(jnp.stack([l, l_t, l_kd, l_r]))
        return tuple(outs)

    def _teacher_params(self, static) -> dict:
        params = {n2: static[f"teacher:{n2}"] for n2 in self.other_names}
        params.update(vqlayers.weights_from_flat(static["teacher_flat"], self.layout))
        return params

    def eval_soft(self, *args):
        """Eval with soft (ratio-weighted) weights — the construction-time
        accuracy curve of Figure 3 (no PNC collapse applied)."""
        s, n = self.s_total, self.cfg.n
        specs = (
            [("z", (s, n), "f32")]
            + [(f"other:{nm}", tuple(self.net.params[nm].shape), "f32") for nm in self.other_names]
            + [
                ("assign", (s, n), "i32"),
                ("frozen", (s,), "f32"),
                ("frozen_idx", (s,), "i32"),
                ("codebook", (self.cfg.k, self.cfg.d), "f32"),
            ]
        )
        nb = len(eval_batch_specs(self.spec))
        st = self._unpack(args[: len(specs)], specs)
        batch = args[len(specs) :]
        assert len(batch) == nb
        params = vqlayers.student_params(
            st["z"], st["frozen"], st["frozen_idx"], st["assign"], st["codebook"],
            self._others_from(st), self.layout,
        )
        _, _, aux = _task_forward_loss(self.spec, self.net, params, batch, self.schedule)
        return _task_metrics(self.spec, aux, batch)

    def eval_hard(self, *args):
        """Eval with final hard codes (Eq. 2) — the deliverable network."""
        s = self.s_total
        specs = (
            [("codes", (s,), "i32")]
            + [(f"other:{nm}", tuple(self.net.params[nm].shape), "f32") for nm in self.other_names]
            + [("codebook", (self.cfg.k, self.cfg.d), "f32")]
        )
        st = self._unpack(args[: len(specs)], specs)
        batch = args[len(specs) :]
        params = vqlayers.hard_params(st["codes"], st["codebook"], self._others_from(st), self.layout)
        _, _, aux = _task_forward_loss(self.spec, self.net, params, batch, self.schedule)
        return _task_metrics(self.spec, aux, batch)

    def infer_hard(self, *args):
        """Serving forward with hard codes.

        ``mini_mlp`` demonstrates the fused Pallas ``vq_matmul`` path
        (decode-inside-the-kernel, DESIGN.md §4); the conv nets decode
        with the reconstruct kernel then run their normal forward.
        """
        s = self.s_total
        specs = (
            [("codes", (s,), "i32")]
            + [(f"other:{nm}", tuple(self.net.params[nm].shape), "f32") for nm in self.other_names]
            + [("codebook", (self.cfg.k, self.cfg.d), "f32")]
        )
        st = self._unpack(args[: len(specs)], specs)
        x = args[len(specs)]
        if self.spec.arch == "mlp":
            return self._mlp_fused_logits(st, x)
        params = vqlayers.hard_params(st["codes"], st["codebook"], self._others_from(st), self.layout)
        if self.spec.task == "denoise":
            out, _ = self.net.forward(params, x)
            return out
        out, _ = self.net.forward(params, x)
        return out

    def _mlp_fused_logits(self, st, x):
        """MLP forward where each compressed dense layer is a single fused
        decode+matmul Pallas kernel call (the ROM-codebook hot path)."""
        from .nets import channel_norm

        others = self._others_from(st)
        cb = st["codebook"]
        h = x.reshape(x.shape[0], -1)
        for lname in ("fc1", "fc2"):
            sl = self.layout.slice_for(f"{lname}.w")
            o, fan_in = sl.layer.row_major_out_first
            codes = st["codes"][sl.offset : sl.offset + sl.groups].reshape(
                o, fan_in // self.cfg.d
            )
            h = pk_vq_matmul.vq_matmul(h, codes, cb) + others[f"{lname}.b"]
            h = channel_norm(h, others[f"{lname}.g"], others[f"{lname}.nb"])
            h = jax.nn.relu(h)
        return h @ others["out.w"] + others["out.b"]

    def denoise_eps(self, *args):
        """Epsilon prediction only (denoiser): the network forward on
        ``(xt, t)`` with hard-coded VQ weights.  The DDPM posterior
        arithmetic (Eq. mean/noise update) runs host-side in the Rust
        coordinator — the sampler *loop* is L3's job, and the pure
        forward reuses the exact graph family of ``eval_hard`` /
        ``infer_hard`` that the xla_extension 0.5.1 HLO-text round-trip
        is known to execute correctly."""
        assert self.spec.task == "denoise"
        s = self.s_total
        specs = (
            [("codes", (s,), "i32")]
            + [(f"other:{nm}", tuple(self.net.params[nm].shape), "f32") for nm in self.other_names]
            + [("codebook", (self.cfg.k, self.cfg.d), "f32")]
        )
        st = self._unpack(args[: len(specs)], specs)
        xt, tdiff = args[len(specs) :]
        params = vqlayers.hard_params(st["codes"], st["codebook"], self._others_from(st), self.layout)
        pack = jnp.concatenate([xt, tdiff.astype(jnp.float32)[:, None]], axis=1)
        eps_pred, _ = self.net.forward(params, pack)
        return eps_pred

    def sample_step(self, *args):
        """One reverse-diffusion step (denoiser only): DDPM posterior mean
        + noise, with epsilon predicted by the hard-coded network."""
        assert self.spec.task == "denoise"
        s = self.s_total
        specs = (
            [("codes", (s,), "i32")]
            + [(f"other:{nm}", tuple(self.net.params[nm].shape), "f32") for nm in self.other_names]
            + [("codebook", (self.cfg.k, self.cfg.d), "f32")]
        )
        st = self._unpack(args[: len(specs)], specs)
        xt, tdiff, noise = args[len(specs) :]
        params = vqlayers.hard_params(st["codes"], st["codebook"], self._others_from(st), self.layout)
        pack = jnp.concatenate([xt, tdiff.astype(jnp.float32)[:, None]], axis=1)
        eps_pred, _ = self.net.forward(params, pack)
        beta = jnp.take(self.schedule["betas"], tdiff)[:, None]
        alpha = jnp.take(self.schedule["alphas"], tdiff)[:, None]
        s1m = jnp.take(self.schedule["sqrt_1m_abar"], tdiff)[:, None]
        mean = (xt - beta / s1m * eps_pred) / jnp.sqrt(alpha)
        not_last = (tdiff > 0).astype(jnp.float32)[:, None]
        return mean + jnp.sqrt(beta) * noise * not_last


def make_step_fns(spec: NetSpec, cfg: VqConfig) -> StepFns:
    """Build a zoo member + its lowering-ready VQ4ALL step functions."""
    return StepFns(build_net(spec), spec, cfg)
