"""Sub-vector layout and differentiable VQ weight reconstruction (Layer 2).

The paper flattens every compressed weight matrix ``W in R^{o x i'}``
(conv kernels are viewed as ``(O, H*W*I)``) and splits each row into
``d``-dimensional sub-vectors (Eq. 1).  VQ4ALL then keeps, network-wide:

* one static candidate table ``A_c (S_total, n)`` — top-n codeword
  indices per sub-vector (Eq. 5);
* one trainable logit tensor ``z (S_total, n)`` whose softmax gives the
  ratios ``R`` (Eq. 6);
* one PNC freeze state — ``frozen (S_total,)`` in {0,1} and
  ``frozen_idx (S_total,)`` selecting which *candidate slot* was locked
  to one-hot (Eq. 14).

All compressed layers of one network are **concatenated** into a single
``(S_total, d)`` sub-vector space; :class:`Layout` records where each
layer's groups live, so there is exactly one logit tensor / one PNC state
per network (this is what lets the Rust coordinator treat construction
as a single flat schedule).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import reconstruct as pk_reconstruct
from .nets import Net, WeightLayer


@dataclasses.dataclass(frozen=True)
class LayerSlice:
    """Where one compressed layer lives in the flat sub-vector space."""

    layer: WeightLayer
    offset: int  # first group index
    groups: int  # number of d-dim groups


@dataclasses.dataclass(frozen=True)
class Layout:
    """Flat sub-vector layout for one network at sub-vector length d."""

    d: int
    slices: tuple[LayerSlice, ...]

    @property
    def s_total(self) -> int:
        return sum(s.groups for s in self.slices)

    def slice_for(self, name: str) -> LayerSlice:
        for s in self.slices:
            if s.layer.name == name:
                return s
        raise KeyError(name)


def make_layout(net: Net, d: int) -> Layout:
    """Build the flat layout; raises if a compressed layer's fan-in does
    not divide ``d`` (those layers must be marked ``compress=False``)."""
    slices = []
    offset = 0
    for layer in net.compressed_layers():
        o, fan_in = layer.row_major_out_first
        if fan_in % d != 0:
            raise ValueError(
                f"{net.name}:{layer.name} fan_in {fan_in} not divisible by d={d}; "
                "mark the layer compress=False"
            )
        groups = o * (fan_in // d)
        slices.append(LayerSlice(layer, offset, groups))
        offset += groups
    return Layout(d=d, slices=tuple(slices))


def _to_out_first(w: jnp.ndarray, layer: WeightLayer) -> jnp.ndarray:
    """Stored param -> (O, fan_in) row-major matrix (Eq. 1's W)."""
    if layer.kind == "dense":
        return w.T  # stored (I, O)
    # conv stored HWIO -> (O, H, W, I) -> (O, HWI)
    return jnp.transpose(w, (3, 0, 1, 2)).reshape(w.shape[3], -1)


def _from_out_first(m: jnp.ndarray, layer: WeightLayer) -> jnp.ndarray:
    """(O, fan_in) -> stored param shape."""
    if layer.kind == "dense":
        return m.T
    h, w, i, o = layer.shape
    return jnp.transpose(m.reshape(o, h, w, i), (1, 2, 3, 0))


def extract_subvectors(params: dict, layout: Layout) -> jnp.ndarray:
    """Flatten all compressed layers into the ``(S_total, d)`` space."""
    parts = []
    for s in layout.slices:
        m = _to_out_first(params[s.layer.name], s.layer)
        parts.append(m.reshape(-1, layout.d))
    return jnp.concatenate(parts, axis=0)


def weights_from_flat(flat: jnp.ndarray, layout: Layout) -> dict:
    """Inverse of :func:`extract_subvectors` — per-layer stored params."""
    out = {}
    for s in layout.slices:
        o, fan_in = s.layer.row_major_out_first
        m = flat[s.offset : s.offset + s.groups].reshape(o, fan_in)
        out[s.layer.name] = _from_out_first(m, s.layer)
    return out


def effective_ratios(
    z: jnp.ndarray, frozen: jnp.ndarray, frozen_idx: jnp.ndarray
) -> jnp.ndarray:
    """Eq. 6 softmax ratios with Eq. 14's PNC one-hot override.

    For frozen groups the ratio is the frozen one-hot (stop-gradient by
    construction: the one-hot does not depend on ``z``); unfrozen groups
    use ``softmax(z)``.
    """
    n = z.shape[-1]
    soft = jax.nn.softmax(z, axis=-1)
    hot = jax.nn.one_hot(frozen_idx, n, dtype=jnp.float32)
    f = frozen.astype(jnp.float32)[:, None]
    return soft * (1.0 - f) + hot * f


def student_params(
    z: jnp.ndarray,
    frozen: jnp.ndarray,
    frozen_idx: jnp.ndarray,
    assign: jnp.ndarray,
    codebook: jnp.ndarray,
    other: dict,
    layout: Layout,
) -> dict:
    """Full parameter dict with compressed weights VQ-reconstructed.

    The decode runs through the Pallas reconstruct kernel (Eq. 8) and is
    differentiable w.r.t. ``z`` and pass-through for ``other``.
    """
    r = effective_ratios(z, frozen, frozen_idx)
    flat = pk_reconstruct.reconstruct(codebook, assign, r)
    params = dict(other)
    params.update(weights_from_flat(flat, layout))
    return params


def hard_codes(
    z: jnp.ndarray, frozen: jnp.ndarray, frozen_idx: jnp.ndarray, assign: jnp.ndarray
) -> jnp.ndarray:
    """Collapse to final codeword ids: frozen slot if set, else argmax(z).

    This is the construction output (Algorithm 1's optimal assignments A):
    ``codes[s] = assign[s, frozen_idx[s]]`` if frozen else
    ``assign[s, argmax_m z[s, m]]``.
    """
    slot = jnp.where(frozen > 0.5, frozen_idx, jnp.argmax(z, axis=-1)).astype(jnp.int32)
    return jnp.take_along_axis(assign, slot[:, None], axis=1)[:, 0]


def hard_params(
    codes: jnp.ndarray, codebook: jnp.ndarray, other: dict, layout: Layout
) -> dict:
    """Parameter dict decoded from final codes (Eq. 2) — inference form."""
    flat = pk_reconstruct.hard_reconstruct(codebook, codes)
    params = dict(other)
    params.update(weights_from_flat(flat, layout))
    return params
