"""The network zoo and VQ4ALL configuration — single source of truth.

Everything the Rust coordinator needs to know about the compression
campaign (which networks exist, their layer tables, the universal-codebook
geometry ``(k, d)``, candidate count ``n``, the PNC threshold ``alpha``)
originates here and is exported into ``artifacts/manifest.json`` by
``aot.py``.  Rust never re-derives any of it.

Paper-scale vs container-scale
------------------------------
The paper runs ResNet-18/50, MobileNet-V2, Mask-RCNN and Stable Diffusion
with codebooks up to ``2^16 x 32``; this container is CPU-only with Pallas
in interpret mode, so the default profile scales every axis down while
keeping the *structure* identical (see DESIGN.md §2).  The paper-exact
codebook arithmetic (Table 1) is computed closed-form in Rust and does not
need these networks.  Profiles:

* ``default`` — the CI/bench profile: five mini networks, k=256, d=4, n=8.
* ``large``   — closer to paper dynamics: k=4096, d=4, n=64 (slower).

Select with ``VQ4ALL_PROFILE=large python -m compile.aot``.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class VqConfig:
    """Universal-codebook and construction hyper-parameters (§5)."""

    k: int  # number of codewords
    d: int  # sub-vector length
    n: int  # candidate assignments per sub-vector
    alpha: float = 0.9999  # PNC freeze threshold (Eq. 14)
    bandwidth: float = 0.01  # KDE bandwidth h (Eq. 3)
    lr_ratios: float = 3e-1  # Adamax lr on ratio logits (§5)
    lr_other: float = 1e-3  # Adam lr on bias / norm parameters (§5.1)
    samples_per_net: int = 2560  # sub-vectors sampled per net for the KDE
    # = 10 * k * d in the paper; scaled with k here.

    @property
    def bits_per_group(self) -> float:
        """Assignment storage cost: log2(k) bits per d weights (§3.1)."""
        import math

        return math.log2(self.k)

    @property
    def effective_bit(self) -> float:
        """Ideal per-weight bit width log2(k)/d (Table 1's 'Bit')."""
        return self.bits_per_group / self.d


@dataclasses.dataclass(frozen=True)
class NetSpec:
    """One member of the zoo."""

    name: str
    task: str  # classify | detect | denoise
    arch: str  # constructor key in nets.py
    input_shape: tuple[int, ...]  # per-sample, e.g. (16, 16, 3)
    num_classes: int
    pretrain_steps: int
    pretrain_lr: float
    calib_size: int
    test_size: int
    batch: int  # calibration batch size (static in the AOT step)
    eval_batch: int  # eval batch size (static)
    seed: int


def _profile() -> str:
    return os.environ.get("VQ4ALL_PROFILE", "default")


def vq_config(profile: str | None = None) -> VqConfig:
    p = profile or _profile()
    if p == "default":
        return VqConfig(k=256, d=4, n=8)
    if p == "large":
        return VqConfig(k=4096, d=4, n=64)
    raise ValueError(f"unknown VQ4ALL_PROFILE {p!r}")


# The five-network zoo mirrors the paper's §5 line-up:
#   ResNet-18 / ResNet-50 / MobileNet-V2  -> mini_resnet18/50, mini_mobilenet
#   Mask-RCNN R-50 FPN                    -> mini_detector
#   Stable Diffusion v1-4                 -> mini_denoiser
# plus mini_mlp as the quickstart / smoke target.
ZOO: tuple[NetSpec, ...] = (
    NetSpec(
        name="mini_mlp",
        task="classify",
        arch="mlp",
        input_shape=(16, 16, 3),
        num_classes=10,
        pretrain_steps=800,
        pretrain_lr=1e-3,
        calib_size=512,
        test_size=1000,
        batch=32,
        eval_batch=100,
        seed=101,
    ),
    NetSpec(
        name="mini_resnet18",
        task="classify",
        arch="resnet18",
        input_shape=(16, 16, 3),
        num_classes=10,
        pretrain_steps=1000,
        pretrain_lr=2e-3,
        calib_size=512,
        test_size=1000,
        batch=32,
        eval_batch=100,
        seed=102,
    ),
    NetSpec(
        name="mini_resnet50",
        task="classify",
        arch="resnet50",
        input_shape=(16, 16, 3),
        num_classes=10,
        pretrain_steps=1500,
        pretrain_lr=1e-3,
        calib_size=512,
        test_size=1000,
        batch=32,
        eval_batch=100,
        seed=103,
    ),
    NetSpec(
        name="mini_mobilenet",
        task="classify",
        arch="mobilenet",
        input_shape=(16, 16, 3),
        num_classes=10,
        pretrain_steps=1500,
        pretrain_lr=1e-3,
        calib_size=512,
        test_size=1000,
        batch=32,
        eval_batch=100,
        seed=104,
    ),
    NetSpec(
        name="mini_detector",
        task="detect",
        arch="detector",
        input_shape=(24, 24, 3),
        num_classes=3,  # shape classes: square / circle / cross
        pretrain_steps=1200,
        pretrain_lr=2e-3,
        calib_size=1500,
        test_size=500,
        batch=16,
        eval_batch=50,
        seed=105,
    ),
    NetSpec(
        name="mini_denoiser",
        task="denoise",
        arch="denoiser",
        input_shape=(2,),  # 2-D diffusion on an 8-mode Gaussian mixture
        num_classes=0,
        pretrain_steps=800,
        pretrain_lr=2e-3,
        calib_size=2048,
        test_size=2048,
        batch=128,
        eval_batch=256,
        seed=106,
    ),
)


def zoo_by_name() -> dict[str, NetSpec]:
    return {s.name: s for s in ZOO}


def get_net(name: str) -> NetSpec:
    try:
        return zoo_by_name()[name]
    except KeyError as e:
        raise KeyError(f"unknown network {name!r}; zoo = {[s.name for s in ZOO]}") from e


def zoo_names(subset: Sequence[str] | None = None) -> list[str]:
    names = [s.name for s in ZOO]
    if subset is None:
        return names
    for s in subset:
        if s not in names:
            raise KeyError(f"unknown network {s!r}; zoo = {names}")
    return list(subset)
