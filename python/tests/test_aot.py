"""AOT boundary checks: manifest consistency, `.vqt` round-trip, HLO
text properties, and that lowered step functions numerically match their
un-lowered python originals on the artifacts actually shipped.

These tests need `make artifacts` to have run; they skip otherwise.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import tensorio, train, vqlayers, zoo

ART = Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_config_matches_zoo(manifest):
    cfg = zoo.vq_config()
    mc = manifest["config"]
    assert mc["k"] == cfg.k and mc["d"] == cfg.d and mc["n"] == cfg.n
    assert mc["alpha"] == cfg.alpha
    assert mc["effective_bit"] == pytest.approx(cfg.effective_bit)


def test_manifest_covers_zoo(manifest):
    names = {n["name"] for n in manifest["networks"]}
    assert names == set(zoo.zoo_names())


def test_every_referenced_file_exists(manifest):
    for net in manifest["networks"]:
        for espec in net["executables"].values():
            assert (ART / espec["hlo"]).exists(), espec["hlo"]
        for fname in net["data"].values():
            assert (ART / fname).exists(), fname
    assert (ART / manifest["codebook"]).exists()


def test_layer_tables_tile_s_total(manifest):
    for net in manifest["networks"]:
        groups = sum(l["groups"] for l in net["layers"])
        assert groups == net["s_total"], net["name"]
        spec = zoo.get_net(net["name"])
        fns = train.make_step_fns(spec, zoo.vq_config())
        assert fns.s_total == net["s_total"], f"{net['name']}: layout drifted from manifest"


def test_state_specs_match_step_factory(manifest):
    cfg = zoo.vq_config()
    for net in manifest["networks"]:
        fns = train.make_step_fns(zoo.get_net(net["name"]), cfg)
        want = [
            {"name": nm, "shape": list(sh), "dtype": dt}
            for nm, sh, dt in fns.state_specs()
        ]
        assert net["state_specs"] == want, f"{net['name']}: state specs drifted"


def test_codebook_tensor_geometry(manifest):
    cb = tensorio.read_tensor(ART / manifest["codebook"])
    cfg = manifest["config"]
    assert cb.shape == (cfg["k"], cfg["d"])
    assert cb.dtype == np.float32
    assert np.isfinite(cb).all()


def test_teacher_flat_matches_layer_table(manifest):
    for net in manifest["networks"]:
        flat = tensorio.read_tensor(ART / net["data"]["teacher_flat"])
        assert flat.shape == (net["s_total"], manifest["config"]["d"])


def test_vqt_roundtrip_tmpdir(tmp_path):
    for arr in [
        np.arange(12, dtype=np.float32).reshape(3, 4),
        np.asarray([[1, -2], [3, 4]], np.int32),
        np.zeros((0,), np.float32),
        np.random.default_rng(0).normal(size=(2, 3, 4)).astype(np.float32),
    ]:
        p = tmp_path / "t.vqt"
        tensorio.write_tensor(p, arr)
        back = tensorio.read_tensor(p)
        assert back.dtype == arr.dtype
        assert back.shape == arr.shape
        assert_allclose(back, arr)


def test_hlo_text_is_parseable_entry_module(manifest):
    """Every artifact must be HLO text with an ENTRY computation (the
    format the Rust loader's HloModuleProto::from_text_file expects)."""
    for net in manifest["networks"]:
        for tag, espec in net["executables"].items():
            text = (ART / espec["hlo"]).read_text()
            assert "HloModule" in text.splitlines()[0], f"{net['name']}:{tag}"
            assert "ENTRY" in text, f"{net['name']}:{tag} has no entry"


def test_eval_hard_artifact_matches_python(manifest):
    """Execute the lowered eval_hard for mini_mlp via jax and compare to
    the un-lowered python function — the same check Rust relies on."""
    cfg = zoo.vq_config()
    spec = zoo.get_net("mini_mlp")
    net_m = next(n for n in manifest["networks"] if n["name"] == "mini_mlp")
    fns = train.make_step_fns(spec, cfg)

    s = net_m["s_total"]
    rng = np.random.default_rng(5)
    codes = rng.integers(0, cfg.k, s).astype(np.int32)
    cb = tensorio.read_tensor(ART / manifest["codebook"])
    others = [
        tensorio.read_tensor(ART / net_m["data"][f"teacher_other_{i}"])
        for i in range(len(net_m["others"]))
    ]
    tx = tensorio.read_tensor(ART / net_m["data"]["test_x"])[: spec.eval_batch]
    ty = tensorio.read_tensor(ART / net_m["data"]["test_y"])[: spec.eval_batch]

    args = [jnp.asarray(codes)] + [jnp.asarray(o) for o in others] + [
        jnp.asarray(cb), jnp.asarray(tx), jnp.asarray(ty)
    ]
    direct = np.asarray(fns.eval_hard(*args))
    assert direct.shape == (2,)
    assert np.isfinite(direct).all()
    # hit count within [0, batch]
    assert 0.0 <= direct[1] <= spec.eval_batch


def test_float_metrics_are_in_healthy_band(manifest):
    """Difficulty calibration guard: classification nets should sit in
    ~[0.65, 0.995] float accuracy (MobileNet sits lowest, mirroring the paper) — high enough to be a real model, low
    enough that compression damage is visible (see data.py docstring)."""
    for net in manifest["networks"]:
        if net["task"] != "classify":
            continue
        m = net["float_metric"]
        assert 0.60 <= m <= 0.998, f"{net['name']}: float acc {m} out of band"
