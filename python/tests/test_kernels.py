"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps randomized shapes/dtypes/tile sizes so the padding and
BlockSpec logic is exercised off the happy path (non-divisible sizes,
single-row inputs, tiles larger than the array, bf16 inputs).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile import kernels as K

SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

FLOAT_DTYPES = st.sampled_from([np.float32, np.float16])


def rng_for(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- distance


@given(
    s=st.integers(1, 300),
    k=st.integers(1, 200),
    d=st.sampled_from([1, 2, 3, 4, 8, 16]),
    bs=st.sampled_from([1, 7, 64, 128]),
    bk=st.sampled_from([1, 13, 256, 512]),
    dtype=FLOAT_DTYPES,
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_distance_matches_ref(s, k, d, bs, bk, dtype, seed):
    rng = rng_for(seed)
    w = rng.normal(size=(s, d)).astype(dtype)
    c = rng.normal(size=(k, d)).astype(dtype)
    got = K.distance.pairwise_sq_dist(w, c, block_s=bs, block_k=bk)
    want = K.ref.pairwise_sq_dist(jnp.asarray(w), jnp.asarray(c))
    assert got.shape == (s, k)
    assert got.dtype == jnp.float32
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_distance_zero_for_identical_vectors():
    w = np.tile(np.arange(6, dtype=np.float32).reshape(1, 6), (4, 1))
    d = np.asarray(K.distance.pairwise_sq_dist(w, w))
    assert_allclose(np.diag(d), 0.0, atol=1e-5)
    assert (d >= 0).all(), "squared distances must be non-negative"


@given(
    s=st.integers(1, 120),
    k=st.integers(2, 100),
    n=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_topn_matches_ref(s, k, n, seed):
    n = min(n, k)
    rng = rng_for(seed)
    w = rng.normal(size=(s, 4)).astype(np.float32)
    c = rng.normal(size=(k, 4)).astype(np.float32)
    a, sq = K.distance.topn_candidates(w, c, n)
    a2, sq2 = K.ref.topn_candidates(jnp.asarray(w), jnp.asarray(c), n)
    # Distances must agree exactly in ordering terms; indices can differ
    # only where distances tie.
    assert_allclose(np.asarray(sq), np.asarray(sq2), rtol=1e-5, atol=1e-5)
    sq_np = np.asarray(sq)
    assert (np.diff(sq_np, axis=1) >= -1e-6).all(), "candidates must be sorted by distance"
    # Candidate 0 must be the true argmin.
    full = np.asarray(K.ref.pairwise_sq_dist(jnp.asarray(w), jnp.asarray(c)))
    assert_allclose(sq_np[:, 0], full.min(axis=1), rtol=1e-5, atol=1e-5)


def test_topn_rejects_bad_n():
    w = np.zeros((3, 2), np.float32)
    c = np.zeros((4, 2), np.float32)
    with pytest.raises(ValueError):
        K.distance.topn_candidates(w, c, 5)
    with pytest.raises(ValueError):
        K.distance.topn_candidates(w, c, 0)


# ------------------------------------------------------------- reconstruct


@given(
    s=st.integers(1, 400),
    k=st.integers(1, 80),
    d=st.sampled_from([1, 2, 4, 8]),
    n=st.integers(1, 16),
    bs=st.sampled_from([1, 5, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_reconstruct_matches_ref(s, k, d, n, bs, seed):
    rng = rng_for(seed)
    cb = rng.normal(size=(k, d)).astype(np.float32)
    a = rng.integers(0, k, size=(s, n)).astype(np.int32)
    z = rng.normal(size=(s, n)).astype(np.float32)
    r = np.asarray(jax.nn.softmax(jnp.asarray(z), axis=-1))
    got = K.reconstruct.reconstruct(cb, a, r, block_s=bs)
    want = K.ref.reconstruct(jnp.asarray(cb), jnp.asarray(a), jnp.asarray(r))
    assert got.shape == (s, d)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_reconstruct_one_hot_equals_hard_decode():
    """reconstruct with one-hot ratios == plain codebook lookup (Eq. 14)."""
    rng = rng_for(7)
    cb = rng.normal(size=(19, 4)).astype(np.float32)
    a = rng.integers(0, 19, size=(33, 6)).astype(np.int32)
    hot = rng.integers(0, 6, size=(33,))
    r = np.zeros((33, 6), np.float32)
    r[np.arange(33), hot] = 1.0
    got = np.asarray(K.reconstruct.reconstruct(cb, a, r))
    want = cb[a[np.arange(33), hot]]
    assert_allclose(got, want, rtol=0, atol=0)


def test_reconstruct_grad_matches_ref():
    """Autodiff through the interpret-mode kernel == autodiff through ref."""
    rng = rng_for(3)
    cb = jnp.asarray(rng.normal(size=(11, 4)).astype(np.float32))
    a = jnp.asarray(rng.integers(0, 11, size=(40, 5)).astype(np.int32))
    z = jnp.asarray(rng.normal(size=(40, 5)).astype(np.float32))

    def loss_kernel(z):
        r = jax.nn.softmax(z, axis=-1)
        return jnp.sum(K.reconstruct.reconstruct(cb, a, r) ** 2)

    def loss_ref(z):
        r = jax.nn.softmax(z, axis=-1)
        return jnp.sum(K.ref.reconstruct(cb, a, r) ** 2)

    g1 = jax.grad(loss_kernel)(z)
    g2 = jax.grad(loss_ref)(z)
    assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-5)


def test_hard_reconstruct_matches_ref():
    rng = rng_for(11)
    cb = rng.normal(size=(23, 8)).astype(np.float32)
    codes = rng.integers(0, 23, size=(77,)).astype(np.int32)
    got = np.asarray(K.reconstruct.hard_reconstruct(cb, codes))
    want = np.asarray(K.ref.hard_reconstruct(jnp.asarray(cb), jnp.asarray(codes)))
    assert_allclose(got, want, rtol=0, atol=0)


# -------------------------------------------------------------- vq_matmul


@given(
    b=st.integers(1, 70),
    o=st.integers(1, 150),
    g=st.integers(1, 32),
    d=st.sampled_from([1, 2, 4, 8]),
    k=st.integers(1, 64),
    bb=st.sampled_from([1, 8, 64]),
    bo=st.sampled_from([1, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_vq_matmul_matches_ref(b, o, g, d, k, bb, bo, seed):
    rng = rng_for(seed)
    x = rng.normal(size=(b, g * d)).astype(np.float32)
    codes = rng.integers(0, k, size=(o, g)).astype(np.int32)
    cb = rng.normal(size=(k, d)).astype(np.float32)
    got = K.vq_matmul.vq_matmul(x, codes, cb, block_b=bb, block_o=bo)
    want = K.ref.vq_matmul(jnp.asarray(x), jnp.asarray(codes), jnp.asarray(cb))
    assert got.shape == (b, o)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_vq_matmul_equals_dense_matmul_on_decoded_weights():
    """Fused kernel == decode-then-dense-matmul (the bandwidth story only
    changes *where* the decode happens, never the numbers)."""
    rng = rng_for(5)
    cb = rng.normal(size=(32, 4)).astype(np.float32)
    codes = rng.integers(0, 32, size=(24, 16)).astype(np.int32)
    x = rng.normal(size=(10, 64)).astype(np.float32)
    w = cb[codes].reshape(24, 64)
    want = x @ w.T
    got = np.asarray(K.vq_matmul.vq_matmul(x, codes, cb))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_vq_matmul_rejects_shape_mismatch():
    x = np.zeros((2, 9), np.float32)  # 9 not divisible into g*d=8
    codes = np.zeros((3, 2), np.int32)
    cb = np.zeros((4, 4), np.float32)
    with pytest.raises(ValueError):
        K.vq_matmul.vq_matmul(x, codes, cb)


# -------------------------------------------------------------------- kde


@given(
    q=st.integers(1, 150),
    n=st.integers(1, 400),
    d=st.sampled_from([1, 2, 4, 8]),
    h=st.sampled_from([0.01, 0.1, 0.5, 1.0]),
    bq=st.sampled_from([1, 32, 256]),
    bn=st.sampled_from([1, 50, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_kde_matches_ref(q, n, d, h, bq, bn, seed):
    rng = rng_for(seed)
    queries = rng.normal(size=(q, d)).astype(np.float32)
    samples = rng.normal(size=(n, d)).astype(np.float32)
    got = K.kde.kde_density(queries, samples, h, block_q=bq, block_n=bn)
    want = K.ref.kde_density(jnp.asarray(queries), jnp.asarray(samples), h)
    assert got.shape == (q,)
    # The kernel's MXU form ||q||^2 - 2 q.s + ||s||^2 rounds the squared
    # distance at ~1e-7 absolute (fp32 cancellation when q ~ s); the
    # exponent amplifies that by 1/(2h^2), so the density's relative error
    # scales like eps_sq / (2 h^2).  Tolerance follows that model (h=0.01
    # -> ~2.5e-3) with the generic fp32 floor at 1e-4.
    rtol = max(1e-4, 5e-7 / (2.0 * h * h))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=1e-6)


def test_kde_density_is_nonnegative_and_peaks_at_data():
    rng = rng_for(9)
    samples = rng.normal(size=(200, 2)).astype(np.float32) * 0.1
    on_data = np.asarray(K.kde.kde_density(samples[:10], samples, 0.1))
    far = np.asarray(K.kde.kde_density(np.full((10, 2), 50.0, np.float32), samples, 0.1))
    assert (on_data >= 0).all() and (far >= 0).all()
    assert on_data.mean() > far.mean() * 1e3, "density must concentrate near data"


def test_kde_integrates_to_one_1d():
    """1-D sanity: trapezoid integral of the density ~ 1."""
    rng = rng_for(13)
    samples = rng.normal(size=(500, 1)).astype(np.float32)
    grid = np.linspace(-6, 6, 2001, dtype=np.float32)[:, None]
    dens = np.asarray(K.kde.kde_density(grid, samples, 0.3))
    integral = np.trapezoid(dens, grid[:, 0])
    assert abs(integral - 1.0) < 1e-2


def test_kde_rejects_bad_bandwidth():
    q = np.zeros((2, 2), np.float32)
    s = np.zeros((3, 2), np.float32)
    with pytest.raises(ValueError):
        K.kde.kde_density(q, s, 0.0)


# ------------------------------------------------------------- ratio math


@given(
    s=st.integers(1, 100),
    n=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_ratio_logit_init_orders_by_distance(s, n, seed):
    """Eq. 7: softmax of the init logits must be ~ proportional to 1/d^2
    and put the largest ratio on the nearest candidate."""
    rng = rng_for(seed)
    sq = np.sort(rng.uniform(0.01, 4.0, size=(s, n)).astype(np.float32), axis=1)
    z = np.asarray(K.ref.init_ratio_logits(jnp.asarray(sq)))
    r = np.asarray(K.ref.ratios_from_logits(jnp.asarray(z)))
    assert_allclose(r.sum(axis=1), 1.0, rtol=1e-5)
    assert (np.argmax(r, axis=1) == 0).all(), "nearest candidate must dominate"
    # r_m proportional to 1/sq_m:  r_m * sq_m constant per row.
    prod = r * sq
    assert_allclose(prod, np.broadcast_to(prod[:, :1], prod.shape), rtol=1e-3)


def test_ratio_regularizer_zero_iff_one_hot():
    r = np.zeros((5, 4), np.float32)
    r[:, 2] = 1.0
    assert float(K.ref.ratio_regularizer(jnp.asarray(r))) == 0.0
    r_soft = np.full((5, 4), 0.25, np.float32)
    assert float(K.ref.ratio_regularizer(jnp.asarray(r_soft))) > 0.0


def test_ratio_regularizer_respects_unset_mask():
    r = np.full((4, 2), 0.5, np.float32)
    mask = np.array([1, 0, 0, 0], np.float32)
    full = float(K.ref.ratio_regularizer(jnp.asarray(r)))
    partial = float(K.ref.ratio_regularizer(jnp.asarray(r), jnp.asarray(mask)))
    assert_allclose(partial, full / 4.0, rtol=1e-6)
