"""L2 correctness: zoo models, sub-vector layout, losses, optimizers,
codebook sampling, datasets — everything below the AOT boundary that
does not need built artifacts."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile import codebook as cb_mod
from compile import data, losses, optim, vqlayers, zoo
from compile.nets import build_net, channel_norm

SETTINGS = dict(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


# ------------------------------------------------------------------ models


@pytest.mark.parametrize("spec", zoo.ZOO, ids=[s.name for s in zoo.ZOO])
def test_zoo_forward_shapes(spec):
    net = build_net(spec)
    b = 2
    if spec.task == "denoise":
        x = jnp.zeros((b, 3), jnp.float32)  # (x, y, t)
        out, feats = net.forward(net.params, x)
        assert out.shape == (b, 2)
    elif spec.task == "detect":
        x = jnp.zeros((b, *spec.input_shape), jnp.float32)
        out, feats = net.forward(net.params, x)
        assert out.ndim == 4 and out.shape[0] == b
        assert out.shape[-1] >= 4 + spec.num_classes
    else:
        x = jnp.zeros((b, *spec.input_shape), jnp.float32)
        out, feats = net.forward(net.params, x)
        assert out.shape == (b, spec.num_classes)
    assert len(feats) >= 1, "block features required for L_kd"
    for f in feats:
        assert f.shape[0] == b


@pytest.mark.parametrize("spec", zoo.ZOO, ids=[s.name for s in zoo.ZOO])
def test_zoo_param_partition(spec):
    """Compressed layers + 'others' partition the parameter dict."""
    net = build_net(spec)
    compressed = {l.name for l in net.compressed_layers()}
    others = set(net.other_names())
    assert compressed.isdisjoint(others)
    assert compressed | others == set(net.params.keys())
    assert compressed, f"{spec.name}: nothing to compress"


@pytest.mark.parametrize("spec", zoo.ZOO, ids=[s.name for s in zoo.ZOO])
def test_layout_tiles_all_compressed_weights(spec):
    net = build_net(spec)
    cfg = zoo.vq_config()
    layout = vqlayers.make_layout(net, cfg.d)
    total = sum(np.prod(net.params[l.name].shape) for l in net.compressed_layers())
    assert layout.s_total * cfg.d == total
    # Slices are contiguous and non-overlapping.
    off = 0
    for s in layout.slices:
        assert s.offset == off
        off += s.groups


@pytest.mark.parametrize("spec", zoo.ZOO, ids=[s.name for s in zoo.ZOO])
def test_extract_then_rebuild_is_identity(spec):
    net = build_net(spec)
    cfg = zoo.vq_config()
    layout = vqlayers.make_layout(net, cfg.d)
    flat = vqlayers.extract_subvectors(net.params, layout)
    assert flat.shape == (layout.s_total, cfg.d)
    rebuilt = vqlayers.weights_from_flat(flat, layout)
    for name, w in rebuilt.items():
        assert_allclose(np.asarray(w), np.asarray(net.params[name]), rtol=0, atol=0)


def test_channel_norm_normalizes():
    x = jnp.asarray(np.random.default_rng(0).normal(3.0, 2.0, (8, 16)).astype(np.float32))
    y = channel_norm(x, jnp.ones((16,)), jnp.zeros((16,)))
    assert abs(float(y.mean())) < 1e-3
    assert abs(float(y.std()) - 1.0) < 5e-2


# ----------------------------------------------------------------- vqlayers


@given(
    s=st.integers(1, 40),
    n=st.integers(2, 8),
    k=st.integers(8, 64),
    d=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_effective_ratios_onehot_when_frozen(s, n, k, d, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(s, n)).astype(np.float32))
    frozen = jnp.asarray((rng.random(s) < 0.5).astype(np.float32))
    frozen_idx = jnp.asarray(rng.integers(0, n, s).astype(np.int32))
    r = np.asarray(vqlayers.effective_ratios(z, frozen, frozen_idx))
    soft = np.asarray(jax.nn.softmax(z, -1))
    for g in range(s):
        assert_allclose(r[g].sum(), 1.0, rtol=1e-5)
        if frozen[g] > 0.5:
            expect = np.zeros(n, np.float32)
            expect[int(frozen_idx[g])] = 1.0
            assert_allclose(r[g], expect, atol=0)
        else:
            assert_allclose(r[g], soft[g], rtol=1e-6)


@given(
    s=st.integers(1, 40),
    n=st.integers(2, 8),
    k=st.integers(8, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_hard_codes_frozen_slot_wins(s, n, k, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(s, n)).astype(np.float32))
    assign = jnp.asarray(rng.integers(0, k, (s, n)).astype(np.int32))
    frozen = jnp.asarray((rng.random(s) < 0.5).astype(np.float32))
    frozen_idx = jnp.asarray(rng.integers(0, n, s).astype(np.int32))
    codes = np.asarray(vqlayers.hard_codes(z, frozen, frozen_idx, assign))
    a = np.asarray(assign)
    for g in range(s):
        slot = int(frozen_idx[g]) if frozen[g] > 0.5 else int(np.argmax(np.asarray(z)[g]))
        assert codes[g] == a[g, slot]


def test_frozen_groups_get_no_gradient():
    """PNC stop-gradient: dL/dz must vanish on frozen groups (Eq. 14)."""
    rng = np.random.default_rng(1)
    s, n, k, d = 6, 4, 16, 2
    z = jnp.asarray(rng.normal(size=(s, n)).astype(np.float32))
    assign = jnp.asarray(rng.integers(0, k, (s, n)).astype(np.int32))
    cb = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    frozen = jnp.asarray(np.array([1, 0, 1, 0, 0, 1], np.float32))
    frozen_idx = jnp.zeros((s,), jnp.int32)

    def loss(z):
        r = vqlayers.effective_ratios(z, frozen, frozen_idx)
        from compile.kernels import ref as pk_ref

        w = pk_ref.reconstruct(cb, assign, r)
        return jnp.sum(w**2)

    g = np.asarray(jax.grad(loss)(z))
    for gi in range(s):
        if frozen[gi] > 0.5:
            assert_allclose(g[gi], 0.0, atol=0)
        else:
            assert np.abs(g[gi]).sum() > 0


# ------------------------------------------------------------------- losses


def test_ratio_regularizer_zero_iff_onehot():
    one_hot = jnp.asarray(np.eye(4, dtype=np.float32)[[0, 1, 3]])
    assert float(losses.ratio_regularizer(one_hot)) == 0.0
    soft = jnp.full((3, 4), 0.25, jnp.float32)
    assert float(losses.ratio_regularizer(soft)) > 0.1


def test_ratio_regularizer_respects_unset_mask():
    soft = jnp.full((2, 4), 0.25, jnp.float32)
    full = float(losses.ratio_regularizer(soft))
    half = float(losses.ratio_regularizer(soft, jnp.asarray([1.0, 0.0])))
    assert_allclose(half, full / 2.0, rtol=1e-6)


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 3.0, 0.0]])
    labels = jnp.asarray([0, 1])
    got = float(losses.cross_entropy(logits, labels))
    want = float(-np.mean(np.log([
        np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0)),
        np.exp(3.0) / (np.exp(3.0) + 2),
    ])))
    assert_allclose(got, want, rtol=1e-6)


def test_kd_loss_zero_for_identical_features():
    feats = [jnp.ones((2, 8)), jnp.zeros((2, 4))]
    assert float(losses.kd_loss(feats, feats)) == 0.0
    other = [f + 1.0 for f in feats]
    assert float(losses.kd_loss(feats, other)) > 0.5


def test_detect_loss_perfect_prediction_is_small():
    g = 4
    t = np.zeros((2, g, g, 5), np.float32)
    t[0, 1, 2] = [1.0, 0.5, 0.5, 0.1, 1.0]
    t[1, 0, 0] = [1.0, 0.2, 0.8, 0.2, 2.0]
    pred = np.zeros((2, g, g, 4 + 3), np.float32)
    pred[..., 0] = -20.0  # no object anywhere...
    for b, (gy, gx) in enumerate([(1, 2), (0, 0)]):
        pred[b, gy, gx, 0] = 20.0
        pred[b, gy, gx, 1:4] = t[b, gy, gx, 1:4]
        pred[b, gy, gx, 4 + int(t[b, gy, gx, 4])] = 20.0
    l = float(losses.detect_loss(jnp.asarray(pred), jnp.asarray(t)))
    assert l < 1e-3, f"perfect prediction should have ~0 loss, got {l}"
    hits = float(losses.detect_hits(jnp.asarray(pred), jnp.asarray(t)))
    assert hits == 2.0


# ---------------------------------------------------------------- optimizers


def test_adamax_converges_on_quadratic():
    p = jnp.asarray([5.0, -3.0])
    m = jnp.zeros(2)
    u = jnp.zeros(2)
    for t in range(1, 200):
        g = 2.0 * p
        p, m, u = optim.adamax_update(p, g, m, u, jnp.float32(t), 0.1)
    assert float(jnp.abs(p).max()) < 0.05


def test_adam_converges_on_quadratic():
    p = jnp.asarray([4.0])
    m = jnp.zeros(1)
    v = jnp.zeros(1)
    for t in range(1, 300):
        p, m, v = optim.adam_update(p, 2.0 * p, m, v, jnp.float32(t), 0.05)
    assert float(jnp.abs(p).max()) < 0.05


def test_cosine_lr_endpoints():
    assert float(optim.cosine_lr(1.0, jnp.float32(0), 100)) == 1.0
    assert float(optim.cosine_lr(1.0, jnp.float32(100), 100)) < 1e-6
    mid = float(optim.cosine_lr(1.0, jnp.float32(50), 100))
    assert_allclose(mid, 0.5, atol=1e-6)


# ------------------------------------------------------------------ codebook


def test_kde_codebook_stats_follow_pool():
    rng = np.random.default_rng(0)
    flats = [rng.normal(0.0, 0.1, (5000, 4)).astype(np.float32)]
    cb, pool = cb_mod.build_universal_codebook(flats, k=512, d=4, bandwidth=0.01, per_net=2000)
    assert cb.shape == (512, 4)
    assert pool.shape == (2000, 4)
    # KDE sample mean/std must track the pool within sampling error.
    assert abs(cb.mean() - pool.mean()) < 0.02
    assert abs(cb.std() / pool.std() - 1.0) < 0.2


def test_sample_subvectors_equal_counts_and_small_net_replacement():
    rng = np.random.default_rng(1)
    big = rng.normal(size=(1000, 4)).astype(np.float32)
    small = rng.normal(size=(10, 4)).astype(np.float32)
    pool = cb_mod.sample_subvectors([big, small], per_net=64)
    assert pool.shape == (128, 4)
    # Second half comes from the small net (with replacement).
    small_rows = {tuple(r) for r in small}
    assert all(tuple(r) in small_rows for r in pool[64:])


# ------------------------------------------------------------------ datasets


def test_synth_imagenet_split_discipline():
    """Same template seed + different sample seed = same classes, new
    samples (the train/test relationship)."""
    x1, y1 = data.synth_imagenet(64, seed=0)
    x2, y2 = data.synth_imagenet(64, seed=1)
    assert x1.shape == (64, 16, 16, 3)
    assert not np.allclose(x1, x2)
    assert set(np.unique(y1)) <= set(range(10))
    # Determinism.
    x1b, y1b = data.synth_imagenet(64, seed=0)
    assert_allclose(x1, x1b)
    assert (y1 == y1b).all()


def test_synth_imagenet_is_not_saturating_easy():
    """The class templates share a common component — nearest-template
    classification on raw pixels must NOT be perfect (difficulty
    calibration; see data.py docstring)."""
    x, y = data.synth_imagenet(400, seed=3)
    # Build per-class means from an independent split and classify.
    xt, yt = data.synth_imagenet(2000, seed=4)
    means = np.stack([xt[yt == c].mean(0) for c in range(10)])
    pred = np.argmin(
        ((x[:, None] - means[None]) ** 2).sum((2, 3, 4)), axis=1
    )
    acc = (pred == y).mean()
    assert 0.3 < acc < 0.995, f"template-matching acc {acc}: dataset difficulty drifted"


def test_synth_shapes_targets_consistent():
    x, t = data.synth_shapes(32, hw=24, grid=4, seed=0)
    assert x.shape == (32, 24, 24, 3)
    assert t.shape == (32, 4, 4, 5)
    obj = t[..., 0]
    assert (obj.sum(axis=(1, 2)) == 1.0).all(), "exactly one object per image"
    on = t[obj > 0.5]
    assert ((on[:, 1:3] >= 0.0) & (on[:, 1:3] <= 1.0)).all(), "cell offsets in [0,1]"
    assert set(np.unique(on[:, 4])) <= {0.0, 1.0, 2.0}


def test_gmm2d_modes_on_circle():
    pts = data.gmm2d(4000, seed=0)
    r = np.linalg.norm(pts, axis=1)
    assert abs(r.mean() - 2.0) < 0.1, "modes sit on the radius-2 circle"
    # All 8 sectors populated.
    ang = np.arctan2(pts[:, 1], pts[:, 0])
    sectors = np.unique((np.round(ang / (2 * np.pi / 8)) % 8).astype(int))
    assert len(sectors) == 8


def test_diffusion_schedule_monotone():
    s = data.diffusion_schedule()
    assert (np.diff(s["betas"]) > 0).all()
    assert (np.diff(s["alpha_bars"]) < 0).all()
    assert_allclose(s["sqrt_abar"] ** 2 + s["sqrt_1m_abar"] ** 2, 1.0, rtol=1e-5)
