"""L1 structural performance analysis (EXPERIMENTS.md §Perf).

Interpret-mode wallclock is NOT a TPU proxy, so kernel performance is
estimated structurally from the BlockSpecs: VMEM footprint per grid step,
HBM traffic, arithmetic intensity, and the MXU-utilization ceiling implied
by tile geometry (MXU = 128x128 systolic; a (m, k) @ (k, n) tile uses the
array at min(m,128)/128 * min(n,128)/128 occupancy per pass, with k the
pipelined dimension).

Run: python -m tools.l1_analysis [--profile default|large|paper]
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass

VMEM_BUDGET = 16 * 1024 * 1024  # v4/v5e-class per-core VMEM, bytes
MXU = 128


@dataclass
class KernelCfg:
    name: str
    # tile dims and problem dims, all in elements
    vmem_bytes: int
    hbm_bytes_per_step: int
    flops_per_step: float
    mxu_m: int  # matmul tile rows
    mxu_n: int  # matmul tile cols
    mxu_k: int  # contraction length
    note: str


def mxu_util(m: int, n: int, k: int) -> float:
    """Occupancy ceiling of a (m,k)@(k,n) tile on a 128x128 MXU.

    Rows/cols below 128 leave array lanes idle; k only affects pipeline
    fill (negligible for k >= 64, modeled as k/(k+128) fill efficiency).
    """
    occ = min(m, MXU) / MXU * min(n, MXU) / MXU
    fill = k / (k + MXU)
    return occ * fill


def distance(s, k, d, bs, bk):
    vmem = 4 * (bs * d + bk * d + bs * bk)
    hbm = 4 * (bs * d + bk * d + bs * bk)  # in tiles + out tile
    flops = 2.0 * bs * bk * d
    return KernelCfg(
        f"distance (S={s}, K={k}, d={d}; tiles {bs}x{bk})",
        vmem, hbm, flops, bs, bk, d,
        "w-tile reused across K axis (inner grid dim)",
    )


def reconstruct(s, n, d, bsr):
    # candidate axis fully in VMEM; gather + weighted sum on VPU,
    # expressed as one-hot matmul for the MXU path when n small.
    vmem = 4 * (bsr * n + bsr * n + bsr * n * d + bsr * d)
    hbm = 4 * (bsr * n * 2 + bsr * d)
    flops = 2.0 * bsr * n * d
    return KernelCfg(
        f"reconstruct (S={s}, n={n}, d={d}; tile {bsr})",
        vmem, hbm, flops, bsr, d, n,
        "VPU-bound (gather+fma); MXU only via one-hot form",
    )


def vq_matmul(b, i, o, k, d, bb, bo):
    g = i // d
    cb_bytes = 4 * k * d
    vmem = 4 * (bb * i + bo * g + bo * i + bb * bo) + cb_bytes
    hbm_codes = 4 * bo * g  # codes streamed instead of weights
    hbm_dense = 4 * bo * i  # what a dense matmul would stream
    flops = 2.0 * bb * bo * i
    c = KernelCfg(
        f"vq_matmul (B={b}, I={i}, O={o}, K=2^{k.bit_length()-1}, d={d}; tiles {bb}x{bo})",
        vmem, hbm_codes + 4 * bb * i + 4 * bb * bo, flops, bb, bo, i,
        f"codebook pinned ({cb_bytes/1e6:.2f} MB); code stream = {hbm_codes/hbm_dense:.2%} of dense weight stream",
    )
    return c


def kde(q, n, d, bq, bn):
    vmem = 4 * (bq * d + bn * d + bn + bq + bq * bn)
    hbm = 4 * (bq * d + bn * d + bn + bq)
    flops = 2.0 * bq * bn * d + 6.0 * bq * bn  # dist + exp
    return KernelCfg(
        f"kde (Q={q}, N={n}, d={d}; tiles {bq}x{bn})",
        vmem, hbm, flops, bq, bn, d,
        "output tile revisited across sample axis (reduction grid)",
    )


def report(cfgs):
    print(f"{'kernel':<62} {'VMEM':>9} {'of budget':>9} {'AI':>7} {'MXU util':>9}")
    for c in cfgs:
        ai = c.flops_per_step / max(c.hbm_bytes_per_step, 1)
        print(
            f"{c.name:<62} {c.vmem_bytes/1e6:>7.2f}MB {c.vmem_bytes/VMEM_BUDGET:>8.1%} "
            f"{ai:>7.1f} {mxu_util(c.mxu_m, c.mxu_n, c.mxu_k):>9.1%}"
        )
        print(f"  └─ {c.note}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="default", choices=["default", "large", "paper"])
    args = ap.parse_args()

    if args.profile == "default":  # container build: k=256, d=4, n=8
        cfgs = [
            distance(57_344, 256, 4, 128, 256),
            reconstruct(57_344, 8, 4, 2048),
            vq_matmul(64, 768, 512, 256, 4, 64, 128),
            kde(256, 2560, 4, 256, 1024),
        ]
    elif args.profile == "large":  # k=4096, d=4, n=64
        cfgs = [
            distance(500_000, 4096, 4, 128, 512),
            reconstruct(500_000, 64, 4, 1024),
            vq_matmul(64, 4096, 4096, 4096, 4, 64, 128),
            kde(4096, 40_960, 4, 256, 1024),
        ]
    else:  # paper 2-bit config: k=2^16, d=8, n=64
        cfgs = [
            distance(1_400_000, 65_536, 8, 128, 512),
            reconstruct(1_400_000, 64, 8, 1024),
            vq_matmul(64, 4096, 4096, 65_536, 8, 64, 128),
            kde(65_536, 655_360, 8, 256, 1024),
        ]
    print(f"profile = {args.profile}; VMEM budget = {VMEM_BUDGET/1e6:.0f} MB; MXU = {MXU}x{MXU}\n")
    report(cfgs)
    print(
        "\nAI = flops / HBM byte per grid step (roofline: v5e ~ 200 f32 "
        "flops/byte; AI below that is bandwidth-bound)."
    )


if __name__ == "__main__":
    main()
