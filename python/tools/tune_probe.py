"""Offline tuning probe (not part of the build): measures (a) synthetic
dataset difficulty, (b) ratio-polarization speed, (c) hard-collapse
damage, under candidate dataset/loss-weight settings — the knobs that
decide whether the scaled-down tables reproduce the paper's *shape*.

Run:  python -m tools.tune_probe --net mini_resnet18 --steps 300 \
          --share 0.6 --noise 0.6 --wr 1.0
"""

from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import codebook as cb_mod
from compile import data, losses, optim, train, vqlayers, zoo
from compile.kernels import ref as pk_ref
from compile.nets import build_net


def harder_synth_imagenet(n, hw=16, num_classes=10, seed=0, template_seed=7,
                          share=0.6, noise=0.6):
    """synth_imagenet variant: class templates share a common component
    (fine class distinctions that weight quantization can destroy) and
    carry more pixel noise."""
    trng = np.random.default_rng(template_seed)
    common = trng.normal(0.0, 1.0, size=(1, hw, hw, 3)).astype(np.float32)
    uniq = trng.normal(0.0, 1.0, size=(num_classes, hw, hw, 3)).astype(np.float32)
    templates = share * common + (1.0 - share) * uniq
    for _ in range(2):
        templates = 0.5 * templates + 0.25 * (
            np.roll(templates, 1, axis=1) + np.roll(templates, 1, axis=2))
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    max_shift = max(hw // 8, 1)
    sx = rng.integers(-max_shift, max_shift + 1, size=n)
    sy = rng.integers(-max_shift, max_shift + 1, size=n)
    scale = rng.uniform(0.7, 1.3, size=n).astype(np.float32)
    nz = rng.normal(0.0, noise, size=(n, hw, hw, 3)).astype(np.float32)
    x = np.empty((n, hw, hw, 3), np.float32)
    for i in range(n):
        img = np.roll(templates[y[i]], (sx[i], sy[i]), axis=(0, 1))
        x[i] = img * scale[i] + nz[i]
    return x, y


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="mini_resnet18")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--share", type=float, default=0.6)
    ap.add_argument("--noise", type=float, default=0.6)
    ap.add_argument("--wr", type=float, default=1.0)
    ap.add_argument("--lr-ratios", type=float, default=0.3)
    ap.add_argument("--pretrain-mult", type=float, default=1.0)
    args = ap.parse_args()

    spec = zoo.get_net(args.net)
    if args.pretrain_mult != 1.0:
        import dataclasses
        spec = dataclasses.replace(spec, pretrain_steps=int(spec.pretrain_steps * args.pretrain_mult))
    cfg = zoo.vq_config()

    gen = functools.partial(harder_synth_imagenet, share=args.share, noise=args.noise)
    x, y = gen(2000, hw=spec.input_shape[0], seed=spec.seed)
    cx, cy = gen(spec.calib_size, hw=spec.input_shape[0], seed=spec.seed + 1)
    tx, ty = gen(1000, hw=spec.input_shape[0], seed=spec.seed + 2)

    net = build_net(spec)
    params, _ = train.pretrain(net, spec, x, y)
    _, float_acc = train.eval_float(net, spec, params, tx, ty)
    print(f"float acc: {float_acc:.4f}")

    layout = vqlayers.make_layout(net, cfg.d)
    wsub = np.asarray(vqlayers.extract_subvectors(params, layout))
    cb, _ = cb_mod.build_universal_codebook([wsub], cfg.k, cfg.d, cfg.bandwidth, cfg.samples_per_net)
    cb = jnp.asarray(cb)

    sq = jnp.sum((jnp.asarray(wsub)[:, None, :] - cb[None]) ** 2, -1)
    order = jnp.argsort(sq, axis=1)[:, : cfg.n]
    assign = order.astype(jnp.int32)
    dists = jnp.take_along_axis(sq, order, axis=1)
    z = jnp.log(dists[:, -1:] / jnp.maximum(dists, 1e-12))

    other_names = net.other_names()
    others = {k: params[k] for k in other_names}
    teacher_others = dict(others)
    s_total = layout.s_total
    frozen = jnp.zeros((s_total,), jnp.float32)
    frozen_idx = jnp.zeros((s_total,), jnp.int32)
    schedule = {k: jnp.asarray(v) for k, v in data.diffusion_schedule().items()}

    # nearest-codeword (n=1) accuracy — the paper's n=1 row.
    hard0 = vqlayers.hard_codes(z, frozen, frozen_idx, assign)
    p0 = vqlayers.hard_params(hard0, cb, others, layout)
    _, near_acc = train.eval_float(net, spec, p0, tx, ty)
    print(f"nearest-VQ (n=1) acc: {near_acc:.4f}")

    wr = args.wr

    def loss_fn(z, oth, batch):
        p = vqlayers.student_params(z, frozen, frozen_idx, assign, cb, oth, layout)
        l_t, feats, _ = train._task_forward_loss(spec, net, p, batch, schedule)
        tparams = dict(teacher_others)
        tparams.update(vqlayers.weights_from_flat(jnp.asarray(wsub), layout))
        _, tfeats, _ = train._task_forward_loss(spec, net, tparams, batch, schedule)
        l_kd = losses.kd_loss(feats, tfeats)
        r = vqlayers.effective_ratios(z, frozen, frozen_idx)
        l_r = losses.ratio_regularizer(r)
        return l_t + l_kd + wr * l_r, (l_t, l_kd, l_r)

    @jax.jit
    def step(z, mz, uz, oth, mo, vo, t, batch):
        (l, parts), (gz, go) = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(z, oth, batch)
        z, mz, uz = optim.adamax_update(z, gz, mz, uz, t, args.lr_ratios)
        oth, mo, vo = optim.adam_update_tree(oth, go, mo, vo, t, 1e-3)
        return z, mz, uz, oth, mo, vo, l, parts

    mz = jnp.zeros_like(z); uz = jnp.zeros_like(z)
    mo = {k: jnp.zeros_like(v) for k, v in others.items()}
    vo = {k: jnp.zeros_like(v) for k, v in others.items()}
    rng = np.random.default_rng(3)
    for i in range(args.steps):
        idx = rng.integers(0, cx.shape[0], spec.batch)
        batch = (jnp.asarray(cx[idx]), jnp.asarray(cy[idx]))
        z, mz, uz, others, mo, vo, l, parts = step(z, mz, uz, others, mo, vo, jnp.float32(i + 1), batch)
        if (i + 1) % 50 == 0:
            rmax = np.asarray(jax.nn.softmax(z, -1).max(-1))
            print(f"step {i+1}: L={float(l):.4f} (t={float(parts[0]):.4f} kd={float(parts[1]):.4f} "
                  f"r={float(parts[2]):.4f}) rmax q50={np.quantile(rmax,0.5):.4f} "
                  f"q10={np.quantile(rmax,0.1):.4f} "
                  f">0.99: {(rmax>0.99).mean():.3f} >0.9999: {(rmax>0.9999).mean():.3f}")

    # soft vs hard-collapse (no PNC) accuracy
    p_soft = vqlayers.student_params(z, frozen, frozen_idx, assign, cb, others, layout)
    _, soft_acc = train.eval_float(net, spec, p_soft, tx, ty)
    hard = vqlayers.hard_codes(z, frozen, frozen_idx, assign)
    p_hard = vqlayers.hard_params(hard, cb, others, layout)
    _, hard_acc = train.eval_float(net, spec, p_hard, tx, ty)
    print(f"soft acc: {soft_acc:.4f}  hard-collapse acc: {hard_acc:.4f}")


if __name__ == "__main__":
    main()
