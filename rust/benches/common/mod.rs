//! Shared setup for the paper-reproduction bench targets.
//!
//! Every bench is a `harness = false` binary: it regenerates one paper
//! table/figure at a scaled workload (CPU interpret mode) and prints the
//! same rows the paper reports.  Environment knobs:
//!
//! * `VQ4ALL_ARTIFACTS`    — artifacts dir (default `artifacts`)
//! * `VQ4ALL_BENCH_STEPS`  — construction steps per campaign (default 60)
//! * `VQ4ALL_BENCH_FULL=1` — paper-scale steps (slow; for EXPERIMENTS.md)

use std::path::PathBuf;

use vq4all::coordinator::Campaign;
use vq4all::runtime::Manifest;
use vq4all::util::config::CampaignConfig;

#[allow(dead_code)]
pub fn artifacts_dir() -> PathBuf {
    Manifest::default_dir()
}

#[allow(dead_code)]
pub fn bench_steps() -> usize {
    if std::env::var("VQ4ALL_BENCH_FULL").is_ok() {
        return 400;
    }
    std::env::var("VQ4ALL_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60)
}

#[allow(dead_code)]
pub fn campaign() -> anyhow::Result<Campaign> {
    vq4all::util::logging::init_from_env();
    let cfg = CampaignConfig {
        steps: bench_steps(),
        ..CampaignConfig::default()
    };
    Campaign::load(&artifacts_dir(), cfg)
}
