//! E2 — regenerate **Figure 2** (accuracy vs compression ratio).
mod common;

use vq4all::bench::Table;
use vq4all::exp::fig2;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    let mut t = Table::new(
        "Figure 2 — accuracy vs compression ratio",
        &["network", "method", "ratio", "metric", "weight MSE", "measured"],
    );
    for net in ["mini_resnet18", "mini_resnet50"] {
        let (vq, _res) = fig2::vq4all_point(&campaign, net)?;
        let pvq = fig2::kmeans_baseline_point(&campaign, net, campaign.manifest.config.k)?;
        let pvq_small = fig2::kmeans_baseline_point(&campaign, net, 16)?;
        let mut anchors = vec![
            (vq.weight_mse, vq.metric),
            (pvq.weight_mse, pvq.metric),
            (pvq_small.weight_mse, pvq_small.metric),
            (1e-7, campaign.manifest.network(net)?.float_metric),
        ];
        for p in [&vq, &pvq, &pvq_small] {
            t.row(vec![
                net.into(),
                p.method.clone(),
                format!("{:.1}x", p.ratio),
                format!("{:.4}", p.metric),
                format!("{:.2e}", p.weight_mse),
                "device".into(),
            ]);
        }
        for (m, ratio, mse) in fig2::distortion_baselines(&campaign, net)? {
            let est = fig2::mse_to_metric(&mut anchors, mse);
            t.row(vec![
                net.into(),
                m,
                format!("{ratio:.1}x"),
                format!("{est:.4}"),
                format!("{mse:.2e}"),
                "proxy".into(),
            ]);
        }
    }
    t.print();
    Ok(())
}
