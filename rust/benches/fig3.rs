//! E7 — regenerate **Figure 3** (PNC vs no-PNC trajectories).
mod common;

use vq4all::exp::fig3;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    let pnc = fig3::run_one(&campaign, "mini_resnet18", false)?;
    let nopnc = fig3::run_one(&campaign, "mini_resnet18", true)?;
    print!("{}", fig3::render(&pnc, &nopnc));
    Ok(())
}
