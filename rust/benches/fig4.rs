//! E8 — regenerate **Figure 4** (alpha threshold sweep).
mod common;

use vq4all::exp::fig4;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    for net in ["mini_resnet18", "mini_resnet50"] {
        let pts = fig4::sweep(&campaign, net, &[0.9, 0.95, 0.99, 0.995, 0.999])?;
        print!("{}", fig4::render(net, &pts));
    }
    Ok(())
}
