//! E11 — regenerate **Figure 5** (codeword-usage distributions).
mod common;

use vq4all::exp::fig5;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    let mut usages = Vec::new();
    for net in ["mini_mlp", "mini_resnet18", "mini_resnet50", "mini_mobilenet"] {
        let res = campaign.construct(net)?;
        usages.push(fig5::usage(&res, campaign.manifest.config.k, 8));
    }
    print!("{}", fig5::render(&usages));
    Ok(())
}
