//! E13 — hot-path microbenchmarks (the §Perf substrate):
//!
//! * **serial vs parallel** candidate assignment (Eq. 5 distance sweep),
//!   k-means, KDE density, the PNC scan, `encode_nearest` (the Table-1
//!   MSE sweep), bulk packed-code unpack, and the batched serving decode
//!   — the in-house-pool hot paths — plus the serving-engine rows
//!   (cold-vs-warm cache, 1-vs-N shards, bounded-vs-unbounded admission
//!   with its conservation check); the comparisons land in
//!   `BENCH_hotpath.json` so later PRs have a perf trajectory
//!   (`VQ4ALL_BENCH_JSON` overrides the path)
//! * **legacy vs specialized** kernel rows (thread-count independent,
//!   gated >= 1.0x unconditionally): `unpack_wordwise` (bit-loop vs u64
//!   window loads), `pack_wordwise` (its encode-side mirror),
//!   `encode_pruned` (full scan vs norm-seeded partial-distance pruning,
//!   bit-identity asserted in-bench), `fused_decode` (reference fused
//!   decode vs wordwise + small-d gather), `staged_encode` (naive
//!   per-stage residual scan vs the pruned staged encoder),
//!   `staged_decode` (scalar stage-summed decode vs the fused
//!   gather-accumulate), `simd_gather` (scalar lane-order row copy vs
//!   the dispatched AVX2/NEON gather) and `simd_scan` (scalar lane-order
//!   pruned nearest scan vs the dispatched arm, codes + distance bits
//!   asserted identical) — plus absolute `rows_per_sec` /
//!   `codes_per_sec` keys in the `engine` summary from the cold-cache
//!   decode run
//! * packed-code decode (the serving weight-stream path)
//! * host weighted reconstruct (checkpoint validation path)
//! * PJRT step latency: `train_step` / `eval_hard` / `infer_hard` on
//!   mini_mlp (the campaign's per-step floor; skipped without artifacts)
//! * router submit/dispatch throughput

mod common;

use std::sync::Arc;

use vq4all::bench::{Bencher, Comparison};
use vq4all::coordinator::calib::CalibStream;
use vq4all::tensor::ops;
use vq4all::coordinator::{NetSession, PncScheduler};
use vq4all::serving::switchsim::decode_batch;
use vq4all::serving::{
    Batch, BatcherConfig, Engine, EngineConfig, FaultPlan, HostedNet, Request, Router,
};
use vq4all::util::json::Json;
use vq4all::util::rng::Rng;
use vq4all::util::threadpool::ThreadPool;
use vq4all::vq::assign::{candidates_with, AssignInit};
use vq4all::vq::kde::KdeSampler;
use vq4all::vq::kmeans::{kmeans_with, KmeansOpts};
use vq4all::vq::pack::{
    pack_codes, pack_codes_reference, unpack_codes, unpack_codes_with, unpack_range,
    unpack_range_reference, StagedCodes,
};
use vq4all::vq::ratios::max_ratios_with;
use vq4all::vq::simd::{self, SimdLevel};
use vq4all::vq::Codebook;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0xB3);
    let pool = ThreadPool::new(0); // all cores
    let threads = pool.threads();
    let mut comparisons: Vec<Comparison> = Vec::new();
    println!("hotpath: {threads} worker threads available");

    // --- serial vs parallel: candidate assignment (Eq. 5) ------------------
    let mut flat = vec![0.0f32; 4 * 20_000];
    rng.fill_normal(&mut flat);
    let cb = {
        let mut words = vec![0.0f32; 256 * 4];
        rng.fill_normal(&mut words);
        Codebook::new(256, 4, words)
    };
    let cand_serial = b.bench("candidates s=20k k=256 n=8 [serial]", || {
        let mut r = Rng::new(1);
        let c = candidates_with(&flat, &cb, 8, AssignInit::Euclid, &mut r, None);
        std::hint::black_box(c.assign.len());
    });
    let cand_par = b.bench("candidates s=20k k=256 n=8 [parallel]", || {
        let mut r = Rng::new(1);
        let c = candidates_with(&flat, &cb, 8, AssignInit::Euclid, &mut r, Some(&pool));
        std::hint::black_box(c.assign.len());
    });
    comparisons.push(Comparison::new(
        "candidate_assignment",
        &cand_serial,
        &cand_par,
        threads,
    ));

    // --- serial vs parallel: k-means (pool pre-created, so the timed
    // region measures the sweeps, not thread spawn/teardown) ----------------
    let km_opts = KmeansOpts {
        max_iters: 10,
        ..Default::default()
    };
    let km_serial = b.bench("kmeans k=64 d=4 s=20k [serial]", || {
        std::hint::black_box(kmeans_with(&flat, 4, 64, &km_opts, None).mse);
    });
    let km_par = b.bench("kmeans k=64 d=4 s=20k [parallel]", || {
        std::hint::black_box(kmeans_with(&flat, 4, 64, &km_opts, Some(&pool)).mse);
    });
    comparisons.push(Comparison::new("kmeans", &km_serial, &km_par, threads));

    // --- serial vs parallel: KDE density -----------------------------------
    let kde = KdeSampler::new(flat[..4 * 20_000].to_vec(), 4, 0.05);
    let q = [0.1f32, -0.3, 0.2, 0.05];
    let kde_serial = b.bench("kde density n=20k d=4 [serial]", || {
        std::hint::black_box(kde.density_with(&q, None));
    });
    let kde_par = b.bench("kde density n=20k d=4 [parallel]", || {
        std::hint::black_box(kde.density_with(&q, Some(&pool)));
    });
    comparisons.push(Comparison::new("kde_density", &kde_serial, &kde_par, threads));

    // --- serial vs parallel: PNC scan --------------------------------------
    let n = 8;
    let mut z = vec![0.0f32; 57_344 * n];
    rng.fill_normal(&mut z);
    let scan_serial = b.bench("PNC scan S=57k n=8 [serial]", || {
        let mut pnc = PncScheduler::new(57_344, 0.9999);
        std::hint::black_box(pnc.scan_with(&z, n, None));
    });
    let scan_par = b.bench("PNC scan S=57k n=8 [parallel]", || {
        let mut pnc = PncScheduler::new(57_344, 0.9999);
        std::hint::black_box(pnc.scan_with(&z, n, Some(&pool)));
    });
    comparisons.push(Comparison::new("pnc_scan", &scan_serial, &scan_par, threads));
    b.bench("max_ratios S=57k n=8 [parallel]", || {
        std::hint::black_box(max_ratios_with(&z, n, Some(&pool)).len());
    });

    // --- serial vs parallel: encode_nearest (Table-1 MSE sweep) ------------
    let enc_serial = b.bench("encode_nearest s=20k k=256 [serial]", || {
        let (m, c) = cb.encode_nearest_with(&flat, None);
        std::hint::black_box((m, c.len()));
    });
    let enc_par = b.bench("encode_nearest s=20k k=256 [parallel]", || {
        let (m, c) = cb.encode_nearest_with(&flat, Some(&pool));
        std::hint::black_box((m, c.len()));
    });
    comparisons.push(Comparison::new("encode_nearest", &enc_serial, &enc_par, threads));

    // --- pure-host serving paths -------------------------------------------
    let codes: Vec<u32> = (0..100_000).map(|_| rng.below(256) as u32).collect();
    let packed = pack_codes(&codes, 8);
    b.bench("unpack 100k codes @8b", || {
        let v = unpack_codes(&packed);
        std::hint::black_box(v.len());
    });

    // --- serial vs parallel: bulk unpack at an awkward width ---------------
    let codes5: Vec<u32> = (0..2_000_000).map(|_| rng.below(32) as u32).collect();
    let packed5 = pack_codes(&codes5, 5);
    let unpack_serial = b.bench("unpack 2M codes @5b [serial]", || {
        let v = unpack_codes_with(&packed5, None);
        std::hint::black_box(v.len());
    });
    let unpack_par = b.bench("unpack 2M codes @5b [parallel]", || {
        let v = unpack_codes_with(&packed5, Some(&pool));
        std::hint::black_box(v.len());
    });
    comparisons.push(Comparison::new("unpack_codes", &unpack_serial, &unpack_par, threads));

    // --- legacy vs specialized: word-level unpack ---------------------------
    // Same 2M-code @5b stream, single-threaded: the retained bit-at-a-
    // time reference against the u64-window kernel.  Thread-count
    // independent, so verify.sh gates it at >= 1.0x unconditionally.
    let mut unpack_dst = vec![0u32; packed5.count];
    let ww_legacy = b.bench("unpack 2M codes @5b [legacy bit-loop]", || {
        unpack_range_reference(&packed5, 0, packed5.count, &mut unpack_dst);
        std::hint::black_box(unpack_dst[0]);
    });
    let ww_spec = b.bench("unpack 2M codes @5b [wordwise]", || {
        unpack_range(&packed5, 0, packed5.count, &mut unpack_dst);
        std::hint::black_box(unpack_dst[0]);
    });
    comparisons.push(Comparison::new("unpack_wordwise", &ww_legacy, &ww_spec, 1));

    // --- legacy vs specialized: word-level pack ------------------------------
    // The encode-side mirror of `unpack_wordwise`: the same 2M-code @5b
    // stream packed through the retained bit-at-a-time reference vs the
    // u64-accumulator kernel, byte-identity asserted in-bench.
    let pk_legacy = b.bench("pack 2M codes @5b [legacy bit-loop]", || {
        let p = pack_codes_reference(&codes5, 5);
        std::hint::black_box(p.data.len());
    });
    let pk_spec = b.bench("pack 2M codes @5b [wordwise]", || {
        let p = pack_codes(&codes5, 5);
        std::hint::black_box(p.data.len());
    });
    comparisons.push(Comparison::new("pack_wordwise", &pk_legacy, &pk_spec, 1));
    assert_eq!(
        pack_codes_reference(&codes5, 5).data,
        packed5.data,
        "wordwise pack bytes diverged from the bit-loop reference"
    );

    // --- legacy vs specialized: pruned nearest-codeword scan ----------------
    // d=16 (>= PRUNE_MIN_D) so the norm-seeded partial-distance scan
    // actually dispatches; the kernels are proven bit-identical, and the
    // bench asserts it on this workload too.  Groups are drawn near
    // codewords — the representative encode workload: every encode in
    // this repo quantizes data its codebook was built to explain (the
    // Table-1 sweeps encode weights against their own KDE codebook), so
    // nearest distances are far below average and the bail bound bites.
    let cb16 = {
        let mut words = vec![0.0f32; 256 * 16];
        rng.fill_normal(&mut words);
        Codebook::new(256, 16, words)
    };
    let mut flat16 = vec![0.0f32; 16 * 4_000];
    for g in 0..4_000 {
        let w = cb16.word(rng.below(256));
        for j in 0..16 {
            flat16[g * 16 + j] = w[j] + rng.normal_f32(0.0, 0.15);
        }
    }
    let enc_legacy = b.bench("encode 4k groups k=256 d=16 [legacy full scan]", || {
        let (m, c) = cb16.encode_nearest_reference(&flat16);
        std::hint::black_box((m, c.len()));
    });
    let enc_spec = b.bench("encode 4k groups k=256 d=16 [pruned]", || {
        let (m, c) = cb16.encode_nearest_with(&flat16, None);
        std::hint::black_box((m, c.len()));
    });
    comparisons.push(Comparison::new("encode_pruned", &enc_legacy, &enc_spec, 1));
    {
        let (m_ref, c_ref) = cb16.encode_nearest_reference(&flat16);
        let (m_new, c_new) = cb16.encode_nearest_with(&flat16, None);
        assert_eq!(m_ref.to_bits(), m_new.to_bits(), "pruned encode MSE diverged");
        assert_eq!(c_ref, c_new, "pruned encode codes diverged");
    }

    // --- legacy vs specialized: staged residual encode -----------------------
    // The same 4k-group d=16 workload at a 2-stage [5, 5] split: the
    // naive full-prefix reference scan vs the production encoder (the
    // PR-5 pruned scan per stage, wordwise pack).  Proven bit-identical
    // by the staged prop_substrate properties and asserted here too.
    let se_legacy = b.bench("staged encode 4k groups d=16 [5,5] [legacy full scan]", || {
        let e = cb16.encode_staged_reference(&flat16, &[5, 5]);
        std::hint::black_box(e.mse);
    });
    let se_spec = b.bench("staged encode 4k groups d=16 [5,5] [pruned per stage]", || {
        let e = cb16.encode_staged(&flat16, &[5, 5], None);
        std::hint::black_box(e.mse);
    });
    comparisons.push(Comparison::new("staged_encode", &se_legacy, &se_spec, 1));
    {
        let r = cb16.encode_staged_reference(&flat16, &[5, 5]);
        let s = cb16.encode_staged(&flat16, &[5, 5], None);
        assert_eq!(r.mse.to_bits(), s.mse.to_bits(), "staged encode MSE diverged");
        assert_eq!(r.codes, s.codes, "staged encode streams diverged");
    }

    // --- legacy vs specialized: fused streaming decode ----------------------
    // 256k codes @5b against the k=256 d=4 serving codebook: the
    // reference (bit-loop unpack + runtime-length copies) vs the fused
    // wordwise + small-d gather kernel the decode plane rides.
    let fuse_n = 262_144.min(packed5.count);
    let mut fused_out = vec![0.0f32; fuse_n * cb.d];
    let fd_legacy = b.bench("fused decode 256k codes @5b d=4 [legacy]", || {
        cb.decode_packed_into_reference(&packed5, 0, fuse_n, &mut fused_out);
        std::hint::black_box(fused_out[0]);
    });
    let fd_spec = b.bench("fused decode 256k codes @5b d=4 [wordwise+gather]", || {
        cb.decode_packed_into(&packed5, 0, fuse_n, &mut fused_out);
        std::hint::black_box(fused_out[0]);
    });
    comparisons.push(Comparison::new("fused_decode", &fd_legacy, &fd_spec, 1));

    // --- legacy vs specialized: staged residual decode -----------------------
    // The same 256k-code window as a 2-stage stream (5b + 3b against the
    // k=256 d=4 serving codebook): the scalar stage-summed reference vs
    // the fused kernel (stage-0 gather write, later stages wordwise
    // unpack + gather-accumulate) every serving decode now rides.
    let codes3: Vec<u32> = (0..packed5.count).map(|_| rng.below(8) as u32).collect();
    let staged2 = StagedCodes::new(vec![packed5.clone(), pack_codes(&codes3, 3)]);
    let mut staged_out = vec![0.0f32; fuse_n * cb.d];
    let sd_legacy = b.bench("staged decode 256k codes 2-stage d=4 [legacy]", || {
        cb.decode_staged_packed_into_reference(&staged2, 0, fuse_n, &mut staged_out);
        std::hint::black_box(staged_out[0]);
    });
    let sd_spec = b.bench("staged decode 256k codes 2-stage d=4 [fused]", || {
        cb.decode_staged_packed_into(&staged2, 0, fuse_n, &mut staged_out);
        std::hint::black_box(staged_out[0]);
    });
    comparisons.push(Comparison::new("staged_decode", &sd_legacy, &sd_spec, 1));
    {
        let mut a = vec![0.0f32; fuse_n * cb.d];
        let mut bb = vec![0.0f32; fuse_n * cb.d];
        cb.decode_staged_packed_into_reference(&staged2, 0, fuse_n, &mut a);
        cb.decode_staged_packed_into(&staged2, 0, fuse_n, &mut bb);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&bb), "staged decode diverged from reference");
    }

    // --- scalar reference vs dispatched SIMD: wide-row gather ---------------
    // 64k random codes against the k=256 d=16 codebook: the scalar
    // lane-order row copy vs whatever arm runtime dispatch picked (AVX2
    // on x86_64, NEON on aarch64).  Byte-identical copies, asserted
    // below — the row measures the vector load/store win alone.  On a
    // host with no vector arm the dispatched side IS the reference, so
    // the row is kept at exactly 1.0x rather than vanishing from the
    // gate.
    let simd_arm = simd::best();
    println!("{}", simd::probe_line());
    let gather_codes: Vec<u32> = (0..65_536).map(|_| rng.below(256) as u32).collect();
    let mut gather_out = vec![0.0f32; gather_codes.len() * cb16.d];
    let sg_ref = b.bench("gather 64k rows d=16 [scalar reference]", || {
        simd::gather_rows_reference(&cb16.words, &gather_codes, cb16.d, &mut gather_out);
        std::hint::black_box(gather_out[0]);
    });
    let sg_spec = if simd_arm == SimdLevel::Scalar {
        println!("simd_gather: no vector arm on this host; dispatched side = scalar reference");
        sg_ref.clone()
    } else {
        b.bench(&format!("gather 64k rows d=16 [{}]", simd_arm.name()), || {
            simd::gather_rows(simd_arm, &cb16.words, &gather_codes, cb16.d, &mut gather_out);
            std::hint::black_box(gather_out[0]);
        })
    };
    comparisons.push(Comparison::new("simd_gather", &sg_ref, &sg_spec, 1));
    {
        let mut want = vec![0.0f32; gather_codes.len() * cb16.d];
        let mut got = vec![0.0f32; gather_codes.len() * cb16.d];
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        simd::gather_rows_reference(&cb16.words, &gather_codes, cb16.d, &mut want);
        simd::gather_rows(simd_arm, &cb16.words, &gather_codes, cb16.d, &mut got);
        assert_eq!(bits(&want), bits(&got), "simd gather diverged from reference");
        simd::gather_rows_add_reference(&cb16.words, &gather_codes, cb16.d, &mut want);
        simd::gather_rows_add(simd_arm, &cb16.words, &gather_codes, cb16.d, &mut got);
        assert_eq!(bits(&want), bits(&got), "simd gather-accumulate diverged from reference");
    }

    // --- scalar reference vs dispatched SIMD: pruned distance scan ----------
    // The encode workload (4k near-codeword groups, k=256 d=16) swept
    // through the level-threaded nearest scan: the scalar lane-order arm
    // vs the dispatched one.  Both sides use the same canonical
    // summation order and bail rule, so the argmin codes AND the f32
    // distance bits must agree exactly — asserted below.
    let ss_ref = b.bench("nearest scan 4k groups k=256 d=16 [scalar reference]", || {
        let mut h = 0u64;
        for g in 0..4_000 {
            let sub = &flat16[g * 16..(g + 1) * 16];
            let (c, dist) =
                ops::nearest_pruned_at(SimdLevel::Scalar, sub, &cb16.words, cb16.norms());
            h ^= (c as u64) ^ u64::from(dist.to_bits());
        }
        std::hint::black_box(h);
    });
    let ss_spec = if simd_arm == SimdLevel::Scalar {
        println!("simd_scan: no vector arm on this host; dispatched side = scalar reference");
        ss_ref.clone()
    } else {
        b.bench(
            &format!("nearest scan 4k groups k=256 d=16 [{}]", simd_arm.name()),
            || {
                let mut h = 0u64;
                for g in 0..4_000 {
                    let sub = &flat16[g * 16..(g + 1) * 16];
                    let (c, dist) =
                        ops::nearest_pruned_at(simd_arm, sub, &cb16.words, cb16.norms());
                    h ^= (c as u64) ^ u64::from(dist.to_bits());
                }
                std::hint::black_box(h);
            },
        )
    };
    comparisons.push(Comparison::new("simd_scan", &ss_ref, &ss_spec, 1));
    for g in 0..4_000 {
        let sub = &flat16[g * 16..(g + 1) * 16];
        let (c0, d0) = ops::nearest_pruned_at(SimdLevel::Scalar, sub, &cb16.words, cb16.norms());
        let (c1, d1) = ops::nearest_pruned_at(simd_arm, sub, &cb16.words, cb16.norms());
        assert_eq!(c0, c1, "simd scan argmin diverged at group {g}");
        assert_eq!(d0.to_bits(), d1.to_bits(), "simd scan distance bits diverged at group {g}");
    }

    let mut out = vec![0.0f32; codes.len() * 4];
    b.bench("hard decode 100k codes (400k weights)", || {
        cb.decode(&codes, &mut out);
    });

    // --- serial vs parallel: batched serving decode ------------------------
    // A formed (padded) batch decodes its rows out of the packed stream:
    // 64 device rows x 4096 codes/row @8b against the k=256 d=4 codebook.
    let device_rows = 64usize;
    let codes_per_row = 4096usize;
    let codes8: Vec<u32> = (0..device_rows * codes_per_row)
        .map(|_| rng.below(256) as u32)
        .collect();
    let packed8 = StagedCodes::single(pack_codes(&codes8, 8));
    let reqs: Vec<Request> = (0..48u64)
        .map(|i| Request {
            id: i,
            net: "bench".into(),
            row: (i as usize * 7) % device_rows,
            arrived_ns: 0,
            deadline_ns: 0,
        })
        .collect();
    let batch = Batch::form("bench", reqs, device_rows);
    let bd_serial = b.bench("batched decode 64x4k codes @8b [serial]", || {
        let r = decode_batch(&batch, &packed8, &cb, codes_per_row, None).unwrap();
        std::hint::black_box(r.weights.len());
    });
    let bd_par = b.bench("batched decode 64x4k codes @8b [parallel]", || {
        let r = decode_batch(&batch, &packed8, &cb, codes_per_row, Some(&pool)).unwrap();
        std::hint::black_box(r.weights.len());
    });
    comparisons.push(Comparison::new("batched_decode", &bd_serial, &bd_par, threads));

    // --- engine: cold vs warm decode cache ----------------------------------
    // One shard hosting the 64x4096 @8b stream with a budget that fits
    // every decoded row: the cold pass decodes fresh each iteration
    // (cache cleared), the warm pass is pure cache-block copies.
    let cb_arc = Arc::new(cb.clone());
    let engine_net = HostedNet {
        name: "bench".into(),
        codes: packed8.clone(),
        codebook: cb_arc.clone(),
        codes_per_row,
        device_batch: device_rows,
    };
    let stride = codes_per_row * cb_arc.d;
    let row_bytes = stride * std::mem::size_of::<f32>();
    let engine_cfg = |shards: usize, cache_bytes: usize| EngineConfig {
        shards,
        cache_bytes,
        max_queue_depth: 0,
        batcher: BatcherConfig {
            max_batch: 16,
            max_linger_ns: 0,
        },
        obs: Default::default(),
    };
    let all_rows: Vec<usize> = (0..device_rows).collect();
    let mut staging = vec![0.0f32; device_rows * stride];
    let budget = device_rows * row_bytes + 1024;
    let mut cold_engine =
        Engine::new(engine_cfg(1, budget), vec![engine_net.clone()]).unwrap();
    let cache_cold = b.bench("engine decode 64x4k @8b [cold cache]", || {
        cold_engine.clear_caches();
        cold_engine
            .decode_rows_into("bench", &all_rows, &mut staging, Some(&pool))
            .unwrap();
        std::hint::black_box(staging[0]);
    });
    let mut warm_engine =
        Engine::new(engine_cfg(1, budget), vec![engine_net.clone()]).unwrap();
    warm_engine
        .decode_rows_into("bench", &all_rows, &mut staging, Some(&pool))
        .unwrap(); // prefill
    let cache_warm = b.bench("engine decode 64x4k @8b [warm cache]", || {
        warm_engine
            .decode_rows_into("bench", &all_rows, &mut staging, Some(&pool))
            .unwrap();
        std::hint::black_box(staging[0]);
    });
    comparisons.push(Comparison::new("engine_cache", &cache_cold, &cache_warm, threads));
    let cache_stats = warm_engine.cache_stats();
    println!(
        "engine cache: {} lookups, hit_rate {:.3}, {} evictions",
        cache_stats.lookups,
        cache_stats.hit_rate(),
        cache_stats.evictions
    );

    // --- engine: obs instrumentation overhead --------------------------------
    // The ISSUE-8 observability contract: per-shard plain-field counters
    // and log2 histograms must cost ~nothing on the hot path.  Same
    // warm-cache stream_batch workload with the obs plane disabled
    // (baseline) vs enabled (instrumented); single-threaded so the row
    // rides only its own >= 0.95x verify gate, not the generic
    // parallel-speedup gate.
    let mut obs_off_cfg = engine_cfg(1, budget);
    obs_off_cfg.obs.enabled = false;
    obs_off_cfg.obs.ring_capacity = 0;
    let mut eng_obs_off = Engine::new(obs_off_cfg, vec![engine_net.clone()]).unwrap();
    let mut eng_obs_on = Engine::new(engine_cfg(1, budget), vec![engine_net.clone()]).unwrap();
    eng_obs_off.stream_batch("bench", &all_rows, None).unwrap(); // prefill
    eng_obs_on.stream_batch("bench", &all_rows, None).unwrap(); // prefill
    let obs_off = b.bench("engine stream 64 rows warm [obs off]", || {
        let s = eng_obs_off.stream_batch("bench", &all_rows, None).unwrap();
        std::hint::black_box(s);
    });
    let obs_on = b.bench("engine stream 64 rows warm [obs on]", || {
        let s = eng_obs_on.stream_batch("bench", &all_rows, None).unwrap();
        std::hint::black_box(s);
    });
    comparisons.push(Comparison::new("obs_overhead", &obs_off, &obs_on, 1));

    // --- engine: fault-probe overhead ----------------------------------------
    // The ISSUE-10 fault-tolerance contract: the injection probes and
    // deadline checks threaded through the dispatch path must cost
    // ~nothing when no plan fires.  Same warm stream_batch workload with
    // no plan armed (baseline) vs an armed all-sites plan at rate 0 —
    // every probe consults the plan, nothing ever fires.  Without the
    // `fault-inject` feature both sides are no-ops and the row pins near
    // 1.0x, proving release builds carry no residue.  Single-threaded so
    // the row rides only its own >= 0.95x verify gate.
    let mut eng_faults_off = Engine::new(engine_cfg(1, budget), vec![engine_net.clone()]).unwrap();
    let mut eng_faults_on = Engine::new(engine_cfg(1, budget), vec![engine_net.clone()]).unwrap();
    eng_faults_on.arm_faults(&FaultPlan::arm_all(0xFA17, 0));
    eng_faults_off.stream_batch("bench", &all_rows, None).unwrap(); // prefill
    eng_faults_on.stream_batch("bench", &all_rows, None).unwrap(); // prefill
    let faults_off = b.bench("engine stream 64 rows warm [faults disarmed]", || {
        let s = eng_faults_off.stream_batch("bench", &all_rows, None).unwrap();
        std::hint::black_box(s);
    });
    let faults_on = b.bench("engine stream 64 rows warm [faults armed, rate 0]", || {
        let s = eng_faults_on.stream_batch("bench", &all_rows, None).unwrap();
        std::hint::black_box(s);
    });
    comparisons.push(Comparison::new("faults_overhead", &faults_off, &faults_on, 1));

    // --- engine: 1 shard serial vs N shards pooled ---------------------------
    // Four hosted nets, 128 requests round-robin; the serial run drives
    // one shard with no pool, the sharded run fans nets across shards on
    // the pool.  Cache off, so both runs do identical decode work.
    let engine_shards = 4usize.min(threads.max(2));
    let hosted_multi: Vec<HostedNet> = (0..4)
        .map(|i| HostedNet {
            name: format!("net{i}"),
            codes: packed8.clone(),
            codebook: cb_arc.clone(),
            codes_per_row,
            device_batch: 16,
        })
        .collect();
    let submit_all = |e: &mut Engine| {
        for r in 0..128usize {
            e.submit(&format!("net{}", r % 4), (r * 7) % device_rows).unwrap();
        }
    };
    // Engines are built once (hosting validation scans the streams) and
    // reused: each iteration times submit + drain only.
    let mut eng_serial = Engine::new(engine_cfg(1, 0), hosted_multi.clone()).unwrap();
    let shards_serial = b.bench("engine drain 128 reqs / 4 nets [1 shard serial]", || {
        submit_all(&mut eng_serial);
        std::hint::black_box(eng_serial.drain(None).unwrap());
    });
    let mut eng_sharded = Engine::new(engine_cfg(engine_shards, 0), hosted_multi.clone()).unwrap();
    let shards_par = b.bench(
        &format!("engine drain 128 reqs / 4 nets [{engine_shards} shards pooled]"),
        || {
            submit_all(&mut eng_sharded);
            std::hint::black_box(eng_sharded.drain(Some(&pool)).unwrap());
        },
    );
    comparisons.push(Comparison::new("engine_shards", &shards_serial, &shards_par, threads));

    // --- engine: admission control (bounded vs unbounded queue) -------------
    // The same 4-net workload arriving as one 128-request burst per
    // iteration before any dispatch: the unbounded plane queues and
    // decodes all of it, the bounded plane (max_queue_depth = 16 on its
    // single shard) admits 16 and sheds the overflow at admission — so
    // the shed never reaches a queue, a batch, or a decode window.
    let mut admission_cfg = engine_cfg(1, 0);
    admission_cfg.max_queue_depth = 16;
    let submit_all_typed = |e: &mut Engine| {
        for r in 0..128usize {
            // try_submit: shed outcomes are data here, not errors.
            let _ = e
                .try_submit(&format!("net{}", r % 4), (r * 7) % device_rows)
                .unwrap();
        }
    };
    let mut eng_adm_unbounded = Engine::new(engine_cfg(1, 0), hosted_multi.clone()).unwrap();
    let adm_unbounded = b.bench("engine 128-req burst / 4 nets [unbounded queue]", || {
        submit_all_typed(&mut eng_adm_unbounded);
        std::hint::black_box(eng_adm_unbounded.drain(None).unwrap());
    });
    let mut eng_adm_bounded = Engine::new(admission_cfg, hosted_multi.clone()).unwrap();
    let adm_bounded = b.bench("engine 128-req burst / 4 nets [max-queue 16]", || {
        submit_all_typed(&mut eng_adm_bounded);
        std::hint::black_box(eng_adm_bounded.drain(None).unwrap());
    });
    comparisons.push(Comparison::new(
        "engine_admission",
        &adm_unbounded,
        &adm_bounded,
        threads,
    ));
    // Conservation must be green serial AND pooled: run the same bounded
    // burst on a sharded plane over the pool and check every ledger.
    let mut pooled_cfg = engine_cfg(engine_shards, 0);
    pooled_cfg.max_queue_depth = 16;
    let mut eng_adm_pooled = Engine::new(pooled_cfg, hosted_multi.clone()).unwrap();
    submit_all_typed(&mut eng_adm_pooled);
    eng_adm_pooled.drain(Some(&pool)).unwrap();
    for (eng, tag) in [
        (&eng_adm_unbounded, "unbounded"),
        (&eng_adm_bounded, "bounded serial"),
        (&eng_adm_pooled, "bounded pooled"),
    ] {
        let (acc, disp, shed) = eng.counters();
        assert_eq!(acc, disp + shed, "admission conservation violated ({tag})");
        // Extended identity (fault plane): no deadlines and no faults in
        // this run, so the expired/failed terms must stay zero and the
        // full conservation equation must still balance.
        let t = eng.totals();
        assert_eq!(
            t.accepted,
            t.served + t.shed + t.expired + t.failed,
            "extended conservation violated ({tag})"
        );
        assert_eq!((t.expired, t.failed), (0, 0), "fault-free run leaked expired/failed ({tag})");
        assert_eq!(eng.total_pending(), 0, "drained plane still pending ({tag})");
    }
    let admission = eng_adm_bounded.totals();
    assert!(admission.shed > 0, "bounded plane never shed — gate would be vacuous");
    println!(
        "engine admission: accepted {} = dispatched {} + shed {} (peak depth {}, budget {})",
        admission.accepted, admission.served, admission.shed, admission.peak_depth, 16
    );
    // The obs plane's own reconciliation, checked in-bench before the
    // summary keys are written: one queue-wait sample per dispatched
    // request, and every shed recorded as a flight-recorder event (the
    // ring only retains the tail, but the recorded counter is lifetime).
    let obs_snapshot = eng_adm_bounded.metrics_snapshot();
    assert_eq!(
        obs_snapshot.queue_ns.count(),
        admission.served,
        "queue-wait histogram out of step with the dispatch ledger"
    );
    assert_eq!(
        obs_snapshot.events_recorded,
        admission.shed,
        "bounded plane's sheds must all land in the flight recorder"
    );

    // --- router -------------------------------------------------------------
    b.bench("router submit+drain 1k reqs / 4 nets", || {
        let mut r = Router::new(&["a", "b", "c", "d"]);
        for i in 0..1000 {
            r.submit(["a", "b", "c", "d"][i % 4], i, i as u64).unwrap();
        }
        while let Some(q) = r.pick() {
            std::hint::black_box(r.drain(q, 32).len());
        }
    });

    // --- PJRT paths (need artifacts) ----------------------------------------
    match common::campaign() {
        Ok(campaign) => {
            let mut sess =
                NetSession::new(&campaign.rt, &campaign.manifest, "mini_mlp", &campaign.codebook)?;

            // What the static-literal cache saves: encoding the static
            // inputs (candidate table, teacher, codebook, ...) to XLA
            // literals, which the naive path would redo every step.
            let statics = sess.statics.clone();
            b.bench("literal-encode statics mini_mlp (cache saves this/step)", || {
                for t in &statics {
                    let l = vq4all::runtime::client::tensor_to_literal(t).unwrap();
                    std::hint::black_box(&l);
                }
            });
            let mut stream = CalibStream::new(
                sess.calib_x.clone(),
                sess.calib_y.clone(),
                "classify",
                sess.net.batch,
                1,
            );
            let batch = stream.next_batch()?;
            b.bench("PJRT train_step mini_mlp (S=57k n=8)", || {
                sess.train_step(&batch).unwrap();
            });
            let codes = sess.hard_codes(&vq4all::vq::ratios::FreezeState::new(sess.net.s_total));
            let codes_t = sess.codes_tensor(&codes);
            let eb: Vec<_> = vq4all::coordinator::calib::EvalBatches::new(
                &sess.test_x.clone(),
                &sess.test_y.clone(),
                "classify",
                sess.net.eval_batch,
                3,
            )
            .take(1)
            .collect::<anyhow::Result<_>>()?;
            b.bench("PJRT eval_hard batch=100 mini_mlp", || {
                sess.eval_batch("eval_hard", Some(&codes_t), &eb[0]).unwrap();
            });
            let x = eb[0][0].clone();
            b.bench("PJRT infer_hard (fused vq_matmul) batch=100", || {
                sess.eval_infer(&codes_t, std::slice::from_ref(&x)).unwrap();
            });
        }
        Err(e) => println!("skipping PJRT benches (no artifacts): {e}"),
    }

    b.report();
    println!("\n== serial vs parallel ({threads} threads) ==");
    for c in &comparisons {
        println!(
            "  {:<22} serial {:>12.0}ns  parallel {:>12.0}ns  speedup {:.2}x",
            c.name,
            c.serial_ns,
            c.parallel_ns,
            c.speedup()
        );
    }
    // Absolute decode-plane throughput (not a serial-vs-parallel ratio):
    // the cold-cache engine run decodes all `device_rows` rows of
    // `codes_per_row` codes fresh every iteration, so rows/codes per
    // second fall straight out of its mean time.  verify.sh gates the
    // keys as present and > 0; the values themselves are machine-local
    // trajectory data.
    let rows_per_sec = cache_cold.throughput(device_rows as f64);
    let codes_per_sec = cache_cold.throughput((device_rows * codes_per_row) as f64);
    println!(
        "engine absolute throughput (cold decode): {rows_per_sec:.0} rows/s, \
         {codes_per_sec:.0} codes/s"
    );
    let engine_extra = Json::obj(vec![
        ("cache_hit_rate", Json::num(cache_stats.hit_rate())),
        ("cache_hits", Json::num(cache_stats.hits as f64)),
        ("cache_misses", Json::num(cache_stats.misses as f64)),
        ("cache_evictions", Json::num(cache_stats.evictions as f64)),
        ("rows_per_sec", Json::num(rows_per_sec)),
        ("codes_per_sec", Json::num(codes_per_sec)),
        ("shards", Json::num(engine_shards as f64)),
        // Admission counters from the bounded (max-queue 16) run —
        // scripts/verify.sh gates accepted == dispatched + shed > 0.
        ("max_queue_depth", Json::num(16.0)),
        ("admission_accepted", Json::num(admission.accepted as f64)),
        ("admission_dispatched", Json::num(admission.served as f64)),
        ("admission_shed", Json::num(admission.shed as f64)),
        // Extended conservation terms (fault plane): both are zero in
        // this fault-free bench, but the keys must exist so the baseline
        // row-set diff catches a report that silently lost them.
        ("admission_expired", Json::num(admission.expired as f64)),
        ("admission_failed", Json::num(admission.failed as f64)),
        ("admission_peak_depth", Json::num(admission.peak_depth as f64)),
        // Observability reconciliation keys from the same bounded run —
        // verify.sh gates obs_queue_count == admission_dispatched (one
        // queue-wait histogram sample per dispatched request) and
        // obs_events > 0 (the bounded plane's sheds must land in the
        // flight recorder).
        ("obs_queue_count", Json::num(obs_snapshot.queue_ns.count() as f64)),
        ("obs_events", Json::num(obs_snapshot.events_recorded as f64)),
        ("obs_events_dropped", Json::num(obs_snapshot.events_dropped as f64)),
        ("obs_decode_hidden_ratio", Json::num(obs_snapshot.decode_hidden_ratio())),
    ]);
    println!(
        "engine summary: hit_rate {:.3} over {} lookups, {engine_shards} shards in the sharded row, \
         {} shed under the bounded queue",
        cache_stats.hit_rate(),
        cache_stats.lookups,
        admission.shed
    );
    println!(
        "engine obs: queue hist count {} (== dispatched {}), {} flight-recorder events \
         ({} dropped), decode-hidden ratio {:.3}",
        obs_snapshot.queue_ns.count(),
        admission.served,
        obs_snapshot.events_recorded,
        obs_snapshot.events_dropped,
        obs_snapshot.decode_hidden_ratio()
    );
    let json_path = std::env::var("VQ4ALL_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    b.write_json(std::path::Path::new(&json_path), &comparisons, &[("engine", engine_extra)])?;
    println!("bench report written to {json_path}");
    Ok(())
}
