//! E13 — hot-path microbenchmarks (the §Perf substrate):
//!
//! * host k-means assignment sweep (the Table-1/Fig-2 analysis loop)
//! * packed-code decode (the serving weight-stream path)
//! * host weighted reconstruct (checkpoint validation path)
//! * PNC scan (the per-interval coordinator cost)
//! * PJRT step latency: `train_step` / `eval_hard` / `infer_hard` on
//!   mini_mlp (the campaign's per-step floor)
//! * router submit/dispatch throughput

mod common;

use vq4all::bench::Bencher;
use vq4all::coordinator::calib::CalibStream;
use vq4all::coordinator::{NetSession, PncScheduler};
use vq4all::serving::Router;
use vq4all::util::rng::Rng;
use vq4all::vq::pack::{pack_codes, unpack_codes};
use vq4all::vq::ratios::max_ratios;
use vq4all::vq::{kmeans::KmeansOpts, Codebook};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0xB3);

    // --- pure-host paths ---------------------------------------------------
    let mut flat = vec![0.0f32; 4 * 20_000];
    rng.fill_normal(&mut flat);
    b.bench("kmeans k=64 d=4 s=20k (full run)", || {
        let _ = vq4all::vq::kmeans::kmeans(&flat, 4, 64, &KmeansOpts { max_iters: 5, ..Default::default() });
    });

    let codes: Vec<u32> = (0..100_000).map(|_| rng.below(256) as u32).collect();
    let packed = pack_codes(&codes, 8);
    b.bench("unpack 100k codes @8b", || {
        let v = unpack_codes(&packed);
        std::hint::black_box(v.len());
    });

    let cb = {
        let mut words = vec![0.0f32; 256 * 4];
        rng.fill_normal(&mut words);
        Codebook::new(256, 4, words)
    };
    let mut out = vec![0.0f32; codes.len() * 4];
    b.bench("hard decode 100k codes (400k weights)", || {
        cb.decode(&codes, &mut out);
    });

    let n = 8;
    let mut z = vec![0.0f32; 57_344 * n];
    rng.fill_normal(&mut z);
    b.bench("PNC scan S=57k n=8 (softmax+argmax)", || {
        let mut pnc = PncScheduler::new(57_344, 0.9999);
        std::hint::black_box(pnc.scan(&z, n));
    });
    b.bench("max_ratios S=57k n=8", || {
        std::hint::black_box(max_ratios(&z, n).len());
    });

    // --- router -------------------------------------------------------------
    b.bench("router submit+drain 1k reqs / 4 nets", || {
        let mut r = Router::new(&["a", "b", "c", "d"]);
        for i in 0..1000 {
            r.submit(["a", "b", "c", "d"][i % 4], i, i as u64).unwrap();
        }
        while let Some(q) = r.pick() {
            std::hint::black_box(r.drain(q, 32).len());
        }
    });

    // --- PJRT paths (need artifacts) ----------------------------------------
    match common::campaign() {
        Ok(campaign) => {
            let mut sess =
                NetSession::new(&campaign.rt, &campaign.manifest, "mini_mlp", &campaign.codebook)?;

            // What the static-literal cache saves: encoding the static
            // inputs (candidate table, teacher, codebook, ...) to XLA
            // literals, which the naive path would redo every step.
            let statics = sess.statics.clone();
            b.bench("literal-encode statics mini_mlp (cache saves this/step)", || {
                for t in &statics {
                    let l = vq4all::runtime::client::tensor_to_literal(t).unwrap();
                    std::hint::black_box(&l);
                }
            });
            let mut stream = CalibStream::new(
                sess.calib_x.clone(),
                sess.calib_y.clone(),
                "classify",
                sess.net.batch,
                1,
            );
            let batch = stream.next_batch()?;
            b.bench("PJRT train_step mini_mlp (S=57k n=8)", || {
                sess.train_step(&batch).unwrap();
            });
            let codes = sess.hard_codes(&vq4all::vq::ratios::FreezeState::new(sess.net.s_total));
            let codes_t = sess.codes_tensor(&codes);
            let eb: Vec<_> = vq4all::coordinator::calib::EvalBatches::new(
                &sess.test_x.clone(),
                &sess.test_y.clone(),
                "classify",
                sess.net.eval_batch,
                3,
            )
            .take(1)
            .collect::<anyhow::Result<_>>()?;
            b.bench("PJRT eval_hard batch=100 mini_mlp", || {
                sess.eval_batch("eval_hard", Some(&codes_t), &eb[0]).unwrap();
            });
            let x = eb[0][0].clone();
            b.bench("PJRT infer_hard (fused vq_matmul) batch=100", || {
                sess.eval_infer(&codes_t, std::slice::from_ref(&x)).unwrap();
            });
        }
        Err(e) => println!("skipping PJRT benches (no artifacts): {e}"),
    }

    b.report();
    Ok(())
}
