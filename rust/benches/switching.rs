//! E12 — task-switch I/O: per-layer DRAM codebooks vs universal ROM
//! (§3.2 / Table 1's I/O column), plus the silicon-area comparison.
mod common;

use vq4all::bench::Table;
use vq4all::rom::AreaModel;
use vq4all::serving::switchsim::{compare, SwitchWorkload};

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(
        "Task switching — codebook traffic (per-layer DRAM vs universal ROM)",
        &["nets", "layers", "cb KB", "P-VQ loads", "P-VQ MB moved", "ROM loads", "I/O multiple"],
    );
    for (nets, layers, kb) in [(2, 8, 64), (5, 20, 64), (5, 20, 256), (8, 30, 256)] {
        let w = SwitchWorkload {
            nets,
            layers_per_net: layers,
            codebook_bytes_per_layer: kb * 1024,
            rounds: 10,
            inferences_per_activation: 5,
            sram_bytes: layers * kb * 1024 * 3 / 2,
        };
        let (pl, rom) = compare(&w);
        t.row(vec![
            nets.to_string(),
            layers.to_string(),
            kb.to_string(),
            pl.codebook_loads.to_string(),
            format!("{:.1}", pl.codebook_bytes_loaded as f64 / 1e6),
            rom.codebook_loads.to_string(),
            format!("{}x vs 1x", pl.codebook_loads.max(1)),
        ]);
    }
    t.print();

    let area = AreaModel::default();
    let (sram, rom_mm2) = area.compare(5 * 20 * 256 * 1024, 2 << 20);
    println!(
        "\nsilicon area (7nm): per-layer SRAM-resident {sram:.3} mm^2 vs universal ROM {rom_mm2:.4} mm^2 ({:.0}x)",
        sram / rom_mm2
    );
    Ok(())
}
