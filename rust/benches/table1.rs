//! E1 — regenerate **Table 1** (UQ vs P-VQ vs U-VQ).
mod common;

use vq4all::exp::table1;
use vq4all::runtime::Manifest;
use vq4all::util::threadpool::ThreadPool;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&common::artifacts_dir())?;
    let pool = ThreadPool::new(0); // all cores; results thread-count-invariant
    let rows = table1::run_with(&manifest, &table1::default_configs(), Some(&pool))?;
    table1::render(&rows).print();
    table1::check_shape(&rows)?;
    println!("shape check: P-VQ/U-VQ < UQ on MSE, U-VQ I/O = 1x — OK");
    Ok(())
}
