//! E1 — regenerate **Table 1** (UQ vs P-VQ vs U-VQ).
mod common;

use vq4all::exp::table1;
use vq4all::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&common::artifacts_dir())?;
    let rows = table1::run(&manifest, &table1::default_configs())?;
    table1::render(&rows).print();
    table1::check_shape(&rows)?;
    println!("shape check: P-VQ/U-VQ < UQ on MSE, U-VQ I/O = 1x — OK");
    Ok(())
}
