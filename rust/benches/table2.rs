//! E3 — regenerate **Table 2** (detection under compression).
mod common;

use vq4all::exp::table2;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    let rows = table2::run(&campaign, "mini_detector")?;
    table2::render(&rows).print();
    Ok(())
}
