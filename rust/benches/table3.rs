//! E4 — regenerate **Table 3** (classification Top-1 / ratio).
mod common;

use vq4all::exp::table3;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    let rows = table3::run(
        &campaign,
        &["mini_resnet18", "mini_resnet50", "mini_mobilenet"],
    )?;
    table3::render(&rows).print();
    Ok(())
}
