//! E5 — regenerate **Table 4** (generative quality, mini diffusion).
mod common;

use vq4all::exp::table4;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    let rows = table4::run(&campaign, "mini_denoiser")?;
    table4::render(&rows).print();
    Ok(())
}
