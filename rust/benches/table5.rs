//! E6 — regenerate **Table 5** (ablations on 2-bit mini_resnet18).
mod common;

use vq4all::exp::table5;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    let net = "mini_resnet18";
    let n_rows = table5::candidate_count(&campaign, net, &[1, 2, 4, 8])?;
    let part_rows = table5::components(&campaign, net)?;
    let index = table5::index_distribution(&campaign, net)?;
    print!("{}", table5::render(&n_rows, &part_rows, &index));
    Ok(())
}
