//! E9/E10 — regenerate **Table 6** (codebook sources) and **Table 7**
//! (assignment-init strategies).
mod common;

use vq4all::exp::table6_7;
use vq4all::vq::assign::AssignInit;

fn main() -> anyhow::Result<()> {
    let campaign = common::campaign()?;
    let target = "mini_resnet18";
    let subsets: Vec<Vec<&str>> = vec![
        vec!["mini_resnet18"],
        vec!["mini_resnet18", "mini_resnet50"],
        vec!["mini_resnet18", "mini_resnet50", "mini_detector"],
        vec!["mini_resnet18", "mini_resnet50", "mini_detector", "mini_denoiser"],
    ];
    let t6 = table6_7::codebook_sources(&campaign, target, &subsets)?;
    table6_7::render("Table 6 — codebook weight-source combinations", &t6).print();

    let variants = [
        (AssignInit::Random, true, "random"),
        (AssignInit::Cosine, true, "cosine"),
        (AssignInit::Euclid, false, "euclid (equal init)"),
        (AssignInit::Euclid, true, "euclid + Eq.7 init"),
    ];
    let t7 = table6_7::assign_init(&campaign, target, &variants)?;
    table6_7::render("Table 7 — candidate-assignment initialization", &t7).print();
    Ok(())
}
