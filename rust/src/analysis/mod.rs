//! `vq4all-audit` — repo-native static analysis for the crate's unsafe
//! and perf-gate contracts.
//!
//! The whole perf story of this crate rests on conventions that used to
//! live only in comments: every `unsafe` `SyncPtr` write from
//! `ThreadPool::parallel_for` hits a disjoint chunk, and every
//! specialized kernel keeps a retained `*_reference`, a property test,
//! and a gated bench row.  This module machine-checks those conventions
//! over the source tree (std-only — the container is offline, so no
//! syn/proc-macro machinery):
//!
//! * [`scan`] — a small line-level Rust scanner (comments, strings,
//!   char-vs-lifetime) producing per-line code/comment parts;
//! * [`rules`] — the four contract rules: `safety-comment`,
//!   `unsafe-allowlist`, `reference-manifest`, `float-accumulation`;
//! * [`run_audit`] / [`audit_sources`] — the tree walker and the
//!   in-memory entry point (the latter is what the negative tests use).
//!
//! The CLI driver is `rust/src/bin/audit.rs` (`cargo run --bin audit`,
//! or `scripts/verify.sh --audit`).  The dynamic counterpart — the
//! `race-audit` cargo feature that shadow-checks actual `SyncPtr` write
//! ranges at every `parallel_for` join — lives in
//! [`crate::util::threadpool`].

pub mod rules;
pub mod scan;

use std::collections::HashSet;
use std::path::{Path, PathBuf};

pub use rules::{Finding, Rule};

/// Files allowed to contain `unsafe`.  This is the audit's module
/// allow-list: the parallel substrate itself, the chunked VQ kernels,
/// the explicit-SIMD dispatch arms, and the serving engine's decode
/// plane.  A new file that needs `unsafe` must be added here —
/// deliberately, in review.
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/util/threadpool.rs",
    "rust/src/vq/assign.rs",
    "rust/src/vq/codebook.rs",
    "rust/src/vq/kde.rs",
    "rust/src/vq/kmeans.rs",
    "rust/src/vq/pack.rs",
    "rust/src/vq/ratios.rs",
    "rust/src/vq/simd/mod.rs",
    "rust/src/vq/simd/x86.rs",
    "rust/src/vq/simd/neon.rs",
    "rust/src/serving/engine/mod.rs",
    "rust/src/serving/engine/shard.rs",
    "rust/src/serving/engine/stream.rs",
];

/// Reference-kernel manifest: every `pub fn *_reference` in the tree
/// must map here to the bench row that gates its specialized twin, must
/// be named by a property in `rust/tests/prop_substrate.rs`, and the
/// row must be listed in `scripts/bench_baseline.json`.  Landing a new
/// specialized kernel therefore forces the property test and the perf
/// gate to land with it.
pub const REFERENCE_KERNELS: &[(&str, &str)] = &[
    ("unpack_range_reference", "unpack_wordwise"),
    ("decode_packed_into_reference", "fused_decode"),
    ("encode_nearest_reference", "encode_pruned"),
    ("pack_codes_reference", "pack_wordwise"),
    ("encode_staged_reference", "staged_encode"),
    ("decode_staged_packed_into_reference", "staged_decode"),
    ("gather_rows_reference", "simd_gather"),
    ("gather_rows_add_reference", "simd_gather"),
    ("sq_dist_lanes_reference", "simd_scan"),
    ("sq_dist_pruned_lanes_reference", "simd_scan"),
];

/// Directories (relative to the repo root) the audit walks.
const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Aggregate result of one audit run.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Lines whose code part contains the `unsafe` token.
    pub unsafe_sites: usize,
    pub reference_kernels: usize,
}

impl AuditReport {
    pub fn passed(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Run every rule over an in-memory corpus of `(relative path, source)`
/// pairs.  `baseline_json` is the raw text of the committed bench-row
/// manifest; `extra_allow` extends [`UNSAFE_ALLOWLIST`] (used by the
/// negative tests and the CI seeded-violation checks).
pub fn audit_sources(
    files: &[(String, String)],
    baseline_json: &str,
    extra_allow: &[String],
) -> AuditReport {
    let mut allow: HashSet<String> = UNSAFE_ALLOWLIST.iter().map(|s| s.to_string()).collect();
    allow.extend(extra_allow.iter().cloned());

    let scanned: Vec<(String, Vec<scan::Line>)> = files
        .iter()
        .map(|(path, src)| (path.clone(), scan::strip(src)))
        .collect();

    let mut report = AuditReport {
        files_scanned: scanned.len(),
        ..Default::default()
    };
    for (path, lines) in &scanned {
        report.unsafe_sites += lines.iter().filter(|l| l.has_code_word("unsafe")).count();
        rules::check_safety_comments(path, lines, &mut report.findings);
        rules::check_allowlist(path, lines, &allow, &mut report.findings);
        rules::check_float_accumulation(path, lines, &mut report.findings);
        report.reference_kernels += rules::reference_kernel_defs(lines).len();
    }
    rules::check_reference_kernels(
        &scanned,
        REFERENCE_KERNELS,
        baseline_json,
        &mut report.findings,
    );
    report.findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    report
}

/// Walk the source tree under `root` and audit it against the baseline
/// manifest at `baseline` (missing baseline is itself a finding — the
/// manifest is part of the contract).
pub fn run_audit(root: &Path, baseline: &Path, extra_allow: &[String]) -> AuditReport {
    let mut files = Vec::new();
    for sub in SCAN_ROOTS {
        collect_rs_files(&root.join(sub), root, &mut files);
    }
    files.sort();
    let sources: Vec<(String, String)> = files
        .iter()
        .filter_map(|(rel, abs)| {
            std::fs::read_to_string(abs).ok().map(|s| (rel.clone(), s))
        })
        .collect();
    let baseline_text = std::fs::read_to_string(baseline).unwrap_or_default();
    let mut report = audit_sources(&sources, &baseline_text, extra_allow);
    if baseline_text.is_empty() {
        report.findings.push(Finding {
            rule: Rule::ReferenceManifest,
            file: baseline.display().to_string(),
            line: 0,
            message: "committed baseline manifest is missing or unreadable".to_string(),
        });
    }
    report
}

fn collect_rs_files(dir: &Path, root: &Path, out: &mut Vec<(String, PathBuf)>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, root, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLEAN_BASELINE: &str =
        "{\"comparisons\": [{\"name\": \"unpack_wordwise\"}, {\"name\": \"fused_decode\"}, \
         {\"name\": \"encode_pruned\"}, {\"name\": \"pack_wordwise\"}, \
         {\"name\": \"staged_encode\"}, {\"name\": \"staged_decode\"}, \
         {\"name\": \"simd_gather\"}, {\"name\": \"simd_scan\"}]}";

    fn prop_file() -> (String, String) {
        (
            "rust/tests/prop_substrate.rs".to_string(),
            "fn p() { unpack_range_reference(); decode_packed_into_reference(); \
             encode_nearest_reference(); pack_codes_reference(); \
             encode_staged_reference(); decode_staged_packed_into_reference(); \
             gather_rows_reference(); gather_rows_add_reference(); \
             sq_dist_lanes_reference(); sq_dist_pruned_lanes_reference(); }\n"
                .to_string(),
        )
    }

    #[test]
    fn clean_corpus_passes() {
        let files = vec![
            (
                "rust/src/vq/pack.rs".to_string(),
                "pub fn unpack_range_reference() {}\n\
                 pub fn pack_codes_reference() {}\n\
                 // SAFETY: chunks are disjoint.\n\
                 fn f(p: SyncPtr<u32>) { let _ = unsafe { p.slice(0, 1) }; }\n"
                    .to_string(),
            ),
            (
                "rust/src/vq/codebook.rs".to_string(),
                "pub fn decode_packed_into_reference() {}\n\
                 pub fn encode_nearest_reference() {}\n\
                 pub fn encode_staged_reference() {}\n\
                 pub fn decode_staged_packed_into_reference() {}\n"
                    .to_string(),
            ),
            (
                "rust/src/vq/simd/mod.rs".to_string(),
                "pub fn gather_rows_reference() {}\n\
                 pub fn gather_rows_add_reference() {}\n\
                 pub fn sq_dist_lanes_reference() {}\n\
                 pub fn sq_dist_pruned_lanes_reference() {}\n"
                    .to_string(),
            ),
            prop_file(),
        ];
        let r = audit_sources(&files, CLEAN_BASELINE, &[]);
        assert!(r.passed(), "{:?}", r.findings);
        assert_eq!(r.unsafe_sites, 1);
        assert_eq!(r.reference_kernels, 10);
    }

    #[test]
    fn uncommented_unsafe_snippet_fails_the_audit() {
        // The crafted negative case from the issue: a bare unsafe block
        // in an allow-listed file must produce a safety-comment finding.
        let files = vec![
            (
                "rust/src/vq/pack.rs".to_string(),
                "fn f(p: *const u8) { let _ = unsafe { *p }; }\n".to_string(),
            ),
            kernels_file(),
            prop_file(),
        ];
        let r = audit_sources(&files, CLEAN_BASELINE, &[]);
        assert!(!r.passed());
        assert!(r
            .findings
            .iter()
            .any(|f| f.rule == Rule::SafetyComment && f.file == "rust/src/vq/pack.rs"));
    }

    fn kernels_file() -> (String, String) {
        (
            "rust/src/vq/codebook.rs".to_string(),
            "pub fn unpack_range_reference() {}\n\
             pub fn decode_packed_into_reference() {}\n\
             pub fn encode_nearest_reference() {}\n\
             pub fn pack_codes_reference() {}\n\
             pub fn encode_staged_reference() {}\n\
             pub fn decode_staged_packed_into_reference() {}\n\
             pub fn gather_rows_reference() {}\n\
             pub fn gather_rows_add_reference() {}\n\
             pub fn sq_dist_lanes_reference() {}\n\
             pub fn sq_dist_pruned_lanes_reference() {}\n"
                .to_string(),
        )
    }

    #[test]
    fn non_allowlisted_unsafe_file_fails() {
        let files = vec![
            (
                "rust/src/serving/rogue.rs".to_string(),
                "// SAFETY: commented, but the module never opted in.\n\
                 fn f(p: *const u8) { let _ = unsafe { *p }; }\n"
                    .to_string(),
            ),
            kernels_file(),
            prop_file(),
        ];
        let r = audit_sources(&files, CLEAN_BASELINE, &[]);
        assert!(r
            .findings
            .iter()
            .any(|f| f.rule == Rule::UnsafeAllowlist && f.file == "rust/src/serving/rogue.rs"));
        // The same corpus passes once the file is explicitly allow-listed.
        let r2 = audit_sources(&files, CLEAN_BASELINE, &["rust/src/serving/rogue.rs".into()]);
        assert!(r2.passed(), "{:?}", r2.findings);
    }

    #[test]
    fn missing_baseline_row_fails() {
        let files = vec![kernels_file(), prop_file()];
        let partial =
            "{\"comparisons\": [{\"name\": \"unpack_wordwise\"}, {\"name\": \"fused_decode\"}]}";
        let r = audit_sources(&files, partial, &[]);
        assert!(r
            .findings
            .iter()
            .any(|f| f.rule == Rule::ReferenceManifest && f.message.contains("encode_pruned")));
    }

    #[test]
    fn real_tree_audit_is_wired() {
        // Walk the actual repo when run from the crate root; this is the
        // same entry point the audit binary uses.  Skip silently if the
        // layout is absent (e.g. running from an unusual cwd).
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        if !root.join("rust/src").is_dir() {
            return;
        }
        let report = run_audit(root, &root.join("scripts/bench_baseline.json"), &[]);
        assert!(report.files_scanned > 50, "walker found too few files");
        assert!(report.unsafe_sites >= 20, "unsafe sites undercounted");
        assert_eq!(report.reference_kernels, REFERENCE_KERNELS.len());
        assert!(
            report.passed(),
            "the committed tree must audit clean:\n{:#?}",
            report.findings
        );
    }
}
