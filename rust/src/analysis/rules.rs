//! The repo-contract rules enforced by `vq4all-audit`.
//!
//! Four rule families over the scanned source tree (see
//! [`super::scan`]):
//!
//! 1. **safety-comment** — every `unsafe` occurrence in code must carry
//!    a justification: a `// SAFETY:` comment on the same line, in the
//!    contiguous comment block directly above the statement, or (for
//!    `unsafe fn` / `unsafe impl` / `unsafe trait` declarations) a
//!    `# Safety` doc section.
//! 2. **unsafe-allowlist** — `unsafe` may appear only in the modules
//!    listed in [`super::UNSAFE_ALLOWLIST`] (the pool, the VQ kernels,
//!    the serving engine).  New files must opt in by being added there.
//! 3. **reference-manifest** — every `pub fn *_reference` kernel must
//!    have an entry in [`super::REFERENCE_KERNELS`], be named by a
//!    property in `rust/tests/prop_substrate.rs`, and have its mapped
//!    bench row present in the committed baseline manifest.  This
//!    cross-checks source against `scripts/bench_baseline.json` and
//!    catches the "kernel landed, gate forgot" failure class.
//! 4. **float-accumulation** — inside a `parallel_for` closure, a `+=`
//!    on a float variable captured from outside the closure is flagged:
//!    cross-chunk float reductions must go through chunk-ordered
//!    partials to stay bit-identical at every thread count.  Suppress a
//!    deliberate exception with `// audit: allow(float-accum)` on the
//!    same or the preceding line.

use std::collections::HashSet;

use super::scan::{has_word, Line};

/// Rule identifiers, stable strings for CI grepping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    SafetyComment,
    UnsafeAllowlist,
    ReferenceManifest,
    FloatAccumulation,
}

impl Rule {
    pub fn name(&self) -> &'static str {
        match self {
            Rule::SafetyComment => "safety-comment",
            Rule::UnsafeAllowlist => "unsafe-allowlist",
            Rule::ReferenceManifest => "reference-manifest",
            Rule::FloatAccumulation => "float-accumulation",
        }
    }
}

/// One audit violation.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    pub file: String,
    /// 1-based; 0 for file-level findings.
    pub line: usize,
    pub message: String,
}

/// Marker that suppresses the float-accumulation rule at one site.
const FLOAT_ACCUM_ALLOW: &str = "audit: allow(float-accum)";

/// How far upward a statement may continue before the safety-comment
/// walk gives up (defensive bound, real statements are far shorter).
const STMT_WALK_LIMIT: usize = 12;

fn stmt_terminates(code: &str) -> bool {
    let t = code.trim_end();
    t.ends_with(';') || t.ends_with('{') || t.ends_with('}')
}

/// Find the first line of the statement containing line `i`: walk
/// upward while the previous line is code that does not terminate a
/// statement (so multi-line statements like
/// `let x: T =\n    unsafe { ... };` anchor at the `let`).
fn statement_start(lines: &[Line], i: usize) -> usize {
    let mut j = i;
    let mut steps = 0;
    while j > 0 && steps < STMT_WALK_LIMIT {
        let prev = &lines[j - 1];
        if prev.is_blank() || prev.is_comment_only() || prev.is_attr_only() {
            break;
        }
        if stmt_terminates(&prev.code) {
            break;
        }
        j -= 1;
        steps += 1;
    }
    j
}

/// Collect the contiguous comment/attribute block directly above line
/// `start` (no blank line may intervene).
fn comment_block_above(lines: &[Line], start: usize) -> String {
    let mut text = String::new();
    let mut j = start;
    while j > 0 {
        let prev = &lines[j - 1];
        if prev.is_comment_only() || prev.is_attr_only() {
            text.push_str(&prev.comment);
            text.push('\n');
            j -= 1;
        } else {
            break;
        }
    }
    text
}

/// Rule 1: `// SAFETY:` discipline for every `unsafe` in code.
pub fn check_safety_comments(path: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    for (i, line) in lines.iter().enumerate() {
        if !line.has_code_word("unsafe") {
            continue;
        }
        let is_decl = ["unsafe fn", "unsafe impl", "unsafe trait"]
            .iter()
            .any(|p| line.code.contains(p));
        if line.comment.contains("SAFETY:") {
            continue;
        }
        let above = comment_block_above(lines, statement_start(lines, i));
        if above.contains("SAFETY:") || (is_decl && above.contains("# Safety")) {
            continue;
        }
        let kind = if is_decl { "declaration" } else { "block" };
        findings.push(Finding {
            rule: Rule::SafetyComment,
            file: path.to_string(),
            line: i + 1,
            message: format!(
                "unsafe {kind} without a `// SAFETY:` comment (same line, or the \
                 comment block directly above the statement{})",
                if is_decl { ", or a `# Safety` doc section" } else { "" }
            ),
        });
    }
}

/// Rule 2: `unsafe` only in allow-listed modules.
pub fn check_allowlist(
    path: &str,
    lines: &[Line],
    allow: &HashSet<String>,
    findings: &mut Vec<Finding>,
) {
    if allow.contains(path) {
        return;
    }
    if let Some(i) = lines.iter().position(|l| l.has_code_word("unsafe")) {
        findings.push(Finding {
            rule: Rule::UnsafeAllowlist,
            file: path.to_string(),
            line: i + 1,
            message: "file uses `unsafe` but is not in analysis::UNSAFE_ALLOWLIST \
                      (new unsafe modules must opt in there)"
                .to_string(),
        });
    }
}

/// Extract `pub fn <ident>` names ending in `_reference` from a scanned
/// file, with their 1-based line numbers.
pub fn reference_kernel_defs(lines: &[Line]) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let code = &line.code;
        for pat in ["pub fn ", "pub(crate) fn "] {
            if let Some(pos) = code.find(pat) {
                let rest = &code[pos + pat.len()..];
                let ident: String = rest
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .collect();
                if ident.ends_with("_reference") {
                    out.push((ident, i + 1));
                }
            }
        }
    }
    out
}

/// Rule 3: reference kernels ↔ property tests ↔ baseline manifest.
///
/// `files` is the scanned corpus; the prop-test file is located by path
/// suffix.  `kernel_map` maps reference-kernel fn names to the bench
/// row that gates them; `baseline_json` is the raw text of the
/// committed row manifest.
pub fn check_reference_kernels(
    files: &[(String, Vec<Line>)],
    kernel_map: &[(&str, &str)],
    baseline_json: &str,
    findings: &mut Vec<Finding>,
) {
    let prop = files
        .iter()
        .find(|(p, _)| p.ends_with("tests/prop_substrate.rs"));
    let prop_text: String = match &prop {
        Some((_, lines)) => lines
            .iter()
            .flat_map(|l| [l.code.as_str(), "\n"])
            .collect(),
        None => String::new(),
    };
    if prop.is_none() {
        findings.push(Finding {
            rule: Rule::ReferenceManifest,
            file: "rust/tests/prop_substrate.rs".to_string(),
            line: 0,
            message: "property-test file missing from the scanned tree".to_string(),
        });
    }

    let mut seen = Vec::new();
    for (path, lines) in files {
        for (name, line_no) in reference_kernel_defs(lines) {
            seen.push(name.clone());
            let Some((_, row)) = kernel_map.iter().find(|(k, _)| *k == name) else {
                findings.push(Finding {
                    rule: Rule::ReferenceManifest,
                    file: path.clone(),
                    line: line_no,
                    message: format!(
                        "reference kernel `{name}` has no entry in \
                         analysis::REFERENCE_KERNELS — add the kernel→bench-row \
                         mapping, a prop_substrate property naming it, and its row \
                         in scripts/bench_baseline.json"
                    ),
                });
                continue;
            };
            if prop.is_some() && !has_word(&prop_text, &name) {
                findings.push(Finding {
                    rule: Rule::ReferenceManifest,
                    file: path.clone(),
                    line: line_no,
                    message: format!(
                        "reference kernel `{name}` is never named in \
                         rust/tests/prop_substrate.rs — the specialized kernel has \
                         no property test pinning it to this reference"
                    ),
                });
            }
            if !baseline_json.contains(&format!("\"{row}\"")) {
                findings.push(Finding {
                    rule: Rule::ReferenceManifest,
                    file: path.clone(),
                    line: line_no,
                    message: format!(
                        "bench row \"{row}\" for reference kernel `{name}` is \
                         missing from the committed baseline manifest \
                         (scripts/bench_baseline.json) — the perf gate would never \
                         notice the row disappearing"
                    ),
                });
            }
        }
    }
    for (name, _) in kernel_map {
        if !seen.iter().any(|s| s == name) {
            findings.push(Finding {
                rule: Rule::ReferenceManifest,
                file: "rust/src/analysis/mod.rs".to_string(),
                line: 0,
                message: format!(
                    "analysis::REFERENCE_KERNELS lists `{name}` but no such \
                     `pub fn` exists in the tree (stale manifest entry)"
                ),
            });
        }
    }
}

/// The extent (inclusive line range) of a `parallel_for(...)` call
/// starting on line `i`: tracks parenthesis depth from the call's
/// opening paren until it closes.  Returns `None` when the call never
/// closes within the cap (malformed source).
fn call_extent(lines: &[Line], i: usize) -> Option<(usize, usize)> {
    let mut depth = 0usize;
    let mut opened = false;
    let mut at = lines[i].code.find("parallel_for").unwrap_or(0);
    for (j, line) in lines.iter().enumerate().skip(i).take(400) {
        for c in line.code[at.min(line.code.len())..].chars() {
            match c {
                '(' => {
                    depth += 1;
                    opened = true;
                }
                ')' => {
                    depth = depth.saturating_sub(1);
                    if opened && depth == 0 {
                        return Some((i, j));
                    }
                }
                _ => {}
            }
        }
        at = 0;
    }
    None
}

/// The identifier on the left of a `+=`, or `None` when the target is
/// indexed (`x[i] +=`, the sanctioned per-chunk slot pattern) or a
/// field/deref expression we do not reason about.
fn plain_accum_target(code: &str) -> Option<String> {
    let lhs = code.split("+=").next()?.trim_end();
    if lhs.ends_with(']') {
        return None;
    }
    let ident: String = lhs
        .chars()
        .rev()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect::<String>()
        .chars()
        .rev()
        .collect();
    if ident.is_empty() || ident.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    // `self.x +=` / `a.b +=` — field accumulation, skip.
    let before = lhs[..lhs.len() - ident.len()].trim_end();
    if before.ends_with('.') {
        return None;
    }
    Some(ident)
}

fn declares(code: &str, ident: &str) -> bool {
    [format!("let mut {ident}"), format!("let {ident}")]
        .iter()
        .any(|p| {
            code.find(p.as_str()).is_some_and(|pos| {
                let after = code[pos + p.len()..].chars().next();
                !matches!(after, Some(c) if c.is_ascii_alphanumeric() || c == '_')
            })
        })
}

fn looks_float_decl(code: &str) -> bool {
    if code.contains("f32") || code.contains("f64") {
        return true;
    }
    // A float literal on the declaration line: digit '.' digit.
    let b = code.as_bytes();
    (1..b.len().saturating_sub(1)).any(|k| {
        b[k] == b'.' && b[k - 1].is_ascii_digit() && b[k + 1].is_ascii_digit()
    })
}

/// Rule 4: captured-float `+=` inside `parallel_for` closures.
pub fn check_float_accumulation(path: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    for i in 0..lines.len() {
        if !lines[i].code.contains("parallel_for") {
            continue;
        }
        let Some((lo, hi)) = call_extent(lines, i) else {
            continue;
        };
        for j in lo..=hi.min(lines.len() - 1) {
            if !lines[j].code.contains("+=") {
                continue;
            }
            let Some(ident) = plain_accum_target(&lines[j].code) else {
                continue;
            };
            // Declared inside the closure extent → per-chunk local, fine.
            if (lo..=j).any(|k| declares(&lines[k].code, &ident)) {
                continue;
            }
            // Explicit suppression.
            let suppressed = lines[j].comment.contains(FLOAT_ACCUM_ALLOW)
                || (j > 0 && lines[j - 1].comment.contains(FLOAT_ACCUM_ALLOW));
            if suppressed {
                continue;
            }
            // Captured: float only if the visible outer declaration says so.
            let outer_decl = (0..lo)
                .rev()
                .take(120)
                .find(|&k| declares(&lines[k].code, &ident));
            let Some(decl_line) = outer_decl else {
                continue;
            };
            if !looks_float_decl(&lines[decl_line].code) {
                continue;
            }
            findings.push(Finding {
                rule: Rule::FloatAccumulation,
                file: path.to_string(),
                line: j + 1,
                message: format!(
                    "`{ident} +=` accumulates a captured float across parallel_for \
                     chunks (declared on line {}) — reduce through chunk-ordered \
                     per-chunk partials instead, or suppress a provably-ordered \
                     site with `// {FLOAT_ACCUM_ALLOW}`",
                    decl_line + 1
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::strip;

    fn run_safety(src: &str) -> Vec<Finding> {
        let mut f = Vec::new();
        check_safety_comments("t.rs", &strip(src), &mut f);
        f
    }

    #[test]
    fn bare_unsafe_block_is_flagged() {
        let f = run_safety("fn f(p: *const u8) { let _ = unsafe { *p }; }\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::SafetyComment);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn same_line_safety_passes() {
        let f = run_safety("let _ = unsafe { g() }; // SAFETY: g is total\n");
        assert!(f.is_empty());
    }

    #[test]
    fn comment_above_statement_passes() {
        let src = "// SAFETY: disjoint chunks.\nlet d = unsafe { p.slice(s, n) };\n";
        assert!(run_safety(src).is_empty());
    }

    #[test]
    fn comment_above_multiline_statement_passes() {
        let src = "// SAFETY: lifetime erasure is scoped.\n\
                   let f2: &'static F =\n    unsafe { std::mem::transmute(f1) };\n";
        assert!(run_safety(src).is_empty());
    }

    #[test]
    fn blank_line_breaks_the_comment_link() {
        let src = "// SAFETY: stale, far away.\n\nlet d = unsafe { g() };\n";
        assert_eq!(run_safety(src).len(), 1);
    }

    #[test]
    fn consecutive_blocks_each_need_their_own_comment() {
        let src = "// SAFETY: covers only the first.\n\
                   let a = unsafe { g() };\nlet b = unsafe { h() };\n";
        let f = run_safety(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn unsafe_decl_accepts_doc_safety_section() {
        let src = "/// # Safety\n/// Caller guarantees disjointness.\n\
                   pub unsafe fn slice(&self) {}\n";
        assert!(run_safety(src).is_empty());
    }

    #[test]
    fn unsafe_in_strings_and_comments_is_ignored() {
        let src = "let s = \"unsafe\"; // the word unsafe here is prose\n";
        assert!(run_safety(src).is_empty());
    }

    #[test]
    fn allowlist_blocks_new_files() {
        let allow: HashSet<String> = ["rust/src/util/threadpool.rs".to_string()].into();
        let lines = strip("fn f() { unsafe { g() } }\n");
        let mut f = Vec::new();
        check_allowlist("rust/src/rogue.rs", &lines, &allow, &mut f);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::UnsafeAllowlist);
        let mut f2 = Vec::new();
        check_allowlist("rust/src/util/threadpool.rs", &lines, &allow, &mut f2);
        assert!(f2.is_empty());
    }

    #[test]
    fn reference_kernel_defs_are_found() {
        let lines = strip(
            "pub fn unpack_range_reference(x: u8) {}\n\
             fn helper_reference_counts() {}\n\
             fn some_test_matches_scratch_reference() {}\n",
        );
        let defs = reference_kernel_defs(&lines);
        assert_eq!(defs.len(), 1);
        assert_eq!(defs[0].0, "unpack_range_reference");
    }

    fn kernel_corpus() -> Vec<(String, Vec<crate::analysis::scan::Line>)> {
        vec![
            (
                "rust/src/vq/k.rs".to_string(),
                strip("pub fn foo_reference(x: u8) {}\n"),
            ),
            (
                "rust/tests/prop_substrate.rs".to_string(),
                strip("fn p() { foo_reference(1); }\n"),
            ),
        ]
    }

    #[test]
    fn mapped_tested_gated_kernel_passes() {
        let mut f = Vec::new();
        check_reference_kernels(
            &kernel_corpus(),
            &[("foo_reference", "foo_row")],
            "{\"comparisons\": [{\"name\": \"foo_row\"}]}",
            &mut f,
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn missing_baseline_row_is_flagged() {
        let mut f = Vec::new();
        check_reference_kernels(
            &kernel_corpus(),
            &[("foo_reference", "foo_row")],
            "{\"comparisons\": []}",
            &mut f,
        );
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("foo_row"));
    }

    #[test]
    fn unmapped_kernel_and_stale_entry_are_flagged() {
        let mut f = Vec::new();
        check_reference_kernels(&kernel_corpus(), &[("gone_reference", "r")], "{}", &mut f);
        let msgs: Vec<_> = f.iter().map(|x| x.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("no entry")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("stale")), "{msgs:?}");
    }

    #[test]
    fn untested_kernel_is_flagged() {
        let corpus = vec![
            (
                "rust/src/vq/k.rs".to_string(),
                strip("pub fn foo_reference(x: u8) {}\n"),
            ),
            ("rust/tests/prop_substrate.rs".to_string(), strip("fn p() {}\n")),
        ];
        let mut f = Vec::new();
        check_reference_kernels(
            &corpus,
            &[("foo_reference", "foo_row")],
            "{\"comparisons\": [{\"name\": \"foo_row\"}]}",
            &mut f,
        );
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("never named"));
    }

    fn run_accum(src: &str) -> Vec<Finding> {
        let mut f = Vec::new();
        check_float_accumulation("t.rs", &strip(src), &mut f);
        f
    }

    #[test]
    fn captured_float_accumulation_is_flagged() {
        let src = "let mut acc = 0.0f32;\n\
                   pool.parallel_for(n, 64, |s, e| {\n\
                       acc += kernel(s, e);\n\
                   })\n\
                   .unwrap();\n";
        let f = run_accum(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::FloatAccumulation);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn closure_local_accumulator_passes() {
        let src = "pool.parallel_for(n, 64, |s, e| {\n\
                       let mut local = 0.0f64;\n\
                       local += kernel(s, e);\n\
                       out[s / 64] = local;\n\
                   })\n\
                   .unwrap();\n";
        assert!(run_accum(src).is_empty());
    }

    #[test]
    fn indexed_slot_writes_pass() {
        let src = "let mut parts = vec![0.0f64; 4];\n\
                   pool.parallel_for(n, 64, |s, e| {\n\
                       parts[s / 64] += kernel(s, e);\n\
                   })\n\
                   .unwrap();\n";
        assert!(run_accum(src).is_empty());
    }

    #[test]
    fn integer_accumulation_passes() {
        let src = "let mut count = 0usize;\n\
                   pool.parallel_for(n, 64, |s, e| {\n\
                       count += e - s;\n\
                   })\n\
                   .unwrap();\n";
        assert!(run_accum(src).is_empty());
    }

    #[test]
    fn suppression_comment_is_honored() {
        let src = "let mut acc = 0.0f32;\n\
                   pool.parallel_for(n, 64, |s, e| {\n\
                       // audit: allow(float-accum)\n\
                       acc += kernel(s, e);\n\
                   })\n\
                   .unwrap();\n";
        assert!(run_accum(src).is_empty());
    }
}
