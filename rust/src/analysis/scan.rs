//! Minimal line-level Rust source scanner.
//!
//! Splits a source file into per-line `(code, comment)` parts with
//! string-literal *contents* removed from the code part (the delimiting
//! quotes stay, so the code keeps its token shape).  That is exactly
//! enough for the repo-contract rules in [`super::rules`]: keyword
//! occurrences ("unsafe", "parallel_for", "+=") are only meaningful in
//! the code part, and `// SAFETY:` markers only in the comment part.
//!
//! This is deliberately **not** a Rust parser.  It handles the lexical
//! constructs that would otherwise confuse a substring search — line and
//! nested block comments, plain and raw strings (both spanning lines),
//! byte strings, and char literals vs. lifetimes — and nothing more.

/// One scanned source line: the code part (string contents blanked) and
/// the comment part (line-comment text plus any block-comment text that
/// crosses the line).
#[derive(Clone, Debug, Default)]
pub struct Line {
    pub code: String,
    pub comment: String,
}

impl Line {
    /// Line holds nothing but whitespace.
    pub fn is_blank(&self) -> bool {
        self.code.trim().is_empty() && self.comment.trim().is_empty()
    }

    /// Line is comment-only (no code tokens).
    pub fn is_comment_only(&self) -> bool {
        self.code.trim().is_empty() && !self.comment.trim().is_empty()
    }

    /// Line is a (single-line) attribute such as `#[allow(...)]`.
    pub fn is_attr_only(&self) -> bool {
        let t = self.code.trim();
        t.starts_with("#[") || t.starts_with("#![")
    }

    /// True when `word` appears in the code part as a standalone token
    /// (not as a substring of a longer identifier).
    pub fn has_code_word(&self, word: &str) -> bool {
        has_word(&self.code, word)
    }
}

/// Standalone-token search in arbitrary text.
pub fn has_word(text: &str, word: &str) -> bool {
    let bytes = text.as_bytes();
    let mut from = 0;
    while let Some(pos) = text[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lexical state carried across lines.
enum State {
    Code,
    /// Inside a nested block comment at the given depth.
    Block(usize),
    /// Inside a `"..."` (or `b"..."`) string literal.
    Str,
    /// Inside a raw string closed by `"` followed by this many `#`s.
    RawStr(usize),
}

/// Scan `source` into per-line code/comment parts.
pub fn strip(source: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Code;
    for raw in source.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut line = Line::default();
        let mut i = 0;
        while i < chars.len() {
            match state {
                State::Block(depth) => {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::Block(depth + 1);
                        line.comment.push_str("/*");
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        state = if depth <= 1 { State::Code } else { State::Block(depth - 1) };
                        line.comment.push_str("*/");
                        i += 2;
                    } else {
                        line.comment.push(chars[i]);
                        i += 1;
                    }
                }
                State::Str => {
                    if chars[i] == '\\' {
                        i += 2;
                    } else if chars[i] == '"' {
                        line.code.push('"');
                        state = State::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    if chars[i] == '"' && closes_raw(&chars, i + 1, hashes) {
                        line.code.push('"');
                        state = State::Code;
                        i += 1 + hashes;
                    } else {
                        i += 1;
                    }
                }
                State::Code => {
                    let c = chars[i];
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        line.comment.push_str(&chars[i..].iter().collect::<String>());
                        i = chars.len();
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::Block(1);
                        line.comment.push_str("/*");
                        i += 2;
                    } else if c == '"' {
                        line.code.push('"');
                        state = State::Str;
                        i += 1;
                    } else if c == 'r' && is_raw_string_start(&chars, i) {
                        let hashes = count_hashes(&chars, i + 1);
                        line.code.push('"');
                        state = State::RawStr(hashes);
                        i += 2 + hashes;
                    } else if c == 'b' && chars.get(i + 1) == Some(&'"') {
                        line.code.push('"');
                        state = State::Str;
                        i += 2;
                    } else if c == '\'' {
                        if let Some(skip) = char_literal_len(&chars, i) {
                            line.code.push(' ');
                            i += skip;
                        } else {
                            // A lifetime tick (`'a`) — plain code.
                            line.code.push(c);
                            i += 1;
                        }
                    } else {
                        line.code.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(line);
    }
    out
}

/// `r"`, `r#"`, `r##"`, ... starting at `chars[at] == 'r'` — but not an
/// identifier that merely contains `r` (checked by the caller passing a
/// code-mode position; we additionally require the previous char not be
/// part of an identifier).
fn is_raw_string_start(chars: &[char], at: usize) -> bool {
    if at > 0 {
        let p = chars[at - 1];
        if p.is_ascii_alphanumeric() || p == '_' {
            return false;
        }
    }
    let mut j = at + 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

fn count_hashes(chars: &[char], from: usize) -> usize {
    let mut n = 0;
    while chars.get(from + n) == Some(&'#') {
        n += 1;
    }
    n
}

fn closes_raw(chars: &[char], from: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| chars.get(from + k) == Some(&'#'))
}

/// Length (in chars, including both quotes) of a char literal starting
/// at `chars[at] == '\''`, or `None` if this is a lifetime tick.
fn char_literal_len(chars: &[char], at: usize) -> Option<usize> {
    match chars.get(at + 1)? {
        '\\' => {
            // Escaped: '\n', '\'', '\\', '\u{..}', '\x7f'.
            let mut j = at + 2;
            if chars.get(j) == Some(&'u') && chars.get(j + 1) == Some(&'{') {
                j += 2;
                while j < chars.len() && chars[j] != '}' {
                    j += 1;
                }
                j += 1;
            } else if chars.get(j) == Some(&'x') {
                j += 3;
            } else {
                j += 1;
            }
            (chars.get(j) == Some(&'\'')).then_some(j + 1 - at)
        }
        _ => (chars.get(at + 2) == Some(&'\'')).then_some(3),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comment_split() {
        let l = strip("let x = 1; // SAFETY: fine\n");
        assert_eq!(l[0].code.trim(), "let x = 1;");
        assert!(l[0].comment.contains("SAFETY:"));
    }

    #[test]
    fn string_contents_removed_from_code() {
        let l = strip("let s = \"unsafe parallel_for\";\n");
        assert!(!l[0].has_code_word("unsafe"));
        assert!(!l[0].code.contains("parallel_for"));
        assert!(l[0].code.contains("let s = "));
    }

    #[test]
    fn raw_string_spanning_lines() {
        let src = "let s = r#\"line one unsafe\nline two\"#;\nlet y = 2;\n";
        let l = strip(src);
        assert!(!l[0].has_code_word("unsafe"));
        assert!(l[2].code.contains("let y = 2;"));
    }

    #[test]
    fn nested_block_comment() {
        let src = "/* outer /* unsafe */ still comment */ let z = 3;\n";
        let l = strip(src);
        assert!(!l[0].has_code_word("unsafe"));
        assert!(l[0].code.contains("let z = 3;"));
        assert!(l[0].comment.contains("still comment"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let l = strip("fn f<'a>(x: &'a str) { let q = '\\''; let w = 'u'; }\n");
        assert!(l[0].code.contains("<'a>"));
        assert!(!l[0].code.contains("'u'"));
    }

    #[test]
    fn word_boundaries() {
        let l = strip("let unsafe_count = 1;\n");
        assert!(!l[0].has_code_word("unsafe"));
        let l = strip("unsafe { x() };\n");
        assert!(l[0].has_code_word("unsafe"));
    }

    #[test]
    fn block_comment_carries_across_lines() {
        let l = strip("/* SAFETY: spans\nlines */ unsafe { f() }\n");
        assert!(l[0].comment.contains("SAFETY:"));
        assert!(l[1].has_code_word("unsafe"));
        assert!(l[1].comment.contains("lines"));
    }
}
