//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bencher::bench`] — warmup, calibrated iteration counts, and
//! mean/p50/p99 reporting, plus a table renderer shared by the paper
//! experiment harnesses.

use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

/// Harness with per-run configuration.
pub struct Bencher {
    /// Target measurement time per benchmark.
    pub target_ms: f64,
    /// Minimum samples.
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // `VQ4ALL_BENCH_MS` scales all benches (CI uses small values).
        let target_ms = std::env::var("VQ4ALL_BENCH_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(300.0);
        Bencher {
            target_ms,
            min_samples: 10,
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE iteration of the operation.
    /// Returns the result and records it for [`Bencher::report`].
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibration: find an iteration count that takes ~10ms.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let samples = ((self.target_ms / 1e3 / once) as usize)
            .clamp(self.min_samples, 100_000);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_secs_f64() * 1e9);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples as u64,
            mean_ns: stats::mean(&times),
            p50_ns: stats::percentile(&times, 50.0),
            p99_ns: stats::percentile(&times, 99.0),
            min_ns: times.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!(
            "bench {:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            res.name,
            res.iters,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p99_ns)
        );
        self.results.push(res.clone());
        res
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Dump every recorded result (plus optional serial-vs-parallel
    /// comparisons) as a JSON report, so later PRs get a perf trajectory
    /// (`BENCH_hotpath.json` is the first consumer).  `extras` appends
    /// additional top-level keys (e.g. the serving-engine cache summary
    /// `scripts/verify.sh` gates on).
    pub fn write_json(
        &self,
        path: &Path,
        comparisons: &[Comparison],
        extras: &[(&str, Json)],
    ) -> anyhow::Result<()> {
        let benchmarks = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(r.name.clone())),
                        ("iters", Json::num(r.iters as f64)),
                        ("mean_ns", Json::num(r.mean_ns)),
                        ("p50_ns", Json::num(r.p50_ns)),
                        ("p99_ns", Json::num(r.p99_ns)),
                        ("min_ns", Json::num(r.min_ns)),
                    ])
                })
                .collect(),
        );
        let comps = Json::Arr(
            comparisons
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        ("name", Json::str(c.name.clone())),
                        ("serial_ns", Json::num(c.serial_ns)),
                        ("parallel_ns", Json::num(c.parallel_ns)),
                        ("threads", Json::num(c.threads as f64)),
                        ("speedup", Json::num(c.speedup())),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("target_ms", Json::num(self.target_ms)),
            ("benchmarks", benchmarks),
            ("comparisons", comps),
        ];
        for (k, v) in extras {
            fields.push((k, v.clone()));
        }
        let doc = Json::obj(fields);
        std::fs::write(path, doc.to_string())
            .map_err(|e| anyhow::anyhow!("writing bench report {path:?}: {e}"))?;
        Ok(())
    }

    pub fn report(&self) {
        println!("\n== bench summary ({} benchmarks) ==", self.results.len());
        for r in &self.results {
            println!("  {:<40} mean {}", r.name, fmt_ns(r.mean_ns));
        }
    }
}

/// One serial-vs-parallel measurement pair (mean ns per iteration).
#[derive(Clone, Debug)]
pub struct Comparison {
    pub name: String,
    pub serial_ns: f64,
    pub parallel_ns: f64,
    /// Worker threads the parallel run used.
    pub threads: usize,
}

impl Comparison {
    pub fn new(name: &str, serial: &BenchResult, parallel: &BenchResult, threads: usize) -> Self {
        Comparison {
            name: name.to_string(),
            serial_ns: serial.mean_ns,
            parallel_ns: parallel.mean_ns,
            threads,
        }
    }

    pub fn speedup(&self) -> f64 {
        self.serial_ns / self.parallel_ns.max(1e-9)
    }
}

/// Human time formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Fixed-width table renderer for the paper-reproduction harnesses.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = format!("\n=== {} ===\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, &w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        s.push_str(&fmt_row(&self.headers, &widths));
        s.push('\n');
        s.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&fmt_row(row, &widths));
            s.push('\n');
        }
        s
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("VQ4ALL_BENCH_MS", "5");
        let mut b = Bencher::new();
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("long-name"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_report_roundtrips() {
        std::env::set_var("VQ4ALL_BENCH_MS", "5");
        let mut b = Bencher::new();
        let serial = b.bench("kernel [serial]", || {
            std::hint::black_box(0u64);
        });
        let parallel = b.bench("kernel [parallel]", || {
            std::hint::black_box(0u64);
        });
        let comp = Comparison::new("kernel", &serial, &parallel, 4);
        assert!(comp.speedup() > 0.0);
        let path = std::env::temp_dir().join("vq4all_bench_report_test.json");
        let extra = crate::util::json::Json::obj(vec![(
            "cache_hit_rate",
            crate::util::json::Json::num(0.5),
        )]);
        b.write_json(&path, &[comp], &[("engine", extra)]).unwrap();
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.req_arr("benchmarks").unwrap().len(), 2);
        let c = &doc.req_arr("comparisons").unwrap()[0];
        assert_eq!(c.req_str("name").unwrap(), "kernel");
        assert_eq!(c.req_usize("threads").unwrap(), 4);
        // Extras land as top-level keys.
        let eng = doc.req("engine").unwrap();
        assert_eq!(eng.req_f64("cache_hit_rate").unwrap(), 0.5);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(5.0), "5.0ns");
        assert!(fmt_ns(1500.0).ends_with("µs"));
        assert!(fmt_ns(2.5e6).ends_with("ms"));
        assert!(fmt_ns(3e9).ends_with("s"));
    }
}
