//! `vq4all-audit` — the repo-contract static analyzer CLI.
//!
//! Usage:
//!
//! ```text
//! cargo run --release --bin audit [-- <repo root>]
//! ```
//!
//! Environment overrides (used by the CI seeded-violation regressions):
//!
//! * `VQ4ALL_AUDIT_ROOT`        repo root to scan (default `.` / argv[1])
//! * `VQ4ALL_AUDIT_BASELINE`    bench-row manifest path
//!                              (default `<root>/scripts/bench_baseline.json`)
//! * `VQ4ALL_AUDIT_EXTRA_ALLOW` colon-separated extra allow-listed
//!                              relative paths for the unsafe-allowlist
//!                              rule (testing only)
//!
//! Exit code 0 when the tree audits clean, 1 when any finding exists.
//! See `vq4all::analysis` for the rules.

use std::path::PathBuf;
use std::process::ExitCode;

use vq4all::analysis;

fn main() -> ExitCode {
    let arg_root = std::env::args().nth(1);
    let root = std::env::var("VQ4ALL_AUDIT_ROOT")
        .ok()
        .or(arg_root)
        .unwrap_or_else(|| ".".to_string());
    let root = PathBuf::from(root);
    let baseline = std::env::var("VQ4ALL_AUDIT_BASELINE")
        .map(PathBuf::from)
        .unwrap_or_else(|_| root.join("scripts/bench_baseline.json"));
    let extra_allow: Vec<String> = std::env::var("VQ4ALL_AUDIT_EXTRA_ALLOW")
        .map(|v| v.split(':').filter(|s| !s.is_empty()).map(str::to_string).collect())
        .unwrap_or_default();

    let report = analysis::run_audit(&root, &baseline, &extra_allow);
    println!(
        "vq4all-audit: {} files, {} unsafe sites, {} reference kernels (root: {})",
        report.files_scanned,
        report.unsafe_sites,
        report.reference_kernels,
        root.display()
    );
    if report.files_scanned == 0 {
        eprintln!("vq4all-audit: FAIL — nothing scanned (wrong root?)");
        return ExitCode::FAILURE;
    }
    if report.passed() {
        println!("vq4all-audit: OK — all contracts hold");
        return ExitCode::SUCCESS;
    }
    for f in &report.findings {
        let loc = if f.line > 0 {
            format!("{}:{}", f.file, f.line)
        } else {
            f.file.clone()
        };
        println!("  FAIL [{}] {loc}: {}", f.rule.name(), f.message);
    }
    eprintln!("vq4all-audit: FAIL — {} finding(s)", report.findings.len());
    ExitCode::FAILURE
}
