//! Print the one-line SIMD dispatch report and exit.
//!
//! CI's `simd-matrix` job runs this under each `VQ4ALL_SIMD` setting
//! and asserts on the `active=` / `best=` fields — proving which kernel
//! arm the accompanying `cargo test` run exercised, rather than
//! trusting that runtime dispatch did the right thing silently.

fn main() {
    println!("{}", vq4all::vq::simd::probe_line());
}
