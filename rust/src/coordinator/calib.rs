//! Calibration-data streaming: deterministic shuffled batches over the
//! `.vqt` datasets, with the task-specific extras (diffusion timesteps
//! and noise for the denoiser — the graph consumes them as inputs so the
//! coordinator owns the randomness).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Batch iterator over a calibration split.
pub struct CalibStream {
    x: Tensor,
    y: Tensor,
    task: String,
    batch: usize,
    timesteps: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl CalibStream {
    pub fn new(x: Tensor, y: Tensor, task: &str, batch: usize, seed: u64) -> Self {
        let n = x.shape[0];
        assert!(batch <= n, "batch {batch} > dataset {n}");
        let mut rng = Rng::new(seed);
        let order = rng.permutation(n);
        CalibStream {
            x,
            y,
            task: task.to_string(),
            batch,
            timesteps: 50,
            order,
            cursor: 0,
            rng,
        }
    }

    pub fn len(&self) -> usize {
        self.x.shape[0]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Next batch as the train-step's batch inputs (manifest order).
    pub fn next_batch(&mut self) -> anyhow::Result<Vec<Tensor>> {
        let n = self.len();
        if self.cursor + self.batch > n {
            // Epoch boundary: reshuffle.
            self.order = self.rng.permutation(n);
            self.cursor = 0;
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;

        let xb = gather_rows(&self.x, idx)?;
        match self.task.as_str() {
            "classify" | "detect" => {
                let yb = gather_rows(&self.y, idx)?;
                Ok(vec![xb, yb])
            }
            "denoise" => {
                // x0 + random timesteps + random noise (graph builds x_t).
                let t: Vec<i32> = (0..self.batch)
                    .map(|_| self.rng.below(self.timesteps) as i32)
                    .collect();
                let mut eps = vec![0.0f32; self.batch * 2];
                self.rng.fill_normal(&mut eps);
                Ok(vec![
                    xb,
                    Tensor::from_i32(&[self.batch], t),
                    Tensor::from_f32(&[self.batch, 2], eps),
                ])
            }
            other => anyhow::bail!("unknown task {other:?}"),
        }
    }
}

/// Row-gather along axis 0 (f32 or i32).
pub fn gather_rows(t: &Tensor, idx: &[usize]) -> anyhow::Result<Tensor> {
    let row: usize = t.shape[1..].iter().product();
    let mut shape = t.shape.clone();
    shape[0] = idx.len();
    match &t.data {
        crate::tensor::Storage::F32(v) => {
            let mut out = Vec::with_capacity(idx.len() * row);
            for &i in idx {
                out.extend_from_slice(&v[i * row..(i + 1) * row]);
            }
            Ok(Tensor::from_f32(&shape, out))
        }
        crate::tensor::Storage::I32(v) => {
            let mut out = Vec::with_capacity(idx.len() * row);
            for &i in idx {
                out.extend_from_slice(&v[i * row..(i + 1) * row]);
            }
            Ok(Tensor::from_i32(&shape, out))
        }
        other => anyhow::bail!("gather_rows: unsupported dtype {:?}", other.dtype()),
    }
}

/// Sequential eval batches (no shuffle, truncating the tail).
pub struct EvalBatches<'a> {
    x: &'a Tensor,
    y: &'a Tensor,
    task: &'a str,
    batch: usize,
    cursor: usize,
    timesteps: usize,
    rng: Rng,
}

impl<'a> EvalBatches<'a> {
    pub fn new(x: &'a Tensor, y: &'a Tensor, task: &'a str, batch: usize, seed: u64) -> Self {
        EvalBatches {
            x,
            y,
            task,
            batch,
            cursor: 0,
            timesteps: 50,
            rng: Rng::new(seed),
        }
    }

    pub fn num_batches(&self) -> usize {
        self.x.shape[0] / self.batch
    }
}

impl<'a> Iterator for EvalBatches<'a> {
    type Item = anyhow::Result<Vec<Tensor>>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.cursor + self.batch > self.x.shape[0] {
            return None;
        }
        let idx: Vec<usize> = (self.cursor..self.cursor + self.batch).collect();
        self.cursor += self.batch;
        let xb = match gather_rows(self.x, &idx) {
            Ok(t) => t,
            Err(e) => return Some(Err(e)),
        };
        let out = match self.task {
            "classify" | "detect" => match gather_rows(self.y, &idx) {
                Ok(yb) => Ok(vec![xb, yb]),
                Err(e) => Err(e),
            },
            "denoise" => {
                let b = self.batch;
                let t: Vec<i32> = (0..b).map(|_| self.rng.below(self.timesteps) as i32).collect();
                let mut eps = vec![0.0f32; b * 2];
                self.rng.fill_normal(&mut eps);
                Ok(vec![
                    xb,
                    Tensor::from_i32(&[b], t),
                    Tensor::from_f32(&[b, 2], eps),
                ])
            }
            other => Err(anyhow::anyhow!("unknown task {other:?}")),
        };
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xy(n: usize) -> (Tensor, Tensor) {
        let x = Tensor::from_f32(&[n, 2], (0..n * 2).map(|i| i as f32).collect());
        let y = Tensor::from_i32(&[n], (0..n as i32).collect());
        (x, y)
    }

    #[test]
    fn batches_have_right_shapes() {
        let (x, y) = xy(10);
        let mut s = CalibStream::new(x, y, "classify", 4, 1);
        let b = s.next_batch().unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].shape, vec![4, 2]);
        assert_eq!(b[1].shape, vec![4]);
    }

    #[test]
    fn epoch_covers_all_samples() {
        let (x, y) = xy(8);
        let mut s = CalibStream::new(x, y, "classify", 4, 2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..2 {
            let b = s.next_batch().unwrap();
            for &v in b[1].as_i32().unwrap() {
                seen.insert(v);
            }
        }
        assert_eq!(seen.len(), 8, "one epoch covers every sample exactly once");
    }

    #[test]
    fn denoise_batches_carry_t_and_eps() {
        let x = Tensor::from_f32(&[16, 2], vec![0.0; 32]);
        let y = Tensor::from_i32(&[16], vec![0; 16]);
        let mut s = CalibStream::new(x, y, "denoise", 8, 3);
        let b = s.next_batch().unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b[1].shape, vec![8]);
        assert!(b[1].as_i32().unwrap().iter().all(|&t| (0..50).contains(&t)));
        assert_eq!(b[2].shape, vec![8, 2]);
    }

    #[test]
    fn eval_batches_sequential_and_truncated() {
        let (x, y) = xy(10);
        let ev = EvalBatches::new(&x, &y, "classify", 4, 0);
        let batches: Vec<_> = ev.map(|b| b.unwrap()).collect();
        assert_eq!(batches.len(), 2, "10/4 -> 2 full batches");
        assert_eq!(batches[0][1].as_i32().unwrap(), &[0, 1, 2, 3]);
        assert_eq!(batches[1][1].as_i32().unwrap(), &[4, 5, 6, 7]);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = xy(10);
        let mut a = CalibStream::new(x.clone(), y.clone(), "classify", 4, 7);
        let mut b = CalibStream::new(x, y, "classify", 4, 7);
        for _ in 0..5 {
            assert_eq!(
                a.next_batch().unwrap()[1].as_i32().unwrap(),
                b.next_batch().unwrap()[1].as_i32().unwrap()
            );
        }
    }
}
