//! The multi-network construction campaign — VQ4ALL's Algorithm 1 over
//! the whole zoo with one frozen universal codebook.
//!
//! Flow per network (the paper's pipeline, Figure 1):
//!
//! 1. `init_assign` (device): top-n candidates + Eq. 7 logits.
//! 2. loop: stream a calibration batch → `train_step` (device) →
//!    every `pnc_interval` steps the PNC scheduler scans the logits and
//!    freezes groups past `alpha` (Eq. 14), feeding the one-hot masks
//!    back as inputs.
//! 3. stop at `steps` or when fully constructed; collapse the remainder
//!    to argmax codes; `eval_hard` (device) for the deliverable metric.
//! 4. pack the codes (`log2 k` bits/group) and account sizes — the
//!    universal codebook contributes **zero** per-network bytes (ROM).

use std::path::Path;

use crate::runtime::artifact::Manifest;
use crate::runtime::client::Runtime;
use crate::tensor::{io, Tensor};
use crate::util::config::CampaignConfig;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use crate::vq::pack::{pack_codes, PackedCodes, SizeReport};
use crate::vq::KdeSampler;

use super::calib::CalibStream;
use super::pnc::PncScheduler;
use super::session::NetSession;

/// Per-network campaign outcome.
#[derive(Clone, Debug)]
pub struct NetResult {
    pub name: String,
    pub task: String,
    pub float_metric: f64,
    pub soft_metric: f64,
    pub hard_metric: f64,
    pub hard_loss: f64,
    pub steps: usize,
    pub frozen_fraction: f64,
    pub loss_curve: Vec<[f32; 4]>,
    /// (step, soft metric) samples when `eval_interval > 0`.
    pub metric_curve: Vec<(usize, f64)>,
    pub packed: PackedCodes,
    pub sizes: SizeReport,
    pub codes: Vec<u32>,
    /// Final ratio logits (S*n) — feeds the Figure-3 ratio histogram.
    pub final_z: Vec<f32>,
    /// Final trained "other" params (bias/norm/head), in `net.others`
    /// order.  Deploying the codes requires *these*, not the teacher's —
    /// they were co-trained with the soft reconstruction (§4.2); pairing
    /// the codes with teacher others measurably degrades the network
    /// (most visibly the denoiser, Table 4).
    pub final_others: Vec<crate::tensor::Tensor>,
}

impl NetResult {
    pub fn accuracy_drop(&self) -> f64 {
        self.float_metric - self.hard_metric
    }
}

/// Whole-campaign outcome.
#[derive(Clone, Debug)]
pub struct CampaignResult {
    pub nets: Vec<NetResult>,
    pub codebook_bytes: usize,
    pub effective_bit: f64,
}

/// Campaign driver.
pub struct Campaign {
    pub rt: Runtime,
    pub manifest: Manifest,
    pub cfg: CampaignConfig,
    pub codebook: Tensor,
}

impl Campaign {
    /// Load the manifest + the default (python-exported) universal
    /// codebook from `dir`.
    pub fn load(dir: &Path, cfg: CampaignConfig) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let codebook = io::read_tensor(&manifest.path(&manifest.codebook_file))?;
        anyhow::ensure!(
            codebook.shape == vec![manifest.config.k, manifest.config.d],
            "codebook shape {:?} != ({}, {})",
            codebook.shape,
            manifest.config.k,
            manifest.config.d
        );
        Ok(Campaign {
            rt: Runtime::cpu()?,
            manifest,
            cfg,
            codebook,
        })
    }

    /// Rebuild the universal codebook in Rust from the zoo's float
    /// sub-vectors (§4.1 done natively — used by Table 6's combination
    /// study and to cross-check the python sampler).  Serial entry point;
    /// output is identical to [`Campaign::build_codebook_from_with`] at
    /// any thread count.
    pub fn build_codebook_from(
        manifest: &Manifest,
        nets: &[&str],
        seed: u64,
    ) -> anyhow::Result<Tensor> {
        Self::build_codebook_from_with(manifest, nets, seed, None)
    }

    /// Native KDE codebook build with the sample-pool construction and
    /// codebook draw spread over a worker pool.
    pub fn build_codebook_from_with(
        manifest: &Manifest,
        nets: &[&str],
        seed: u64,
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<Tensor> {
        let cfg = &manifest.config;
        let mut flats = Vec::new();
        for name in nets {
            let nm = manifest.network(name)?;
            let t = io::read_tensor(&manifest.path(nm.data_file("teacher_flat")?))?;
            flats.push(t.as_f32()?.to_vec());
        }
        let refs: Vec<&[f32]> = flats.iter().map(|v| v.as_slice()).collect();
        let mut rng = Rng::new(seed);
        let per_net = 10 * cfg.k; // sub-vectors per net, paper's 10*k*d weights
        let kde_pool = KdeSampler::pool_from_networks_with(&refs, cfg.d, per_net, &mut rng, pool);
        let kde = KdeSampler::new(kde_pool, cfg.d, cfg.bandwidth as f32);
        let cb = kde.sample_codebook_with(cfg.k, &mut rng, pool);
        Ok(Tensor::from_f32(&[cfg.k, cfg.d], cb.words))
    }

    /// Default loss weights per task, modulated by the Table-5 toggles.
    /// Classification/detection follow Eq. 12 (all ones).  The denoiser
    /// uses a KD-dominant weighting: at the scaled schedule the eps-MSE
    /// gradient is batch-noise-dominated and drifts assignments toward
    /// codes that match eps-MSE but bias the 50-step sampling chain
    /// (FID 500 vs 7 — measured in EXPERIMENTS.md E5); block-wise KD
    /// against the float teacher is the signal that preserves
    /// generation, mirroring the paper's 100x-smaller lr for SD (§5.3).
    pub fn task_loss_weights(task: &str, use_t: bool, use_kd: bool, use_r: bool) -> [f32; 3] {
        let base = if task == "denoise" {
            [0.05, 1.0, 1.0]
        } else {
            [1.0, 1.0, 1.0]
        };
        [
            if use_t { base[0] } else { 0.0 },
            if use_kd { base[1] } else { 0.0 },
            if use_r { base[2] } else { 0.0 },
        ]
    }

    /// Construct one network; the core loop.
    pub fn construct(&self, name: &str) -> anyhow::Result<NetResult> {
        let sess = NetSession::new(&self.rt, &self.manifest, name, &self.codebook)?;
        self.construct_with_session(sess)
    }

    /// Run the construction loop on a prepared session (the Table-6/7
    /// harnesses override the codebook or candidate table first).
    pub fn construct_with_session(&self, mut sess: NetSession) -> anyhow::Result<NetResult> {
        let name = sess.net.name.clone();
        let name = name.as_str();
        // One worker pool for the whole construction run: the PNC scans
        // and the §5.1 special-layer k-means below share it.
        let pool = self.cfg.parallelism().pool();
        let pool = pool.as_ref();
        let w = self.cfg.loss_weights.unwrap_or_else(|| {
            Self::task_loss_weights(
                &sess.net.task,
                self.cfg.use_task_loss,
                self.cfg.use_kd_loss,
                self.cfg.use_ratio_reg,
            )
        });
        sess.set_loss_weights(w);
        if let Some(n_eff) = self.cfg.candidate_mask {
            sess.mask_candidates(n_eff)?;
        }
        let mut pnc = if self.cfg.disable_pnc {
            PncScheduler::disabled(sess.net.s_total)
        } else {
            PncScheduler::new(sess.net.s_total, self.cfg.alpha)
        };

        let mut stream = CalibStream::new(
            sess.calib_x.clone(),
            sess.calib_y.clone(),
            &sess.net.task,
            sess.net.batch,
            self.cfg.seed ^ sess.net.s_total as u64,
        );

        let mut loss_curve = Vec::with_capacity(self.cfg.steps);
        let mut metric_curve = Vec::new();
        crate::log_info!(
            "campaign",
            "[{name}] constructing: S={} steps={} alpha={}",
            sess.net.s_total,
            self.cfg.steps,
            if self.cfg.disable_pnc { f64::NAN } else { self.cfg.alpha }
        );

        for step in 0..self.cfg.steps {
            let batch = stream.next_batch()?;
            let m = sess.train_step(&batch)?;
            loss_curve.push(m);

            if self.cfg.pnc_interval > 0 && (step + 1) % self.cfg.pnc_interval == 0 {
                let newly = pnc.scan_with(sess.z(), sess.n, pool);
                if newly > 0 {
                    sess.set_freeze(pnc.frozen_tensor(), pnc.frozen_idx_tensor());
                }
                crate::log_debug!(
                    "campaign",
                    "[{name}] step {} L={:.4} frozen {}/{}",
                    step + 1,
                    m[0],
                    pnc.num_frozen(),
                    pnc.total()
                );
                if pnc.all_frozen() {
                    crate::log_info!("campaign", "[{name}] fully constructed at step {}", step + 1);
                    break;
                }
            }
            if self.cfg.eval_interval > 0 && (step + 1) % self.cfg.eval_interval == 0 {
                let (_, acc) = sess.evaluate("eval_soft", None)?;
                metric_curve.push((step + 1, acc));
            }
        }

        // Soft (construction-time) metric, then the hard collapse.
        let (_, soft_metric) = sess.evaluate("eval_soft", None)?;
        let codes = sess.hard_codes(&pnc.state);
        let codes_t = sess.codes_tensor(&codes);

        // §5.1 special-layer pass: quantize the output head with a small
        // private codebook before the final eval, so `hard_metric`
        // measures the fully compressed network.
        let mut other_bytes: usize = sess.net.others.iter().map(|o| o.elems() * 4).sum();
        let mut special_codebook_bytes = 0usize;
        if let Some((ks, ds)) = self.cfg.output_codebook {
            for sl in crate::quant::special::compress_output_layers(&mut sess, ks, ds, pool)? {
                crate::log_info!(
                    "campaign",
                    "[{name}] special layer {}: {} -> {} bytes ({:.1}x, mse {:.2e})",
                    sl.name,
                    sl.float_bytes,
                    sl.compressed_bytes,
                    sl.ratio(),
                    sl.mse
                );
                other_bytes = other_bytes - sl.float_bytes + sl.compressed_bytes;
                special_codebook_bytes += sl.codebook_bytes;
            }
        }
        let (hard_loss, hard_metric) = sess.evaluate("eval_hard", Some(&codes_t))?;

        let bits = (usize::BITS - (sess.k - 1).leading_zeros()).max(1);
        let packed = pack_codes(&codes, bits);
        let sizes = SizeReport {
            float_bytes: sess.net.s_total * sess.d * 4,
            assign_bytes: packed.bytes(),
            // The universal codebook amortizes into ROM; only private
            // special-layer codebooks are charged to the network.
            codebook_bytes: special_codebook_bytes,
            other_bytes,
        };

        crate::log_info!(
            "campaign",
            "[{name}] done: float={:.4} soft={:.4} hard={:.4} ratio={:.1}x frozen={:.1}%",
            sess.net.float_metric,
            soft_metric,
            hard_metric,
            sizes.ratio(),
            100.0 * pnc.progress()
        );

        Ok(NetResult {
            name: name.to_string(),
            task: sess.net.task.clone(),
            float_metric: sess.net.float_metric,
            soft_metric,
            hard_metric,
            hard_loss,
            steps: sess.steps_run,
            frozen_fraction: pnc.progress(),
            loss_curve,
            metric_curve,
            packed,
            sizes,
            codes,
            final_z: sess.z().to_vec(),
            final_others: sess.others().to_vec(),
        })
    }

    /// Final ratio logits of a construction run (Figure 3's histogram).
    pub fn construct_final_z(&self, name: &str) -> anyhow::Result<(Vec<f32>, usize)> {
        let res = self.construct(name)?;
        Ok((res.final_z, self.manifest.config.n))
    }

    /// Construct every requested network with the shared codebook.
    pub fn run(&self, names: &[&str]) -> anyhow::Result<CampaignResult> {
        let mut nets = Vec::new();
        for name in names {
            nets.push(self.construct(name)?);
        }
        Ok(CampaignResult {
            nets,
            codebook_bytes: self.manifest.config.k * self.manifest.config.d * 4,
            effective_bit: self.manifest.config.effective_bit,
        })
    }
}
