//! Resumable campaign checkpoints.
//!
//! A checkpoint is a directory: `meta.json` (network, step, PNC state
//! summary, config echo) + `.vqt` tensors for every state entry and the
//! freeze masks.  Loading restores a `NetSession`'s state vector and the
//! scheduler, byte-identically (verified by the resume-equivalence
//! integration test).

use std::path::Path;

use crate::tensor::{io, Tensor};
use crate::util::json::Json;
use crate::vq::ratios::FreezeState;

use super::pnc::PncScheduler;
use super::session::NetSession;

/// Save `sess` + `pnc` into `dir`.
pub fn save(dir: &Path, sess: &NetSession, pnc: &PncScheduler, step: usize) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    for (i, t) in sess.state.iter().enumerate() {
        io::write_tensor(&dir.join(format!("state_{i}.vqt")), t)?;
    }
    let s = sess.net.s_total;
    io::write_tensor(
        &dir.join("frozen.vqt"),
        &Tensor::from_f32(&[s], pnc.frozen_tensor()),
    )?;
    io::write_tensor(
        &dir.join("frozen_idx.vqt"),
        &Tensor::from_i32(&[s], pnc.frozen_idx_tensor()),
    )?;
    let meta = Json::obj(vec![
        ("network", Json::str(sess.net.name.clone())),
        ("step", Json::num(step as f64)),
        ("state_tensors", Json::num(sess.state.len() as f64)),
        ("alpha", Json::num(pnc.alpha)),
        ("num_frozen", Json::num(pnc.num_frozen() as f64)),
        ("s_total", Json::num(s as f64)),
    ]);
    std::fs::write(dir.join("meta.json"), meta.to_string())?;
    Ok(())
}

/// Restore state + scheduler into an existing session.
/// Returns the step count recorded at save time.
pub fn load(dir: &Path, sess: &mut NetSession, pnc: &mut PncScheduler) -> anyhow::Result<usize> {
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))
        .map_err(|e| anyhow::anyhow!("reading checkpoint meta: {e}"))?;
    let meta = crate::util::json::parse(&meta_text)?;
    let net = meta.req_str("network")?;
    anyhow::ensure!(
        net == sess.net.name,
        "checkpoint is for {net:?}, session is {:?}",
        sess.net.name
    );
    let count = meta.req_usize("state_tensors")?;
    anyhow::ensure!(
        count == sess.state.len(),
        "checkpoint has {count} state tensors, session expects {}",
        sess.state.len()
    );
    for i in 0..count {
        let t = io::read_tensor(&dir.join(format!("state_{i}.vqt")))?;
        anyhow::ensure!(
            t.shape == sess.state[i].shape,
            "state_{i} shape {:?} != {:?}",
            t.shape,
            sess.state[i].shape
        );
        sess.state[i] = t;
    }
    let frozen = io::read_tensor(&dir.join("frozen.vqt"))?;
    let frozen_idx = io::read_tensor(&dir.join("frozen_idx.vqt"))?;
    let fs = FreezeState {
        frozen: frozen.as_f32()?.to_vec(),
        frozen_idx: frozen_idx.as_i32()?.to_vec(),
    };
    pnc.state = fs;
    sess.set_freeze(pnc.frozen_tensor(), pnc.frozen_idx_tensor());
    meta.req_usize("step")
}

#[cfg(test)]
mod tests {
    // Full save/load round-trips over a real session live in
    // rust/tests/integration_runtime.rs (they need artifacts).  Here we
    // cover the meta validation logic with a fabricated directory.
    #[test]
    fn load_rejects_missing_meta() {
        let dir = std::env::temp_dir().join("vq4all_ckpt_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("meta.json"));
        assert!(text.is_err());
    }
}
