//! The VQ4ALL coordinator — the paper's Algorithm 1 as a Rust system.
//!
//! The split with the AOT graphs (DESIGN.md §3): the device executes
//! *one gradient step at a time* (`train_step` artifact, Algorithm 1
//! line 10); everything stateful and schedule-shaped lives here —
//!
//! * [`session`]  — per-network state machine over the manifest's
//!   calling convention (state/static/batch tensor vectors, literal
//!   caching for the hot loop).
//! * [`pnc`]      — the Progressive-Network-Construction scheduler
//!   (Eq. 14): scans ratio logits, freezes groups past `alpha`, never
//!   unfreezes, reports construction progress.
//! * [`calib`]    — calibration batch streaming (deterministic shuffles;
//!   diffusion timestep/noise sampling for the denoiser).
//! * [`campaign`] — the multi-network construction campaign: one frozen
//!   universal codebook, N networks, shared schedule, final packing and
//!   accuracy accounting.
//! * [`checkpoint`] — resumable campaign state (z, Adamax moments,
//!   freeze state) on disk.
//! * [`report`]   — human- and machine-readable campaign reports.

pub mod calib;
pub mod campaign;
pub mod checkpoint;
pub mod pnc;
pub mod report;
pub mod session;

pub use campaign::{Campaign, CampaignResult, NetResult};
pub use pnc::PncScheduler;
pub use session::NetSession;
