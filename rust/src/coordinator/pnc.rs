//! Progressive-Network-Construction scheduler (§4.3, Eq. 14).
//!
//! Every `interval` steps the coordinator reads the ratio logits `z`
//! back from the device and this scheduler:
//!
//! 1. computes `softmax(z)` per group,
//! 2. freezes every *unfrozen* group whose max ratio exceeds `alpha`
//!    (one-hot mask, ratio pinned at 1 — Eq. 14),
//! 3. never unfreezes (the monotonicity invariant, property-tested in
//!    `rust/tests/prop_coordinator.rs`).
//!
//! The paper's DKM ablation ("no PNC") is `alpha > 1`: nothing freezes
//! during training and the final hard collapse happens in one shot.

use crate::util::threadpool::ThreadPool;
use crate::vq::ratios::{max_ratios_with, FreezeState};

/// Scheduler state + policy for one network.
#[derive(Clone, Debug)]
pub struct PncScheduler {
    pub alpha: f64,
    pub state: FreezeState,
    /// Freeze counts per scan (the Figure-3 construction trajectory).
    pub history: Vec<usize>,
}

impl PncScheduler {
    pub fn new(s_total: usize, alpha: f64) -> Self {
        PncScheduler {
            alpha,
            state: FreezeState::new(s_total),
            history: Vec::new(),
        }
    }

    /// "Disable PNC" configuration (DKM-style, Table 5 / Figure 3).
    pub fn disabled(s_total: usize) -> Self {
        Self::new(s_total, 2.0) // unreachable threshold
    }

    /// Scan logits `z (s, n)` and freeze qualifying groups.
    /// Returns how many *new* groups were frozen in this scan.
    pub fn scan(&mut self, z: &[f32], n: usize) -> usize {
        self.scan_with(z, n, None)
    }

    /// [`PncScheduler::scan`] with the softmax/argmax sweep spread over a
    /// worker pool (the construction-sweep hot path: the coordinator
    /// reads `z` back every `pnc_interval` steps and scans all `s`
    /// groups).  Freeze decisions are identical to the serial path — the
    /// ratio sweep is row-independent and the freeze loop itself stays
    /// sequential.
    pub fn scan_with(&mut self, z: &[f32], n: usize, pool: Option<&ThreadPool>) -> usize {
        let before = self.state.num_frozen();
        for (g, (r, m)) in max_ratios_with(z, n, pool).into_iter().enumerate() {
            if !self.state.is_frozen(g) && (r as f64) > self.alpha {
                self.state.freeze(g, m);
            }
        }
        let now = self.state.num_frozen();
        self.history.push(now);
        now - before
    }

    pub fn num_frozen(&self) -> usize {
        self.state.num_frozen()
    }

    pub fn total(&self) -> usize {
        self.state.frozen.len()
    }

    pub fn all_frozen(&self) -> bool {
        self.state.all_frozen()
    }

    /// Fraction constructed (the campaign progress metric).
    pub fn progress(&self) -> f64 {
        self.num_frozen() as f64 / self.total().max(1) as f64
    }

    /// Device-facing tensors for the next train step.
    pub fn frozen_tensor(&self) -> Vec<f32> {
        self.state.frozen.clone()
    }

    pub fn frozen_idx_tensor(&self) -> Vec<i32> {
        self.state.frozen_idx.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn z_rows(rows: &[[f32; 4]]) -> Vec<f32> {
        rows.iter().flatten().copied().collect()
    }

    #[test]
    fn freezes_only_past_alpha() {
        let mut s = PncScheduler::new(2, 0.99);
        // Row 0: dominated logit -> max ratio ~ 1. Row 1: flat -> 0.25.
        let z = z_rows(&[[20.0, 0.0, 0.0, 0.0], [0.0, 0.0, 0.0, 0.0]]);
        let newly = s.scan(&z, 4);
        assert_eq!(newly, 1);
        assert!(s.state.is_frozen(0));
        assert!(!s.state.is_frozen(1));
        assert_eq!(s.state.frozen_idx[0], 0);
    }

    #[test]
    fn monotone_never_unfreezes() {
        let mut s = PncScheduler::new(1, 0.9);
        let hot = z_rows(&[[10.0, 0.0, 0.0, 0.0]]);
        let cold = z_rows(&[[0.0, 0.0, 0.0, 0.0]]);
        s.scan(&hot, 4);
        assert_eq!(s.num_frozen(), 1);
        s.scan(&cold, 4); // ratios collapsed back — freeze must persist
        assert_eq!(s.num_frozen(), 1);
        assert_eq!(s.state.frozen_idx[0], 0);
    }

    #[test]
    fn disabled_never_freezes() {
        let mut s = PncScheduler::disabled(3);
        let z = z_rows(&[[50.0, 0., 0., 0.], [50.0, 0., 0., 0.], [50.0, 0., 0., 0.]]);
        assert_eq!(s.scan(&z, 4), 0);
        assert_eq!(s.num_frozen(), 0);
    }

    #[test]
    fn pooled_scan_matches_serial() {
        let mut rng = crate::util::rng::Rng::new(21);
        let (s, n) = (2000, 4);
        let mut z = vec![0.0f32; s * n];
        rng.fill_normal(&mut z);
        for v in z.iter_mut() {
            *v *= 8.0; // push some rows past alpha
        }
        let mut serial = PncScheduler::new(s, 0.9);
        let mut pooled = PncScheduler::new(s, 0.9);
        let pool = ThreadPool::new(4);
        assert_eq!(serial.scan(&z, n), pooled.scan_with(&z, n, Some(&pool)));
        assert_eq!(serial.state.frozen, pooled.state.frozen);
        assert_eq!(serial.state.frozen_idx, pooled.state.frozen_idx);
        assert!(serial.num_frozen() > 0, "workload should freeze something");
    }

    #[test]
    fn history_tracks_progress() {
        let mut s = PncScheduler::new(2, 0.9);
        s.scan(&z_rows(&[[10., 0., 0., 0.], [0., 0., 0., 0.]]), 4);
        s.scan(&z_rows(&[[10., 0., 0., 0.], [0., 10., 0., 0.]]), 4);
        assert_eq!(s.history, vec![1, 2]);
        assert!(s.all_frozen());
        assert_eq!(s.progress(), 1.0);
        assert_eq!(s.state.frozen_idx[1], 1, "second group froze to slot 1");
    }
}
