//! Campaign reports: the human table plus a machine-readable JSON dump
//! (consumed by EXPERIMENTS.md bookkeeping and the bench harnesses).

use crate::bench::Table;
use crate::util::json::Json;

use super::campaign::CampaignResult;

/// Render the campaign summary table.
pub fn table(res: &CampaignResult) -> Table {
    let mut t = Table::new(
        "VQ4ALL campaign — universal codebook, hard-constructed networks",
        &[
            "network", "task", "float", "soft", "hard", "drop", "ratio", "scope", "steps",
            "frozen%",
        ],
    );
    for n in &res.nets {
        t.row(vec![
            n.name.clone(),
            n.task.clone(),
            format!("{:.4}", n.float_metric),
            format!("{:.4}", n.soft_metric),
            format!("{:.4}", n.hard_metric),
            format!("{:+.4}", -n.accuracy_drop()),
            format!("{:.1}x", n.sizes.ratio()),
            format!("{:.1}x", n.sizes.scope_ratio()),
            n.steps.to_string(),
            format!("{:.1}", 100.0 * n.frozen_fraction),
        ]);
    }
    t
}

/// JSON dump for downstream tooling.
pub fn to_json(res: &CampaignResult) -> Json {
    Json::obj(vec![
        ("codebook_bytes", Json::num(res.codebook_bytes as f64)),
        ("effective_bit", Json::num(res.effective_bit)),
        (
            "networks",
            Json::Arr(
                res.nets
                    .iter()
                    .map(|n| {
                        Json::obj(vec![
                            ("name", Json::str(n.name.clone())),
                            ("task", Json::str(n.task.clone())),
                            ("float_metric", Json::num(n.float_metric)),
                            ("soft_metric", Json::num(n.soft_metric)),
                            ("hard_metric", Json::num(n.hard_metric)),
                            ("steps", Json::num(n.steps as f64)),
                            ("frozen_fraction", Json::num(n.frozen_fraction)),
                            ("ratio", Json::num(n.sizes.ratio())),
                            ("scope_ratio", Json::num(n.sizes.scope_ratio())),
                            ("assign_bytes", Json::num(n.sizes.assign_bytes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::campaign::NetResult;
    use crate::vq::pack::{pack_codes, SizeReport};

    fn fake_result() -> CampaignResult {
        CampaignResult {
            nets: vec![NetResult {
                name: "mini_mlp".into(),
                task: "classify".into(),
                float_metric: 0.99,
                soft_metric: 0.97,
                hard_metric: 0.96,
                hard_loss: 0.1,
                steps: 100,
                frozen_fraction: 1.0,
                loss_curve: vec![],
                metric_curve: vec![],
                packed: pack_codes(&[1, 2, 3], 8),
                sizes: SizeReport {
                    float_bytes: 1000,
                    assign_bytes: 62,
                    codebook_bytes: 0,
                    other_bytes: 10,
                },
                codes: vec![1, 2, 3],
                final_z: vec![],
                final_others: vec![],
            }],
            codebook_bytes: 4096,
            effective_bit: 2.0,
        }
    }

    #[test]
    fn table_and_json_render() {
        let res = fake_result();
        let t = table(&res);
        let s = t.render();
        assert!(s.contains("mini_mlp"));
        assert!(s.contains("0.9600"));
        let j = to_json(&res);
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.req_arr("networks").unwrap()[0]
                .req_str("name")
                .unwrap(),
            "mini_mlp"
        );
    }
}
