//! Per-network construction session: owns the compiled executables, the
//! state/static tensor vectors (manifest calling convention), and the
//! name-based input assembly for every artifact.
//!
//! Hot-loop note: static inputs (candidate table, codebook, teacher
//! weights) are encoded to XLA literals **once** and cached; per-step
//! inputs (state, batch) are encoded per call.  `set_freeze` is the only
//! operation that invalidates static cache entries.

use std::collections::BTreeMap;

use crate::runtime::artifact::{Manifest, NetworkManifest};
use crate::runtime::client::{tensor_to_literal, Executable, Runtime};
use crate::tensor::{io, Tensor};

/// A network under construction.
pub struct NetSession {
    pub net: NetworkManifest,
    pub k: usize,
    pub d: usize,
    pub n: usize,
    execs: BTreeMap<String, Executable>,
    /// State tensors, aligned with `net.state_specs`.
    pub state: Vec<Tensor>,
    /// Static tensors, aligned with `net.static_specs`.
    pub statics: Vec<Tensor>,
    static_lits: Vec<Option<xla::Literal>>,
    state_idx: BTreeMap<String, usize>,
    static_idx: BTreeMap<String, usize>,
    /// Datasets (loaded once).
    pub calib_x: Tensor,
    pub calib_y: Tensor,
    pub test_x: Tensor,
    pub test_y: Tensor,
    /// Float sub-vectors (teacher) — also the KDE pool contribution.
    pub teacher_flat: Tensor,
    pub steps_run: usize,
}

impl NetSession {
    /// Build a session: load executables + data, run `init_assign` on the
    /// device (Pallas distance kernel), initialize state per §4.1/§4.2.
    pub fn new(
        rt: &Runtime,
        manifest: &Manifest,
        name: &str,
        codebook: &Tensor,
    ) -> anyhow::Result<Self> {
        let net = manifest.network(name)?.clone();
        let cfg = &manifest.config;

        let mut execs = BTreeMap::new();
        for (ename, espec) in &net.executables {
            execs.insert(
                ename.clone(),
                rt.load(&manifest.path(&espec.hlo), espec)?,
            );
        }

        let load = |tag: &str| -> anyhow::Result<Tensor> {
            io::read_tensor(&manifest.path(net.data_file(tag)?))
        };
        let calib_x = load("calib_x")?;
        let calib_y = load("calib_y")?;
        let test_x = load("test_x")?;
        let test_y = load("test_y")?;
        let teacher_flat = load("teacher_flat")?;
        anyhow::ensure!(
            teacher_flat.shape == vec![net.s_total, cfg.d],
            "teacher_flat shape {:?} != ({}, {})",
            teacher_flat.shape,
            net.s_total,
            cfg.d
        );

        // ---- init_assign on the device (Eq. 5 + Eq. 7).
        let init = execs
            .get("init_assign")
            .ok_or_else(|| anyhow::anyhow!("{name}: missing init_assign artifact"))?;
        let out = init.run(&[teacher_flat.clone(), codebook.clone()])?;
        let (assign, z0) = (out[0].clone(), out[1].clone());

        // ---- teacher "other" params, in manifest order.
        let mut teacher_others = Vec::new();
        for i in 0..net.others.len() {
            teacher_others.push(load(&format!("teacher_other_{i}"))?);
        }

        // ---- state vector per state_specs.
        let mut state = Vec::new();
        let mut state_idx = BTreeMap::new();
        for spec in &net.state_specs {
            state_idx.insert(spec.name.clone(), state.len());
            let t = match spec.name.as_str() {
                "z" => z0.clone(),
                nm if nm.starts_with("other:") => {
                    let base = &nm["other:".len()..];
                    let oi = net
                        .others
                        .iter()
                        .position(|o| o.name == base)
                        .ok_or_else(|| anyhow::anyhow!("unknown other param {base:?}"))?;
                    teacher_others[oi].clone()
                }
                // m_z, u_z, m_other:*, v_other:*, t -> zeros
                _ => match spec.dtype {
                    crate::tensor::DType::I32 => Tensor::zeros_i32(&spec.shape),
                    _ => Tensor::zeros_f32(&spec.shape),
                },
            };
            anyhow::ensure!(
                t.shape == spec.shape,
                "{name}: state {:?} shape {:?} != {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
            state.push(t);
        }

        // ---- static vector per static_specs.
        let mut statics = Vec::new();
        let mut static_idx = BTreeMap::new();
        let mut teacher_iter = teacher_others.iter();
        for spec in &net.static_specs {
            static_idx.insert(spec.name.clone(), statics.len());
            let t = match spec.name.as_str() {
                "assign" => assign.clone(),
                "frozen" => Tensor::zeros_f32(&spec.shape),
                "frozen_idx" => Tensor::zeros_i32(&spec.shape),
                "codebook" => codebook.clone(),
                "teacher_flat" => teacher_flat.clone(),
                "loss_w" => Tensor::from_f32(&[3], vec![1.0, 1.0, 1.0]),
                nm if nm.starts_with("teacher:") => teacher_iter
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("teacher param underflow at {nm}"))?
                    .clone(),
                other => anyhow::bail!("unknown static {other:?}"),
            };
            anyhow::ensure!(
                t.shape == spec.shape,
                "{name}: static {:?} shape {:?} != {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
            statics.push(t);
        }
        let static_lits = vec![None; statics.len()];

        Ok(NetSession {
            net,
            k: cfg.k,
            d: cfg.d,
            n: cfg.n,
            execs,
            state,
            statics,
            static_lits,
            state_idx,
            static_idx,
            calib_x,
            calib_y,
            test_x,
            test_y,
            teacher_flat,
            steps_run: 0,
        })
    }

    pub fn exec(&self, name: &str) -> anyhow::Result<&Executable> {
        self.execs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("{}: no executable {name:?}", self.net.name))
    }

    // ---- state/static access ----------------------------------------------

    pub fn state_by_name(&self, name: &str) -> &Tensor {
        &self.state[self.state_idx[name]]
    }

    pub fn static_by_name(&self, name: &str) -> &Tensor {
        &self.statics[self.static_idx[name]]
    }

    /// Ratio logits `z` (S*n, row-major).
    pub fn z(&self) -> &[f32] {
        self.state_by_name("z").as_f32().expect("z is f32")
    }

    /// Candidate table (S*n).
    pub fn assign_u32(&self) -> Vec<u32> {
        self.static_by_name("assign")
            .as_i32()
            .expect("assign is i32")
            .iter()
            .map(|&x| x as u32)
            .collect()
    }

    /// The current "other" params (bias/norm/head), in `net.others`
    /// order.
    pub fn others(&self) -> Vec<Tensor> {
        self.net
            .others
            .iter()
            .map(|o| self.state_by_name(&format!("other:{}", o.name)).clone())
            .collect()
    }

    /// Install trained "other" params (from a finished campaign's
    /// `NetResult::final_others`) into this session, in `net.others`
    /// order.  Serving/generation sessions must do this before pairing
    /// the campaign's codes with `eval_hard` / `infer_hard`.
    pub fn set_others(&mut self, others: &[Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(
            others.len() == self.net.others.len(),
            "{}: got {} other params, net has {}",
            self.net.name,
            others.len(),
            self.net.others.len()
        );
        let names: Vec<String> = self.net.others.iter().map(|o| o.name.clone()).collect();
        for (name, t) in names.iter().zip(others) {
            self.set_state(&format!("other:{name}"), t.clone())?;
        }
        Ok(())
    }

    /// Replace one state tensor by name (shape-checked).  Used by the
    /// §5.1 special-layer pass to feed per-layer-VQ-reconstructed head
    /// weights back through the `other:` inputs.
    pub fn set_state(&mut self, name: &str, t: Tensor) -> anyhow::Result<()> {
        let i = *self
            .state_idx
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("{}: no state tensor {name:?}", self.net.name))?;
        anyhow::ensure!(
            t.shape == self.state[i].shape,
            "{name}: shape {:?} != {:?}",
            t.shape,
            self.state[i].shape
        );
        self.state[i] = t;
        Ok(())
    }

    fn set_static(&mut self, name: &str, t: Tensor) {
        let i = self.static_idx[name];
        self.statics[i] = t;
        self.static_lits[i] = None; // invalidate cache
    }

    /// Push a new PNC freeze mask to the device inputs.
    pub fn set_freeze(&mut self, frozen: Vec<f32>, frozen_idx: Vec<i32>) {
        let s = self.net.s_total;
        self.set_static("frozen", Tensor::from_f32(&[s], frozen));
        self.set_static("frozen_idx", Tensor::from_i32(&[s], frozen_idx));
    }

    /// Per-term loss weights `[w_t, w_kd, w_r]` (Table 5 ablations).
    pub fn set_loss_weights(&mut self, w: [f32; 3]) {
        self.set_static("loss_w", Tensor::from_f32(&[3], w.to_vec()));
    }

    /// Replace the candidate table + initial logits (Table 7's
    /// initialization-strategy ablation builds these host-side).
    pub fn override_candidates(&mut self, assign: Tensor, z0: Tensor) {
        let zi = self.state_idx["z"];
        assert_eq!(z0.shape, self.state[zi].shape, "z0 shape mismatch");
        self.state[zi] = z0;
        assert_eq!(
            assign.shape,
            self.static_by_name("assign").shape,
            "assign shape mismatch"
        );
        self.set_static("assign", assign);
    }

    /// Emulate a candidate count `n_eff < n` by pinning the logits of
    /// slots >= n_eff to -inf-like values: those candidates get ~0 ratio
    /// and can never become optimal (Table 5's n ablation).  At
    /// `n_eff = 1` this degenerates to plain nearest-codeword VQ.
    pub fn mask_candidates(&mut self, n_eff: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            n_eff >= 1 && n_eff <= self.n,
            "candidate mask {n_eff} out of range 1..={}",
            self.n
        );
        let n = self.n;
        let zi = self.state_idx["z"];
        let z = self.state[zi].as_f32_mut()?;
        for g in 0..z.len() / n {
            for m in n_eff..n {
                z[g * n + m] = -1e9;
            }
        }
        Ok(())
    }

    fn static_literal(&mut self, i: usize) -> anyhow::Result<&xla::Literal> {
        if self.static_lits[i].is_none() {
            self.static_lits[i] = Some(tensor_to_literal(&self.statics[i])?);
        }
        Ok(self.static_lits[i].as_ref().unwrap())
    }

    // ---- execution ---------------------------------------------------------

    /// One construction step (Algorithm 1 line 10).  Returns
    /// `[L, L_t, L_kd, L_r]`.
    pub fn train_step(&mut self, batch: &[Tensor]) -> anyhow::Result<[f32; 4]> {
        let nstate = self.state.len();
        let nstatic = self.statics.len();
        let mut lits = Vec::with_capacity(nstate + nstatic + batch.len());
        for t in &self.state {
            lits.push(tensor_to_literal(t)?);
        }
        for i in 0..nstatic {
            lits.push(self.static_literal(i)?.clone());
        }
        for t in batch {
            lits.push(tensor_to_literal(t)?);
        }
        let exec = self
            .execs
            .get("train_step")
            .ok_or_else(|| anyhow::anyhow!("missing train_step"))?;
        let mut outs = exec.run_literals(&lits)?;
        let metrics_t = outs.pop().ok_or_else(|| anyhow::anyhow!("no metrics output"))?;
        anyhow::ensure!(
            outs.len() == nstate,
            "train_step returned {} state tensors, expected {nstate}",
            outs.len()
        );
        self.state = outs;
        self.steps_run += 1;
        let m = metrics_t.as_f32()?;
        Ok([m[0], m[1], m[2], m[3]])
    }

    /// Assemble inputs for an eval/infer executable by spec name:
    /// `codes` from the argument, `z`/`other:*` from state, statics by
    /// name, and remaining (batch) inputs consumed in order.
    fn assemble(
        &mut self,
        exec_name: &str,
        codes: Option<&Tensor>,
        batch: &[Tensor],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let specs = self.exec(exec_name)?.spec.inputs.clone();
        let mut lits = Vec::with_capacity(specs.len());
        let mut batch_iter = batch.iter();
        for spec in &specs {
            let name = spec.name.as_str();
            if name == "codes" {
                let c = codes.ok_or_else(|| anyhow::anyhow!("{exec_name} needs codes"))?;
                lits.push(tensor_to_literal(c)?);
            } else if let Some(&i) = self.state_idx.get(name) {
                lits.push(tensor_to_literal(&self.state[i])?);
            } else if let Some(&i) = self.static_idx.get(name) {
                lits.push(self.static_literal(i)?.clone());
            } else {
                let t = batch_iter
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("{exec_name}: batch underflow at {name:?}"))?;
                lits.push(tensor_to_literal(t)?);
            }
        }
        Ok(lits)
    }

    /// Public input assembly (used by the serving layer).
    pub fn assemble_public(
        &mut self,
        exec_name: &str,
        codes: Option<&Tensor>,
        batch: &[Tensor],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        self.assemble(exec_name, codes, batch)
    }

    /// Run an eval executable over one batch; returns `(loss_sum, hits)`.
    pub fn eval_batch(
        &mut self,
        exec_name: &str,
        codes: Option<&Tensor>,
        batch: &[Tensor],
    ) -> anyhow::Result<(f64, f64)> {
        let lits = self.assemble(exec_name, codes, batch)?;
        let outs = self.exec(exec_name)?.run_literals(&lits)?;
        let m = outs[0].as_f32()?;
        Ok((m[0] as f64, m[1] as f64))
    }

    /// Full test-set eval; returns `(mean loss, metric)` where metric is
    /// accuracy / hit-rate per sample.
    pub fn evaluate(&mut self, exec_name: &str, codes: Option<&Tensor>) -> anyhow::Result<(f64, f64)> {
        let eb = self.net.eval_batch;
        let test_x = self.test_x.clone();
        let test_y = self.test_y.clone();
        let task = self.net.task.clone();
        let batches: Vec<Vec<Tensor>> =
            super::calib::EvalBatches::new(&test_x, &test_y, &task, eb, 17)
                .collect::<anyhow::Result<_>>()?;
        let mut loss = 0.0;
        let mut hits = 0.0;
        let mut count = 0usize;
        for b in &batches {
            let (l, h) = self.eval_batch(exec_name, codes, b)?;
            loss += l;
            hits += h;
            count += eb;
        }
        anyhow::ensure!(count > 0, "empty test set");
        Ok((loss / count as f64, hits / count as f64))
    }

    /// Collapse to final hard codes (frozen slot or argmax — Eq. 2 form).
    pub fn hard_codes(&self, fs: &crate::vq::ratios::FreezeState) -> Vec<u32> {
        crate::vq::ratios::hard_codes(self.z(), &self.assign_u32(), self.n, fs)
    }

    /// Hard codes as an i32 tensor for the eval/infer artifacts.
    pub fn codes_tensor(&self, codes: &[u32]) -> Tensor {
        Tensor::from_i32(
            &[self.net.s_total],
            codes.iter().map(|&c| c as i32).collect(),
        )
    }
}
