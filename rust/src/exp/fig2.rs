//! E2 — **Figure 2**: accuracy vs compression ratio for the ResNet
//! stand-ins, VQ4ALL against the baseline families.
//!
//! Curves produced:
//! * **VQ4ALL** — the real campaign: constructed codes evaluated through
//!   the device `eval_hard` path, ratio from the packed-size accounting
//!   (universal codebook amortized to ROM).
//! * **P-VQ (k-means)** — the per-layer baseline evaluated through the
//!   *same device path*: the network's own sub-vectors are k-means'd and
//!   the baseline codebook is substituted for the universal one
//!   (`eval_hard` accepts any (codes, codebook) pair); the codebook
//!   bytes count against the network, which is exactly what separates
//!   the curves at high ratios in the paper.
//! * **UQ / ternary** — post-training distortion baselines: exact
//!   storage ratio + weight-space MSE, mapped to an estimated metric by
//!   monotone interpolation against the device-measured anchors.
//!   (The AOT graphs only accept weights via (codes, codebook), so
//!   arbitrary-valued UQ weights cannot ride the device path; the
//!   monotone map preserves the orderings Figure 2 asserts.  Recorded
//!   in DESIGN.md §2.)

use crate::coordinator::{Campaign, NetResult};
use crate::quant::{ternary, uniform};
use crate::tensor::{io, Tensor};
use crate::vq::kmeans::{kmeans, KmeansOpts};

/// One point on a Figure-2 curve.
#[derive(Clone, Debug)]
pub struct Point {
    pub method: String,
    pub ratio: f64,
    pub metric: f64,
    pub weight_mse: f64,
}

/// Run the true VQ4ALL campaign point for `net`.
pub fn vq4all_point(campaign: &Campaign, net: &str) -> anyhow::Result<(Point, NetResult)> {
    let res = campaign.construct(net)?;
    let nm = campaign.manifest.network(net)?;
    let flat_t = io::read_tensor(&campaign.manifest.path(nm.data_file("teacher_flat")?))?;
    let flat = flat_t.as_f32()?;
    let cb = crate::vq::Codebook::new(
        campaign.manifest.config.k,
        campaign.manifest.config.d,
        campaign.codebook.as_f32()?.to_vec(),
    );
    let decoded = cb.decode_vec(&res.codes);
    let mse = crate::util::stats::mse(flat, &decoded);
    Ok((
        Point {
            method: "VQ4ALL".into(),
            ratio: res.sizes.scope_ratio(),
            metric: res.hard_metric,
            weight_mse: mse,
        },
        res,
    ))
}

/// Per-layer k-means baseline through the real device eval path.
pub fn kmeans_baseline_point(campaign: &Campaign, net: &str, k: usize) -> anyhow::Result<Point> {
    let cfg = &campaign.manifest.config;
    let nm = campaign.manifest.network(net)?;
    let flat_t = io::read_tensor(&campaign.manifest.path(nm.data_file("teacher_flat")?))?;
    let flat = flat_t.as_f32()?;
    let res = kmeans(flat, cfg.d, k, &KmeansOpts::default());

    // The eval_hard artifact's codebook input has fixed shape (K, d) —
    // embed the (possibly smaller) baseline codebook in the first k rows.
    let mut words = res.codebook.words.clone();
    words.resize(cfg.k * cfg.d, 0.0);
    let cb_tensor = Tensor::from_f32(&[cfg.k, cfg.d], words);
    let mut sess =
        crate::coordinator::NetSession::new(&campaign.rt, &campaign.manifest, net, &cb_tensor)?;
    let codes_t = sess.codes_tensor(&res.codes);
    let (_, metric) = sess.evaluate("eval_hard", Some(&codes_t))?;

    // Per-layer accounting: the private codebook counts against the net.
    let bits = (k as f64).log2().max(1.0);
    let assign_bytes = (flat.len() / cfg.d) as f64 * bits / 8.0;
    let scope_bytes = flat.len() as f64 * 4.0;
    let ratio = scope_bytes / (assign_bytes + res.codebook.storage_bytes() as f64);
    Ok(Point {
        method: format!("P-VQ k={k}"),
        ratio,
        metric,
        weight_mse: res.mse,
    })
}

/// Distortion-proxy baselines: (method, ratio, weight MSE).
pub fn distortion_baselines(campaign: &Campaign, net: &str) -> anyhow::Result<Vec<(String, f64, f64)>> {
    let nm = campaign.manifest.network(net)?;
    let flat_t = io::read_tensor(&campaign.manifest.path(nm.data_file("teacher_flat")?))?;
    let flat = flat_t.as_f32()?;
    let mut out = Vec::new();
    for bits in [1u32, 2, 3, 4] {
        let mse = uniform::quant_mse(flat, bits, uniform::Granularity::PerTensor);
        out.push((format!("UQ-{bits}bit"), 32.0 / bits as f64, mse));
    }
    let t = ternary::ternary_mse(flat, 0.05);
    out.push(("TTQ-style".into(), 16.0, t));
    Ok(out)
}

/// Map a weight-MSE to an estimated metric given measured anchors
/// (monotone linear interpolation in log-MSE; clamped at the ends).
pub fn mse_to_metric(anchors: &mut Vec<(f64, f64)>, mse: f64) -> f64 {
    anchors.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    if anchors.is_empty() {
        return f64::NAN;
    }
    let x = mse.max(1e-12).ln();
    if x <= anchors[0].0.max(1e-12).ln() {
        return anchors[0].1;
    }
    for w in anchors.windows(2) {
        let (m0, a0) = (w[0].0.max(1e-12).ln(), w[0].1);
        let (m1, a1) = (w[1].0.max(1e-12).ln(), w[1].1);
        if x <= m1 {
            let t = (x - m0) / (m1 - m0).max(1e-12);
            return a0 + t * (a1 - a0);
        }
    }
    anchors.last().unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolation_is_monotone_and_clamped() {
        let mut anchors = vec![(1e-4, 0.95), (1e-2, 0.60), (1e-3, 0.85)];
        let hi = mse_to_metric(&mut anchors, 1e-5);
        let mid = mse_to_metric(&mut anchors, 3e-3);
        let lo = mse_to_metric(&mut anchors, 1.0);
        assert_eq!(hi, 0.95, "below-range clamps to best");
        assert_eq!(lo, 0.60, "above-range clamps to worst");
        assert!(mid < 0.85 && mid > 0.60, "interpolates: {mid}");
    }
}
