//! E7 — **Figure 3**: PNC vs no-PNC.
//!
//! *Up*: soft accuracy per eval interval for both configurations, plus
//! the end-of-training hard collapse — without PNC the collapse drops
//! accuracy sharply (Eq. 13's gap), with PNC the hard and soft curves
//! meet.
//!
//! *Down*: the distribution of each group's largest ratio at the end of
//! training (no-PNC run) — the paper's "15% outliers far from 1".

use crate::coordinator::Campaign;
use crate::util::stats::Histogram;
use crate::vq::ratios::max_ratios;

/// One configuration's trajectory.
#[derive(Clone, Debug)]
pub struct Trajectory {
    pub label: String,
    pub metric_curve: Vec<(usize, f64)>,
    pub soft_final: f64,
    pub hard_final: f64,
    /// Largest-ratio histogram at end of training (16 bins over [0, 1]).
    pub ratio_hist: Vec<f64>,
}

/// Run one configuration and collect the Figure-3 signals.
pub fn run_one(campaign: &Campaign, net: &str, disable_pnc: bool) -> anyhow::Result<Trajectory> {
    let mut cfg = campaign.cfg.clone();
    cfg.disable_pnc = disable_pnc;
    if cfg.eval_interval == 0 {
        cfg.eval_interval = (cfg.steps / 5).max(1);
    }
    let c2 = Campaign {
        rt: crate::runtime::Runtime::cpu()?,
        manifest: campaign.manifest.clone(),
        cfg,
        codebook: campaign.codebook.clone(),
    };
    let res = c2.construct(net)?;

    // Final largest-ratio distribution (the paper's lower panel).
    let n = c2.manifest.config.n;
    let mut hist = Histogram::new(0.0, 1.0000001, 16);
    for (r, _) in max_ratios(&res.final_z, n) {
        hist.push(r as f64);
    }
    Ok(Trajectory {
        label: if disable_pnc { "no PNC (DKM-style)" } else { "PNC" }.to_string(),
        metric_curve: res.metric_curve.clone(),
        soft_final: res.soft_metric,
        hard_final: res.hard_metric,
        ratio_hist: hist.normalized(),
    })
}

/// Render both trajectories.
pub fn render(pnc: &Trajectory, nopnc: &Trajectory) -> String {
    let mut s = String::from("\n=== Figure 3 — PNC vs no-PNC (soft curve; hard collapse) ===\n");
    for t in [pnc, nopnc] {
        s.push_str(&format!("{:<22} curve:", t.label));
        for (step, m) in &t.metric_curve {
            s.push_str(&format!(" {step}:{m:.3}"));
        }
        s.push_str(&format!(
            "  | soft {:.4} -> hard {:.4} (collapse {:+.4})\n",
            t.soft_final,
            t.hard_final,
            t.hard_final - t.soft_final
        ));
    }
    s.push_str("largest-ratio histogram (no PNC), 16 bins over [0,1]:\n  ");
    for (i, m) in nopnc.ratio_hist.iter().enumerate() {
        if *m > 0.0005 {
            s.push_str(&format!("[{:.2}]{:.1}% ", (i as f64 + 0.5) / 16.0, m * 100.0));
        }
    }
    s.push('\n');
    s
}
