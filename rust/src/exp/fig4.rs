//! E8 — **Figure 4** (supplementary §7): the ratio-threshold `alpha`
//! sweep for the PNC scheduler on 2-bit mini_resnet18/50.
//!
//! The paper's finding: smaller alpha freezes too eagerly and hurts
//! accuracy; alpha = 0.9999 is the sweet spot, and ResNet-50 is more
//! sensitive below 0.95.

use crate::coordinator::Campaign;

#[derive(Clone, Debug)]
pub struct Point {
    pub alpha: f64,
    pub metric: f64,
    pub frozen_fraction: f64,
    pub steps: usize,
}

pub fn sweep(campaign: &Campaign, net: &str, alphas: &[f64]) -> anyhow::Result<Vec<Point>> {
    let mut out = Vec::new();
    for &alpha in alphas {
        let mut cfg = campaign.cfg.clone();
        cfg.alpha = alpha;
        let c2 = Campaign {
            rt: crate::runtime::Runtime::cpu()?,
            manifest: campaign.manifest.clone(),
            cfg,
            codebook: campaign.codebook.clone(),
        };
        let res = c2.construct(net)?;
        out.push(Point {
            alpha,
            metric: res.hard_metric,
            frozen_fraction: res.frozen_fraction,
            steps: res.steps,
        });
    }
    Ok(out)
}

pub fn render(net: &str, points: &[Point]) -> String {
    let mut s = format!("\n=== Figure 4 — alpha sweep ({net}) ===\n");
    for p in points {
        s.push_str(&format!(
            "alpha={:<8} hard={:.4} frozen={:>5.1}% steps={}\n",
            p.alpha,
            p.metric,
            p.frozen_fraction * 100.0,
            p.steps
        ));
    }
    s
}
