//! E11 — **Figure 5** (supplementary §8): optimal-assignment
//! distribution over the universal codebook's codewords, per network.
//!
//! The paper's point: every low-bit network uses the codewords of the
//! shared codebook *evenly* — no codeword starvation, so the universal
//! table's information capacity is fully exercised.  We report the
//! usage histogram plus summary statistics (fraction of codewords used,
//! normalized entropy).

use crate::coordinator::campaign::NetResult;

#[derive(Clone, Debug)]
pub struct Usage {
    pub net: String,
    /// Histogram of code usage over codeword-index buckets.
    pub buckets: Vec<f64>,
    /// Fraction of the k codewords referenced at least once.
    pub coverage: f64,
    /// Shannon entropy of the usage distribution / log2(k) — 1.0 = uniform.
    pub normalized_entropy: f64,
}

pub fn usage(res: &NetResult, k: usize, nbuckets: usize) -> Usage {
    let mut counts = vec![0u64; k];
    for &c in &res.codes {
        counts[c as usize] += 1;
    }
    let used = counts.iter().filter(|&&c| c > 0).count();
    let total: u64 = counts.iter().sum();
    let mut entropy = 0.0f64;
    for &c in &counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            entropy -= p * p.log2();
        }
    }
    // Fold counts into index buckets (usage mass per codebook region).
    let mut buckets = vec![0.0f64; nbuckets.min(k)];
    let per = (k as f64) / buckets.len() as f64;
    for (i, &c) in counts.iter().enumerate() {
        let b = ((i as f64 / per) as usize).min(buckets.len() - 1);
        buckets[b] += c as f64;
    }
    let sum: f64 = buckets.iter().sum::<f64>().max(1.0);
    for b in buckets.iter_mut() {
        *b /= sum;
    }
    Usage {
        net: res.name.clone(),
        buckets,
        coverage: used as f64 / k as f64,
        normalized_entropy: entropy / (k as f64).log2(),
    }
}

pub fn render(usages: &[Usage]) -> String {
    let mut s = String::from("\n=== Figure 5 — codeword usage per network (universal codebook) ===\n");
    for u in usages {
        s.push_str(&format!(
            "{:<16} coverage {:>5.1}%  norm-entropy {:.3}  buckets:",
            u.net,
            u.coverage * 100.0,
            u.normalized_entropy
        ));
        for b in &u.buckets {
            s.push_str(&format!(" {:.1}", b * 100.0));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vq::pack::{pack_codes, SizeReport};

    fn fake(codes: Vec<u32>) -> NetResult {
        NetResult {
            name: "t".into(),
            task: "classify".into(),
            float_metric: 0.0,
            soft_metric: 0.0,
            hard_metric: 0.0,
            hard_loss: 0.0,
            steps: 0,
            frozen_fraction: 0.0,
            loss_curve: vec![],
            metric_curve: vec![],
            packed: pack_codes(&codes, 8),
            sizes: SizeReport::default(),
            codes,
            final_z: vec![],
            final_others: vec![],
        }
    }

    #[test]
    fn uniform_usage_has_high_entropy() {
        let codes: Vec<u32> = (0..1024).map(|i| i % 64).collect();
        let u = usage(&fake(codes), 64, 8);
        assert!((u.coverage - 1.0).abs() < 1e-9);
        assert!(u.normalized_entropy > 0.99, "entropy {}", u.normalized_entropy);
        for b in &u.buckets {
            assert!((b - 0.125).abs() < 0.01, "bucket {b}");
        }
    }

    #[test]
    fn skewed_usage_has_low_entropy_and_coverage() {
        let codes = vec![3u32; 1000];
        let u = usage(&fake(codes), 64, 8);
        assert!(u.coverage < 0.02);
        assert!(u.normalized_entropy < 0.01);
    }
}
