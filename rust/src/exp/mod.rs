//! Paper-experiment harnesses — one module per table/figure
//! (the E1..E13 index in DESIGN.md §6).  Each module exposes a
//! `run(...)` returning renderable rows; the `benches/` targets and the
//! examples are thin drivers over these.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod stages;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6_7;
