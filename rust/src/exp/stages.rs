//! E-RS — **residual-stage sweep**: accuracy vs stage count at *matched
//! total assignment bits* against one universal codebook.
//!
//! The staged encoder ([`Codebook::encode_staged`]) spends a bit budget
//! either as one deep scan (e.g. 10 bits → the full 1024-word codebook)
//! or as several shallow residual scans over *prefixes* of the same
//! codebook (5+5 bits → two 32-word scans, stage 1 quantizing the stage-0
//! residual).  Same ROM, same total bits per weight group — only the
//! stage structure varies, which is exactly the axis this sweep isolates.
//!
//! The interesting regime is the universal-codebook deployment the paper
//! targets: the codebook is sampled **once** from the zoo-wide KDE and
//! then reused for networks it never saw (§3.2's post-fab onboarding
//! story).  When an onboarded net's weight scale does not match the KDE
//! pool (here 6×), no single codeword lands near a target sub-vector, but
//! a *sum* of two does — the residual stage reaches 2× the codebook's
//! radius — so 2 stages strictly beat 1 stage at the same bit budget.
//! On a matched-scale net the deep single scan wins instead; both rows
//! are reported so the trade is visible rather than averaged away.

use crate::runtime::artifact::Manifest;
use crate::tensor::io;
use crate::util::config::Parallelism;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use crate::vq::{Codebook, KdeSampler};

/// One row of the sweep: a stage split of the total bit budget.
#[derive(Clone, Debug)]
pub struct Row {
    /// Stage count (`stage_bits.len()`).
    pub stages: usize,
    /// Bits per stage, stage order (sums to the matched budget).
    pub stage_bits: Vec<u32>,
    /// Codebook prefix each stage scans (`Codebook::stage_k`).
    pub stage_k: Vec<usize>,
    /// Total assignment bits per weight group — constant across rows.
    pub total_bits: u32,
    /// Final residual MSE after the last stage.
    pub mse: f64,
    /// Residual MSE after each stage.
    pub stage_mse: Vec<f64>,
    /// Per-stage fraction of the scanned prefix actually addressed.
    pub used_fraction: Vec<f64>,
}

/// The default matched-bits splits: 10 bits spent as 1, 2, or 3 stages.
pub fn default_splits() -> Vec<Vec<u32>> {
    vec![vec![10], vec![5, 5], vec![4, 3, 3]]
}

/// Encode `flat` under every split and report one row per split.
/// Panics if the splits do not all sum to the same total (the sweep's
/// whole point is the matched budget).
pub fn sweep_with(
    cb: &Codebook,
    flat: &[f32],
    splits: &[Vec<u32>],
    pool: Option<&ThreadPool>,
) -> Vec<Row> {
    assert!(!splits.is_empty(), "stage sweep needs at least one split");
    let total: u32 = splits[0].iter().sum();
    let mut rows = Vec::new();
    for split in splits {
        assert_eq!(
            split.iter().sum::<u32>(),
            total,
            "split {split:?} breaks the matched {total}-bit budget"
        );
        let enc = cb.encode_staged(flat, split, pool);
        rows.push(Row {
            stages: split.len(),
            stage_bits: split.clone(),
            stage_k: split.iter().map(|&b| cb.stage_k(b)).collect(),
            total_bits: total,
            mse: enc.mse,
            stage_mse: enc.stage_mse.clone(),
            used_fraction: enc.utilization.iter().map(|u| u.used_fraction()).collect(),
        });
    }
    rows
}

/// Artifact-driven sweep: sample the universal KDE codebook exactly as
/// the Table 1 U-VQ arm does, then run every zoo network's flat weight
/// stream through [`sweep_with`], averaging MSE across nets weighted by
/// weight count.  Rows come back in `splits` order.
pub fn run(manifest: &Manifest, splits: &[Vec<u32>]) -> anyhow::Result<Vec<Row>> {
    let own = Parallelism::default().pool();
    let pool = own.as_ref();
    let d = manifest.config.d;
    let mut flats = Vec::new();
    for net in &manifest.networks {
        let t = io::read_tensor(&manifest.path(net.data_file("teacher_flat")?))?;
        let v = t.as_f32()?.to_vec();
        let usable = (v.len() / d) * d;
        flats.push(v[..usable].to_vec());
    }
    let refs: Vec<&[f32]> = flats.iter().map(|v| v.as_slice()).collect();
    let k = manifest.config.k;
    let mut rng = Rng::new(0xE5);
    let kde_pool = KdeSampler::pool_from_networks_with(&refs, d, 10 * k.min(2000), &mut rng, pool);
    let kde = KdeSampler::new(kde_pool, d, manifest.config.bandwidth as f32);
    let cb = kde.sample_codebook_with(k, &mut rng, pool);

    let mut rows: Vec<Row> = Vec::new();
    let mut weights = 0usize;
    for f in &flats {
        let net_rows = sweep_with(&cb, f, splits, pool);
        if rows.is_empty() {
            rows = net_rows
                .into_iter()
                .map(|mut r| {
                    r.mse *= f.len() as f64;
                    for m in &mut r.stage_mse {
                        *m *= f.len() as f64;
                    }
                    r
                })
                .collect();
        } else {
            for (acc, r) in rows.iter_mut().zip(net_rows) {
                acc.mse += r.mse * f.len() as f64;
                for (a, m) in acc.stage_mse.iter_mut().zip(&r.stage_mse) {
                    *a += m * f.len() as f64;
                }
            }
        }
        weights += f.len();
    }
    for r in &mut rows {
        r.mse /= weights as f64;
        for m in &mut r.stage_mse {
            *m /= weights as f64;
        }
    }
    Ok(rows)
}

/// Render as a table (one row per split).
pub fn render(rows: &[Row]) -> crate::bench::Table {
    let mut t = crate::bench::Table::new(
        "Residual stages — MSE vs stage count at matched total bits",
        &["Stages", "Split", "Prefix k", "Bits", "MSE", "Stage MSE", "Used"],
    );
    for r in rows {
        t.row(vec![
            format!("{}", r.stages),
            format!("{:?}", r.stage_bits),
            format!("{:?}", r.stage_k),
            format!("{}", r.total_bits),
            format!("{:.3e}", r.mse),
            r.stage_mse
                .iter()
                .map(|m| format!("{m:.2e}"))
                .collect::<Vec<_>>()
                .join(" → "),
            r.used_fraction
                .iter()
                .map(|u| format!("{:.0}%", u * 100.0))
                .collect::<Vec<_>>()
                .join("/"),
        ]);
    }
    t
}

/// Self-contained synthetic sweep (unit-test scale) in the regime the
/// module doc describes: the universal codebook is KDE-sampled from a
/// 0.05-scale weight pool, then an *unseen* net at 0.3 scale (6× hotter
/// than anything the KDE saw) is onboarded post-fab.  Returns the rows
/// for `[10]` vs `[5, 5]` at a matched 10-bit budget.
pub fn synthetic_stages_ordering(seed: u64) -> Vec<Row> {
    let mut rng = Rng::new(seed);
    let mut pool_w = vec![0.0f32; 4 * 4000];
    rng.fill_normal(&mut pool_w);
    for v in pool_w.iter_mut() {
        *v *= 0.05; // weight-scale KDE pool, as in table1's synthetic run
    }
    let kde = KdeSampler::new(pool_w, 4, 0.01);
    let cb = kde.sample_codebook(1024, &mut rng);
    let mut target = vec![0.0f32; 4 * 4000];
    rng.fill_normal(&mut target);
    for v in target.iter_mut() {
        *v *= 0.3; // unseen net, 6x the pool's scale
    }
    sweep_with(&cb, &target, &[vec![10], vec![5, 5]], None)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The PR's headline claim: at a matched total bit budget, on a net
    /// whose scale the universal codebook never saw, 2 residual stages
    /// beat 1 deep stage strictly.  (Verified stable across seeds — the
    /// margin is ~8–11%, far outside noise.)
    #[test]
    fn two_stages_beat_one_at_matched_bits_on_unseen_scale() {
        let rows = synthetic_stages_ordering(17);
        assert_eq!(rows.len(), 2);
        let (one, two) = (&rows[0], &rows[1]);
        assert_eq!(one.total_bits, 10);
        assert_eq!(two.total_bits, 10);
        assert_eq!(one.stage_k, vec![1024], "10 bits scan the full codebook");
        assert_eq!(two.stage_k, vec![32, 32], "5-bit stages scan a 32-word prefix");
        assert!(
            two.mse < one.mse,
            "2-stage {} must strictly beat 1-stage {} at matched bits",
            two.mse,
            one.mse
        );
        // The residual stage must actually refine, not just tie.
        assert!(two.stage_mse[1] < two.stage_mse[0]);
    }

    #[test]
    fn sweep_rejects_budget_mismatch() {
        let r = std::panic::catch_unwind(|| {
            let cb = Codebook::new(4, 2, vec![0.0; 8]);
            sweep_with(&cb, &[0.0; 8], &[vec![2], vec![2, 2]], None)
        });
        assert!(r.is_err(), "unequal split totals must panic");
    }
}
