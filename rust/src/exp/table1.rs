//! E1 — **Table 1**: UQ vs per-layer VQ vs universal VQ across the zoo.
//!
//! Columns reproduced: ideal bit width, (k, d), codebook memory `C`,
//! weight MSE, compression rate, codebook I/O multiple.
//!
//! Method: the float sub-vectors of every zoo network are loaded from
//! the artifacts; for each bit config we (a) uniform-quantize per layer,
//! (b) k-means a per-layer codebook, (c) sample one universal KDE
//! codebook shared by all networks — then measure reconstruction MSE
//! and account storage exactly as §3.1 prescribes.  The I/O column comes
//! from the `rom::memsim` switch storm.
//!
//! The paper's (k, d) pairs are used for the *accounting*; the measured
//! MSE uses scaled-down k (CPU k-means at 2^16 is impractical here) with
//! the (k, d) relationship preserved — the orderings UQ ≫ U-VQ ≈ P-VQ
//! are what the experiment asserts.

use crate::quant::uniform::{self, Granularity};
use crate::rom::memsim::TrafficReport;
use crate::runtime::artifact::Manifest;
use crate::serving::switchsim::{compare, io_multiple, SwitchWorkload};
use crate::tensor::io;
use crate::util::config::Parallelism;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::threadpool::ThreadPool;
use crate::vq::kmeans::{kmeans, kmeans_with, KmeansOpts};
use crate::vq::KdeSampler;

/// One row of Table 1.
#[derive(Clone, Debug)]
pub struct Row {
    pub bit: f64,
    pub k: usize,
    pub d: usize,
    pub kind: &'static str, // UQ | P-VQ | U-VQ
    pub codebook_bytes: usize,
    pub mse: f64,
    pub rate: f64,
    pub io_multiple: f64,
}

/// Per-bit configuration mirroring the paper's Table 1 geometry
/// (k grows with d so bits/weight stays constant).
#[derive(Clone, Copy, Debug)]
pub struct BitConfig {
    pub bit: u32,
    /// per-layer VQ (k, d)
    pub pvq: (usize, usize),
    /// universal VQ (k, d)
    pub uvq: (usize, usize),
}

/// Scaled-down analogues of the paper's configs (same bit widths, same
/// d-doubling structure; k capped for CPU k-means).
pub fn default_configs() -> Vec<BitConfig> {
    vec![
        BitConfig {
            bit: 3,
            pvq: (64, 2),
            uvq: (4096, 4),
        },
        BitConfig {
            bit: 2,
            pvq: (256, 4),
            uvq: (4096, 6),
        },
        BitConfig {
            bit: 1,
            pvq: (256, 8),
            uvq: (4096, 12),
        },
    ]
}

/// Load every network's float sub-vectors re-grouped at dimension `d`.
fn zoo_flats(manifest: &Manifest, d: usize) -> anyhow::Result<Vec<Vec<f32>>> {
    let mut out = Vec::new();
    for net in &manifest.networks {
        let t = io::read_tensor(&manifest.path(net.data_file("teacher_flat")?))?;
        let v = t.as_f32()?.to_vec();
        // Regroup: the artifact stores (S, d0); we reinterpret the same
        // weight stream at sub-vector length d (truncating the tail).
        let usable = (v.len() / d) * d;
        out.push(v[..usable].to_vec());
    }
    Ok(out)
}

fn switch_report(nets: usize, layers: usize, cb_bytes: usize) -> (TrafficReport, TrafficReport) {
    compare(&SwitchWorkload {
        nets,
        layers_per_net: layers,
        codebook_bytes_per_layer: cb_bytes,
        rounds: 10,
        inferences_per_activation: 5,
        sram_bytes: (layers * cb_bytes) * 3 / 2, // fits 1.5 networks
    })
}

/// Run E1 with an internally owned all-cores pool (the per-layer k-means
/// and `encode_nearest` sweeps are the experiment's hot loops).  Returns
/// rows grouped by bit width: UQ, P-VQ, U-VQ.
pub fn run(manifest: &Manifest, configs: &[BitConfig]) -> anyhow::Result<Vec<Row>> {
    let own = Parallelism::default().pool();
    run_with(manifest, configs, own.as_ref())
}

/// [`run`] on a caller-provided pool (`None` = fully serial).  Output is
/// bit-identical at every parallelism setting — every sweep underneath
/// follows the fixed-chunk determinism contract.
pub fn run_with(
    manifest: &Manifest,
    configs: &[BitConfig],
    pool: Option<&ThreadPool>,
) -> anyhow::Result<Vec<Row>> {
    let mut rows = Vec::new();
    let layers_per_net = 8; // representative per-layer codebook count
    for cfg in configs {
        // ---------------- UQ
        let flats = zoo_flats(manifest, 4)?;
        let mut mse_acc = 0.0;
        let mut weights = 0usize;
        for f in &flats {
            mse_acc += uniform::quant_mse(f, cfg.bit, Granularity::PerTensor) * f.len() as f64;
            weights += f.len();
        }
        rows.push(Row {
            bit: cfg.bit as f64,
            k: 0,
            d: 0,
            kind: "UQ",
            codebook_bytes: 0,
            mse: mse_acc / weights as f64,
            rate: 32.0 / cfg.bit as f64,
            io_multiple: 0.0,
        });

        // ---------------- P-VQ: per-network k-means codebooks
        let (kp, dp) = cfg.pvq;
        let flats = zoo_flats(manifest, dp)?;
        let mut mse_acc = 0.0;
        let mut weights = 0usize;
        let mut cb_bytes = 0usize;
        let mut assign_bits = 0f64;
        for f in &flats {
            let res = kmeans_with(f, dp, kp, &KmeansOpts::default(), pool);
            mse_acc += res.mse * f.len() as f64;
            weights += f.len();
            // per-layer: each of `layers_per_net` layers holds its own
            // codebook of the same geometry
            cb_bytes += layers_per_net * res.codebook.storage_bytes();
            assign_bits += (f.len() / dp) as f64 * (kp as f64).log2();
        }
        let (pl_traffic, rom_traffic) = switch_report(flats.len(), layers_per_net, kp * dp * 4);
        rows.push(Row {
            bit: cfg.bit as f64,
            k: kp,
            d: dp,
            kind: "P-VQ",
            codebook_bytes: cb_bytes,
            mse: mse_acc / weights as f64,
            rate: (weights as f64 * 32.0) / (assign_bits + cb_bytes as f64 * 8.0),
            // The paper's I/O column counts total codebook loads over the
            // task-switch benchmark, normalized to the universal codebook's
            // single (tape-out) load — its "514x vs 1x".
            io_multiple: io_multiple(&pl_traffic, &rom_traffic),
        });

        // ---------------- U-VQ: one KDE codebook for the whole zoo
        let (ku, du) = cfg.uvq;
        let flats = zoo_flats(manifest, du)?;
        let refs: Vec<&[f32]> = flats.iter().map(|v| v.as_slice()).collect();
        let mut rng = Rng::new(0xE1 + cfg.bit as u64);
        let kde_pool =
            KdeSampler::pool_from_networks_with(&refs, du, 10 * ku.min(2000), &mut rng, pool);
        let kde = KdeSampler::new(kde_pool, du, manifest.config.bandwidth as f32);
        let ucb = kde.sample_codebook_with(ku, &mut rng, pool);
        let mut mse_acc = 0.0;
        let mut weights = 0usize;
        let mut assign_bits = 0f64;
        for f in &flats {
            let (m, _) = ucb.encode_nearest_with(f, pool);
            mse_acc += m * f.len() as f64;
            weights += f.len();
            assign_bits += (f.len() / du) as f64 * (ku as f64).log2();
        }
        rows.push(Row {
            bit: cfg.bit as f64,
            k: ku,
            d: du,
            kind: "U-VQ",
            codebook_bytes: ucb.storage_bytes(),
            // universal codebook sits in ROM: amortized to zero per-model
            rate: (weights as f64 * 32.0) / assign_bits,
            mse: mse_acc / weights as f64,
            io_multiple: 1.0, // normalized: loaded once at tape-out
        });
    }
    Ok(rows)
}

/// Render as the paper's table.
pub fn render(rows: &[Row]) -> crate::bench::Table {
    let mut t = crate::bench::Table::new(
        "Table 1 — UQ vs P-VQ vs U-VQ (zoo-wide)",
        &["Bit", "k,d", "Type", "C", "MSE", "Rate", "I/O"],
    );
    for r in rows {
        t.row(vec![
            format!("{}", r.bit),
            if r.k == 0 {
                "-".into()
            } else {
                format!("2^{}, {}", (r.k as f64).log2() as u32, r.d)
            },
            r.kind.into(),
            if r.codebook_bytes == 0 {
                "-".into()
            } else {
                format!("{}K", r.codebook_bytes / 1024)
            },
            format!("{:.2e}", r.mse),
            if r.kind == "UQ" {
                format!("{:.0}x", r.rate)
            } else {
                format!("{:.1}x", r.rate)
            },
            match r.kind {
                "UQ" => "-".into(),
                "U-VQ" => "1x".into(),
                _ => format!("{:.0}x", r.io_multiple),
            },
        ]);
    }
    t
}

/// The claims the paper's Table 1 makes, as assertions (used by the
/// integration test and recorded in EXPERIMENTS.md):
/// at every bit width, P-VQ and U-VQ beat UQ on MSE, and U-VQ's I/O is
/// 1 while P-VQ's is orders of magnitude higher.
pub fn check_shape(rows: &[Row]) -> anyhow::Result<()> {
    for chunk in rows.chunks(3) {
        let (uq, pvq, uvq) = (&chunk[0], &chunk[1], &chunk[2]);
        anyhow::ensure!(
            pvq.mse < uq.mse,
            "bit {}: P-VQ mse {} !< UQ {}",
            uq.bit,
            pvq.mse,
            uq.mse
        );
        anyhow::ensure!(
            uvq.mse < uq.mse,
            "bit {}: U-VQ mse {} !< UQ {}",
            uq.bit,
            uvq.mse,
            uq.mse
        );
        anyhow::ensure!(
            uvq.io_multiple <= 1.0 && pvq.io_multiple > 100.0,
            "I/O ordering broken: U-VQ {} vs P-VQ {} (expected orders of magnitude)",
            uvq.io_multiple,
            pvq.io_multiple
        );
    }
    Ok(())
}

/// Self-contained MSE comparison on synthetic weights (unit-test scale).
pub fn synthetic_mse_ordering(seed: u64) -> (f64, f64, f64) {
    let mut rng = Rng::new(seed);
    let mut w = vec![0.0f32; 4 * 4000];
    rng.fill_normal(&mut w);
    for v in w.iter_mut() {
        *v *= 0.05; // weight-scale values
    }
    let uq = uniform::quant_mse(&w, 2, Granularity::PerTensor);
    let pv = kmeans(&w, 4, 256, &KmeansOpts::default()).mse;
    let pool = w.clone();
    let kde = KdeSampler::new(pool, 4, 0.01);
    let ucb = kde.sample_codebook(256, &mut rng);
    let (uv, _) = ucb.encode_nearest(&w);
    let _ = stats::mean(&[uq, pv, uv]);
    (uq, pv, uv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_ordering_matches_paper() {
        let (uq, pvq, uvq) = synthetic_mse_ordering(11);
        assert!(pvq < uq, "P-VQ {pvq} must beat UQ {uq}");
        assert!(uvq < uq, "U-VQ {uvq} must beat UQ {uq}");
        // Paper: U-VQ error on par with P-VQ (within a small factor).
        assert!(uvq < pvq * 4.0, "U-VQ {uvq} should be near P-VQ {pvq}");
    }
}
