//! E3 — **Table 2**: detection under compression (mini_detector, the
//! Mask-RCNN stand-in on the synthetic shapes task).
//!
//! Rows: uncompressed float, P-VQ (k-means, device-evaluated), VQ4ALL.
//! Columns: model size, compression ratio, AP proxy (mAP@0.5-style hit
//! rate — DESIGN.md §2 records the metric substitution).

use crate::coordinator::Campaign;
use crate::vq::kmeans::{kmeans, KmeansOpts};
use crate::tensor::{io, Tensor};

#[derive(Clone, Debug)]
pub struct Row {
    pub method: String,
    pub size_bytes: usize,
    pub ratio: f64,
    pub ap: f64,
}

pub fn run(campaign: &Campaign, net: &str) -> anyhow::Result<Vec<Row>> {
    let nm = campaign.manifest.network(net)?;
    let cfg = &campaign.manifest.config;
    let scope_bytes = nm.s_total * cfg.d * 4;
    let other_bytes: usize = nm.others.iter().map(|o| o.elems() * 4).sum();
    let float_total = scope_bytes + other_bytes;
    let mut rows = vec![Row {
        method: "float (uncompressed)".into(),
        size_bytes: float_total,
        ratio: 1.0,
        ap: nm.float_metric,
    }];

    // P-VQ baseline through the device eval.
    let flat_t = io::read_tensor(&campaign.manifest.path(nm.data_file("teacher_flat")?))?;
    let flat = flat_t.as_f32()?;
    let km = kmeans(flat, cfg.d, cfg.k, &KmeansOpts::default());
    let cb_tensor = Tensor::from_f32(&[cfg.k, cfg.d], km.codebook.words.clone());
    let mut sess = crate::coordinator::NetSession::new(&campaign.rt, &campaign.manifest, net, &cb_tensor)?;
    let codes_t = sess.codes_tensor(&km.codes);
    let (_, pvq_ap) = sess.evaluate("eval_hard", Some(&codes_t))?;
    let pvq_assign = nm.s_total * cfg.k.next_power_of_two().trailing_zeros() as usize / 8;
    let pvq_size = pvq_assign + km.codebook.storage_bytes() + other_bytes;
    rows.push(Row {
        method: "P-VQ (k-means, per-net codebook)".into(),
        size_bytes: pvq_size,
        ratio: float_total as f64 / pvq_size as f64,
        ap: pvq_ap,
    });

    // VQ4ALL.
    let vq = campaign.construct(net)?;
    let vq_size = vq.sizes.compressed_total();
    rows.push(Row {
        method: "VQ4ALL (universal codebook)".into(),
        size_bytes: vq_size,
        ratio: vq.sizes.ratio(),
        ap: vq.hard_metric,
    });
    Ok(rows)
}

pub fn render(rows: &[Row]) -> crate::bench::Table {
    let mut t = crate::bench::Table::new(
        "Table 2 — detection under compression (mini_detector / synthetic shapes)",
        &["method", "size", "ratio", "AP@0.5-proxy"],
    );
    for r in rows {
        t.row(vec![
            r.method.clone(),
            format!("{:.2} KB", r.size_bytes as f64 / 1024.0),
            format!("{:.1}x", r.ratio),
            format!("{:.3}", r.ap),
        ]);
    }
    t
}
