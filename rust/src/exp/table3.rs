//! E4 — **Table 3**: classification Top-1 / compression ratio for the
//! three classification stand-ins, VQ4ALL vs the EWGS-style UQ proxy
//! and the DKM-style (no-PNC) variant, per effective bit width.
//!
//! The artifact geometry fixes (k, d) per build profile, so the bit axis
//! is realized the same way the paper realizes it — one codebook
//! geometry per bit point — with the default profile's 2-bit geometry
//! measured on-device and the other bit points reported from the
//! closed-form accounting plus the E1 distortion model.

use crate::coordinator::Campaign;
use crate::quant::uniform;
use crate::tensor::io;

#[derive(Clone, Debug)]
pub struct Row {
    pub net: String,
    pub method: String,
    pub metric: f64,
    pub scope_ratio: f64,
    pub device_measured: bool,
}

/// Device-measured block at the build profile's bit width:
/// VQ4ALL vs DKM-style (no PNC) vs UQ distortion proxy.
pub fn run(campaign: &Campaign, nets: &[&str]) -> anyhow::Result<Vec<Row>> {
    // One pool for the per-net `encode_nearest` sweeps (the campaign's
    // construction loops spin their own internally).
    let pool = campaign.cfg.parallelism().pool();
    let mut rows = Vec::new();
    for net in nets {
        // VQ4ALL (full pipeline).
        let vq = campaign.construct(net)?;
        rows.push(Row {
            net: net.to_string(),
            method: "VQ4ALL".into(),
            metric: vq.hard_metric,
            scope_ratio: vq.sizes.scope_ratio(),
            device_measured: true,
        });

        // DKM-style: same differentiable machinery, no PNC, one-shot
        // hard transition at the end (the paper's own framing of DKM).
        let mut cfg = campaign.cfg.clone();
        cfg.disable_pnc = true;
        let c2 = Campaign {
            rt: crate::runtime::Runtime::cpu()?,
            manifest: campaign.manifest.clone(),
            cfg,
            codebook: campaign.codebook.clone(),
        };
        let dkm = c2.construct(net)?;
        // Per-layer accounting for DKM: private codebook counts.
        let k = campaign.manifest.config.k;
        let d = campaign.manifest.config.d;
        let nm = campaign.manifest.network(net)?;
        let scope_bytes = (nm.s_total * d * 4) as f64;
        let assign_bytes = nm.s_total as f64 * (k as f64).log2() / 8.0;
        rows.push(Row {
            net: net.to_string(),
            method: "DKM-style".into(),
            metric: dkm.hard_metric,
            scope_ratio: scope_bytes / (assign_bytes + (k * d * 4) as f64),
            device_measured: true,
        });

        // EWGS-style UQ proxy at the same effective bit width.
        let bit = campaign.manifest.config.effective_bit.round().max(1.0) as u32;
        let flat_t = io::read_tensor(&campaign.manifest.path(nm.data_file("teacher_flat")?))?;
        let flat = flat_t.as_f32()?;
        let mse = uniform::quant_mse(flat, bit, uniform::Granularity::PerTensor);
        // Anchor map from the two device-measured points of this net.
        let cb = crate::vq::Codebook::new(k, d, campaign.codebook.as_f32()?.to_vec());
        let (vq_mse, _) = cb.encode_nearest_with(flat, pool.as_ref());
        let mut anchors = vec![(vq_mse, vq.hard_metric), (vq_mse * 4.0, dkm.hard_metric.min(vq.hard_metric))];
        anchors.push((1e-7, nm.float_metric));
        let est = super::fig2::mse_to_metric(&mut anchors, mse);
        rows.push(Row {
            net: net.to_string(),
            method: format!("UQ-{bit}bit (EWGS-style)"),
            metric: est,
            scope_ratio: 32.0 / bit as f64,
            device_measured: false,
        });
    }
    Ok(rows)
}

pub fn render(rows: &[Row]) -> crate::bench::Table {
    let mut t = crate::bench::Table::new(
        "Table 3 — classification Top-1 / scope ratio",
        &["network", "method", "top1", "ratio", "measured"],
    );
    for r in rows {
        t.row(vec![
            r.net.clone(),
            r.method.clone(),
            format!("{:.4}", r.metric),
            format!("{:.1}x", r.scope_ratio),
            if r.device_measured { "device" } else { "proxy" }.into(),
        ]);
    }
    t
}
