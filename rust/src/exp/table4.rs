//! E5 — **Table 4**: generative quality of the compressed denoiser
//! (mini_denoiser, the Stable-Diffusion stand-in).
//!
//! The Rust coordinator runs the full reverse-diffusion loop through the
//! `sample_step` artifact (hard-coded VQ weights decoded from the
//! codebook inside the graph), then scores the samples:
//!
//! * **FID-proxy** — exact 2-D Fréchet distance between generated and
//!   real data (same formula as FID with identity features; DESIGN.md
//!   §2).  Lower is better.
//! * **IS-proxy** — mode coverage/entropy over the 8 GMM modes: the
//!   exponential of the entropy of the mode-assignment histogram
//!   (max 8.0 = all modes covered evenly).  Higher is better.
//!
//! Rows: data floor (split-half Fréchet), VQ4ALL, per-layer k-means at
//! the same k, and a crushed-k baseline standing in for the
//! Q-diffusion/PCR failure mode at 2 bits.

use crate::coordinator::{Campaign, NetSession};
use crate::tensor::ops::{frechet_distance_2d, mean_cov_2d};
use crate::tensor::{io, Tensor};
use crate::util::rng::Rng;
use crate::vq::kmeans::{kmeans, KmeansOpts};

#[derive(Clone, Debug)]
pub struct Row {
    pub method: String,
    pub fid: f64,
    pub is_proxy: f64,
}

/// Linear-beta DDPM schedule constants — must match
/// `python/compile/data.diffusion_schedule` (verified by the
/// `schedule_matches_python` test below).
pub fn diffusion_schedule(timesteps: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut betas = Vec::with_capacity(timesteps);
    for i in 0..timesteps {
        let frac = i as f32 / (timesteps - 1) as f32;
        betas.push(1e-4 + frac * (0.25 - 1e-4));
    }
    let alphas: Vec<f32> = betas.iter().map(|b| 1.0 - b).collect();
    let mut abar = Vec::with_capacity(timesteps);
    let mut acc = 1.0f32;
    for &a in &alphas {
        acc *= a;
        abar.push(acc);
    }
    (betas, alphas, abar)
}

/// Run the reverse-diffusion chain for `rounds` batches; returns
/// generated x0 samples (flattened (n, 2)).
///
/// The network's epsilon prediction runs on device (`denoise_eps`
/// artifact, hard VQ weights decoded from the universal codebook); the
/// DDPM posterior update runs here in the coordinator — the sampler
/// *loop* is L3 state, and the pure forward reuses the graph family the
/// xla_extension HLO-text round-trip executes correctly (the fused
/// `sample_step` form hits a mis-executed gather/select on this runtime
/// — see DESIGN.md §10).
pub fn generate(sess: &mut NetSession, codes: &Tensor, rounds: usize, seed: u64) -> anyhow::Result<Vec<f32>> {
    let b = sess.net.eval_batch;
    let timesteps = 50usize;
    let (betas, alphas, abar) = diffusion_schedule(timesteps);
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(rounds * b * 2);
    for _ in 0..rounds {
        let mut xt = vec![0.0f32; b * 2];
        rng.fill_normal(&mut xt);
        for t in (0..timesteps).rev() {
            let tdiff = Tensor::from_i32(&[b], vec![t as i32; b]);
            let xt_t = Tensor::from_f32(&[b, 2], xt.clone());
            let lits = sess.assemble_public("denoise_eps", Some(codes), &[xt_t, tdiff])?;
            let outs = sess.exec("denoise_eps")?.run_literals(&lits)?;
            let eps_pred = outs[0].as_f32()?;

            let beta = betas[t];
            let s1m = (1.0 - abar[t]).sqrt().max(1e-12);
            let inv_sqrt_alpha = 1.0 / alphas[t].sqrt();
            let sqrt_beta = beta.sqrt();
            let last = t == 0;
            for i in 0..b * 2 {
                let mean = inv_sqrt_alpha * (xt[i] - beta / s1m * eps_pred[i]);
                let z = if last { 0.0 } else { rng.normal() as f32 };
                xt[i] = mean + sqrt_beta * z;
            }
        }
        out.extend_from_slice(&xt);
    }
    Ok(out)
}

/// FID-proxy: exact 2-D Fréchet distance.
pub fn fid_proxy(gen: &[f32], real: &[f32]) -> f64 {
    let (mg, cg) = mean_cov_2d(gen);
    let (mr, cr) = mean_cov_2d(real);
    frechet_distance_2d(mg, cg, mr, cr)
}

/// IS-proxy: exp(entropy) of the 8-mode assignment histogram.
pub fn is_proxy(gen: &[f32], modes: usize, radius: f32) -> f64 {
    let n = gen.len() / 2;
    let mut counts = vec![0u64; modes];
    for i in 0..n {
        let (x, y) = (gen[2 * i], gen[2 * i + 1]);
        let ang = (y.atan2(x) + 2.0 * std::f32::consts::PI) % (2.0 * std::f32::consts::PI);
        let m = ((ang / (2.0 * std::f32::consts::PI) * modes as f32).round() as usize) % modes;
        // Only count samples near the ring (real modes live at r=radius).
        let r = (x * x + y * y).sqrt();
        if (r - radius).abs() < radius * 0.5 {
            counts[m] += 1;
        }
    }
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0f64;
    for &c in &counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.ln();
        }
    }
    h.exp()
}

pub fn run(campaign: &Campaign, net: &str) -> anyhow::Result<Vec<Row>> {
    let nm = campaign.manifest.network(net)?;
    anyhow::ensure!(nm.task == "denoise", "table4 needs the denoiser");
    let cfg = &campaign.manifest.config;
    let test = io::read_tensor(&campaign.manifest.path(nm.data_file("test_x")?))?;
    let real = test.as_f32()?;
    let half = real.len() / 4 * 2;
    let rounds = 4;

    let mut rows = vec![Row {
        method: "data floor (split-half)".into(),
        fid: fid_proxy(&real[..half], &real[half..]),
        is_proxy: is_proxy(real, 8, 2.0),
    }];

    // VQ4ALL.
    let vq = campaign.construct(net)?;
    let mut sess = NetSession::new(&campaign.rt, &campaign.manifest, net, &campaign.codebook)?;
    sess.set_others(&vq.final_others)?; // codes pair with the trained norms
    let codes_t = sess.codes_tensor(&vq.codes);
    let gen = generate(&mut sess, &codes_t, rounds, 0xD1FF)?;
    rows.push(Row {
        method: "VQ4ALL (universal)".into(),
        fid: fid_proxy(&gen, real),
        is_proxy: is_proxy(&gen, 8, 2.0),
    });

    // Per-layer k-means at the same k.
    let flat_t = io::read_tensor(&campaign.manifest.path(nm.data_file("teacher_flat")?))?;
    let flat = flat_t.as_f32()?;
    for (label, k) in [("P-VQ (k-means, same k)", cfg.k), ("crushed P-VQ (k=8)", 8)] {
        let km = kmeans(flat, cfg.d, k, &KmeansOpts::default());
        let mut words = km.codebook.words.clone();
        words.resize(cfg.k * cfg.d, 0.0);
        let cb = Tensor::from_f32(&[cfg.k, cfg.d], words);
        let mut s2 = NetSession::new(&campaign.rt, &campaign.manifest, net, &cb)?;
        let codes_t = s2.codes_tensor(&km.codes);
        let gen = generate(&mut s2, &codes_t, rounds, 0xD1FF + k as u64)?;
        rows.push(Row {
            method: label.into(),
            fid: fid_proxy(&gen, real),
            is_proxy: is_proxy(&gen, 8, 2.0),
        });
    }
    Ok(rows)
}

pub fn render(rows: &[Row]) -> crate::bench::Table {
    let mut t = crate::bench::Table::new(
        "Table 4 — generative quality (mini_denoiser, 2-D DDPM)",
        &["method", "FID-proxy (down)", "IS-proxy (up, max 8)"],
    );
    for r in rows {
        t.row(vec![
            r.method.clone(),
            format!("{:.4}", r.fid),
            format!("{:.2}", r.is_proxy),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_matches_python() {
        // data.diffusion_schedule(50): betas = linspace(1e-4, 0.25, 50).
        let (betas, alphas, abar) = diffusion_schedule(50);
        assert!((betas[0] - 1e-4).abs() < 1e-9);
        assert!((betas[49] - 0.25).abs() < 1e-7);
        assert!((alphas[0] - (1.0 - 1e-4)).abs() < 1e-7);
        // abar is the running product and strictly decreasing.
        let mut acc = 1.0f32;
        for (i, (&a, &ab)) in alphas.iter().zip(&abar).enumerate() {
            acc *= a;
            assert!((acc - ab).abs() < 1e-6, "abar[{i}]");
        }
        assert!(abar.windows(2).all(|w| w[1] < w[0]));
    }

    #[test]
    fn fid_proxy_zero_on_identical() {
        let mut rng = Rng::new(1);
        let mut pts = vec![0.0f32; 2000];
        rng.fill_normal(&mut pts);
        assert!(fid_proxy(&pts, &pts) < 1e-9);
    }

    #[test]
    fn is_proxy_full_ring_vs_single_mode() {
        // Points evenly on the 8-mode ring.
        let mut ring = Vec::new();
        for i in 0..800 {
            let ang = 2.0 * std::f32::consts::PI * (i % 8) as f32 / 8.0;
            ring.push(2.0 * ang.cos());
            ring.push(2.0 * ang.sin());
        }
        assert!(is_proxy(&ring, 8, 2.0) > 7.5);
        // Collapsed to one mode.
        let one: Vec<f32> = (0..800).flat_map(|_| [2.0f32, 0.0]).collect();
        assert!(is_proxy(&one, 8, 2.0) < 1.2);
    }
}
