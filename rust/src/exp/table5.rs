//! E6 — **Table 5**: ablations on 2-bit ResNet-18 (our mini_resnet18).
//!
//! Three studies, exactly as the paper's table:
//!
//! 1. candidate count `n` — the artifact geometry fixes n at build time,
//!    so the sweep emulates smaller n by *masking* candidates above the
//!    cutoff (their logits pinned to −inf via a large negative value in
//!    `z0` — they can never win), which reproduces the paper's n=1
//!    degeneration to plain nearest-codeword VQ;
//! 2. pipeline parts — `loss_w` zeroing for L_t / L_kd / L_r and
//!    `disable_pnc` for the PNC row;
//! 3. the index histogram of optimal assignments over candidate slots
//!    (the paper's "83.1% in 0..11" row showing near candidates win).

use crate::coordinator::Campaign;
use crate::util::config::CampaignConfig;
use crate::util::stats::Histogram;

/// Result row.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub metric: f64,
    pub converged: bool,
}

/// Ablation on candidate count (masking emulation).
pub fn candidate_count(campaign: &Campaign, net: &str, n_values: &[usize]) -> anyhow::Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &n_eff in n_values {
        let mut c2 = Campaign {
            rt: crate::runtime::Runtime::cpu()?,
            manifest: campaign.manifest.clone(),
            cfg: campaign.cfg.clone(),
            codebook: campaign.codebook.clone(),
        };
        c2.cfg.candidate_mask = Some(n_eff);
        let res = c2.construct(net)?;
        rows.push(Row {
            label: format!("n={n_eff}"),
            metric: res.hard_metric,
            converged: true,
        });
    }
    Ok(rows)
}

/// Pipeline-component ablation (the paper's "Part" block).
pub fn components(campaign: &Campaign, net: &str) -> anyhow::Result<Vec<Row>> {
    let variants: Vec<(&str, Box<dyn Fn(&mut CampaignConfig)>)> = vec![
        ("full", Box::new(|_c: &mut CampaignConfig| {})),
        ("no L_t", Box::new(|c| c.use_task_loss = false)),
        ("no L_kd", Box::new(|c| c.use_kd_loss = false)),
        ("no L_r", Box::new(|c| c.use_ratio_reg = false)),
        ("no PNC", Box::new(|c| c.disable_pnc = true)),
    ];
    let mut rows = Vec::new();
    for (label, patch) in variants {
        let mut cfg = campaign.cfg.clone();
        patch(&mut cfg);
        let c2 = Campaign {
            rt: crate::runtime::Runtime::cpu()?,
            manifest: campaign.manifest.clone(),
            cfg,
            codebook: campaign.codebook.clone(),
        };
        let res = c2.construct(net)?;
        // "nc" in the paper = loss diverges; we flag non-finite losses or
        // a soft metric that collapsed below chance.
        let last_loss = res.loss_curve.last().map(|m| m[0]).unwrap_or(f32::NAN);
        rows.push(Row {
            label: label.to_string(),
            metric: res.hard_metric,
            converged: last_loss.is_finite(),
        });
    }
    Ok(rows)
}

/// Index distribution of optimal assignments over candidate slots.
/// Returns normalized mass per slot bucket (paper buckets: 12 slots per
/// bucket at n=64; scaled to n/5 buckets here).
pub fn index_distribution(campaign: &Campaign, net: &str) -> anyhow::Result<Vec<f64>> {
    let res = campaign.construct(net)?;
    let n = campaign.manifest.config.n;
    // Recover the winning slot per group by re-deriving from codes:
    // the campaign's PNC state is internal, so recompute via a fresh
    // scheduler over the final z (codes = assign[slot]).
    let mut sess = crate::coordinator::NetSession::new(
        &campaign.rt,
        &campaign.manifest,
        net,
        &campaign.codebook,
    )?;
    // Replay: winning slot = position of the final code in the candidate row.
    let assign = sess.assign_u32();
    let mut hist = Histogram::new(0.0, n as f64, n.min(8));
    for (g, &code) in res.codes.iter().enumerate() {
        let row = &assign[g * n..(g + 1) * n];
        if let Some(slot) = row.iter().position(|&c| c == code) {
            hist.push(slot as f64);
        }
    }
    let _ = &mut sess;
    Ok(hist.normalized())
}

/// Render the three blocks as the paper's stacked table.
pub fn render(n_rows: &[Row], part_rows: &[Row], index_mass: &[f64]) -> String {
    let mut s = String::from("\n=== Table 5 — ablations (2-bit mini_resnet18) ===\n");
    s.push_str("n        : ");
    for r in n_rows {
        s.push_str(&format!("{}={:.3}  ", r.label, r.metric));
    }
    s.push_str("\nPart     : ");
    for r in part_rows {
        if r.converged {
            s.push_str(&format!("{}={:.3}  ", r.label, r.metric));
        } else {
            s.push_str(&format!("{}=nc  ", r.label));
        }
    }
    s.push_str("\nIndex    : ");
    for (i, m) in index_mass.iter().enumerate() {
        s.push_str(&format!("b{i}={:.1}%  ", m * 100.0));
    }
    s.push('\n');
    s
}
