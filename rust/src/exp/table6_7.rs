//! E9/E10 — **Table 6** (codebook source combinations) and **Table 7**
//! (candidate-assignment initialization methods).
//!
//! Table 6: the universal codebook is KDE-sampled from growing subsets
//! of the zoo's weights (net1, net1+2, ...) and each codebook is used to
//! construct the target network — the paper's finding is near-flat
//! accuracy, i.e. VQ4ALL does not depend on distribution match.
//!
//! Table 7: candidate tables built with random / cosine / Euclidean
//! selection, with and without Eq. 7's inverse-distance ratio init —
//! random collapses, Euclid+init wins (host-side `vq::assign` provides
//! the variants; the session's candidate table and z0 are overridden).

use crate::coordinator::{Campaign, NetSession};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::vq::assign::{candidates, equal_ratio_logits, init_ratio_logits, AssignInit};
use crate::vq::Codebook;

#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub metric: f64,
}

/// Table 6: construct `target` with codebooks sampled from subsets.
pub fn codebook_sources(
    campaign: &Campaign,
    target: &str,
    subsets: &[Vec<&str>],
) -> anyhow::Result<Vec<Row>> {
    let mut rows = Vec::new();
    for (i, subset) in subsets.iter().enumerate() {
        let cb = Campaign::build_codebook_from(&campaign.manifest, subset, 0x7AB6 + i as u64)?;
        let c2 = Campaign {
            rt: crate::runtime::Runtime::cpu()?,
            manifest: campaign.manifest.clone(),
            cfg: campaign.cfg.clone(),
            codebook: cb,
        };
        let res = c2.construct(target)?;
        rows.push(Row {
            label: subset.join("+"),
            metric: res.hard_metric,
        });
    }
    Ok(rows)
}

/// Table 7: construct `target` with each candidate-init strategy.
/// `with_ratio_init = false` uses equal logits (supplementary §10).
pub fn assign_init(
    campaign: &Campaign,
    target: &str,
    variants: &[(AssignInit, bool, &str)],
) -> anyhow::Result<Vec<Row>> {
    let cfg = &campaign.manifest.config;
    let cb = Codebook::new(cfg.k, cfg.d, campaign.codebook.as_f32()?.to_vec());
    let mut rows = Vec::new();
    for (init, ratio_init, label) in variants {
        // Build the candidate table host-side.
        let mut sess = NetSession::new(&campaign.rt, &campaign.manifest, target, &campaign.codebook)?;
        let flat = sess.teacher_flat.as_f32()?.to_vec();
        let mut rng = Rng::new(0x7AB7);
        let cand = candidates(&flat, &cb, cfg.n, *init, &mut rng);
        let z0 = if *ratio_init {
            init_ratio_logits(&cand)
        } else {
            equal_ratio_logits(sess.net.s_total, cfg.n)
        };
        sess.override_candidates(
            Tensor::from_i32(
                &[sess.net.s_total, cfg.n],
                cand.assign.iter().map(|&c| c as i32).collect(),
            ),
            Tensor::from_f32(&[sess.net.s_total, cfg.n], z0),
        );
        let res = campaign.construct_with_session(sess)?;
        rows.push(Row {
            label: label.to_string(),
            metric: res.hard_metric,
        });
    }
    Ok(rows)
}

pub fn render(title: &str, rows: &[Row]) -> crate::bench::Table {
    let mut t = crate::bench::Table::new(title, &["variant", "metric"]);
    for r in rows {
        t.row(vec![r.label.clone(), format!("{:.4}", r.metric)]);
    }
    t
}
