//! # VQ4ALL — Efficient Neural Network Representation via a Universal Codebook
//!
//! Production-quality reproduction of Deng et al., *VQ4ALL* (2024) as a
//! three-layer Rust + JAX + Pallas system (see `DESIGN.md`):
//!
//! * **Layer 1/2** (build time, python): Pallas kernels + JAX step
//!   functions, AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 3** (this crate): the coordinator that constructs many
//!   low-bit networks from one frozen universal codebook — candidate
//!   initialization, the Progressive-Network-Construction scheduler,
//!   multi-network campaigns, the ROM/memory simulator behind the
//!   paper's hardware claims, and a serving router demonstrating
//!   zero-reload task switching.
//!
//! Module map:
//!
//! | module        | role |
//! |---------------|------|
//! | [`analysis`]  | `vq4all-audit`: repo-contract static analyzer (SAFETY discipline, unsafe allow-list, reference-kernel manifest) |
//! | [`util`]      | in-house substrates: PRNG, JSON, CLI, config, logging, thread pool, stats |
//! | [`tensor`]    | host tensors, `.vqt` I/O, host math (matmul/softmax/top-k) |
//! | [`vq`]        | vector-quantization substrate: k-means, KDE sampling, candidate assignment, bit-packing, codebook formats |
//! | [`quant`]     | baselines: uniform quantization, ternary, per-layer VQ, PQF-style permutation, DKM-style hard transition |
//! | [`rom`]       | memory-hierarchy + silicon-area model (Table 1 I/O column, task-switch cost) |
//! | [`runtime`]   | PJRT wrapper: manifest-driven artifact loading & execution |
//! | [`coordinator`] | the VQ4ALL campaign: PNC scheduler, calibration streaming, checkpoints, reports |
//! | [`serving`]   | multi-network router / batcher / task-switch simulator |
//! | [`exp`]       | one module per paper table & figure (E1..E13 in DESIGN.md) |
//! | [`bench`]     | micro-benchmark harness (criterion is unavailable offline) |
//! | [`testing`]   | property-testing mini-framework |

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod exp;
pub mod quant;
pub mod rom;
pub mod runtime;
pub mod serving;
pub mod tensor;
pub mod testing;
pub mod util;
pub mod vq;

/// Crate version, surfaced by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
