//! `vq4all` — the launcher.
//!
//! Subcommands:
//!
//! * `codebook`  — build a universal codebook in Rust (KDE over zoo
//!   sub-vectors; §4.1) and write it as `.vqt`.
//! * `compress`  — run the construction campaign over the zoo (or a
//!   subset) and print the summary table.  `--config configs/x.toml`
//!   sets the schedule; CLI flags override.
//! * `eval`      — evaluate previously saved codes against the test set.
//! * `check`     — load + compile every artifact (CI gate).
//! * `report`    — dump the last campaign result JSON.
//!
//! Examples live in `examples/` (quickstart, compress_zoo, serve_switch)
//! and the paper harnesses in `benches/`.

use std::path::{Path, PathBuf};

use vq4all::coordinator::{report, Campaign};
use vq4all::runtime::{Manifest, Runtime};
use vq4all::tensor::io;
use vq4all::util::cli::Cli;
use vq4all::util::config::{CampaignConfig, RawConfig};

fn main() -> anyhow::Result<()> {
    vq4all::util::logging::init_from_env();
    let cli = Cli::new(
        "vq4all",
        "universal-codebook network construction (VQ4ALL reproduction)",
    )
    .opt("artifacts", "artifacts", "artifacts directory (make artifacts)")
    .opt("config", "", "campaign config TOML")
    .opt("nets", "", "comma-separated zoo subset (default: all)")
    .opt("steps", "", "construction steps override")
    .opt("alpha", "", "PNC threshold override")
    .opt("seed", "", "campaign seed override")
    .opt("out", "", "output path (codebook/report subcommands)")
    .opt("codes", "", "codes .vqt path (eval subcommand)")
    .threads_opt()
    .flag("no-pnc", "disable PNC (DKM-style ablation)")
    .flag("version", "print version");

    let args = cli.parse()?;
    if args.has("version") {
        println!("vq4all {}", vq4all::VERSION);
        return Ok(());
    }
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("compress");
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));

    // Config: file -> CLI overrides -> defaults.
    let mut cfg = match args.get("config") {
        Some(p) if !p.is_empty() => CampaignConfig::from_raw(&RawConfig::load(Path::new(p))?)?,
        _ => CampaignConfig::default(),
    };
    if let Some(s) = args.get("steps") {
        if !s.is_empty() {
            cfg.steps = s.parse()?;
        }
    }
    if let Some(a) = args.get("alpha") {
        if !a.is_empty() {
            cfg.alpha = a.parse()?;
        }
    }
    if let Some(s) = args.get("seed") {
        if !s.is_empty() {
            cfg.seed = s.parse()?;
        }
    }
    if args.has("no-pnc") {
        cfg.disable_pnc = true;
    }
    if let Some(t) = args.get("threads") {
        if !t.is_empty() {
            cfg.threads = args.parallelism()?.threads;
        }
    }

    match cmd {
        "check" => check(&dir),
        "codebook" => codebook(&dir, &args),
        "compress" => compress(&dir, cfg, &args),
        "eval" => eval(&dir, cfg, &args),
        other => anyhow::bail!(
            "unknown subcommand {other:?} (expected check | codebook | compress | eval)"
        ),
    }
}

fn check(dir: &Path) -> anyhow::Result<()> {
    let manifest = Manifest::load(dir)?;
    let rt = Runtime::cpu()?;
    let mut n = 0;
    for net in &manifest.networks {
        for (name, spec) in &net.executables {
            rt.load(&manifest.path(&spec.hlo), spec)
                .map_err(|e| anyhow::anyhow!("{}::{name}: {e}", net.name))?;
            n += 1;
        }
    }
    println!("all {n} artifacts load + compile on {}", rt.platform());
    Ok(())
}

fn codebook(dir: &Path, args: &vq4all::util::cli::Args) -> anyhow::Result<()> {
    let manifest = Manifest::load(dir)?;
    let nets: Vec<String> = match args.list("nets") {
        Some(v) if !v.is_empty() && !v[0].is_empty() => v,
        _ => manifest.networks.iter().map(|n| n.name.clone()).collect(),
    };
    let refs: Vec<&str> = nets.iter().map(|s| s.as_str()).collect();
    let pool = args.parallelism()?.pool();
    let cb = Campaign::build_codebook_from_with(&manifest, &refs, 2024, pool.as_ref())?;
    let out = PathBuf::from(args.get_or("out", "codebook.vqt"));
    io::write_tensor(&out, &cb)?;
    println!(
        "wrote {}x{} universal codebook from {nets:?} to {out:?}",
        manifest.config.k, manifest.config.d
    );
    Ok(())
}

fn compress(dir: &Path, cfg: CampaignConfig, args: &vq4all::util::cli::Args) -> anyhow::Result<()> {
    let campaign = Campaign::load(dir, cfg)?;
    let nets: Vec<String> = match args.list("nets") {
        Some(v) if !v.is_empty() && !v[0].is_empty() => v,
        _ => campaign
            .manifest
            .networks
            .iter()
            .map(|n| n.name.clone())
            .collect(),
    };
    let refs: Vec<&str> = nets.iter().map(|s| s.as_str()).collect();
    let result = campaign.run(&refs)?;
    report::table(&result).print();
    if let Some(out) = args.get("out") {
        if !out.is_empty() {
            std::fs::write(out, report::to_json(&result).to_string())?;
            println!("report written to {out}");
            // Also persist each network's packed codes next to the report.
            for n in &result.nets {
                let codes_path = format!("{out}.{}.codes.vqt", n.name);
                io::write_tensor(
                    Path::new(&codes_path),
                    &vq4all::tensor::Tensor::from_i32(
                        &[n.codes.len()],
                        n.codes.iter().map(|&c| c as i32).collect(),
                    ),
                )?;
            }
        }
    }
    Ok(())
}

fn eval(dir: &Path, cfg: CampaignConfig, args: &vq4all::util::cli::Args) -> anyhow::Result<()> {
    let campaign = Campaign::load(dir, cfg)?;
    let nets = args
        .list("nets")
        .filter(|v| !v.is_empty() && !v[0].is_empty())
        .ok_or_else(|| anyhow::anyhow!("eval needs --nets <name>"))?;
    let codes_path = args
        .get("codes")
        .filter(|s| !s.is_empty())
        .ok_or_else(|| anyhow::anyhow!("eval needs --codes <file.vqt>"))?;
    let codes = io::read_tensor(Path::new(codes_path))?;
    let mut sess = vq4all::coordinator::NetSession::new(
        &campaign.rt,
        &campaign.manifest,
        &nets[0],
        &campaign.codebook,
    )?;
    let (loss, metric) = sess.evaluate("eval_hard", Some(&codes))?;
    println!("{}: loss {loss:.4} metric {metric:.4}", nets[0]);
    Ok(())
}
