//! Quantization baselines the paper compares against.
//!
//! * [`uniform`] — symmetric uniform quantization (the UQ rows of
//!   Table 1; EWGS-style post-training variant for Table 3).
//! * [`ternary`] — TTQ-style ternary weights (Figure 2's low-ratio
//!   competitor).
//! * [`pvq`]     — per-layer vector quantization (DeepCompression / BGD /
//!   DKM family): one k-means codebook per layer, including PQF's
//!   permutation preprocessing as an option.
//!
//! The *trained* baselines (DKM's differentiable k-means with a forced
//! hard transition) reuse the VQ4ALL campaign with `disable_pnc = true`
//! (Table 5 / Figure 3 ablation) — the paper itself frames DKM that way.

//! * [`special`] — §5.1's special-layer pass: the output head gets a
//!   small *private* per-layer codebook (the one place the paper mixes
//!   per-layer VQ into the universal-codebook construction).

pub mod pvq;
pub mod special;
pub mod ternary;
pub mod uniform;
