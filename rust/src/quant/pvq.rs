//! Per-layer vector quantization — the P-VQ rows of Table 1 and the
//! BGD/PQF-style baselines of Figure 2.
//!
//! Each layer gets its own k-means codebook over its `d`-dim sub-vectors.
//! Options model the baseline family:
//!
//! * plain (DeepCompression-style): k-means, nearest assignment;
//! * PQF-style: a rate-distortion-motivated **permutation** of the
//!   input dimension before splitting into sub-vectors, so correlated
//!   weights land in the same sub-vector (we implement the variance-
//!   balancing greedy permutation PQF's reordering step approximates).

use crate::util::rng::Rng;
use crate::vq::codebook::Codebook;
use crate::vq::kmeans::{kmeans, KmeansOpts};

/// One compressed layer under per-layer VQ.
#[derive(Clone, Debug)]
pub struct PvqLayer {
    pub codebook: Codebook,
    pub codes: Vec<u32>,
    /// Optional input permutation applied before sub-vector split
    /// (PQF-style).  `None` for the plain baseline.
    pub perm: Option<Vec<usize>>,
    pub mse: f64,
}

/// Options for [`compress_layer`].
#[derive(Clone, Debug)]
pub struct PvqOpts {
    pub k: usize,
    pub d: usize,
    pub permute: bool,
    pub kmeans: KmeansOpts,
}

/// Compress one `(rows, cols)` out-first weight matrix.
pub fn compress_layer(w: &[f32], rows: usize, cols: usize, opts: &PvqOpts) -> PvqLayer {
    assert_eq!(w.len(), rows * cols);
    assert!(cols % opts.d == 0, "cols {cols} not divisible by d {}", opts.d);
    let (work, perm) = if opts.permute {
        let p = variance_balancing_permutation(w, rows, cols, opts.d);
        (apply_col_permutation(w, rows, cols, &p), Some(p))
    } else {
        (w.to_vec(), None)
    };
    let res = kmeans(&work, opts.d, opts.k, &opts.kmeans);
    PvqLayer {
        codebook: res.codebook,
        codes: res.codes,
        perm,
        mse: res.mse,
    }
}

/// Decode back to the original layout (undoing the permutation).
pub fn decode_layer(l: &PvqLayer, rows: usize, cols: usize) -> Vec<f32> {
    let mut flat = l.codebook.decode_vec(&l.codes);
    if let Some(p) = &l.perm {
        flat = undo_col_permutation(&flat, rows, cols, p);
    }
    flat
}

/// Greedy variance-balancing permutation: sort columns by variance, then
/// deal them round-robin into `cols / d` buckets so each sub-vector mixes
/// high- and low-variance dimensions (the effect PQF's rate-distortion
/// reordering is after).
pub fn variance_balancing_permutation(w: &[f32], rows: usize, cols: usize, d: usize) -> Vec<usize> {
    let mut var = vec![0.0f64; cols];
    for c in 0..cols {
        let mut mean = 0.0f64;
        for r in 0..rows {
            mean += w[r * cols + c] as f64;
        }
        mean /= rows as f64;
        let mut v = 0.0f64;
        for r in 0..rows {
            let dx = w[r * cols + c] as f64 - mean;
            v += dx * dx;
        }
        var[c] = v;
    }
    let mut order: Vec<usize> = (0..cols).collect();
    order.sort_by(|&a, &b| var[b].partial_cmp(&var[a]).unwrap_or(std::cmp::Ordering::Equal));
    // Deal round-robin into groups: group g takes order[g], order[g+G], ...
    let groups = cols / d;
    let mut perm = vec![0usize; cols];
    let mut slot = vec![0usize; groups];
    for (rank, &col) in order.iter().enumerate() {
        let g = rank % groups;
        perm[g * d + slot[g]] = col;
        slot[g] += 1;
    }
    perm
}

/// `out[r, j] = w[r, perm[j]]`.
pub fn apply_col_permutation(w: &[f32], rows: usize, cols: usize, perm: &[usize]) -> Vec<f32> {
    assert_eq!(perm.len(), cols);
    let mut out = vec![0.0f32; w.len()];
    for r in 0..rows {
        for j in 0..cols {
            out[r * cols + j] = w[r * cols + perm[j]];
        }
    }
    out
}

/// Inverse of [`apply_col_permutation`].
pub fn undo_col_permutation(w: &[f32], rows: usize, cols: usize, perm: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; w.len()];
    for r in 0..rows {
        for j in 0..cols {
            out[r * cols + perm[j]] = w[r * cols + j];
        }
    }
    out
}

/// Random permutation baseline (for the ablation bench).
pub fn random_permutation(cols: usize, rng: &mut Rng) -> Vec<usize> {
    rng.permutation(cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutation_roundtrip() {
        let w: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let mut rng = Rng::new(1);
        let p = random_permutation(6, &mut rng);
        let ap = apply_col_permutation(&w, 4, 6, &p);
        let back = undo_col_permutation(&ap, 4, 6, &p);
        assert_eq!(back, w);
    }

    #[test]
    fn variance_permutation_is_permutation() {
        let mut rng = Rng::new(2);
        let mut w = vec![0.0f32; 8 * 12];
        rng.fill_normal(&mut w);
        let mut p = variance_balancing_permutation(&w, 8, 12, 4);
        p.sort_unstable();
        assert_eq!(p, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn permute_helps_on_heterogeneous_columns() {
        // Columns 0..2 high variance, 2..8 tiny: without permutation the
        // high-variance dims concentrate in one sub-vector.
        let mut rng = Rng::new(3);
        let rows = 256;
        let cols = 8;
        let mut w = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let sigma = if c < 2 { 3.0 } else { 0.05 };
                w[r * cols + c] = rng.normal_f32(0.0, sigma);
            }
        }
        let base = PvqOpts {
            k: 16,
            d: 4,
            permute: false,
            kmeans: KmeansOpts::default(),
        };
        let plain = compress_layer(&w, rows, cols, &base);
        let permuted = compress_layer(
            &w,
            rows,
            cols,
            &PvqOpts {
                permute: true,
                ..base
            },
        );
        assert!(
            permuted.mse <= plain.mse * 1.05,
            "permuted {} should not lose to plain {}",
            permuted.mse,
            plain.mse
        );
        // Decode must restore the original column order statistics: the
        // high-variance columns stay high-variance after decode.
        let dec = decode_layer(&permuted, rows, cols);
        let col_var = |w: &[f32], c: usize| -> f64 {
            let mean: f64 = (0..rows).map(|r| w[r * cols + c] as f64).sum::<f64>() / rows as f64;
            (0..rows)
                .map(|r| (w[r * cols + c] as f64 - mean).powi(2))
                .sum::<f64>()
                / rows as f64
        };
        assert!(col_var(&dec, 0) > col_var(&dec, 5) * 10.0);
    }

    #[test]
    fn decode_shape_and_fidelity() {
        let mut rng = Rng::new(4);
        let mut w = vec![0.0f32; 64 * 8];
        rng.fill_normal(&mut w);
        let l = compress_layer(
            &w,
            64,
            8,
            &PvqOpts {
                k: 64,
                d: 2,
                permute: false,
                kmeans: KmeansOpts::default(),
            },
        );
        let dec = decode_layer(&l, 64, 8);
        assert_eq!(dec.len(), w.len());
        assert!(crate::util::stats::mse(&w, &dec) < 0.5);
    }
}
