//! Special-layer per-layer VQ (§5.1): the paper constructs the *output
//! layer* of classification networks with a **small per-layer codebook**
//! (2⁸×4 at 2-bit, 2⁸×8 at 1-bit) derived from clustering its own
//! weights, while every other layer uses the universal codebook.
//!
//! The campaign applies this post-construction: the head weights (stored
//! in the "others" float inputs because the universal-codebook layout
//! excludes them) are k-means-quantized host-side, the reconstructed
//! weights are fed back through the same `other:` inputs of `eval_hard`
//! / `infer_hard`, and the size accounting charges the packed codes plus
//! the private codebook instead of float bytes.

use crate::coordinator::session::NetSession;
use crate::tensor::Tensor;
use crate::util::threadpool::ThreadPool;
use crate::vq::kmeans::{kmeans_with, KmeansOpts};
use crate::vq::pack::{pack_codes, PackedCodes};

/// Per-layer VQ result for one special layer.
#[derive(Clone, Debug)]
pub struct SpecialLayer {
    pub name: String,
    /// Original float byte count.
    pub float_bytes: usize,
    /// Packed assignment bytes + private codebook bytes.
    pub compressed_bytes: usize,
    pub mse: f64,
    pub packed: PackedCodes,
    pub codebook_bytes: usize,
}

impl SpecialLayer {
    pub fn ratio(&self) -> f64 {
        self.float_bytes as f64 / self.compressed_bytes.max(1) as f64
    }
}

/// Heuristic for which "other" params are the §5.1 special layers:
/// the output head's weight matrices (large 2-D tensors named like the
/// zoo's heads).  Bias/norm vectors stay float, exactly as the paper
/// keeps biases and BN uncompressed.
pub fn special_candidates(sess: &NetSession) -> Vec<String> {
    sess.net
        .others
        .iter()
        .filter(|o| {
            let is_weight = o.name.ends_with(".w") || o.name.ends_with("head.w");
            // 2-D (dense) or 4-D (1x1-conv head) weights above a size floor.
            is_weight && o.shape.len() >= 2 && o.elems() >= 256
        })
        .map(|o| o.name.clone())
        .collect()
}

/// Quantize one special layer in place: cluster its sub-vectors with a
/// private (k, d) codebook, replace the session's float tensor with the
/// reconstruction, and return the accounting.
pub fn compress_special_layer(
    sess: &mut NetSession,
    name: &str,
    k: usize,
    d: usize,
    pool: Option<&ThreadPool>,
) -> anyhow::Result<SpecialLayer> {
    let state_name = format!("other:{name}");
    let t = sess.state_by_name(&state_name).clone();
    let w = t.as_f32()?;
    let usable = (w.len() / d) * d;
    anyhow::ensure!(usable > 0, "{name}: too small for d={d}");

    let res = kmeans_with(
        &w[..usable],
        d,
        k.min(usable / d),
        &KmeansOpts::default(),
        pool,
    );
    let mut recon = w.to_vec();
    let decoded = res.codebook.decode_vec(&res.codes);
    recon[..usable].copy_from_slice(&decoded);

    let bits = (usize::BITS - (res.codebook.k - 1).leading_zeros()).max(1);
    let packed = pack_codes(&res.codes, bits);
    let cb_bytes = res.codebook.storage_bytes();
    // The unquantized tail (len % d) stays float and is charged as such.
    let tail_bytes = (w.len() - usable) * 4;

    sess.set_state(&state_name, Tensor::from_f32(&t.shape, recon))?;

    Ok(SpecialLayer {
        name: name.to_string(),
        float_bytes: w.len() * 4,
        compressed_bytes: packed.bytes() + cb_bytes + tail_bytes,
        mse: res.mse,
        packed,
        codebook_bytes: cb_bytes,
    })
}

/// Compress every special candidate of a session (the §5.1 pass).
/// Returns per-layer reports; the session's float inputs now hold the
/// reconstructed weights, so subsequent `eval_hard` / `infer_hard` runs
/// measure the fully compressed network.
pub fn compress_output_layers(
    sess: &mut NetSession,
    k: usize,
    d: usize,
    pool: Option<&ThreadPool>,
) -> anyhow::Result<Vec<SpecialLayer>> {
    let mut out = Vec::new();
    for name in special_candidates(sess) {
        out.push(compress_special_layer(sess, &name, k, d, pool)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vq::kmeans::kmeans;

    #[test]
    fn kmeans_special_accounting_is_consistent() {
        // Pure accounting check (session-level behaviour is covered by
        // the integration test): compressed bytes < float bytes for a
        // realistic head, and the ratio matches the formula.
        let mut rng = crate::util::rng::Rng::new(3);
        let mut w = vec![0.0f32; 128 * 10];
        rng.fill_normal(&mut w);
        let res = kmeans(&w, 4, 64, &KmeansOpts::default());
        let bits = (usize::BITS - (res.codebook.k - 1).leading_zeros()).max(1);
        let packed = pack_codes(&res.codes, bits);
        let compressed = packed.bytes() + res.codebook.storage_bytes();
        assert!(compressed < w.len() * 4, "{compressed} !< {}", w.len() * 4);
        // 6-bit codes on 320 groups = 240 bytes; codebook 64*4*4 = 1024.
        assert_eq!(packed.bytes(), (320 * 6usize).div_ceil(8));
    }
}
