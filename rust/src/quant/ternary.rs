//! TTQ-style ternary quantization (Figure 2 baseline).
//!
//! Weights map to `{-w_n, 0, +w_p}` with a sparsity threshold
//! `t * max|w|`; the positive/negative magnitudes are the means of the
//! surviving weights (the post-training analogue of Trained Ternary
//! Quantization — we ablate only the representation, not TTQ's training
//! loop, which the paper also sources from the original numbers).

/// Ternarization result.
#[derive(Clone, Debug)]
pub struct Ternary {
    pub w_pos: f32,
    pub w_neg: f32,
    pub threshold: f32,
    /// -1 / 0 / +1 per weight.
    pub signs: Vec<i8>,
}

/// Ternarize with threshold fraction `t` (TTQ uses ~0.05).
pub fn ternarize(w: &[f32], t: f32) -> Ternary {
    let absmax = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let thr = t * absmax;
    let mut signs = Vec::with_capacity(w.len());
    let (mut sp, mut np_, mut cp, mut cn) = (0.0f64, 0.0f64, 0usize, 0usize);
    for &x in w {
        if x > thr {
            signs.push(1);
            sp += x as f64;
            cp += 1;
        } else if x < -thr {
            signs.push(-1);
            np_ += (-x) as f64;
            cn += 1;
        } else {
            signs.push(0);
        }
    }
    Ternary {
        w_pos: if cp > 0 { (sp / cp as f64) as f32 } else { 0.0 },
        w_neg: if cn > 0 { (np_ / cn as f64) as f32 } else { 0.0 },
        threshold: thr,
        signs,
    }
}

/// Dequantize.
pub fn dequantize(t: &Ternary, out: &mut [f32]) {
    assert_eq!(out.len(), t.signs.len());
    for (o, &s) in out.iter_mut().zip(&t.signs) {
        *o = match s {
            1 => t.w_pos,
            -1 => -t.w_neg,
            _ => 0.0,
        };
    }
}

/// Quantize-dequantize MSE per weight.
pub fn ternary_mse(w: &[f32], t: f32) -> f64 {
    let q = ternarize(w, t);
    let mut deq = vec![0.0f32; w.len()];
    dequantize(&q, &mut deq);
    crate::util::stats::mse(w, &deq)
}

/// Storage: 2 bits per weight (trit packed at 2b) + two f32 magnitudes.
pub fn storage_bytes(num_weights: usize) -> usize {
    (num_weights * 2).div_ceil(8) + 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn signs_and_magnitudes() {
        let w = [1.0f32, -1.0, 0.01, 0.8, -0.6];
        let t = ternarize(&w, 0.1);
        assert_eq!(t.signs, vec![1, -1, 0, 1, -1]);
        assert!((t.w_pos - 0.9).abs() < 1e-6);
        assert!((t.w_neg - 0.8).abs() < 1e-6);
    }

    #[test]
    fn better_than_nothing_worse_than_8bit() {
        let mut rng = Rng::new(2);
        let mut w = vec![0.0f32; 2000];
        rng.fill_normal(&mut w);
        let mt = ternary_mse(&w, 0.05);
        let zero_mse = crate::util::stats::mse(&w, &vec![0.0; 2000]);
        let m8 = crate::quant::uniform::quant_mse(
            &w,
            8,
            crate::quant::uniform::Granularity::PerTensor,
        );
        assert!(mt < zero_mse, "ternary beats the zero model");
        assert!(mt > m8, "ternary is coarser than 8-bit");
    }

    #[test]
    fn all_zero_input() {
        let t = ternarize(&[0.0; 10], 0.05);
        assert!(t.signs.iter().all(|&s| s == 0));
        assert_eq!(t.w_pos, 0.0);
    }
}
