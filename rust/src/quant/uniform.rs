//! Symmetric uniform quantization (§3.1's UQ).
//!
//! `W ≈ s * W_int` with a shared scale per tensor (or per channel), the
//! classic b-bit PTQ.  Provides quantize/dequantize, the MSE accounting
//! for Table 1, and size accounting for the Figure-2 baselines.

/// Quantization granularity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerTensor,
    /// Rows of a `(rows, cols)` matrix get independent scales
    /// (channel-wise for out-first weight matrices).
    PerRow { rows: usize },
}

/// Result of uniform quantization.
#[derive(Clone, Debug)]
pub struct UniformQuant {
    pub bits: u32,
    pub qmax: i32,
    /// One scale (PerTensor) or `rows` scales (PerRow).
    pub scales: Vec<f32>,
    pub values: Vec<i32>,
}

/// Symmetric b-bit quantization: levels in `[-qmax, qmax]`,
/// `qmax = 2^(b-1) - 1` (b >= 2), or {-1, +1} at b = 1 (sign quant).
pub fn quantize(w: &[f32], bits: u32, gran: Granularity) -> UniformQuant {
    assert!((1..=16).contains(&bits));
    let qmax: i32 = if bits == 1 { 1 } else { (1 << (bits - 1)) - 1 };
    let (rows, cols) = match gran {
        Granularity::PerTensor => (1, w.len()),
        Granularity::PerRow { rows } => {
            assert!(rows > 0 && w.len() % rows == 0, "rows must divide len");
            (rows, w.len() / rows)
        }
    };
    let mut scales = vec![0.0f32; rows];
    let mut values = vec![0i32; w.len()];
    for r in 0..rows {
        let seg = &w[r * cols..(r + 1) * cols];
        let absmax = seg.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let scale = if absmax == 0.0 { 1.0 } else { absmax / qmax as f32 };
        scales[r] = scale;
        for (i, &x) in seg.iter().enumerate() {
            let q = (x / scale).round() as i32;
            values[r * cols + i] = q.clamp(-qmax, qmax).max(if bits == 1 { -1 } else { -qmax });
            if bits == 1 && values[r * cols + i] == 0 {
                // sign quantization: no zero level
                values[r * cols + i] = if x >= 0.0 { 1 } else { -1 };
            }
        }
    }
    UniformQuant {
        bits,
        qmax,
        scales,
        values,
    }
}

/// Dequantize back to f32.
pub fn dequantize(q: &UniformQuant, gran: Granularity, out: &mut [f32]) {
    assert_eq!(out.len(), q.values.len());
    let (rows, cols) = match gran {
        Granularity::PerTensor => (1, out.len()),
        Granularity::PerRow { rows } => (rows, out.len() / rows),
    };
    assert_eq!(q.scales.len(), rows);
    for r in 0..rows {
        let s = q.scales[r];
        for i in 0..cols {
            out[r * cols + i] = q.values[r * cols + i] as f32 * s;
        }
    }
}

/// Quantize-dequantize MSE per weight (Table 1's UQ MSE column).
pub fn quant_mse(w: &[f32], bits: u32, gran: Granularity) -> f64 {
    let q = quantize(w, bits, gran);
    let mut deq = vec![0.0f32; w.len()];
    dequantize(&q, gran, &mut deq);
    crate::util::stats::mse(w, &deq)
}

/// Storage bytes: packed integer values + f32 scales.
pub fn storage_bytes(num_weights: usize, bits: u32, num_scales: usize) -> usize {
    (num_weights * bits as usize).div_ceil(8) + num_scales * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_high_bits_is_accurate() {
        let mut rng = Rng::new(1);
        let mut w = vec![0.0f32; 1000];
        rng.fill_normal(&mut w);
        let mse8 = quant_mse(&w, 8, Granularity::PerTensor);
        let mse2 = quant_mse(&w, 2, Granularity::PerTensor);
        assert!(mse8 < 1e-3, "8-bit mse {mse8}");
        assert!(mse2 > mse8 * 10.0, "error grows as bits shrink");
    }

    #[test]
    fn per_row_beats_per_tensor_on_heterogeneous_rows() {
        // Both rows are exactly representable under their own scale
        // (3-bit, qmax = 3), but under the shared scale (10.0) row 0
        // collapses to zero. Per-row must therefore be exact while
        // per-tensor keeps row 0's full energy as error.
        let mut w = vec![0.0f32; 200];
        for i in 0..100 {
            w[i] = 0.01 * ((i % 7) as f32 - 3.0); // multiples of 0.01, |.| <= 0.03
            w[100 + i] = 10.0 * ((i % 7) as f32 - 3.0); // multiples of 10, |.| <= 30
        }
        let mt = quant_mse(&w, 3, Granularity::PerTensor);
        let mr = quant_mse(&w, 3, Granularity::PerRow { rows: 2 });
        assert!(mr < 1e-12, "per-row is exact here, got {mr}");
        assert!(mt > 1e-6, "per-tensor zeroes row 0, got {mt}");
    }

    #[test]
    fn one_bit_is_sign_times_scale() {
        let w = [0.5f32, -0.25, 0.1, -0.9];
        let q = quantize(&w, 1, Granularity::PerTensor);
        assert!(q.values.iter().all(|&v| v == 1 || v == -1));
        let mut deq = vec![0.0; 4];
        dequantize(&q, Granularity::PerTensor, &mut deq);
        for (d, w) in deq.iter().zip(&w) {
            assert_eq!(d.signum(), w.signum());
        }
    }

    #[test]
    fn zero_tensor_safe() {
        let w = [0.0f32; 8];
        assert_eq!(quant_mse(&w, 4, Granularity::PerTensor), 0.0);
    }

    #[test]
    fn storage_accounting() {
        assert_eq!(storage_bytes(1000, 3, 1), 375 + 4);
        assert_eq!(storage_bytes(8, 8, 2), 8 + 8);
    }
}
