//! First-order silicon-area model for codebook storage (§3.2's
//! "reduces the silicon area" claim, quantified).
//!
//! Bit-cell areas are process-normalized (units of F², the square of the
//! feature size), standard digital-VLSI rules of thumb:
//!
//! * mask ROM bit  ≈ 0.3 F² (diffusion-programmed NOR ROM)
//! * SRAM 6T bit   ≈ 150 F²  (logic-process 6T cell)
//! * DRAM on-chip (eDRAM) ≈ 30 F²
//!
//! The point of the model is the *ratio* — a ROM-resident universal
//! codebook costs ~500× less area per bit than keeping per-layer
//! codebooks hot in SRAM, which is the paper's architectural argument.

/// Technology constants in F² per bit.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    pub rom_f2_per_bit: f64,
    pub sram_f2_per_bit: f64,
    pub edram_f2_per_bit: f64,
    /// Feature size in nm (for absolute mm² figures).
    pub feature_nm: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            rom_f2_per_bit: 0.3,
            sram_f2_per_bit: 150.0,
            edram_f2_per_bit: 30.0,
            feature_nm: 7.0,
        }
    }
}

impl AreaModel {
    fn f2_to_mm2(&self, f2: f64) -> f64 {
        let f_m = self.feature_nm * 1e-9;
        f2 * f_m * f_m * 1e6 // m² -> mm²
    }

    /// Area (mm²) of `bytes` of mask ROM.
    pub fn rom_mm2(&self, bytes: usize) -> f64 {
        self.f2_to_mm2(bytes as f64 * 8.0 * self.rom_f2_per_bit)
    }

    /// Area (mm²) of `bytes` of SRAM.
    pub fn sram_mm2(&self, bytes: usize) -> f64 {
        self.f2_to_mm2(bytes as f64 * 8.0 * self.sram_f2_per_bit)
    }

    /// Area comparison for a deployment:
    /// per-layer VQ needs `sum(per_layer_bytes)` hot in SRAM (or a
    /// working set `sram_working_set` if given); universal VQ needs one
    /// ROM table.  Returns (per_layer_mm2, universal_mm2).
    pub fn compare(&self, per_layer_total_bytes: usize, universal_bytes: usize) -> (f64, f64) {
        (
            self.sram_mm2(per_layer_total_bytes),
            self.rom_mm2(universal_bytes),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rom_is_hundreds_of_times_denser_than_sram() {
        let m = AreaModel::default();
        let (sram, rom) = m.compare(1 << 20, 1 << 20);
        assert!(sram / rom > 100.0, "sram {sram} rom {rom}");
    }

    #[test]
    fn absolute_scale_sane() {
        // 2 MB universal codebook in 7nm ROM should be well under 0.01 mm².
        let m = AreaModel::default();
        let mm2 = m.rom_mm2(2 << 20);
        assert!(mm2 < 0.01, "2MB ROM = {mm2} mm²");
        // 2 MB of SRAM is macroscopic (~0.1-1 mm² at 7nm).
        assert!(m.sram_mm2(2 << 20) > 0.05);
    }

    #[test]
    fn monotone_in_bytes() {
        let m = AreaModel::default();
        assert!(m.rom_mm2(2048) > m.rom_mm2(1024));
    }
}
