//! Codebook-traffic simulator (Table 1 `I/O`, §3.2 task switching).
//!
//! Model: a serving platform hosts `N` networks and continuously
//! switches between tasks.  Every inference of a network with *per-layer*
//! codebooks must have each layer's codebook resident; with a small
//! on-chip buffer the codebooks of other layers/networks evict each
//! other, so task switches (and layer walks, when the buffer is smaller
//! than the per-network total) re-load codebooks from DRAM.  The
//! *universal* codebook is a static table: it is burned into ROM and
//! never transferred.
//!
//! Table 1's `514x` is the paper's measured per-layer-VQ I/O multiple
//! across its five-network zoo; our simulator reproduces the *structure*
//! (hundreds-to-one) — the exact constant depends on layer counts.

use std::collections::VecDeque;

/// Where codebooks live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodebookPlacement {
    /// One codebook per layer, staged through an SRAM buffer of
    /// `sram_bytes`; misses stream from DRAM.
    PerLayerDram { sram_bytes: usize },
    /// Single universal codebook in on-chip ROM (never transferred).
    UniversalRom,
}

/// Static description of one network's codebook demand.
#[derive(Clone, Debug)]
pub struct NetCodebooks {
    pub name: String,
    /// Bytes of each per-layer codebook (empty under UniversalRom).
    pub layer_codebooks: Vec<usize>,
}

/// Traffic accounting.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TrafficReport {
    /// Bytes moved DRAM -> SRAM for codebooks.
    pub codebook_bytes_loaded: u64,
    /// Number of codebook load events (the `I/O` count of Table 1).
    pub codebook_loads: u64,
    /// Inferences served.
    pub inferences: u64,
    /// Task switches performed.
    pub switches: u64,
}

impl TrafficReport {
    /// Loads per inference — the normalized `I/O` column.
    pub fn loads_per_inference(&self) -> f64 {
        self.codebook_loads as f64 / self.inferences.max(1) as f64
    }
}

/// LRU-cached codebook buffer simulator.
pub struct MemSim {
    placement: CodebookPlacement,
    nets: Vec<NetCodebooks>,
    /// LRU of (net, layer) keys currently resident, with sizes.
    resident: VecDeque<(usize, usize)>,
    resident_bytes: usize,
    pub report: TrafficReport,
}

impl MemSim {
    pub fn new(placement: CodebookPlacement, nets: Vec<NetCodebooks>) -> Self {
        MemSim {
            placement,
            nets,
            resident: VecDeque::new(),
            resident_bytes: 0,
            report: TrafficReport::default(),
        }
    }

    /// Serve one inference on network `net`: every layer's codebook must
    /// be touched in order.
    pub fn infer(&mut self, net: usize) {
        self.report.inferences += 1;
        match self.placement {
            CodebookPlacement::UniversalRom => {
                // ROM: zero codebook traffic, ever.
            }
            CodebookPlacement::PerLayerDram { sram_bytes } => {
                let layers = self.nets[net].layer_codebooks.clone();
                for (li, bytes) in layers.iter().enumerate() {
                    self.touch(net, li, *bytes, sram_bytes);
                }
            }
        }
    }

    /// Record a task switch (bookkeeping only; the eviction pressure is
    /// what actually causes reloads).
    pub fn switch_task(&mut self) {
        self.report.switches += 1;
    }

    fn touch(&mut self, net: usize, layer: usize, bytes: usize, cap: usize) {
        let key = (net, layer);
        if let Some(pos) = self.resident.iter().position(|&k| k == key) {
            // Hit: refresh LRU position.
            self.resident.remove(pos);
            self.resident.push_back(key);
            return;
        }
        // Miss: load from DRAM, evicting LRU entries as needed.
        self.report.codebook_loads += 1;
        self.report.codebook_bytes_loaded += bytes as u64;
        while self.resident_bytes + bytes > cap && !self.resident.is_empty() {
            let (en, el) = self.resident.pop_front().unwrap();
            self.resident_bytes -= self.nets[en].layer_codebooks[el];
        }
        if self.resident_bytes + bytes <= cap {
            self.resident.push_back(key);
            self.resident_bytes += bytes;
        }
        // else: codebook larger than the whole buffer — streamed, never resident.
    }
}

/// Round-robin task-switch workload: `rounds` passes over `nets`,
/// `per_task` inferences each, switching between tasks.
pub fn switch_storm(sim: &mut MemSim, nets: usize, rounds: usize, per_task: usize) {
    for _ in 0..rounds {
        for n in 0..nets {
            sim.switch_task();
            for _ in 0..per_task {
                sim.infer(n);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zoo(nets: usize, layers: usize, bytes: usize) -> Vec<NetCodebooks> {
        (0..nets)
            .map(|i| NetCodebooks {
                name: format!("net{i}"),
                layer_codebooks: vec![bytes; layers],
            })
            .collect()
    }

    #[test]
    fn rom_placement_never_loads() {
        let mut sim = MemSim::new(CodebookPlacement::UniversalRom, zoo(3, 10, 1 << 20));
        switch_storm(&mut sim, 3, 5, 4);
        assert_eq!(sim.report.codebook_loads, 0);
        assert_eq!(sim.report.codebook_bytes_loaded, 0);
        assert_eq!(sim.report.inferences, 60);
    }

    #[test]
    fn tiny_sram_reloads_every_layer() {
        // Buffer fits one codebook: every layer touch is a miss.
        let mut sim = MemSim::new(
            CodebookPlacement::PerLayerDram { sram_bytes: 1024 },
            zoo(2, 8, 1024),
        );
        switch_storm(&mut sim, 2, 3, 2);
        // 2 nets * 3 rounds * 2 inf * 8 layers = 96 touches, all misses
        // except consecutive hits on the same layer? Layers cycle, so all miss.
        assert_eq!(sim.report.codebook_loads, 96);
        assert!((sim.report.loads_per_inference() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn big_sram_loads_once_per_codebook() {
        // Buffer fits everything: first pass loads, rest hit.
        let mut sim = MemSim::new(
            CodebookPlacement::PerLayerDram { sram_bytes: 1 << 30 },
            zoo(3, 5, 4096),
        );
        switch_storm(&mut sim, 3, 10, 10);
        assert_eq!(sim.report.codebook_loads, 15, "one load per (net, layer)");
    }

    #[test]
    fn eviction_pressure_causes_thrash_on_switch() {
        // Buffer fits exactly one network's codebooks: switching between
        // two networks evicts, so each round reloads.
        let nets = zoo(2, 4, 1024);
        let mut sim = MemSim::new(
            CodebookPlacement::PerLayerDram { sram_bytes: 4 * 1024 },
            nets,
        );
        switch_storm(&mut sim, 2, 5, 3);
        // Each task activation reloads its 4 codebooks once (then hits).
        // 2 nets * 5 rounds = 10 activations * 4 layers = 40 loads.
        assert_eq!(sim.report.codebook_loads, 40);
        assert_eq!(sim.report.switches, 10);
    }

    #[test]
    fn oversized_codebook_streams() {
        let mut sim = MemSim::new(
            CodebookPlacement::PerLayerDram { sram_bytes: 512 },
            zoo(1, 2, 1024),
        );
        sim.infer(0);
        sim.infer(0);
        // never resident -> 2 layers * 2 inferences = 4 loads
        assert_eq!(sim.report.codebook_loads, 4);
    }
}
