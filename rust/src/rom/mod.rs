//! ROM / memory-hierarchy simulator — the hardware model behind §3.2 and
//! Table 1's `I/O` column.
//!
//! * [`memsim`] — counts codebook traffic for serving workloads under
//!   three placements: per-layer codebooks in DRAM (reloaded per layer
//!   per inference), per-layer codebooks cached in SRAM, and the single
//!   universal codebook in ROM (loaded zero times after tape-out).
//! * [`area`]   — a first-order silicon-area model (bit-cell areas for
//!   ROM/SRAM) quantifying the paper's "reduces silicon area" claim.

pub mod area;
pub mod memsim;

pub use area::AreaModel;
pub use memsim::{CodebookPlacement, MemSim, TrafficReport};
