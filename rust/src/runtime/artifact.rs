//! `artifacts/manifest.json` parsing — the single contract between the
//! python build path and the Rust run path (DESIGN.md §5).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::tensor::DType;
use crate::util::json::{self, Json};

/// One tensor in an executable signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> anyhow::Result<Self> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        // `others` entries omit dtype in the manifest — they are always
        // f32 parameters (bias/norm/excluded weights).
        let dtype = match j.get("dtype").and_then(|d| d.as_str()) {
            Some(s) => DType::from_str_name(s)?,
            None => DType::F32,
        };
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            shape,
            dtype,
        })
    }
}

/// One AOT executable: HLO file + signature.
#[derive(Clone, Debug)]
pub struct ExecSpec {
    pub hlo: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One compressed layer's slice in the flat sub-vector space.
#[derive(Clone, Debug)]
pub struct LayerSlice {
    pub name: String,
    pub kind: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub groups: usize,
}

/// Everything the manifest records about one zoo network.
#[derive(Clone, Debug)]
pub struct NetworkManifest {
    pub name: String,
    pub task: String,
    pub arch: String,
    pub input_shape: Vec<usize>,
    pub batch: usize,
    pub eval_batch: usize,
    pub calib_size: usize,
    pub test_size: usize,
    pub s_total: usize,
    pub float_loss: f64,
    pub float_metric: f64,
    pub layers: Vec<LayerSlice>,
    /// Per-stage FNV-1a checksums of the net's packed code streams
    /// (`vq::pack::StagedCodes::checksums`), recorded at build time as
    /// hex strings (JSON numbers are f64-backed here and cannot carry
    /// 64 bits losslessly).  Empty = manifest predates the key; nothing
    /// to verify against.
    pub code_checksums: Vec<u64>,
    pub others: Vec<TensorSpec>,
    pub state_specs: Vec<TensorSpec>,
    pub static_specs: Vec<TensorSpec>,
    pub batch_specs: Vec<TensorSpec>,
    pub eval_batch_specs: Vec<TensorSpec>,
    pub executables: BTreeMap<String, ExecSpec>,
    pub data: BTreeMap<String, String>,
}

impl NetworkManifest {
    pub fn exec(&self, name: &str) -> anyhow::Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("network {} has no executable {name:?}", self.name))
    }

    pub fn data_file(&self, tag: &str) -> anyhow::Result<&str> {
        self.data
            .get(tag)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("network {} has no data file {tag:?}", self.name))
    }

    /// Total f32 weights in the compressed scope.
    pub fn compressed_weights(&self, d: usize) -> usize {
        self.s_total * d
    }

    /// Verify a loaded code stream against the manifest's recorded
    /// per-stage checksums.  A manifest without the key verifies
    /// vacuously (legacy builds); one with the key must match stage for
    /// stage — a mismatch means the packed bytes on disk are not the
    /// ones the build stamped, and the net must not be hosted.
    pub fn verify_code_checksums(
        &self,
        staged: &crate::vq::pack::StagedCodes,
    ) -> anyhow::Result<()> {
        if self.code_checksums.is_empty() {
            return Ok(());
        }
        anyhow::ensure!(
            self.code_checksums.len() == staged.stages(),
            "network {}: manifest records {} code checksum(s) but the stream has {} stage(s)",
            self.name,
            self.code_checksums.len(),
            staged.stages()
        );
        staged.verify_checksums(&self.code_checksums).map_err(|e| {
            anyhow::anyhow!("network {}: code-stream integrity failure: {e}", self.name)
        })
    }
}

/// VQ configuration as exported by `compile/zoo.py`.
#[derive(Clone, Debug)]
pub struct VqConfig {
    pub k: usize,
    pub d: usize,
    pub n: usize,
    pub alpha: f64,
    pub bandwidth: f64,
    pub effective_bit: f64,
    /// Residual quantization stages (`vq::StagedCodes`).  Manifests
    /// predating the staged format omit the key, which means exactly one
    /// stage — the legacy single-stream encoding.
    pub stages: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: VqConfig,
    pub networks: Vec<NetworkManifest>,
    pub codebook_file: String,
    pub kde_pool_file: String,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} — run `make artifacts` first"))?;
        let root = json::parse(&text)?;
        let cfg = root.req("config")?;
        let config = VqConfig {
            k: cfg.req_usize("k")?,
            d: cfg.req_usize("d")?,
            n: cfg.req_usize("n")?,
            alpha: cfg.req_f64("alpha")?,
            bandwidth: cfg.req_f64("bandwidth")?,
            effective_bit: cfg.req_f64("effective_bit")?,
            stages: cfg.get("stages").and_then(|v| v.as_usize()).unwrap_or(1),
        };
        let mut networks = Vec::new();
        for nj in root.req_arr("networks")? {
            networks.push(parse_network(nj)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            config,
            networks,
            codebook_file: root.req_str("codebook")?.to_string(),
            kde_pool_file: root.req_str("kde_pool")?.to_string(),
        })
    }

    pub fn network(&self, name: &str) -> anyhow::Result<&NetworkManifest> {
        self.networks
            .iter()
            .find(|n| n.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no network {name:?} in manifest (have: {:?})",
                    self.networks.iter().map(|n| &n.name).collect::<Vec<_>>()
                )
            })
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Default artifacts dir: `$VQ4ALL_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("VQ4ALL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

fn parse_specs(j: &Json) -> anyhow::Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected spec array"))?
        .iter()
        .map(TensorSpec::from_json)
        .collect()
}

fn parse_network(nj: &Json) -> anyhow::Result<NetworkManifest> {
    let mut layers = Vec::new();
    for lj in nj.req_arr("layers")? {
        layers.push(LayerSlice {
            name: lj.req_str("name")?.to_string(),
            kind: lj.req_str("kind")?.to_string(),
            shape: lj
                .req_arr("shape")?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
            offset: lj.req_usize("offset")?,
            groups: lj.req_usize("groups")?,
        });
    }
    let mut executables = BTreeMap::new();
    for (name, ej) in nj
        .req("executables")?
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("executables must be an object"))?
    {
        executables.insert(
            name.clone(),
            ExecSpec {
                hlo: ej.req_str("hlo")?.to_string(),
                inputs: parse_specs(ej.req("inputs")?)?,
                outputs: parse_specs(ej.req("outputs")?)?,
            },
        );
    }
    // Optional per-stage code-stream checksums (same optional-key
    // pattern as `config.stages`: absent means a legacy manifest).
    let code_checksums = match nj.get("code_checksums") {
        None => Vec::new(),
        Some(v) => v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("code_checksums must be an array of hex strings"))?
            .iter()
            .map(|s| {
                let h = s
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("each code checksum must be a hex string"))?;
                u64::from_str_radix(h, 16)
                    .map_err(|e| anyhow::anyhow!("bad code checksum {h:?}: {e}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    let mut data = BTreeMap::new();
    for (tag, f) in nj
        .req("data")?
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("data must be an object"))?
    {
        data.insert(
            tag.clone(),
            f.as_str()
                .ok_or_else(|| anyhow::anyhow!("data file must be a string"))?
                .to_string(),
        );
    }
    Ok(NetworkManifest {
        name: nj.req_str("name")?.to_string(),
        task: nj.req_str("task")?.to_string(),
        arch: nj.req_str("arch")?.to_string(),
        input_shape: nj
            .req_arr("input_shape")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect(),
        batch: nj.req_usize("batch")?,
        eval_batch: nj.req_usize("eval_batch")?,
        calib_size: nj.req_usize("calib_size")?,
        test_size: nj.req_usize("test_size")?,
        s_total: nj.req_usize("s_total")?,
        float_loss: nj.req_f64("float_loss")?,
        float_metric: nj.req_f64("float_metric")?,
        layers,
        code_checksums,
        others: parse_specs(nj.req("others")?)?,
        state_specs: parse_specs(nj.req("state_specs")?)?,
        static_specs: parse_specs(nj.req("static_specs")?)?,
        batch_specs: parse_specs(nj.req("batch_specs")?)?,
        eval_batch_specs: parse_specs(nj.req("eval_batch_specs")?)?,
        executables,
        data,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "config": {"k": 256, "d": 4, "n": 8, "alpha": 0.9999,
                 "bandwidth": 0.01, "lr_ratios": 0.3, "lr_other": 0.001,
                 "samples_per_net": 2560, "effective_bit": 2.0},
      "codebook": "zoo__codebook.vqt",
      "kde_pool": "zoo__kde_pool.vqt",
      "networks": [{
        "name": "tiny", "task": "classify", "arch": "mlp",
        "input_shape": [4, 4, 3], "num_classes": 10,
        "batch": 8, "eval_batch": 16, "calib_size": 64, "test_size": 64,
        "s_total": 100, "float_loss": 0.1, "float_metric": 0.99,
        "pretrain_final_loss": 0.01,
        "layers": [{"name": "fc1.w", "kind": "dense", "shape": [48, 16],
                     "offset": 0, "groups": 100}],
        "excluded_layers": [],
        "others": [{"name": "fc1.b", "shape": [16], "dtype": "f32"}],
        "state_specs": [{"name": "z", "shape": [100, 8], "dtype": "f32"}],
        "static_specs": [{"name": "assign", "shape": [100, 8], "dtype": "i32"}],
        "batch_specs": [{"name": "x", "shape": [8, 4, 4, 3], "dtype": "f32"}],
        "eval_batch_specs": [{"name": "x", "shape": [16, 4, 4, 3], "dtype": "f32"}],
        "executables": {
          "train_step": {"hlo": "tiny__train_step.hlo.txt",
            "inputs": [{"name": "z", "shape": [100, 8], "dtype": "f32"}],
            "outputs": [{"name": "out0", "shape": [100, 8], "dtype": "f32"}]}
        },
        "data": {"calib_x": "tiny__calib_x.vqt"}
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("vq4all_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.k, 256);
        assert_eq!(m.config.d, 4);
        assert_eq!(m.config.stages, 1, "missing stages key means legacy single-stage");
        let net = m.network("tiny").unwrap();
        assert_eq!(net.s_total, 100);
        assert_eq!(net.layers[0].groups, 100);
        let ex = net.exec("train_step").unwrap();
        assert_eq!(ex.inputs[0].shape, vec![100, 8]);
        assert_eq!(ex.inputs[0].dtype, DType::F32);
        assert!(net.exec("nope").is_err());
        assert!(m.network("ghost").is_err());
        assert_eq!(net.data_file("calib_x").unwrap(), "tiny__calib_x.vqt");
    }

    #[test]
    fn stages_key_rides_the_config_block() {
        let dir = std::env::temp_dir().join("vq4all_manifest_staged_test");
        std::fs::create_dir_all(&dir).unwrap();
        let staged = SAMPLE.replace("\"effective_bit\": 2.0", "\"effective_bit\": 2.0, \"stages\": 3");
        std::fs::write(dir.join("manifest.json"), staged).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.stages, 3);
    }

    #[test]
    fn code_checksums_parse_and_verify() {
        use crate::vq::pack::{pack_codes, StagedCodes};

        let staged = StagedCodes::new(vec![
            pack_codes(&[1u32, 2, 3, 0], 3),
            pack_codes(&[0u32, 1, 0, 1], 1),
        ]);
        let sums = staged.checksums();
        let hex = format!(
            "\"code_checksums\": [\"{:x}\", \"{:x}\"], \"excluded_layers\"",
            sums[0], sums[1]
        );
        let stamped = SAMPLE.replace("\"excluded_layers\"", &hex);

        let dir = std::env::temp_dir().join("vq4all_manifest_checksum_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), &stamped).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let net = m.network("tiny").unwrap();
        assert_eq!(net.code_checksums, sums);
        net.verify_code_checksums(&staged).unwrap();

        // A corrupted stream no longer matches, and the error names the
        // network so an operator knows which artifact to rebuild.
        let mut bad = StagedCodes::new(vec![
            pack_codes(&[1u32, 2, 3, 4], 3),
            pack_codes(&[0u32, 1, 0, 1], 1),
        ]);
        let err = net.verify_code_checksums(&bad).unwrap_err().to_string();
        assert!(err.contains("tiny"), "err: {err}");
        assert!(err.contains("integrity"), "err: {err}");
        // Stage-count mismatch is its own loud error.
        bad = StagedCodes::single(pack_codes(&[1u32, 2, 3, 0], 3));
        assert!(net.verify_code_checksums(&bad).is_err());

        // Legacy manifests (no key) verify vacuously; malformed keys do
        // not parse at all.
        let legacy_dir = std::env::temp_dir().join("vq4all_manifest_legacy_test");
        std::fs::create_dir_all(&legacy_dir).unwrap();
        std::fs::write(legacy_dir.join("manifest.json"), SAMPLE).unwrap();
        let legacy = Manifest::load(&legacy_dir).unwrap();
        let lnet = legacy.network("tiny").unwrap();
        assert!(lnet.code_checksums.is_empty());
        lnet.verify_code_checksums(&staged).unwrap();

        let mangled = SAMPLE.replace(
            "\"excluded_layers\"",
            "\"code_checksums\": [\"not-hex\"], \"excluded_layers\"",
        );
        std::fs::write(dir.join("manifest.json"), mangled).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
