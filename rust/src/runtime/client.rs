//! PJRT client wrapper: HLO-text loading, literal marshalling, named
//! executables with signature validation.
//!
//! The interchange rules (DESIGN.md §5, /opt/xla-example/README.md):
//!
//! * artifacts are HLO **text**; `HloModuleProto::from_text_file`
//!   reassigns instruction ids so jax ≥ 0.5 output loads on
//!   xla_extension 0.5.1;
//! * every lowered function returns a **tuple** (python lowers with
//!   `return_tuple=True`), so results are decomposed on the host;
//! * execution is synchronous on the CPU PJRT client.

use std::path::Path;

use crate::tensor::{DType, Storage, Tensor};

use super::artifact::{ExecSpec, TensorSpec};

/// Shared PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact with its manifest signature.
    pub fn load(&self, hlo_path: &Path, spec: &ExecSpec) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {hlo_path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {hlo_path:?}: {e:?}"))?;
        Ok(Executable {
            exe,
            spec: spec.clone(),
            name: hlo_path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled artifact + its signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ExecSpec,
    pub name: String,
}

impl Executable {
    /// Execute with host tensors; validates shapes/dtypes against the
    /// manifest signature and returns the decomposed output tuple.
    pub fn run(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<anyhow::Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (the hot path keeps static inputs
    /// as literals across steps to skip re-encoding).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> anyhow::Result<Vec<Tensor>> {
        if literals.len() != self.spec.inputs.len() {
            anyhow::bail!(
                "{}: got {} inputs, signature has {}",
                self.name,
                literals.len(),
                self.spec.inputs.len()
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(literals)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {}: {e:?}", self.name))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {}: {e:?}", self.name))?;
        if parts.len() != self.spec.outputs.len() {
            anyhow::bail!(
                "{}: got {} outputs, signature has {}",
                self.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| literal_to_tensor(l, s))
            .collect()
    }

    fn check_inputs(&self, inputs: &[Tensor]) -> anyhow::Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            anyhow::bail!(
                "{}: got {} inputs, signature has {} ({:?})",
                self.name,
                inputs.len(),
                self.spec.inputs.len(),
                self.spec.inputs.iter().map(|s| &s.name).collect::<Vec<_>>()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != s.shape {
                anyhow::bail!(
                    "{}: input {:?} shape {:?} != manifest {:?}",
                    self.name,
                    s.name,
                    t.shape,
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                anyhow::bail!(
                    "{}: input {:?} dtype {:?} != manifest {:?}",
                    self.name,
                    s.name,
                    t.dtype(),
                    s.dtype
                );
            }
        }
        Ok(())
    }
}

/// Host tensor -> XLA literal.
pub fn tensor_to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    let (ty, bytes): (xla::ElementType, Vec<u8>) = match &t.data {
        Storage::F32(v) => (
            xla::ElementType::F32,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        Storage::I32(v) => (
            xla::ElementType::S32,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        Storage::U32(v) => (
            xla::ElementType::U32,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        Storage::F64(v) => (
            xla::ElementType::F64,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        Storage::I64(v) => (
            xla::ElementType::S64,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        Storage::U8(v) => (xla::ElementType::U8, v.clone()),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, &bytes)
        .map_err(|e| anyhow::anyhow!("building literal {:?}: {e:?}", t.shape))
}

/// XLA literal -> host tensor, validated against the manifest spec.
pub fn literal_to_tensor(l: &xla::Literal, spec: &TensorSpec) -> anyhow::Result<Tensor> {
    let data = match spec.dtype {
        DType::F32 => Storage::F32(
            l.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("reading f32 output {:?}: {e:?}", spec.name))?,
        ),
        DType::I32 => Storage::I32(
            l.to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("reading i32 output {:?}: {e:?}", spec.name))?,
        ),
        other => anyhow::bail!("unsupported output dtype {other:?}"),
    };
    if data.len() != spec.elems() {
        anyhow::bail!(
            "output {:?}: got {} elements, expected {} {:?}",
            spec.name,
            data.len(),
            spec.elems(),
            spec.shape
        );
    }
    Ok(Tensor {
        shape: spec.shape.clone(),
        data,
    })
}
