//! PJRT runtime: load the AOT artifacts python produced and execute them
//! from the Rust hot path.  Python never runs at request time.
//!
//! * [`artifact`] — `manifest.json` parsing: networks, layer tables,
//!   executable signatures, data files.
//! * [`client`]   — the `xla` crate wrapper: CPU PJRT client, HLO-text
//!   loading (`HloModuleProto::from_text_file` — serialized protos from
//!   jax >= 0.5 are rejected by xla_extension 0.5.1, see DESIGN.md §5),
//!   literal marshalling to/from host [`Tensor`]s, named executables.

pub mod artifact;
pub mod client;

pub use artifact::{ExecSpec, Manifest, NetworkManifest, TensorSpec};
pub use client::{Executable, Runtime};
