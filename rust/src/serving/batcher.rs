//! Dynamic batcher: coalesce queued requests into device-sized batches.
//!
//! Policy: fire when `max_batch` requests are waiting, or when the
//! oldest waiting request has lingered past `max_linger_ns`.  The AOT
//! `infer_hard` artifacts have a *fixed* batch dimension, so short
//! batches are padded (rows repeat) and the padding is dropped on the
//! way out — the padded fraction is tracked as a utilization metric.

use crate::util::threadpool::ThreadPool;
use crate::vq::codebook::Codebook;
use crate::vq::pack::StagedCodes;

use super::engine::router::Request;
use super::engine::stream::{self, DecodeStats};
use super::switchsim::{decode_batch, BatchDecode};

/// Batcher policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_linger_ns: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_linger_ns: 2_000_000, // 2ms
        }
    }
}

/// A formed batch (possibly padded to the artifact's fixed batch size).
#[derive(Clone, Debug)]
pub struct Batch {
    pub net: String,
    pub requests: Vec<Request>,
    /// Row indices padded up to the device batch size.
    pub rows: Vec<usize>,
    pub padded: usize,
}

impl Batch {
    /// Build from drained requests, padding to `device_batch` rows.
    pub fn form(net: &str, requests: Vec<Request>, device_batch: usize) -> Self {
        assert!(!requests.is_empty(), "empty batch");
        assert!(requests.len() <= device_batch, "batch overflow");
        let mut rows: Vec<usize> = requests.iter().map(|r| r.row).collect();
        let padded = device_batch - rows.len();
        for i in 0..padded {
            rows.push(rows[i % requests.len()]); // repeat real rows
        }
        // Padding accounting invariants: the device always sees exactly
        // `device_batch` rows, and every row is either a real request or
        // a counted pad (nothing dropped, nothing double-counted).
        assert_eq!(rows.len(), device_batch, "padding accounting drift");
        assert_eq!(
            padded + requests.len(),
            rows.len(),
            "padding accounting drift"
        );
        Batch {
            net: net.to_string(),
            requests,
            rows,
            padded,
        }
    }

    pub fn utilization(&self) -> f64 {
        self.requests.len() as f64 / self.rows.len() as f64
    }

    /// Decode this batch's weight rows out of a packed assignment stream
    /// through the worker pool — see [`decode_batch`] for the row
    /// addressing and the determinism contract.  This is what gives the
    /// utilization metric something measurable: padded rows are decoded
    /// too (the fixed-batch device cannot skip them), so
    /// `utilization()` is exactly the useful fraction of the decode work.
    pub fn decode_rows(
        &self,
        staged: &StagedCodes,
        cb: &Codebook,
        codes_per_row: usize,
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<BatchDecode> {
        decode_batch(self, staged, cb, codes_per_row, pool)
    }

    /// Streaming twin of [`Batch::decode_rows`]: unpack + decode this
    /// batch's weight rows **directly into `dst`** (the `infer_hard`
    /// input staging buffer, `rows.len() * codes_per_row * cb.d` f32s),
    /// skipping the intermediate weights allocation on the hot path.
    /// Same row addressing and determinism contract — see
    /// [`stream::decode_into`].
    pub fn decode_rows_into(
        &self,
        staged: &StagedCodes,
        cb: &Codebook,
        codes_per_row: usize,
        dst: &mut [f32],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<DecodeStats> {
        stream::decode_into(self, staged, cb, codes_per_row, dst, pool)
    }
}

/// Decide whether a queue should fire now.
pub fn should_fire(cfg: &BatcherConfig, depth: usize, oldest_arrival_ns: u64, now_ns: u64) -> bool {
    depth >= cfg.max_batch
        || (depth > 0 && now_ns.saturating_sub(oldest_arrival_ns) >= cfg.max_linger_ns)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, row: usize, t: u64) -> Request {
        Request {
            id,
            net: "a".into(),
            row,
            arrived_ns: t,
            deadline_ns: 0,
        }
    }

    #[test]
    fn fires_on_size_or_linger() {
        let cfg = BatcherConfig {
            max_batch: 4,
            max_linger_ns: 100,
        };
        assert!(should_fire(&cfg, 4, 0, 0), "full batch fires");
        assert!(!should_fire(&cfg, 2, 1000, 1050), "young partial waits");
        assert!(should_fire(&cfg, 2, 1000, 1101), "lingered partial fires");
        assert!(!should_fire(&cfg, 0, 0, u64::MAX), "empty never fires");
    }

    #[test]
    fn padding_repeats_real_rows() {
        let b = Batch::form("a", vec![req(0, 7, 0), req(1, 9, 0)], 5);
        assert_eq!(b.rows, vec![7, 9, 7, 9, 7]);
        assert_eq!(b.padded, 3);
        assert!((b.utilization() - 0.4).abs() < 1e-9);
    }

    /// The `device_batch == requests.len()` zero-padding edge: no pad
    /// rows are appended and the row list is exactly the request rows.
    #[test]
    fn full_batch_no_padding() {
        let b = Batch::form("a", (0..4).map(|i| req(i, 10 + i as usize, 0)).collect(), 4);
        assert_eq!(b.padded, 0);
        assert_eq!(b.utilization(), 1.0);
        assert_eq!(b.rows, vec![10, 11, 12, 13], "rows are the request rows, unpadded");
        assert_eq!(b.rows.len(), b.requests.len());
    }

    #[test]
    fn decode_rows_delegates_to_batched_decode() {
        use crate::vq::pack::pack_codes;

        let cb = Codebook::new(2, 2, vec![0., 0., 1., 1.]);
        // 3 device rows of 2 codes each.
        let packed = StagedCodes::single(pack_codes(&[0u32, 1, 1, 1, 0, 0], 1));
        let b = Batch::form("a", vec![req(0, 1, 0)], 3); // rows [1, 1, 1]
        let r = b.decode_rows(&packed, &cb, 2, None).unwrap();
        assert_eq!(r.weights, vec![1., 1., 1., 1.].repeat(3));
        assert!((r.utilization - b.utilization()).abs() < 1e-12);
    }

    #[test]
    fn decode_rows_into_streams_the_same_bits() {
        use crate::vq::pack::pack_codes;

        let cb = Codebook::new(2, 2, vec![0., 0., 1., 1.]);
        // 3 rows of 2 codes, single-stage staged stream.
        let packed = StagedCodes::single(pack_codes(&[0u32, 1, 1, 1, 0, 0], 1));
        let b = Batch::form("a", vec![req(0, 1, 0), req(1, 2, 0)], 3);
        let alloc = b.decode_rows(&packed, &cb, 2, None).unwrap();
        let mut dst = vec![0.0f32; b.rows.len() * 2 * cb.d];
        let s = b.decode_rows_into(&packed, &cb, 2, &mut dst, None).unwrap();
        assert_eq!(dst, alloc.weights);
        assert_eq!(s.codes_unpacked, alloc.codes_unpacked);
        assert_eq!(s.packed_bytes_read, alloc.packed_bytes_read);
        assert!((s.utilization - alloc.utilization).abs() < 1e-12);
        // Wrong-size destination is an error, not UB.
        let mut short = vec![0.0f32; 5];
        assert!(b.decode_rows_into(&packed, &cb, 2, &mut short, None).is_err());
    }

    #[test]
    #[should_panic(expected = "batch overflow")]
    fn overflow_checked() {
        Batch::form("a", (0..5).map(|i| req(i, 0, 0)).collect(), 4);
    }
}
