//! Decode cache: an LRU over decoded f32 row-blocks keyed on
//! `(net, row window)` with byte-budget eviction and hit/miss/evict
//! accounting — the cache-aware half of the decode plane.  VQ serving
//! lives or dies on codebook-access locality (VQ-LLM, arXiv:2503.02236);
//! hot rows of a hosted network's packed stream are decoded once and
//! then served as straight memcpys.
//!
//! **Coherence invariant:** entries are only ever inserted from the
//! output of the streaming decode kernel and lookups return them
//! unmodified, so a cache-served row is bit-identical to a fresh
//! `decode_batch` of the same window — property-tested across evictions
//! and widths 1..=32 in `rust/tests/prop_substrate.rs`.  The key is
//! stage-agnostic by construction: a window identifies a code range of
//! the net's *staged* stream, and the cached block is the fully
//! stage-summed decode ([`Codebook::decode_staged_packed_into`]'s
//! output), so residual stages add zero keys and zero coherence cases —
//! the same property test runs at stage counts 1..=3.
//!
//! [`Codebook::decode_staged_packed_into`]: crate::vq::codebook::Codebook::decode_staged_packed_into

use std::collections::BTreeMap;

/// Cache key: one decoded row window — codes `[start, end)` of a hosted
/// network's staged assignment stream (the same range addresses every
/// residual stage).  The network is identified by its
/// shard-local numeric id (assigned at hosting time, see
/// `Shard::net_id`), keeping the key `Copy` so the hot lookup path does
/// no allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct RowWindow {
    /// Shard-local hosted-net id.
    pub net: u32,
    pub start: usize,
    pub end: usize,
}

/// Hit/miss/evict accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// Fold another shard's counters in (engine-level aggregation).
    pub fn merge(&mut self, other: &CacheStats) {
        self.lookups += other.lookups;
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
    }
}

struct Entry {
    data: Vec<f32>,
    stamp: u64,
}

/// LRU decode cache with a byte budget (`budget_bytes == 0` disables
/// caching entirely: every lookup misses and inserts are dropped).
pub struct DecodeCache {
    budget_bytes: usize,
    bytes: usize,
    map: BTreeMap<RowWindow, Entry>,
    /// Recency index: stamp -> key.  Stamps are unique (monotone clock),
    /// so the smallest stamp is always the least-recently-used entry.
    lru: BTreeMap<u64, RowWindow>,
    clock: u64,
    pub stats: CacheStats,
}

impl DecodeCache {
    pub fn new(budget_bytes: usize) -> Self {
        DecodeCache {
            budget_bytes,
            bytes: 0,
            map: BTreeMap::new(),
            lru: BTreeMap::new(),
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.budget_bytes > 0
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Resident f32 payload bytes.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Drop every entry (counters survive — they are cumulative).
    pub fn clear(&mut self) {
        self.map.clear();
        self.lru.clear();
        self.bytes = 0;
    }

    /// Non-mutating membership probe: is a block for this window
    /// resident right now?  No stats, no recency refresh — the observer
    /// the admission property tests use to prove a shed request's row
    /// was never decoded (on an eviction-free budget, every decoded
    /// window is resident).
    pub fn contains(&self, key: &RowWindow) -> bool {
        self.map.contains_key(key)
    }

    /// Look up a window.  A hit refreshes recency and returns the block.
    /// One tree descent on the hot path: the entry is fetched mutably
    /// once and its recency stamp rewritten in place (the old
    /// double-lookup re-descended the map after updating the LRU index).
    pub fn get(&mut self, key: &RowWindow) -> Option<&[f32]> {
        self.stats.lookups += 1;
        match self.map.get_mut(key) {
            None => {
                self.stats.misses += 1;
                None
            }
            Some(e) => {
                self.stats.hits += 1;
                self.lru.remove(&e.stamp);
                self.clock += 1;
                e.stamp = self.clock;
                self.lru.insert(self.clock, *key);
                Some(&e.data)
            }
        }
    }

    /// Insert (or refresh) a decoded block, evicting least-recently-used
    /// entries until the byte budget holds.  Blocks larger than the whole
    /// budget are not cached (they would evict everything for one row).
    pub fn insert(&mut self, key: RowWindow, data: &[f32]) {
        let bytes = data.len() * std::mem::size_of::<f32>();
        if !self.enabled() || bytes > self.budget_bytes {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            self.lru.remove(&old.stamp);
            self.bytes -= old.data.len() * std::mem::size_of::<f32>();
        }
        while self.bytes + bytes > self.budget_bytes {
            let (&victim_stamp, _) = self
                .lru
                .iter()
                .next()
                .expect("over budget with no resident entries");
            let victim = self.lru.remove(&victim_stamp).unwrap();
            let e = self.map.remove(&victim).unwrap();
            self.bytes -= e.data.len() * std::mem::size_of::<f32>();
            self.stats.evictions += 1;
        }
        self.clock += 1;
        self.lru.insert(self.clock, key);
        self.map.insert(
            key,
            Entry {
                data: data.to_vec(),
                stamp: self.clock,
            },
        );
        self.bytes += bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(net: u32, row: usize) -> RowWindow {
        RowWindow {
            net,
            start: row * 4,
            end: (row + 1) * 4,
        }
    }

    #[test]
    fn hit_returns_exact_block_and_counts() {
        let mut c = DecodeCache::new(1024);
        assert!(c.get(&key(0, 0)).is_none());
        c.insert(key(0, 0), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.get(&key(0, 0)).unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(c.get(&key(1, 0)).is_none(), "keys are per-net");
        assert!(c.contains(&key(0, 0)));
        assert!(!c.contains(&key(1, 0)));
        assert_eq!(c.stats.lookups, 3, "contains() is not a lookup");
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 2);
        assert!((c.stats.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.bytes(), 16);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn evicts_least_recently_used_under_byte_budget() {
        // Budget fits exactly two 4-f32 blocks (32 bytes).
        let mut c = DecodeCache::new(32);
        c.insert(key(0, 0), &[0.0; 4]);
        c.insert(key(0, 1), &[1.0; 4]);
        assert_eq!(c.len(), 2);
        // Touch row 0 so row 1 becomes the LRU victim.
        assert!(c.get(&key(0, 0)).is_some());
        c.insert(key(0, 2), &[2.0; 4]);
        assert_eq!(c.stats.evictions, 1);
        assert!(c.get(&key(0, 1)).is_none(), "LRU entry evicted");
        assert!(c.get(&key(0, 0)).is_some(), "recently-used entry kept");
        assert!(c.get(&key(0, 2)).is_some());
        assert!(c.bytes() <= 32, "budget respected: {} bytes", c.bytes());
    }

    #[test]
    fn oversized_blocks_and_disabled_cache_are_no_ops() {
        let mut c = DecodeCache::new(8);
        c.insert(key(0, 0), &[0.0; 4]); // 16 bytes > 8 budget
        assert!(c.is_empty());
        let mut off = DecodeCache::new(0);
        off.insert(key(0, 0), &[0.0]);
        assert!(off.get(&key(0, 0)).is_none());
        assert!(!off.enabled());
        assert_eq!(off.stats.misses, 1);
    }

    #[test]
    fn reinsert_replaces_without_leaking_bytes() {
        let mut c = DecodeCache::new(64);
        c.insert(key(0, 0), &[0.0; 4]);
        c.insert(key(0, 0), &[9.0; 4]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 16);
        assert_eq!(c.get(&key(0, 0)).unwrap(), &[9.0; 4]);
    }

    #[test]
    fn clear_drops_entries_but_keeps_counters() {
        let mut c = DecodeCache::new(64);
        c.insert(key(0, 0), &[0.0; 4]);
        assert!(c.get(&key(0, 0)).is_some());
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.stats.hits, 1, "cumulative counters survive clear");
        assert!(c.get(&key(0, 0)).is_none());
    }
}
