//! `serving::engine` — the sharded, cache-aware decode plane.
//!
//! The paper's deployment argument (§3.2) is that a ROM-resident
//! universal codebook makes hosting *many* networks on one platform
//! cheap; what remains expensive at serving time is repeatedly unpacking
//! and decoding assignment streams.  This subsystem attacks that cost on
//! three axes:
//!
//! * **Sharded dispatch plane** ([`Engine`]) — `EngineConfig::shards`
//!   worker shards, each owning a disjoint subset of the hosted networks
//!   with its own router queue set ([`shard`]).  Shards share no mutable
//!   state, so the engine fans them across `util::threadpool` under the
//!   established deterministic-chunking contract: per-shard results and
//!   cache state are bit-identical at every thread count, and every
//!   accepted request is dispatched exactly once (property-tested in
//!   `rust/tests/prop_substrate.rs`).
//! * **Decode cache** ([`cache`]) — an LRU keyed on `(net, row window)`
//!   holding decoded f32 row-blocks, with byte-budget eviction and
//!   hit/miss/evict accounting.  Cache-served rows are bit-identical to
//!   a fresh `decode_batch` (the coherence invariant, property-tested).
//! * **Streaming decode** ([`stream`]) — [`stream::decode_into`] /
//!   `Batch::decode_rows_into` unpack + decode straight into a
//!   caller-provided `infer_hard` staging buffer through the fused
//!   [`crate::vq::Codebook::decode_packed_into`] kernel, eliminating the
//!   intermediate weights allocation on the hot path.
//!
//! `serving::server` (virtual clock) and `serving::tcp` (wall clock)
//! attach an [`Engine`] as their decode plane; `benches/hotpath.rs`
//! tracks cold-vs-warm-cache and 1-vs-N-shard engine rows in
//! `BENCH_hotpath.json`, gated by `scripts/verify.sh`.

pub mod cache;
pub mod shard;
pub mod stream;

pub use cache::{CacheStats, DecodeCache, RowWindow};
pub use shard::{HostedNet, RowServe, Shard, ShardStats};
pub use stream::{decode_into, decode_rows_into, DecodeStats};

use std::collections::BTreeMap;

use crate::serving::batcher::BatcherConfig;
use crate::util::threadpool::{SyncPtr, ThreadPool};

/// Engine-level configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Worker shards (clamped to the hosted-network count).
    pub shards: usize,
    /// Per-shard decode-cache byte budget (0 disables the cache).
    pub cache_bytes: usize,
    /// Batching policy every shard applies to its queues.
    pub batcher: BatcherConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            shards: 1,
            cache_bytes: 1 << 20, // 1 MiB per shard
            batcher: BatcherConfig::default(),
        }
    }
}

/// Aggregate serving counters across shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineTotals {
    pub served: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub rows_decoded: u64,
    pub rows_from_cache: u64,
}

/// The sharded, cache-aware decode plane.
pub struct Engine {
    pub cfg: EngineConfig,
    shards: Vec<Shard>,
    /// net -> shard index (deterministic round-robin placement).
    placement: BTreeMap<String, usize>,
    /// Virtual time (ns) — advanced by [`Engine::tick`], mirrored into
    /// every shard dispatch.
    pub now_ns: u64,
    accepted: u64,
}

impl Engine {
    /// Build the plane: networks are assigned to shards round-robin in
    /// the given order, so placement depends only on the input order —
    /// never on thread scheduling.
    pub fn new(cfg: EngineConfig, nets: Vec<HostedNet>) -> anyhow::Result<Self> {
        anyhow::ensure!(cfg.shards >= 1, "engine needs at least one shard");
        anyhow::ensure!(cfg.batcher.max_batch >= 1, "engine batcher needs max_batch >= 1");
        anyhow::ensure!(!nets.is_empty(), "engine hosts no networks");
        let nshards = cfg.shards.min(nets.len());
        let mut buckets: Vec<Vec<HostedNet>> = (0..nshards).map(|_| Vec::new()).collect();
        let mut placement = BTreeMap::new();
        for (i, n) in nets.into_iter().enumerate() {
            let s = i % nshards;
            anyhow::ensure!(
                placement.insert(n.name.clone(), s).is_none(),
                "duplicate hosted network {:?}",
                n.name
            );
            buckets[s].push(n);
        }
        let shards = buckets
            .into_iter()
            .enumerate()
            .map(|(id, ns)| Shard::new(id, ns, cfg.cache_bytes))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Engine {
            cfg,
            shards,
            placement,
            now_ns: 0,
            accepted: 0,
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    pub fn hosts(&self, net: &str) -> bool {
        self.placement.contains_key(net)
    }

    /// The hosted network's descriptor (None if unknown).
    pub fn hosted(&self, net: &str) -> Option<&HostedNet> {
        self.placement.get(net).and_then(|&s| self.shards[s].net(net))
    }

    /// Decoded f32s per row of `net`.
    pub fn row_stride(&self, net: &str) -> anyhow::Result<usize> {
        self.hosted(net)
            .map(|n| n.row_stride())
            .ok_or_else(|| anyhow::anyhow!("engine: unknown network {net:?}"))
    }

    /// Advance virtual time.
    pub fn tick(&mut self, ns: u64) {
        self.now_ns += ns;
    }

    /// Enqueue a request on the owning shard at the current virtual
    /// time; returns its shard-local id.  Out-of-range rows are rejected
    /// here (before they can reach a decode), so `accepted` counts only
    /// requests the plane is obligated to serve.
    pub fn submit(&mut self, net: &str, row: usize) -> anyhow::Result<u64> {
        let &s = self
            .placement
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("engine: unknown network {net:?}"))?;
        let shard = &mut self.shards[s];
        let stream_rows = shard.net(net).expect("placement without hosted net").stream_rows();
        anyhow::ensure!(
            row < stream_rows,
            "engine: row {row} out of range for {net:?} ({stream_rows} stream rows)"
        );
        let id = shard.router.submit(net, row, self.now_ns)?;
        self.accepted += 1;
        Ok(id)
    }

    pub fn total_pending(&self) -> usize {
        self.shards.iter().map(|s| s.router.total_pending()).sum()
    }

    /// One dispatch round: every shard fires at most one batch.  With a
    /// multi-thread pool and more than one shard, shards run
    /// concurrently (they share no state) with serial in-shard decode;
    /// otherwise shards run in order and the pool (if any) parallelizes
    /// the in-shard row decode instead.  Either way each shard's
    /// behavior depends only on its own queues and the virtual clock, so
    /// outputs, stats, and cache state are bit-identical.
    pub fn dispatch_round(&mut self, pool: Option<&ThreadPool>) -> anyhow::Result<usize> {
        let now = self.now_ns;
        let cfg = self.cfg.batcher;
        match pool {
            Some(tp) if tp.threads() > 1 && self.shards.len() > 1 => {
                let n = self.shards.len();
                let mut results: Vec<anyhow::Result<usize>> = (0..n).map(|_| Ok(0)).collect();
                let shards_ptr = SyncPtr::new(&mut self.shards);
                let res_ptr = SyncPtr::new(&mut results);
                tp.parallel_for(n, 1, |start, end| {
                    for s in start..end {
                        // SAFETY: each chunk owns disjoint shard + result
                        // slots.
                        let shard = unsafe { &mut shards_ptr.slice(s, 1)[0] };
                        let out = unsafe { &mut res_ptr.slice(s, 1)[0] };
                        *out = shard.dispatch_one(&cfg, now, None);
                    }
                })
                .expect("engine shard worker panicked");
                let mut total = 0;
                for r in results {
                    total += r?;
                }
                Ok(total)
            }
            _ => {
                let mut total = 0;
                for shard in &mut self.shards {
                    total += shard.dispatch_one(&cfg, now, pool)?;
                }
                Ok(total)
            }
        }
    }

    /// Dispatch until every queue is empty, force-firing partial batches
    /// by advancing the virtual clock past the linger deadline (mirrors
    /// `server::drain_all`).
    pub fn drain(&mut self, pool: Option<&ThreadPool>) -> anyhow::Result<u64> {
        let mut total = 0u64;
        loop {
            let before = self.total_pending();
            if before == 0 {
                break;
            }
            self.tick(self.cfg.batcher.max_linger_ns + 1);
            let served = self.dispatch_round(pool)?;
            total += served as u64;
            if served == 0 && self.total_pending() == before {
                anyhow::bail!("engine wedged with {before} pending requests");
            }
        }
        Ok(total)
    }

    /// Conservation counters `(accepted, dispatched)` — equal once the
    /// plane is drained.
    pub fn counters(&self) -> (u64, u64) {
        (
            self.accepted,
            self.shards.iter().map(|s| s.stats.served).sum(),
        )
    }

    /// Aggregate decode-cache counters across shards.
    pub fn cache_stats(&self) -> CacheStats {
        let mut out = CacheStats::default();
        for s in &self.shards {
            out.merge(&s.cache.stats);
        }
        out
    }

    /// Aggregate serving counters across shards.
    pub fn totals(&self) -> EngineTotals {
        let mut t = EngineTotals::default();
        for s in &self.shards {
            t.served += s.stats.served;
            t.batches += s.stats.batches;
            t.padded_rows += s.stats.padded_rows;
            t.rows_decoded += s.stats.rows_decoded;
            t.rows_from_cache += s.stats.rows_from_cache;
        }
        t
    }

    /// Drop every shard's cache entries (cumulative counters survive) —
    /// the bench's cold-cache reset.
    pub fn clear_caches(&mut self) {
        for s in &mut self.shards {
            s.cache.clear();
        }
    }

    /// The raw decode-plane API: stream `rows` of `net` through the
    /// owning shard's cache into `dst` (`dst.len() == rows.len() *
    /// row_stride`).  Batch-serving callers use [`Engine::stream_batch`].
    pub fn decode_rows_into(
        &mut self,
        net: &str,
        rows: &[usize],
        dst: &mut [f32],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<RowServe> {
        let &s = self
            .placement
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("engine: unknown network {net:?}"))?;
        self.shards[s].decode_rows_into(net, rows, dst, pool)
    }

    /// Stream a dispatched batch's weight rows through the owning
    /// shard's cache into its staging buffer, mapping caller rows onto
    /// the packed stream cyclically — the one call `serving::server` and
    /// `serving::tcp` make per batch.  `Ok(None)` when `net` is not
    /// hosted on this plane.
    pub fn stream_batch(
        &mut self,
        net: &str,
        rows: &[usize],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<Option<RowServe>> {
        let Some(&s) = self.placement.get(net) else {
            return Ok(None);
        };
        self.shards[s].stream_batch(net, rows, pool).map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::vq::pack::pack_codes;
    use crate::vq::Codebook;
    use std::sync::Arc;

    fn hosted(name: &str, rows: usize, cpr: usize, cb: &Arc<Codebook>, rng: &mut Rng) -> HostedNet {
        let codes: Vec<u32> = (0..rows * cpr).map(|_| rng.below(cb.k) as u32).collect();
        HostedNet {
            name: name.into(),
            packed: pack_codes(&codes, cb.index_bits()),
            codebook: cb.clone(),
            codes_per_row: cpr,
            device_batch: 4,
        }
    }

    fn test_cb(rng: &mut Rng) -> Arc<Codebook> {
        let mut words = vec![0.0f32; 8 * 2];
        rng.fill_normal(&mut words);
        Arc::new(Codebook::new(8, 2, words))
    }

    fn cfg(shards: usize, cache_bytes: usize) -> EngineConfig {
        EngineConfig {
            shards,
            cache_bytes,
            batcher: BatcherConfig {
                max_batch: 4,
                max_linger_ns: 100,
            },
        }
    }

    #[test]
    fn placement_is_round_robin_and_disjoint() {
        let mut rng = Rng::new(1);
        let cb = test_cb(&mut rng);
        let nets: Vec<HostedNet> = (0..5)
            .map(|i| hosted(&format!("n{i}"), 6, 3, &cb, &mut rng))
            .collect();
        let e = Engine::new(cfg(2, 0), nets).unwrap();
        assert_eq!(e.shard_count(), 2);
        // Round-robin: n0,n2,n4 -> shard 0; n1,n3 -> shard 1.
        for (name, want) in [("n0", 0), ("n1", 1), ("n2", 0), ("n3", 1), ("n4", 0)] {
            assert!(e.hosts(name));
            assert!(e.shards()[want].hosts(name), "{name} not on shard {want}");
        }
        assert!(!e.hosts("ghost"));
        assert!(e.hosted("n3").is_some());
        // More shards than nets clamps.
        let mut rng = Rng::new(2);
        let cb = test_cb(&mut rng);
        let one = vec![hosted("solo", 4, 2, &cb, &mut rng)];
        assert_eq!(Engine::new(cfg(8, 0), one).unwrap().shard_count(), 1);
    }

    #[test]
    fn submit_validates_net_and_row() {
        let mut rng = Rng::new(3);
        let cb = test_cb(&mut rng);
        let mut e = Engine::new(cfg(1, 0), vec![hosted("a", 6, 3, &cb, &mut rng)]).unwrap();
        assert!(e.submit("ghost", 0).is_err());
        assert!(e.submit("a", 6).is_err(), "stream holds rows 0..6");
        e.submit("a", 5).unwrap();
        let (acc, disp) = e.counters();
        assert_eq!((acc, disp), (1, 0), "rejected submits are not accepted");
    }

    #[test]
    fn drain_serves_everything_exactly_once_across_shards() {
        let mut rng = Rng::new(4);
        let cb = test_cb(&mut rng);
        let nets: Vec<HostedNet> = (0..3)
            .map(|i| hosted(&format!("n{i}"), 8, 2, &cb, &mut rng))
            .collect();
        let mut e = Engine::new(cfg(3, 4096), nets).unwrap();
        let mut per_net = [0u64; 3];
        for i in 0..37 {
            let n = i % 3;
            e.submit(&format!("n{n}"), i % 8).unwrap();
            per_net[n] += 1;
        }
        let served = e.drain(None).unwrap();
        assert_eq!(served, 37);
        let (acc, disp) = e.counters();
        assert_eq!(acc, 37);
        assert_eq!(disp, 37);
        assert_eq!(e.total_pending(), 0);
        for (i, &want) in per_net.iter().enumerate() {
            let name = format!("n{i}");
            let got: u64 = e
                .shards()
                .iter()
                .map(|s| s.stats.served_by_net.get(&name).copied().unwrap_or(0))
                .sum();
            assert_eq!(got, want, "{name} served count");
        }
        let t = e.totals();
        assert_eq!(t.served, 37);
        assert_eq!(t.rows_decoded + t.rows_from_cache, t.served + t.padded_rows);
        assert!(t.rows_from_cache > 0, "repeat rows should hit the cache");
    }

    #[test]
    fn decode_plane_matches_fresh_decode_and_counts_hits() {
        let mut rng = Rng::new(5);
        let cb = test_cb(&mut rng);
        let net = hosted("a", 6, 4, &cb, &mut rng);
        let packed = net.packed.clone();
        let mut e = Engine::new(cfg(1, 1 << 16), vec![net]).unwrap();
        let stride = e.row_stride("a").unwrap();
        let rows = [3usize, 1, 3];
        let mut dst = vec![0.0f32; rows.len() * stride];
        let first = e.decode_rows_into("a", &rows, &mut dst, None).unwrap();
        assert_eq!(first, RowServe { hits: 0, misses: 3 });
        // Second pass over the same rows is all cache hits…
        let mut dst2 = vec![0.0f32; rows.len() * stride];
        let second = e.decode_rows_into("a", &rows, &mut dst2, None).unwrap();
        assert_eq!(second, RowServe { hits: 3, misses: 0 });
        // …and bit-identical to the fresh decode.
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dst), bits(&dst2));
        for (i, &row) in rows.iter().enumerate() {
            let mut fresh = vec![0.0f32; stride];
            cb.decode_packed_into(&packed, row * 4, (row + 1) * 4, &mut fresh);
            assert_eq!(bits(&dst2[i * stride..(i + 1) * stride]), bits(&fresh));
        }
        let cs = e.cache_stats();
        assert_eq!(cs.lookups, 6);
        assert_eq!(cs.hits, 3);
        assert_eq!(cs.misses, 3);
        assert!((cs.hit_rate() - 0.5).abs() < 1e-12);
        e.clear_caches();
        let third = e.decode_rows_into("a", &rows, &mut dst2, None).unwrap();
        assert_eq!(third.misses, 3, "cleared cache decodes fresh");
    }

    #[test]
    fn stream_batch_maps_rows_cyclically_and_skips_unhosted_nets() {
        let mut rng = Rng::new(7);
        let cb = test_cb(&mut rng);
        let net = hosted("a", 4, 3, &cb, &mut rng); // 4 stream rows
        let mut e = Engine::new(cfg(1, 1 << 16), vec![net]).unwrap();
        // Caller rows beyond the stream wrap cyclically: 5 % 4 == 1, so
        // both positions decode window 1 (both miss — inserts happen
        // after the batch's lookups).
        let rs = e.stream_batch("a", &[5, 1], None).unwrap().unwrap();
        assert_eq!(rs, RowServe { hits: 0, misses: 2 });
        let rs2 = e.stream_batch("a", &[5], None).unwrap().unwrap();
        assert_eq!(rs2, RowServe { hits: 1, misses: 0 }, "wrapped row hits the cached window");
        assert!(e.stream_batch("ghost", &[0], None).unwrap().is_none());
    }

    #[test]
    fn rejects_bad_configs() {
        let mut rng = Rng::new(6);
        let cb = test_cb(&mut rng);
        assert!(Engine::new(cfg(0, 0), vec![hosted("a", 4, 2, &cb, &mut rng)]).is_err());
        assert!(Engine::new(cfg(1, 0), vec![]).is_err());
        let dup = vec![hosted("a", 4, 2, &cb, &mut rng), hosted("a", 4, 2, &cb, &mut rng)];
        assert!(Engine::new(cfg(2, 0), dup).is_err());
        let mut zero_batch = cfg(1, 0);
        zero_batch.batcher.max_batch = 0;
        assert!(Engine::new(zero_batch, vec![hosted("a", 4, 2, &cb, &mut rng)]).is_err());
        // Packed codes that cannot address the codebook are rejected at
        // hosting time, not mid-serve.
        let cb3 = Arc::new(Codebook::new(3, 1, vec![0.0, 1.0, 2.0]));
        let bad = HostedNet {
            name: "bad".into(),
            packed: pack_codes(&[0u32, 1, 2, 3], 2), // code 3 >= k = 3
            codebook: cb3,
            codes_per_row: 2,
            device_batch: 1,
        };
        assert!(Engine::new(cfg(1, 0), vec![bad]).is_err());
    }
}
