//! `serving::engine` — the sharded, cache-aware decode plane.
//!
//! The paper's deployment argument (§3.2) is that a ROM-resident
//! universal codebook makes hosting *many* networks on one platform
//! cheap; what remains expensive at serving time is repeatedly unpacking
//! and decoding assignment streams.  This subsystem attacks that cost on
//! three axes:
//!
//! * **Sharded routing/dispatch plane** ([`Engine`]) —
//!   `EngineConfig::shards` worker shards, each owning a disjoint subset
//!   of the hosted networks with its own [`router`] queue set
//!   ([`shard`]); the **only** `Router` construction sites in the crate.
//!   Shards share no mutable state, so the engine fans them across
//!   `util::threadpool` under the established deterministic-chunking
//!   contract: per-shard results and cache state are bit-identical at
//!   every thread count, and every accepted request is dispatched
//!   exactly once (property-tested in `rust/tests/prop_substrate.rs`).
//! * **Admission control** — a per-shard queue-depth budget
//!   ([`EngineConfig::max_queue_depth`]): over-budget submissions
//!   resolve to the typed [`Admission::Rejected`] (shed — never
//!   enqueued, never decoded) on [`Engine::try_submit`], while
//!   wall-clock callers probe [`Engine::would_admit`] and defer with
//!   backpressure instead.  Conservation
//!   (`accepted == dispatched + shed`, per net via [`NetLedger`]) and
//!   serial-vs-pooled shed-decision identity are property-tested.
//! * **Decode cache** ([`cache`]) — an LRU keyed on `(net, row window)`
//!   holding decoded f32 row-blocks, with byte-budget eviction and
//!   hit/miss/evict accounting.  Cache-served rows are bit-identical to
//!   a fresh `decode_batch` (the coherence invariant, property-tested).
//! * **Streaming decode** ([`stream`]) — [`stream::decode_into`] /
//!   `Batch::decode_rows_into` unpack + decode straight into a
//!   caller-provided `infer_hard` staging buffer through the fused
//!   staged kernel
//!   ([`crate::vq::Codebook::decode_staged_packed_into`]: one gather
//!   per residual stage, stage 0 writes and later stages accumulate),
//!   eliminating the intermediate weights allocation on the hot path.
//!   Hosted nets carry [`crate::vq::StagedCodes`]; `stages == 1` is the
//!   legacy single-stream format and decodes identically.
//! * **Observability** ([`crate::serving::obs`]) — each shard carries a
//!   [`crate::serving::obs::ShardObs`] slice (request-lifecycle stage
//!   histograms on the engine clock, per-net counters, a flight
//!   recorder of shed/deferral/eviction/error events), merged by
//!   [`Engine::metrics_snapshot`] into one [`MetricsSnapshot`] whose
//!   totals reconcile exactly with the conservation counters; the TCP
//!   `/metrics` and `/trace` verbs expose it.
//!
//! `serving::server` (virtual clock, [`Engine::tick`]) and
//! `serving::tcp` (wall clock, [`Engine::set_now`]) are thin front-ends
//! over this plane: admission → shard queue → fire-selection
//! ([`Engine::next_batch`]) → cached/streamed decode
//! ([`Engine::stream_batch`]) → `infer_hard` is one shared code path.
//! `benches/hotpath.rs` tracks the cold-vs-warm-cache, 1-vs-N-shard, and
//! bounded-vs-unbounded-admission engine rows in `BENCH_hotpath.json`,
//! gated by `scripts/verify.sh`.

pub mod cache;
pub mod router;
pub mod shard;
pub mod stream;

pub use cache::{CacheStats, DecodeCache, RowWindow};
pub use router::{Request, Router};
pub use shard::{HostedNet, NetLedger, RowServe, Shard, ShardStats};
pub use stream::{decode_into, decode_rows_into, row_window_bytes, DecodeStats};

use std::collections::BTreeMap;

use crate::serving::batcher::{Batch, BatcherConfig};
use crate::serving::faults::FaultPlan;
use crate::serving::obs::{Event, EventKind, MetricsSnapshot, ObsConfig};
use crate::util::threadpool::{SyncPtr, ThreadPool};

/// Engine-level configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Worker shards (clamped to the hosted-network count).
    pub shards: usize,
    /// Per-shard decode-cache byte budget (0 disables the cache).
    pub cache_bytes: usize,
    /// Per-shard admission budget: a shard whose queued backlog is at
    /// this depth sheds further submissions with a typed
    /// [`Admission::Rejected`] (0 = unbounded, the default).
    pub max_queue_depth: usize,
    /// Batching policy every shard applies to its queues.
    pub batcher: BatcherConfig,
    /// Observability plane knobs ([`crate::serving::obs`]): histogram /
    /// flight-recorder instrumentation, on by default; the
    /// `obs_overhead` bench row gates its cost on the `stream_batch`
    /// path.
    pub obs: ObsConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            shards: 1,
            cache_bytes: 1 << 20, // 1 MiB per shard
            max_queue_depth: 0,
            batcher: BatcherConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

/// Typed admission outcome of [`Engine::try_submit`]: the deterministic
/// shed decision the virtual-clock front-end surfaces to its callers.
/// (The wall-clock TCP front-end avoids shedding by probing
/// [`Engine::would_admit`] and deferring — backpressure — instead.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Enqueued on the owning shard under this shard-local request id.
    Accepted { id: u64 },
    /// Shed: the owning shard's backlog was at the
    /// [`EngineConfig::max_queue_depth`] budget.  The request was never
    /// enqueued, so it can never reach a batch, a decode, or
    /// `infer_hard` — not even as a padded row.
    Rejected {
        /// The shard that refused the request.
        shard: usize,
        /// Its queue depth at the moment of refusal.
        depth: usize,
    },
}

/// Aggregate serving counters across shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineTotals {
    /// Validated submissions offered to the plane (served + shed + queued).
    pub accepted: u64,
    pub served: u64,
    /// Submissions rejected at admission.
    pub shed: u64,
    /// Requests whose deadline lapsed before their batch fired (shed at
    /// fire time, before decode).
    pub expired: u64,
    /// Requests failed with a structured error by a shard or net
    /// quarantine.
    pub failed: u64,
    /// Front-end backpressure events (see [`Engine::note_deferral`]).
    pub deferred: u64,
    /// Deepest backlog any single shard ever held.
    pub peak_depth: usize,
    pub batches: u64,
    pub padded_rows: u64,
    pub rows_decoded: u64,
    pub rows_from_cache: u64,
}

/// The sharded, cache-aware decode plane.
pub struct Engine {
    pub cfg: EngineConfig,
    shards: Vec<Shard>,
    /// net -> shard index (deterministic round-robin placement).
    placement: BTreeMap<String, usize>,
    /// Virtual time (ns) — advanced by [`Engine::tick`] (virtual-clock
    /// front-ends) or [`Engine::set_now`] (wall-clock front-ends),
    /// mirrored into every shard dispatch.
    pub now_ns: u64,
    /// Round-robin start shard for [`Engine::next_batch`] scans, so a
    /// hot shard cannot starve the others on the front-end fire path.
    fire_cursor: usize,
}

impl Engine {
    /// Build the plane: networks are assigned to shards round-robin in
    /// the given order, so placement depends only on the input order —
    /// never on thread scheduling.
    pub fn new(cfg: EngineConfig, nets: Vec<HostedNet>) -> anyhow::Result<Self> {
        anyhow::ensure!(cfg.shards >= 1, "engine needs at least one shard");
        anyhow::ensure!(cfg.batcher.max_batch >= 1, "engine batcher needs max_batch >= 1");
        anyhow::ensure!(!nets.is_empty(), "engine hosts no networks");
        let nshards = cfg.shards.min(nets.len());
        let mut buckets: Vec<Vec<HostedNet>> = (0..nshards).map(|_| Vec::new()).collect();
        let mut placement = BTreeMap::new();
        for (i, n) in nets.into_iter().enumerate() {
            let s = i % nshards;
            anyhow::ensure!(
                placement.insert(n.name.clone(), s).is_none(),
                "duplicate hosted network {:?}",
                n.name
            );
            buckets[s].push(n);
        }
        let shards = buckets
            .into_iter()
            .enumerate()
            .map(|(id, ns)| Shard::new(id, ns, cfg.cache_bytes, cfg.obs))
            .collect::<anyhow::Result<Vec<_>>>()?;
        // One probe line per engine so serving logs record which SIMD
        // arm the decode plane resolved to (and why, if overridden).
        crate::log_debug!("engine", "{}", crate::vq::simd::probe_line());
        Ok(Engine {
            cfg,
            shards,
            placement,
            now_ns: 0,
            fire_cursor: 0,
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Chaos hook (`fault-inject` builds only): mutable shard access so
    /// the chaos suite can corrupt hosted bytes ([`Shard::corrupt_net_byte`])
    /// and drive quarantine/recovery paths directly.
    #[cfg(feature = "fault-inject")]
    pub fn shards_mut(&mut self) -> &mut [Shard] {
        &mut self.shards
    }

    pub fn hosts(&self, net: &str) -> bool {
        self.placement.contains_key(net)
    }

    /// The hosted network's descriptor (None if unknown).
    pub fn hosted(&self, net: &str) -> Option<&HostedNet> {
        self.placement.get(net).and_then(|&s| self.shards[s].net(net))
    }

    /// Decoded f32s per row of `net`.
    pub fn row_stride(&self, net: &str) -> anyhow::Result<usize> {
        self.hosted(net)
            .map(|n| n.row_stride())
            .ok_or_else(|| anyhow::anyhow!("engine: unknown network {net:?}"))
    }

    /// Per-stage codeword utilization of a hosted net, computed once at
    /// hosting time by the owning shard (None if unknown).  The TCP
    /// `/stats` verb surfaces this per net.
    pub fn net_utilization(&self, net: &str) -> Option<&[crate::vq::assign::Utilization]> {
        self.placement
            .get(net)
            .and_then(|&s| self.shards[s].stats.utilization.get(net))
            .map(|v| v.as_slice())
    }

    /// Advance virtual time.
    pub fn tick(&mut self, ns: u64) {
        self.now_ns += ns;
    }

    /// Drive the plane's clock from an external (wall) clock — monotone,
    /// so interleaved `tick`s can never run it backwards.  The TCP
    /// front-end calls this with `Instant`-derived nanoseconds before
    /// every admission and fire scan.
    pub fn set_now(&mut self, now_ns: u64) {
        if now_ns > self.now_ns {
            self.now_ns = now_ns;
        }
    }

    /// Offer a request to the owning shard at the current clock under
    /// the [`EngineConfig::max_queue_depth`] admission budget.  Unknown
    /// nets, out-of-range rows, and quarantined shards/nets are
    /// *errors* (never counted — the plane was never obligated to serve
    /// them); valid submissions always count as accepted and resolve to
    /// exactly one of [`Admission::Accepted`] (enqueued) or
    /// [`Admission::Rejected`] (shed), so
    /// `accepted == dispatched + shed + expired + failed` holds once
    /// drained.
    pub fn try_submit(&mut self, net: &str, row: usize) -> anyhow::Result<Admission> {
        self.try_submit_deadline(net, row, 0)
    }

    /// [`Engine::try_submit`] with a request deadline on the engine
    /// clock (`deadline_ns`, 0 = none).  The deadline is enforced at
    /// fire time: a request whose deadline lapsed before its batch
    /// fired is ledgered `expired` and shed before any decode work is
    /// spent on it (a `DeadlineExpired` flight-recorder event per
    /// request).
    pub fn try_submit_deadline(
        &mut self,
        net: &str,
        row: usize,
        deadline_ns: u64,
    ) -> anyhow::Result<Admission> {
        let &s = self
            .placement
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("engine: unknown network {net:?}"))?;
        let shard = &mut self.shards[s];
        anyhow::ensure!(
            !shard.is_quarantined(),
            "engine: shard {s} is quarantined (Engine::revive_shard restores it)"
        );
        anyhow::ensure!(
            !shard.net_quarantined(net),
            "engine: {net:?} is quarantined after a code-stream integrity failure"
        );
        let stream_rows = shard.net(net).expect("placement without hosted net").stream_rows();
        anyhow::ensure!(
            row < stream_rows,
            "engine: row {row} out of range for {net:?} ({stream_rows} stream rows)"
        );
        Ok(shard.admit(net, row, self.now_ns, deadline_ns, self.cfg.max_queue_depth))
    }

    /// [`Engine::try_submit`] for callers that treat shedding as an
    /// error (benches, tests, unbounded planes); returns the enqueued
    /// request's shard-local id.
    pub fn submit(&mut self, net: &str, row: usize) -> anyhow::Result<u64> {
        match self.try_submit(net, row)? {
            Admission::Accepted { id } => Ok(id),
            Admission::Rejected { shard, depth } => anyhow::bail!(
                "engine: {net:?} shed at admission (shard {shard} depth {depth} at budget {})",
                self.cfg.max_queue_depth
            ),
        }
    }

    /// Check-only admission probe (no counters, no side effects): would
    /// a submission for `net` be admitted right now?  `false` for
    /// unknown nets.  The TCP front-end uses this to *defer* (hold the
    /// request and stop pulling from the wire — backpressure) instead of
    /// shedding.
    pub fn would_admit(&self, net: &str) -> bool {
        match self.placement.get(net) {
            Some(&s) => {
                self.cfg.max_queue_depth == 0
                    || self.shards[s].router.total_pending() < self.cfg.max_queue_depth
            }
            None => false,
        }
    }

    /// Whether submissions for `net` would be refused by a quarantine —
    /// either its owning shard (a dispatch-time failure; see
    /// [`Engine::revive_shard`]) or the net itself (a code-stream
    /// integrity failure).  `false` for unknown nets (they fail
    /// admission with their own error).  Front-ends check this before
    /// parking a request so nothing waits forever on a shard that will
    /// never serve it.
    pub fn quarantined(&self, net: &str) -> bool {
        match self.placement.get(net) {
            Some(&s) => self.shards[s].is_quarantined() || self.shards[s].net_quarantined(net),
            None => false,
        }
    }

    /// Record one backpressure event on `net`'s owning shard: a
    /// front-end held a request back (instead of shedding it) because
    /// [`Engine::would_admit`] said no.  Unknown nets are ignored.
    pub fn note_deferral(&mut self, net: &str) {
        if let Some(&s) = self.placement.get(net) {
            let now = self.now_ns;
            let sh = &mut self.shards[s];
            sh.stats.deferred += 1;
            let depth = sh.router.total_pending() as u64;
            sh.obs.touch(now);
            sh.obs.note_event(EventKind::Deferral, net, depth, 0);
        }
    }

    /// Record a request the plane refused *before* admission (unknown
    /// net, out-of-range row, malformed request) on the owning shard's
    /// flight recorder — shard 0 when no shard owns the net.  These
    /// never touch the conservation counters (the plane was never
    /// obligated to serve them); the flight recorder is how they stay
    /// explainable after the fact.
    pub fn note_rejected(&mut self, net: &str, kind: EventKind, a: u64, b: u64) {
        let s = self.placement.get(net).copied().unwrap_or(0);
        let now = self.now_ns;
        let sh = &mut self.shards[s];
        sh.obs.touch(now);
        sh.obs.note_event(kind, net, a, b);
    }

    /// Record front-end measured stage durations for one responded
    /// batch of `net`: decode (split hit/miss via `serve`), infer, and
    /// respond.  The engine never reads a wall clock itself — the
    /// front-end owns the clock choice (`Instant` deltas on TCP,
    /// virtual-clock deltas on `serving::server`), so engine-driven
    /// runs stay deterministic.  Unknown nets are ignored.
    pub fn observe_batch(
        &mut self,
        net: &str,
        serve: RowServe,
        decode_ns: u64,
        infer_ns: u64,
        respond_ns: u64,
    ) {
        if let Some(&s) = self.placement.get(net) {
            let now = self.now_ns;
            let sh = &mut self.shards[s];
            sh.obs.touch(now);
            sh.obs.note_stages(decode_ns, infer_ns, respond_ns, serve.misses > 0);
        }
    }

    /// Front-end construction check, shared by `Server::new` and
    /// `TcpServer::new`: every session must be hosted at the artifact's
    /// fixed eval batch (the plane forms the batches), and — the
    /// converse — every hosted net must have a session, because the
    /// plane is the routing table and a hosted net without a session
    /// would admit requests nobody can serve.
    pub fn validate_sessions<'n>(
        &self,
        front_end: &str,
        sessions: impl IntoIterator<Item = (&'n str, usize)>,
    ) -> anyhow::Result<()> {
        let mut seen: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
        for (name, eval_batch) in sessions {
            let hosted = self.hosted(name).ok_or_else(|| {
                anyhow::anyhow!("{front_end}: {name:?} is not hosted on the decode plane")
            })?;
            anyhow::ensure!(
                hosted.device_batch == eval_batch,
                "{front_end}: {name:?} hosted at device_batch {} but its artifact runs eval_batch {eval_batch}",
                hosted.device_batch
            );
            seen.insert(name);
        }
        for shard in &self.shards {
            for net in shard.net_names() {
                anyhow::ensure!(
                    seen.contains(net),
                    "{front_end}: plane hosts {net:?} but no session serves it"
                );
            }
        }
        Ok(())
    }

    pub fn total_pending(&self) -> usize {
        self.shards.iter().map(|s| s.router.total_pending()).sum()
    }

    /// One dispatch round: every shard fires at most one batch.  With a
    /// multi-thread pool and more than one shard, shards run
    /// concurrently (they share no state) with serial in-shard decode;
    /// otherwise shards run in order and the pool (if any) parallelizes
    /// the in-shard row decode instead.  Either way each shard's
    /// behavior depends only on its own queues and the virtual clock, so
    /// outputs, stats, and cache state are bit-identical.
    pub fn dispatch_round(&mut self, pool: Option<&ThreadPool>) -> anyhow::Result<usize> {
        let now = self.now_ns;
        let cfg = self.cfg.batcher;
        let total = match pool {
            Some(tp) if tp.threads() > 1 && self.shards.len() > 1 => {
                let n = self.shards.len();
                let mut results: Vec<anyhow::Result<usize>> = (0..n).map(|_| Ok(0)).collect();
                let shards_ptr = SyncPtr::new(&mut self.shards);
                let res_ptr = SyncPtr::new(&mut results);
                tp.parallel_for(n, 1, |start, end| {
                    for s in start..end {
                        // SAFETY: each chunk owns a disjoint shard slot.
                        let shard = unsafe { &mut shards_ptr.slice(s, 1)[0] };
                        // SAFETY: and the matching disjoint result slot.
                        let out = unsafe { &mut res_ptr.slice(s, 1)[0] };
                        *out = shard.dispatch_one(&cfg, now, None);
                    }
                })
                .map_err(|e| anyhow::anyhow!("engine shard fan-out failed: {e}"))?;
                let mut total = 0;
                for r in results {
                    match r {
                        Ok(served) => total += served,
                        // The failing shard already quarantined itself
                        // and ledgered every lost request as `failed`
                        // (conservation closes); the round keeps the
                        // healthy shards serving.
                        Err(e) => crate::log_debug!("engine", "dispatch failure absorbed: {e}"),
                    }
                }
                total
            }
            _ => {
                let mut total = 0;
                for shard in &mut self.shards {
                    match shard.dispatch_one(&cfg, now, pool) {
                        Ok(served) => total += served,
                        Err(e) => crate::log_debug!("engine", "dispatch failure absorbed: {e}"),
                    }
                }
                total
            }
        };
        // Injected slow-ops stall the engine clock — deterministically,
        // because the per-shard stalls are summed in shard order.
        let stall: u64 = self.shards.iter_mut().map(|s| s.take_stall_ns()).sum();
        if stall > 0 {
            self.tick(stall);
        }
        Ok(total)
    }

    /// Dispatch until every queue is empty, force-firing partial batches
    /// by advancing the virtual clock past the linger deadline (mirrors
    /// `server::drain_all`).
    pub fn drain(&mut self, pool: Option<&ThreadPool>) -> anyhow::Result<u64> {
        let mut total = 0u64;
        let mut stalled_rounds = 0u32;
        loop {
            let before = self.total_pending();
            if before == 0 {
                break;
            }
            self.tick(self.cfg.batcher.max_linger_ns + 1);
            let served = self.dispatch_round(pool)?;
            total += served as u64;
            if served == 0 && self.total_pending() == before {
                // Injected shard wedges stall single rounds; only a
                // sustained run of zero-progress rounds is a real wedge.
                stalled_rounds += 1;
                anyhow::ensure!(
                    stalled_rounds < 64,
                    "engine wedged with {before} pending requests"
                );
            } else {
                stalled_rounds = 0;
            }
        }
        Ok(total)
    }

    /// Fire-selection for the front-ends: scan the shards (round-robin
    /// from a rotating cursor, so no shard starves) and drain at most
    /// one device batch from the first one that should fire at the
    /// current clock.  The caller then streams the batch through
    /// [`Engine::stream_batch`] and runs its artifact — admission →
    /// shard queue → fire-selection → cached/streamed decode →
    /// `infer_hard` is one code path for `serving::server`,
    /// `serving::tcp`, the benches, and the property tests.
    pub fn next_batch(&mut self) -> Option<Batch> {
        let n = self.shards.len();
        let now = self.now_ns;
        let cfg = self.cfg.batcher;
        let mut fired = None;
        for off in 0..n {
            let s = (self.fire_cursor + off) % n;
            if let Some(batch) = self.shards[s].next_batch(&cfg, now) {
                self.fire_cursor = (s + 1) % n;
                fired = Some(batch);
                break;
            }
        }
        // Injected slow-ops stall the engine clock here too, so the
        // front-end fire path sees the same latency as the standalone
        // plane.
        let stall: u64 = self.shards.iter_mut().map(|s| s.take_stall_ns()).sum();
        if stall > 0 {
            self.tick(stall);
        }
        fired
    }

    /// Conservation counters `(accepted, dispatched, shed)` —
    /// `accepted == dispatched + shed` once a *fault-free* plane is
    /// drained.  Under deadlines or quarantines use [`Engine::totals`]:
    /// the full identity is
    /// `accepted == dispatched + shed + expired + failed`.
    pub fn counters(&self) -> (u64, u64, u64) {
        let t = self.totals();
        (t.accepted, t.served, t.shed)
    }

    /// Arm a deterministic fault plan: each shard gets an independent
    /// fork (`plan.fork(shard index)`), exactly like the chunked
    /// per-shard RNG streams — so firing schedules replay identically
    /// across runs and thread counts.  The probes are compiled in only
    /// under the `fault-inject` feature; without it the armed plan is
    /// inert (gated by the `faults_overhead` bench row).
    pub fn arm_faults(&mut self, plan: &FaultPlan) {
        for (i, s) in self.shards.iter_mut().enumerate() {
            s.faults = Some(plan.fork(i as u64));
        }
    }

    /// Drop every shard's fault plan.
    pub fn disarm_faults(&mut self) {
        for s in &mut self.shards {
            s.faults = None;
        }
    }

    /// Front-end failure path: a dispatched batch could not be decoded.
    /// Mirrors the standalone plane ([`Shard::dispatch_one`]): the
    /// batch's requests move from `served` to `failed`, and — unless
    /// the failure was a per-net integrity quarantine — the owning
    /// shard is quarantined, its queued requests failed and counted.
    /// Unknown nets are ignored.
    pub fn fail_batch(&mut self, batch: &Batch) {
        let Some(&s) = self.placement.get(batch.net.as_str()) else {
            return;
        };
        let now = self.now_ns;
        let sh = &mut self.shards[s];
        let in_flight = sh.fail_batch(batch, now);
        if sh.net_quarantined(&batch.net) {
            return;
        }
        let drained = sh.quarantine(now);
        sh.obs
            .note_event(EventKind::Quarantined, &batch.net, s as u64, in_flight + drained);
    }

    /// Clear a shard's quarantine flag so it admits and fires again.
    /// Its ledgers are untouched — everything failed while quarantined
    /// stays ledgered `failed`, so conservation still closes after
    /// revival.  Nets quarantined for integrity failures stay down
    /// (only re-hosting fixes corrupt streams).
    pub fn revive_shard(&mut self, shard: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            shard < self.shards.len(),
            "engine: no shard {shard} (plane has {})",
            self.shards.len()
        );
        self.shards[shard].revive();
        Ok(())
    }

    /// Re-verify every hosted net's packed streams against the
    /// hosting-time checksums ([`Shard::verify_hosted`]).  Mismatching
    /// nets are quarantined (queued requests failed, `HostingError`
    /// events) and the call errors naming them — corrupted packed bytes
    /// are always caught at hosting or here, never served.
    pub fn verify_hosted(&mut self) -> anyhow::Result<()> {
        let now = self.now_ns;
        let mut bad = Vec::new();
        for s in &mut self.shards {
            if let Err(e) = s.verify_hosted(now) {
                bad.push(e.to_string());
            }
        }
        anyhow::ensure!(bad.is_empty(), "engine: {}", bad.join("; "));
        Ok(())
    }

    /// Aggregate decode-cache counters across shards.
    pub fn cache_stats(&self) -> CacheStats {
        let mut out = CacheStats::default();
        for s in &self.shards {
            out.merge(&s.cache.stats);
        }
        out
    }

    /// Aggregate serving counters across shards.
    pub fn totals(&self) -> EngineTotals {
        let mut t = EngineTotals::default();
        for s in &self.shards {
            t.accepted += s.stats.accepted;
            t.served += s.stats.served;
            t.shed += s.stats.shed;
            t.expired += s.stats.expired;
            t.failed += s.stats.failed;
            t.deferred += s.stats.deferred;
            t.peak_depth = t.peak_depth.max(s.stats.peak_depth);
            t.batches += s.stats.batches;
            t.padded_rows += s.stats.padded_rows;
            t.rows_decoded += s.stats.rows_decoded;
            t.rows_from_cache += s.stats.rows_from_cache;
        }
        t
    }

    /// One coherent observability snapshot, merged across shards.  Its
    /// totals are *defined* to reconcile with the engine's conservation
    /// identities — `accepted == dispatched + shed + expired + failed`
    /// (and per net via the ledgers),
    /// `cache_hits + cache_misses == cache_lookups`, and — in
    /// fault-free operation — `queue_ns.count() == dispatched` (a
    /// failed batch keeps its fire-time spans, so under faults the span
    /// count exceeds `dispatched` by the in-flight failures) — and,
    /// because every stamp uses the engine clock, serial and pooled
    /// runs produce *equal* snapshots (property-tested in
    /// `prop_substrate`).
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        let t = self.totals();
        let c = self.cache_stats();
        let mut snap = MetricsSnapshot {
            shards: self.shards.len() as u64,
            hosted_nets: self.placement.len() as u64,
            accepted: t.accepted,
            dispatched: t.served,
            shed: t.shed,
            expired: t.expired,
            failed: t.failed,
            deferred: t.deferred,
            batches: t.batches,
            padded_rows: t.padded_rows,
            rows_from_cache: t.rows_from_cache,
            rows_decoded: t.rows_decoded,
            cache_lookups: c.lookups,
            cache_hits: c.hits,
            cache_misses: c.misses,
            cache_evictions: c.evictions,
            pending: self.total_pending() as u64,
            ..MetricsSnapshot::default()
        };
        for sh in &self.shards {
            snap.absorb_shard(&sh.obs);
            for (net, l) in &sh.stats.by_net {
                let dst = snap.per_net.entry(net.clone()).or_default();
                dst.accepted += l.accepted;
                dst.served += l.served;
                dst.shed += l.shed;
                dst.expired += l.expired;
                dst.failed += l.failed;
            }
            for (net, depth) in sh.router.depths() {
                if depth > 0 {
                    snap.per_net.entry(net.to_string()).or_default().pending += depth as u64;
                }
            }
        }
        snap
    }

    /// Every shard's retained flight-recorder events as
    /// `(shard, event)`, oldest first within a shard — the `/trace`
    /// verb body.
    pub fn trace_events(&self) -> Vec<(usize, Event)> {
        let mut out = Vec::new();
        for (i, sh) in self.shards.iter().enumerate() {
            out.extend(sh.obs.recorder.events().cloned().map(|e| (i, e)));
        }
        out
    }

    /// Drop every shard's cache entries (cumulative counters survive) —
    /// the bench's cold-cache reset.
    pub fn clear_caches(&mut self) {
        for s in &mut self.shards {
            s.cache.clear();
        }
    }

    /// The raw decode-plane API: stream `rows` of `net` through the
    /// owning shard's cache into `dst` (`dst.len() == rows.len() *
    /// row_stride`).  Batch-serving callers use [`Engine::stream_batch`].
    pub fn decode_rows_into(
        &mut self,
        net: &str,
        rows: &[usize],
        dst: &mut [f32],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<RowServe> {
        let &s = self
            .placement
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("engine: unknown network {net:?}"))?;
        self.shards[s].decode_rows_into(net, rows, dst, pool)
    }

    /// Stream a dispatched batch's weight rows through the owning
    /// shard's cache into its staging buffer, mapping caller rows onto
    /// the packed stream cyclically — the one call `serving::server` and
    /// `serving::tcp` make per batch.  `Ok(None)` when `net` is not
    /// hosted on this plane.
    pub fn stream_batch(
        &mut self,
        net: &str,
        rows: &[usize],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<Option<RowServe>> {
        let Some(&s) = self.placement.get(net) else {
            return Ok(None);
        };
        self.shards[s].stream_batch(net, rows, pool).map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::vq::pack::{pack_codes, StagedCodes};
    use crate::vq::Codebook;
    use std::sync::Arc;

    fn hosted(name: &str, rows: usize, cpr: usize, cb: &Arc<Codebook>, rng: &mut Rng) -> HostedNet {
        let codes: Vec<u32> = (0..rows * cpr).map(|_| rng.below(cb.k) as u32).collect();
        HostedNet {
            name: name.into(),
            codes: StagedCodes::single(pack_codes(&codes, cb.index_bits())),
            codebook: cb.clone(),
            codes_per_row: cpr,
            device_batch: 4,
        }
    }

    fn test_cb(rng: &mut Rng) -> Arc<Codebook> {
        let mut words = vec![0.0f32; 8 * 2];
        rng.fill_normal(&mut words);
        Arc::new(Codebook::new(8, 2, words))
    }

    fn cfg(shards: usize, cache_bytes: usize) -> EngineConfig {
        EngineConfig {
            shards,
            cache_bytes,
            max_queue_depth: 0,
            batcher: BatcherConfig {
                max_batch: 4,
                max_linger_ns: 100,
            },
            obs: ObsConfig::default(),
        }
    }

    #[test]
    fn placement_is_round_robin_and_disjoint() {
        let mut rng = Rng::new(1);
        let cb = test_cb(&mut rng);
        let nets: Vec<HostedNet> = (0..5)
            .map(|i| hosted(&format!("n{i}"), 6, 3, &cb, &mut rng))
            .collect();
        let e = Engine::new(cfg(2, 0), nets).unwrap();
        assert_eq!(e.shard_count(), 2);
        // Round-robin: n0,n2,n4 -> shard 0; n1,n3 -> shard 1.
        for (name, want) in [("n0", 0), ("n1", 1), ("n2", 0), ("n3", 1), ("n4", 0)] {
            assert!(e.hosts(name));
            assert!(e.shards()[want].hosts(name), "{name} not on shard {want}");
        }
        assert!(!e.hosts("ghost"));
        assert!(e.hosted("n3").is_some());
        // More shards than nets clamps.
        let mut rng = Rng::new(2);
        let cb = test_cb(&mut rng);
        let one = vec![hosted("solo", 4, 2, &cb, &mut rng)];
        assert_eq!(Engine::new(cfg(8, 0), one).unwrap().shard_count(), 1);
    }

    #[test]
    fn submit_validates_net_and_row() {
        let mut rng = Rng::new(3);
        let cb = test_cb(&mut rng);
        let mut e = Engine::new(cfg(1, 0), vec![hosted("a", 6, 3, &cb, &mut rng)]).unwrap();
        assert!(e.submit("ghost", 0).is_err());
        assert!(e.submit("a", 6).is_err(), "stream holds rows 0..6");
        e.submit("a", 5).unwrap();
        let (acc, disp, shed) = e.counters();
        assert_eq!((acc, disp, shed), (1, 0, 0), "invalid submits are not accepted");
    }

    #[test]
    fn admission_sheds_at_the_queue_budget_and_conserves() {
        let mut rng = Rng::new(9);
        let cb = test_cb(&mut rng);
        let mut c = cfg(1, 0);
        c.max_queue_depth = 2;
        let mut e = Engine::new(c, vec![hosted("a", 6, 3, &cb, &mut rng)]).unwrap();
        assert!(e.would_admit("a"));
        assert!(matches!(e.try_submit("a", 0).unwrap(), Admission::Accepted { .. }));
        assert!(matches!(e.try_submit("a", 1).unwrap(), Admission::Accepted { .. }));
        assert!(!e.would_admit("a"), "backlog at budget");
        assert!(!e.would_admit("ghost"), "unknown nets are never admitted");
        match e.try_submit("a", 2).unwrap() {
            Admission::Rejected { shard, depth } => {
                assert_eq!(shard, 0);
                assert_eq!(depth, 2);
            }
            other => panic!("expected a shed, got {other:?}"),
        }
        assert!(e.submit("a", 2).is_err(), "submit() surfaces the shed as an error");
        e.note_deferral("a");
        e.note_deferral("ghost"); // ignored
        let t = e.totals();
        assert_eq!((t.accepted, t.shed, t.deferred, t.peak_depth), (4, 2, 1, 2));
        // Shedding freed nothing: the two queued requests still drain.
        let served = e.drain(None).unwrap();
        assert_eq!(served, 2);
        let (acc, disp, shed) = e.counters();
        assert_eq!(acc, disp + shed, "admission conservation");
        let ledger = e.shards()[0].stats.by_net["a"];
        assert_eq!(
            (ledger.accepted, ledger.served, ledger.shed),
            (4, 2, 2),
            "per-net ledger conserves"
        );
        assert!(e.would_admit("a"), "drained plane admits again");
    }

    #[test]
    fn metrics_snapshot_reconciles_and_traces_the_shed() {
        let mut rng = Rng::new(21);
        let cb = test_cb(&mut rng);
        let mut c = cfg(1, 1 << 16);
        c.max_queue_depth = 2;
        let mut e = Engine::new(c, vec![hosted("a", 6, 3, &cb, &mut rng)]).unwrap();
        e.tick(10);
        e.try_submit("a", 0).unwrap();
        e.try_submit("a", 1).unwrap();
        assert!(matches!(e.try_submit("a", 5).unwrap(), Admission::Rejected { .. }));
        e.note_deferral("a");
        e.note_rejected("ghost", EventKind::HostingError, 3, 0);

        let queued = e.metrics_snapshot();
        assert_eq!(queued.pending, 2);
        assert_eq!(queued.per_net["a"].pending, 2);

        e.drain(None).unwrap();
        e.observe_batch("a", RowServe { hits: 0, misses: 2 }, 40, 100, 5);
        let s = e.metrics_snapshot();
        assert_eq!((s.accepted, s.dispatched, s.shed, s.deferred), (3, 2, 1, 1));
        assert_eq!(s.accepted, s.dispatched + s.shed, "conservation");
        assert_eq!(s.queue_ns.count(), s.dispatched, "one span per dispatched request");
        assert_eq!(s.per_net["a"].queue_ns.count(), 2);
        assert_eq!(s.cache_hits + s.cache_misses, s.cache_lookups);
        assert_eq!(s.per_net["a"].rows_hit + s.per_net["a"].rows_missed, s.cache_lookups);
        assert!(s.decoded_bytes_read > 0, "misses account packed bytes");
        assert_eq!(s.pending, 0);
        assert_eq!(s.infer_ns.count(), 1);
        assert!((s.decode_hidden_ratio() - 0.4).abs() < 1e-12);
        // The shed, the deferral, and the hosting error are explainable
        // from the flight recorder.
        let kinds: Vec<EventKind> = e.trace_events().iter().map(|(_, ev)| ev.kind).collect();
        assert_eq!(
            kinds,
            vec![EventKind::Shed, EventKind::Deferral, EventKind::HostingError]
        );
        let shed_ev = &e.trace_events()[0].1;
        assert_eq!((shed_ev.at_ns, shed_ev.net.as_str(), shed_ev.a, shed_ev.b), (10, "a", 5, 2));
        assert_eq!(s.events_recorded, 3);
        assert_eq!(s.events_dropped, 0);

        // Disabled obs: same engine traffic, empty obs plane — and the
        // conservation counters still fill the snapshot.
        let mut rng = Rng::new(21);
        let cb = test_cb(&mut rng);
        let mut c2 = cfg(1, 1 << 16);
        c2.max_queue_depth = 2;
        c2.obs = ObsConfig {
            enabled: false,
            ring_capacity: 256,
        };
        let mut e2 = Engine::new(c2, vec![hosted("a", 6, 3, &cb, &mut rng)]).unwrap();
        e2.tick(10);
        e2.try_submit("a", 0).unwrap();
        e2.try_submit("a", 1).unwrap();
        let _ = e2.try_submit("a", 5).unwrap();
        e2.drain(None).unwrap();
        let s2 = e2.metrics_snapshot();
        assert_eq!((s2.accepted, s2.dispatched, s2.shed), (3, 2, 1));
        assert_eq!(s2.queue_ns.count(), 0, "disabled obs records no spans");
        assert!(e2.trace_events().is_empty());
    }

    #[test]
    fn next_batch_fires_the_front_end_path_and_rotates_shards() {
        let mut rng = Rng::new(10);
        let cb = test_cb(&mut rng);
        let nets: Vec<HostedNet> = (0..2)
            .map(|i| hosted(&format!("n{i}"), 8, 2, &cb, &mut rng))
            .collect();
        let mut e = Engine::new(cfg(2, 4096), nets).unwrap();
        assert!(e.next_batch().is_none(), "idle plane fires nothing");
        for i in 0..4 {
            e.submit("n0", i).unwrap();
            e.submit("n1", i).unwrap();
        }
        // Both shards are full (max_batch = 4); the cursor alternates.
        let first = e.next_batch().expect("full queue fires");
        let second = e.next_batch().expect("other shard fires");
        assert_ne!(first.net, second.net, "cursor rotation reaches both shards");
        assert_eq!(first.requests.len() + second.requests.len(), 8);
        // next_batch records the serve-side counters; the decode halves
        // stay zero until the caller streams the batch.
        let t = e.totals();
        assert_eq!(t.served, 8);
        assert_eq!(t.rows_decoded + t.rows_from_cache, 0);
        let rs = e
            .stream_batch(&first.net, &first.rows, None)
            .unwrap()
            .expect("hosted net streams");
        assert_eq!(rs.hits + rs.misses, first.rows.len());
        assert_eq!(e.totals().rows_decoded + e.totals().rows_from_cache, first.rows.len() as u64);
        assert_eq!(e.total_pending(), 0);
    }

    #[test]
    fn validate_sessions_checks_both_directions() {
        let mut rng = Rng::new(12);
        let cb = test_cb(&mut rng);
        let nets: Vec<HostedNet> = (0..2)
            .map(|i| hosted(&format!("n{i}"), 4, 2, &cb, &mut rng))
            .collect();
        let e = Engine::new(cfg(1, 0), nets).unwrap();
        // One-to-one at the hosted device_batch (4): ok.
        assert!(e.validate_sessions("t", [("n0", 4), ("n1", 4)]).is_ok());
        // A session the plane does not host.
        assert!(e.validate_sessions("t", [("n0", 4), ("ghost", 4)]).is_err());
        // Batch-geometry mismatch.
        assert!(e.validate_sessions("t", [("n0", 4), ("n1", 8)]).is_err());
        // A hosted net with no session would admit unservable requests.
        assert!(e.validate_sessions("t", [("n0", 4)]).is_err());
    }

    #[test]
    fn set_now_is_monotone() {
        let mut rng = Rng::new(11);
        let cb = test_cb(&mut rng);
        let mut e = Engine::new(cfg(1, 0), vec![hosted("a", 4, 2, &cb, &mut rng)]).unwrap();
        e.set_now(100);
        assert_eq!(e.now_ns, 100);
        e.set_now(50);
        assert_eq!(e.now_ns, 100, "wall clock never runs backwards");
        e.tick(5);
        assert_eq!(e.now_ns, 105);
    }

    #[test]
    fn drain_serves_everything_exactly_once_across_shards() {
        let mut rng = Rng::new(4);
        let cb = test_cb(&mut rng);
        let nets: Vec<HostedNet> = (0..3)
            .map(|i| hosted(&format!("n{i}"), 8, 2, &cb, &mut rng))
            .collect();
        let mut e = Engine::new(cfg(3, 4096), nets).unwrap();
        let mut per_net = [0u64; 3];
        for i in 0..37 {
            let n = i % 3;
            e.submit(&format!("n{n}"), i % 8).unwrap();
            per_net[n] += 1;
        }
        let served = e.drain(None).unwrap();
        assert_eq!(served, 37);
        let (acc, disp, shed) = e.counters();
        assert_eq!(acc, 37);
        assert_eq!(disp, 37);
        assert_eq!(shed, 0, "unbounded plane sheds nothing");
        assert_eq!(e.total_pending(), 0);
        for (i, &want) in per_net.iter().enumerate() {
            let name = format!("n{i}");
            let got: u64 = e
                .shards()
                .iter()
                .map(|s| s.stats.by_net.get(&name).map(|l| l.served).unwrap_or(0))
                .sum();
            assert_eq!(got, want, "{name} served count");
        }
        let t = e.totals();
        assert_eq!(t.served, 37);
        assert_eq!(t.rows_decoded + t.rows_from_cache, t.served + t.padded_rows);
        assert!(t.rows_from_cache > 0, "repeat rows should hit the cache");
    }

    #[test]
    fn decode_plane_matches_fresh_decode_and_counts_hits() {
        let mut rng = Rng::new(5);
        let cb = test_cb(&mut rng);
        let net = hosted("a", 6, 4, &cb, &mut rng);
        let staged = net.codes.clone();
        let mut e = Engine::new(cfg(1, 1 << 16), vec![net]).unwrap();
        let stride = e.row_stride("a").unwrap();
        let rows = [3usize, 1, 3];
        let mut dst = vec![0.0f32; rows.len() * stride];
        let first = e.decode_rows_into("a", &rows, &mut dst, None).unwrap();
        assert_eq!(first, RowServe { hits: 0, misses: 3 });
        // Second pass over the same rows is all cache hits…
        let mut dst2 = vec![0.0f32; rows.len() * stride];
        let second = e.decode_rows_into("a", &rows, &mut dst2, None).unwrap();
        assert_eq!(second, RowServe { hits: 3, misses: 0 });
        // …and bit-identical to the fresh decode.
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dst), bits(&dst2));
        for (i, &row) in rows.iter().enumerate() {
            let mut fresh = vec![0.0f32; stride];
            cb.decode_staged_packed_into(&staged, row * 4, (row + 1) * 4, &mut fresh);
            assert_eq!(bits(&dst2[i * stride..(i + 1) * stride]), bits(&fresh));
        }
        let cs = e.cache_stats();
        assert_eq!(cs.lookups, 6);
        assert_eq!(cs.hits, 3);
        assert_eq!(cs.misses, 3);
        assert!((cs.hit_rate() - 0.5).abs() < 1e-12);
        e.clear_caches();
        let third = e.decode_rows_into("a", &rows, &mut dst2, None).unwrap();
        assert_eq!(third.misses, 3, "cleared cache decodes fresh");
    }

    #[test]
    fn stream_batch_maps_rows_cyclically_and_skips_unhosted_nets() {
        let mut rng = Rng::new(7);
        let cb = test_cb(&mut rng);
        let net = hosted("a", 4, 3, &cb, &mut rng); // 4 stream rows
        let mut e = Engine::new(cfg(1, 1 << 16), vec![net]).unwrap();
        // Caller rows beyond the stream wrap cyclically: 5 % 4 == 1, so
        // both positions decode window 1 (both miss — inserts happen
        // after the batch's lookups).
        let rs = e.stream_batch("a", &[5, 1], None).unwrap().unwrap();
        assert_eq!(rs, RowServe { hits: 0, misses: 2 });
        let rs2 = e.stream_batch("a", &[5], None).unwrap().unwrap();
        assert_eq!(rs2, RowServe { hits: 1, misses: 0 }, "wrapped row hits the cached window");
        assert!(e.stream_batch("ghost", &[0], None).unwrap().is_none());
    }

    #[test]
    fn rejects_bad_configs() {
        let mut rng = Rng::new(6);
        let cb = test_cb(&mut rng);
        assert!(Engine::new(cfg(0, 0), vec![hosted("a", 4, 2, &cb, &mut rng)]).is_err());
        assert!(Engine::new(cfg(1, 0), vec![]).is_err());
        let dup = vec![hosted("a", 4, 2, &cb, &mut rng), hosted("a", 4, 2, &cb, &mut rng)];
        assert!(Engine::new(cfg(2, 0), dup).is_err());
        let mut zero_batch = cfg(1, 0);
        zero_batch.batcher.max_batch = 0;
        assert!(Engine::new(zero_batch, vec![hosted("a", 4, 2, &cb, &mut rng)]).is_err());
        // Packed codes that cannot address the codebook are rejected at
        // hosting time, not mid-serve.
        let cb3 = Arc::new(Codebook::new(3, 1, vec![0.0, 1.0, 2.0]));
        let bad = HostedNet {
            name: "bad".into(),
            codes: StagedCodes::single(pack_codes(&[0u32, 1, 2, 3], 2)), // code 3 >= k = 3
            codebook: cb3.clone(),
            codes_per_row: 2,
            device_batch: 1,
        };
        assert!(Engine::new(cfg(1, 0), vec![bad]).is_err());
        // A bad code hiding in a later stage is caught too.
        let bad_stage = HostedNet {
            name: "bad2".into(),
            codes: StagedCodes::new(vec![
                pack_codes(&[0u32, 1, 2, 0], 2),
                pack_codes(&[0u32, 1, 2, 3], 2), // stage 1 code 3 >= k = 3
            ]),
            codebook: cb3,
            codes_per_row: 2,
            device_batch: 1,
        };
        assert!(Engine::new(cfg(1, 0), vec![bad_stage]).is_err());
    }

    #[test]
    fn deadlines_expire_at_fire_time_and_conserve() {
        let mut rng = Rng::new(31);
        let cb = test_cb(&mut rng);
        let mut e = Engine::new(cfg(1, 0), vec![hosted("a", 8, 2, &cb, &mut rng)]).unwrap();
        // Two requests with deadlines that lapse before the linger
        // fires, one without, one with a generous deadline.
        e.try_submit_deadline("a", 0, 50).unwrap();
        e.try_submit_deadline("a", 1, 0).unwrap();
        e.try_submit_deadline("a", 2, 60).unwrap();
        e.try_submit_deadline("a", 3, 1_000_000).unwrap();
        let served = e.drain(None).unwrap();
        assert_eq!(served, 2, "lapsed deadlines are shed before decode");
        let t = e.totals();
        assert_eq!((t.accepted, t.served, t.expired, t.failed), (4, 2, 2, 0));
        assert_eq!(t.accepted, t.served + t.shed + t.expired + t.failed, "conservation");
        let ledger = e.shards()[0].stats.by_net["a"];
        assert_eq!((ledger.served, ledger.expired), (2, 2), "per-net ledger");
        // One DeadlineExpired event per lapsed request, payload = (row,
        // deadline).
        let expired_evs: Vec<_> = e
            .trace_events()
            .into_iter()
            .filter(|(_, ev)| ev.kind == EventKind::DeadlineExpired)
            .collect();
        assert_eq!(expired_evs.len(), 2);
        assert_eq!((expired_evs[0].1.a, expired_evs[0].1.b), (0, 50));
        assert_eq!((expired_evs[1].1.a, expired_evs[1].1.b), (2, 60));
        let s = e.metrics_snapshot();
        assert_eq!((s.expired, s.failed), (2, 0));
        assert_eq!(s.per_net["a"].expired, 2);
    }

    #[test]
    fn failed_batch_quarantines_counts_and_revives() {
        let mut rng = Rng::new(33);
        let cb = test_cb(&mut rng);
        let mut e = Engine::new(cfg(1, 4096), vec![hosted("a", 8, 2, &cb, &mut rng)]).unwrap();
        for i in 0..6 {
            e.submit("a", i).unwrap();
        }
        // Fire one batch (4 of 6), then report its decode as failed —
        // the front-end failure path.
        let batch = {
            e.tick(1_000);
            e.next_batch().expect("full queue fires")
        };
        assert_eq!(batch.requests.len(), 4);
        e.fail_batch(&batch);
        // The in-flight 4 and the queued 2 are all ledgered failed; the
        // shard is quarantined and refuses admissions and fires.
        let t = e.totals();
        assert_eq!((t.accepted, t.served, t.failed), (6, 0, 6));
        assert_eq!(t.accepted, t.served + t.shed + t.expired + t.failed, "conservation");
        assert_eq!(e.total_pending(), 0, "quarantine drained the queues");
        assert!(e.shards()[0].is_quarantined());
        assert!(e.try_submit("a", 0).is_err(), "quarantined shard refuses admission");
        assert!(e.next_batch().is_none(), "quarantined shard never fires");
        assert!(
            e.stream_batch("a", &[0], None).is_err(),
            "quarantined shard never serves a row"
        );
        // The loss is explainable: per-request failures + the
        // quarantine marker.
        let kinds: Vec<EventKind> = e.trace_events().iter().map(|(_, ev)| ev.kind).collect();
        assert_eq!(kinds.iter().filter(|k| **k == EventKind::RequestFailed).count(), 6);
        assert_eq!(kinds.iter().filter(|k| **k == EventKind::Quarantined).count(), 1);
        let q = e
            .trace_events()
            .into_iter()
            .find(|(_, ev)| ev.kind == EventKind::Quarantined)
            .unwrap()
            .1;
        assert_eq!((q.a, q.b), (0, 6), "shard 0, 6 requests failed with it");
        // Revival restores service without touching the ledgers.
        assert!(e.revive_shard(7).is_err());
        e.revive_shard(0).unwrap();
        e.submit("a", 1).unwrap();
        e.drain(None).unwrap();
        let t = e.totals();
        assert_eq!((t.accepted, t.served, t.failed), (7, 1, 6));
        assert_eq!(t.accepted, t.served + t.shed + t.expired + t.failed);
    }

    #[test]
    fn verify_hosted_passes_on_clean_streams() {
        let mut rng = Rng::new(34);
        let cb = test_cb(&mut rng);
        let nets: Vec<HostedNet> = (0..3)
            .map(|i| hosted(&format!("n{i}"), 6, 2, &cb, &mut rng))
            .collect();
        let mut e = Engine::new(cfg(2, 0), nets).unwrap();
        e.verify_hosted().expect("unmodified streams re-verify");
        // Hosting-time checksums are exposed per net and match a fresh
        // recompute.
        let sums = e.shards()[0].hosted_checksums("n0").unwrap().to_vec();
        assert_eq!(sums, e.hosted("n0").unwrap().codes.checksums());
    }

    #[test]
    fn hosting_reports_per_stage_utilization() {
        let mut rng = Rng::new(13);
        let cb = test_cb(&mut rng); // k = 8
        let net = HostedNet {
            name: "a".into(),
            codes: StagedCodes::new(vec![
                pack_codes(&[0u32, 1, 0, 3], 3),
                pack_codes(&[7u32, 7, 7, 7], 3),
            ]),
            codebook: cb,
            codes_per_row: 2,
            device_batch: 1,
        };
        let e = Engine::new(cfg(1, 0), vec![net]).unwrap();
        let util = e.net_utilization("a").expect("hosted net has utilization");
        assert_eq!(util.len(), 2);
        assert_eq!((util[0].k, util[0].total, util[0].used), (8, 4, 3));
        assert_eq!((util[1].used, util[1].entropy_bits), (1, 0.0), "collapsed stage");
        assert!(e.net_utilization("ghost").is_none());
    }
}
