//! Request router: one queue per hosted network, round-robin-with-
//! backlog-priority dispatch, conservation guarantees (every accepted
//! request is dispatched exactly once — property-tested).
//!
//! The router is an **engine-internal** component: since the serving
//! planes were unified, the only construction sites are the engine's
//! shards ([`super::shard::Shard`]) — the front-ends (`serving::server`,
//! `serving::tcp`) route exclusively through the engine's per-shard
//! router queue sets.

use std::collections::VecDeque;

use crate::serving::batcher::{should_fire, BatcherConfig};

/// One inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub net: String,
    /// Row index into the network's input pool (the demo serves from a
    /// preloaded tensor; a production build would carry the payload).
    pub row: usize,
    /// Arrival timestamp (ns, monotonic) for latency accounting.
    pub arrived_ns: u64,
    /// Optional deadline on the front-end clock (ns, same monotonic
    /// clock as `arrived_ns`); `0` means none.  Checked at fire time:
    /// a request whose deadline lapsed is counted `expired` and shed
    /// before decode instead of burning a batch slot.
    pub deadline_ns: u64,
}

impl Request {
    /// Whether this request's deadline has lapsed at `now_ns`.
    pub fn expired(&self, now_ns: u64) -> bool {
        self.deadline_ns != 0 && now_ns > self.deadline_ns
    }
}

/// Router over the hosted networks.
pub struct Router {
    queues: Vec<(String, VecDeque<Request>)>,
    next_id: u64,
    accepted: u64,
    dispatched: u64,
    rr_cursor: usize,
}

impl Router {
    pub fn new(networks: &[&str]) -> Self {
        Router {
            queues: networks
                .iter()
                .map(|n| (n.to_string(), VecDeque::new()))
                .collect(),
            next_id: 0,
            accepted: 0,
            dispatched: 0,
            rr_cursor: 0,
        }
    }

    pub fn networks(&self) -> Vec<&str> {
        self.queues.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Enqueue a request; returns its id, or an error for unknown nets.
    pub fn submit(&mut self, net: &str, row: usize, now_ns: u64) -> anyhow::Result<u64> {
        self.submit_with_deadline(net, row, now_ns, 0)
    }

    /// [`Router::submit`] with an explicit deadline on the front-end
    /// clock (`0` = none).  The deadline rides the queued [`Request`]
    /// and is enforced at fire time by the shard.
    pub fn submit_with_deadline(
        &mut self,
        net: &str,
        row: usize,
        now_ns: u64,
        deadline_ns: u64,
    ) -> anyhow::Result<u64> {
        let q = self
            .queues
            .iter_mut()
            .find(|(n, _)| n == net)
            .ok_or_else(|| anyhow::anyhow!("router: unknown network {net:?}"))?;
        let id = self.next_id;
        self.next_id += 1;
        self.accepted += 1;
        q.1.push_back(Request {
            id,
            net: net.to_string(),
            row,
            arrived_ns: now_ns,
            deadline_ns,
        });
        Ok(id)
    }

    /// Depth of a queue.
    pub fn depth(&self, net: &str) -> usize {
        self.queues
            .iter()
            .find(|(n, _)| n == net)
            .map(|(_, q)| q.len())
            .unwrap_or(0)
    }

    pub fn total_pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Per-net queue depths in declaration order — the obs plane's
    /// per-net pending gauges (`Engine::metrics_snapshot`).
    pub fn depths(&self) -> impl Iterator<Item = (&str, usize)> {
        self.queues.iter().map(|(n, q)| (n.as_str(), q.len()))
    }

    /// Arrival time of the oldest waiting request in `net`'s queue
    /// (None if empty) — the batcher's linger clock.
    pub fn oldest_arrival(&self, net: &str) -> Option<u64> {
        self.queues
            .iter()
            .find(|(n, _)| n == net)
            .and_then(|(_, q)| q.front())
            .map(|r| r.arrived_ns)
    }

    /// Pick the next network to serve: the deepest backlog, with a
    /// round-robin cursor breaking ties so no queue starves.
    pub fn pick(&mut self) -> Option<usize> {
        let n = self.queues.len();
        if n == 0 {
            return None;
        }
        let mut best: Option<(usize, usize)> = None; // (queue idx, depth)
        for off in 0..n {
            let i = (self.rr_cursor + off) % n;
            let depth = self.queues[i].1.len();
            if depth > 0 && best.map(|(_, d)| depth > d).unwrap_or(true) {
                best = Some((i, depth));
            }
        }
        best.map(|(i, _)| {
            self.rr_cursor = (i + 1) % n;
            i
        })
    }

    /// Drain up to `max` requests from queue `i`.
    pub fn drain(&mut self, i: usize, max: usize) -> Vec<Request> {
        let q = &mut self.queues[i].1;
        let take = q.len().min(max);
        let out: Vec<Request> = q.drain(..take).collect();
        self.dispatched += out.len() as u64;
        out
    }

    /// Name-keyed twin of [`Router::drain`] — every other router API is
    /// keyed by network name, so callers no longer need the
    /// `names.iter().position(...)` dance.  Unknown nets drain nothing.
    pub fn drain_net(&mut self, net: &str, max: usize) -> Vec<Request> {
        match self.queues.iter().position(|(n, _)| n == net) {
            Some(i) => self.drain(i, max),
            None => Vec::new(),
        }
    }

    /// Remove every request in `net`'s queue whose deadline lapsed at
    /// `now_ns`, preserving the order of the survivors.  The removed
    /// requests do **not** count as dispatched — the caller ledgers
    /// them `expired` (the fire path sheds them before decode).
    pub fn expire_net(&mut self, net: &str, now_ns: u64) -> Vec<Request> {
        let Some((_, q)) = self.queues.iter_mut().find(|(n, _)| n == net) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        q.retain(|r| {
            if r.expired(now_ns) {
                out.push(r.clone());
                false
            } else {
                true
            }
        });
        out
    }

    /// Drain every queue wholesale (queue-declaration order).  Nothing
    /// here counts as dispatched — the caller ledgers the requests
    /// (`failed`, on shard quarantine) so conservation still closes.
    pub fn take_all(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        for (_, q) in &mut self.queues {
            out.extend(q.drain(..));
        }
        out
    }

    /// Drain one net's queue wholesale without counting dispatched —
    /// the net-quarantine drain (the caller ledgers the requests
    /// `failed`).  Unknown nets drain nothing.
    pub fn take_net(&mut self, net: &str) -> Vec<Request> {
        match self.queues.iter_mut().find(|(n, _)| n == net) {
            Some((_, q)) => q.drain(..).collect(),
            None => Vec::new(),
        }
    }

    /// Roll the dispatched counter back by `n`: a drained batch failed
    /// before serving (quarantined dispatch), so its requests move from
    /// `dispatched` to the caller's `failed` ledger — conservation
    /// (`accepted == dispatched + shed + expired + failed`) still
    /// closes.
    pub fn undispatch(&mut self, n: u64) {
        self.dispatched = self.dispatched.saturating_sub(n);
    }

    /// First queue (in declaration order) whose depth or linger says it
    /// should fire under `cfg` — the dispatch scan `server::Server` and
    /// the engine shards share.
    pub fn next_fireable(&self, cfg: &BatcherConfig, now_ns: u64) -> Option<&str> {
        self.queues
            .iter()
            .find(|(_, q)| match q.front() {
                // Empty queues never fire, whatever the policy says.
                None => false,
                Some(oldest) => should_fire(cfg, q.len(), oldest.arrived_ns, now_ns),
            })
            .map(|(n, _)| n.as_str())
    }

    pub fn net_name(&self, i: usize) -> &str {
        &self.queues[i].0
    }

    /// Conservation counters (accepted, dispatched).
    pub fn counters(&self) -> (u64, u64) {
        (self.accepted, self.dispatched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_to_right_queue() {
        let mut r = Router::new(&["a", "b"]);
        r.submit("a", 0, 0).unwrap();
        r.submit("b", 1, 0).unwrap();
        r.submit("b", 2, 0).unwrap();
        assert_eq!(r.depth("a"), 1);
        assert_eq!(r.depth("b"), 2);
        assert!(r.submit("ghost", 0, 0).is_err());
    }

    #[test]
    fn pick_prefers_backlog_then_round_robins() {
        let mut r = Router::new(&["a", "b"]);
        r.submit("b", 0, 0).unwrap();
        r.submit("b", 1, 0).unwrap();
        r.submit("a", 2, 0).unwrap();
        let first = r.pick().unwrap();
        assert_eq!(r.net_name(first), "b", "deeper queue first");
        let drained = r.drain(first, 10);
        assert_eq!(drained.len(), 2);
        let second = r.pick().unwrap();
        assert_eq!(r.net_name(second), "a");
    }

    #[test]
    fn drain_net_matches_indexed_drain_and_counts() {
        let mut r = Router::new(&["a", "b"]);
        for i in 0..5 {
            r.submit("b", i, 0).unwrap();
        }
        let got = r.drain_net("b", 3);
        assert_eq!(got.len(), 3);
        assert_eq!(r.depth("b"), 2);
        assert!(r.drain_net("ghost", 10).is_empty(), "unknown nets drain nothing");
        let rest = r.drain_net("b", 10);
        assert_eq!(rest.len(), 2);
        let (acc, disp) = r.counters();
        assert_eq!(acc, 5);
        assert_eq!(disp, 5, "drain_net feeds the conservation counter");
    }

    #[test]
    fn conservation() {
        let mut r = Router::new(&["a", "b", "c"]);
        for i in 0..30 {
            r.submit(["a", "b", "c"][i % 3], i, i as u64).unwrap();
        }
        let mut served = 0;
        while let Some(i) = r.pick() {
            served += r.drain(i, 4).len();
        }
        assert_eq!(served, 30);
        let (acc, disp) = r.counters();
        assert_eq!(acc, disp);
        assert_eq!(r.total_pending(), 0);
    }

    #[test]
    fn empty_router_picks_none() {
        let mut r = Router::new(&["a"]);
        assert!(r.pick().is_none());
    }

    #[test]
    fn expire_net_removes_only_lapsed_and_preserves_order() {
        let mut r = Router::new(&["a"]);
        r.submit_with_deadline("a", 0, 0, 50).unwrap(); // lapses at 51
        r.submit("a", 1, 0).unwrap(); // no deadline, never expires
        r.submit_with_deadline("a", 2, 0, 200).unwrap();
        r.submit_with_deadline("a", 3, 0, 40).unwrap();
        let expired = r.expire_net("a", 100);
        assert_eq!(
            expired.iter().map(|x| x.row).collect::<Vec<_>>(),
            vec![0, 3],
            "only lapsed deadlines removed, queue order"
        );
        assert_eq!(r.depth("a"), 2, "survivors stay queued");
        let (acc, disp) = r.counters();
        assert_eq!((acc, disp), (4, 0), "expiry never counts as dispatched");
        // Deadline exactly == now is not yet expired (strict >).
        assert!(r.expire_net("a", 200).is_empty());
        assert_eq!(r.expire_net("a", 201).len(), 1);
        assert!(r.expire_net("ghost", 1000).is_empty());
    }

    #[test]
    fn take_all_empties_without_counting_dispatched() {
        let mut r = Router::new(&["a", "b"]);
        for i in 0..3 {
            r.submit("a", i, 0).unwrap();
        }
        r.submit("b", 9, 0).unwrap();
        let taken = r.take_all();
        assert_eq!(taken.len(), 4);
        assert_eq!(r.total_pending(), 0);
        let (acc, disp) = r.counters();
        assert_eq!((acc, disp), (4, 0), "quarantine drain bypasses dispatched");
    }

    #[test]
    fn take_net_and_undispatch_keep_conservation_closable() {
        let mut r = Router::new(&["a", "b"]);
        r.submit("a", 0, 0).unwrap();
        r.submit("a", 1, 0).unwrap();
        r.submit("b", 2, 0).unwrap();
        assert_eq!(r.take_net("a").len(), 2, "net quarantine drains its queue");
        assert_eq!(r.depth("b"), 1, "other queues untouched");
        assert!(r.take_net("ghost").is_empty());
        assert_eq!(r.drain_net("b", 4).len(), 1);
        assert_eq!(r.counters(), (3, 1));
        r.undispatch(1);
        assert_eq!(r.counters(), (3, 0), "failed batch rolls dispatched back");
        r.undispatch(5);
        assert_eq!(r.counters().1, 0, "rollback saturates at zero");
    }

    #[test]
    fn next_fireable_honors_size_and_linger() {
        let cfg = BatcherConfig {
            max_batch: 2,
            max_linger_ns: 100,
        };
        let mut r = Router::new(&["a", "b"]);
        assert!(r.next_fireable(&cfg, 0).is_none(), "empty router");
        r.submit("b", 0, 1000).unwrap();
        assert!(r.next_fireable(&cfg, 1050).is_none(), "young partial waits");
        assert_eq!(r.next_fireable(&cfg, 1101), Some("b"), "lingered partial fires");
        r.submit("a", 1, 1050).unwrap();
        r.submit("a", 2, 1050).unwrap();
        assert_eq!(
            r.next_fireable(&cfg, 1060),
            Some("a"),
            "full batch fires in declaration order before b lingers"
        );
        // Empty queues never fire even under a zero-size policy.
        let zero = BatcherConfig {
            max_batch: 0,
            max_linger_ns: 0,
        };
        let empty = Router::new(&["a"]);
        assert!(empty.next_fireable(&zero, u64::MAX).is_none());
    }
}
