//! One engine shard: a disjoint subset of the hosted networks with its
//! own router queue set, decode cache, and reusable streaming-decode
//! staging buffer.  Shards share no mutable state, so the engine can fan
//! them across the worker pool — and because each shard's behavior
//! depends only on its own queues and the virtual clock, results and
//! cache state are bit-identical at every thread count.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::serving::batcher::{Batch, BatcherConfig};
use crate::serving::faults::{FaultPlan, FaultSite};
use crate::serving::obs::{EventKind, ObsConfig, ShardObs};
use crate::util::stats::Summary;
use crate::util::threadpool::{SyncPtr, ThreadPool};
use crate::vq::assign::Utilization;
use crate::vq::codebook::Codebook;
use crate::vq::pack::{unpack_range, StagedCodes};

use super::cache::{DecodeCache, RowWindow};
use super::router::Router;
use super::Admission;

/// One network hosted on the decode plane: its staged assignment
/// streams (one packed stream per residual stage — `stages == 1` is the
/// legacy single-stream format), the shared (ROM-resident) universal
/// codebook, and the row geometry — row `r` covers codes
/// `[r * codes_per_row, (r + 1) * codes_per_row)` of every stage.
#[derive(Clone, Debug)]
pub struct HostedNet {
    pub name: String,
    pub codes: StagedCodes,
    /// Shared universal codebook (one `Arc` across every hosted net and
    /// every residual stage — the §3.2 premise).
    pub codebook: Arc<Codebook>,
    pub codes_per_row: usize,
    /// Fixed device batch its `infer_hard` artifact was lowered at.
    pub device_batch: usize,
}

impl HostedNet {
    /// Rows the staged streams hold at this geometry.
    pub fn stream_rows(&self) -> usize {
        self.codes.count() / self.codes_per_row
    }

    /// Decoded f32s per row.
    pub fn row_stride(&self) -> usize {
        self.codes_per_row * self.codebook.d
    }
}

/// Cache-aware row serve accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RowServe {
    /// Rows copied straight out of the decode cache.
    pub hits: usize,
    /// Rows decoded fresh from the packed stream.
    pub misses: usize,
}

/// Per-net conservation ledger: every validated submission lands in
/// `accepted`, and then in exactly one of `served` (dispatched through a
/// batch), `shed` (rejected at admission), `expired` (deadline lapsed
/// before its batch fired), or `failed` (lost to a quarantine) — so
/// after a drain `accepted == served + shed + expired + failed` holds
/// per net, per shard, and engine-wide (property-tested in
/// `rust/tests/prop_substrate.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetLedger {
    pub accepted: u64,
    pub served: u64,
    pub shed: u64,
    /// Requests whose deadline lapsed before their batch fired (shed at
    /// fire time, pre-decode).
    pub expired: u64,
    /// Requests failed with a structured error by a quarantine.
    pub failed: u64,
}

/// Per-shard serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Validated submissions offered to this shard (admitted + shed).
    pub accepted: u64,
    pub served: u64,
    /// Submissions rejected at admission (queue depth at budget).
    pub shed: u64,
    /// Requests whose deadline lapsed before their batch fired — shed
    /// at fire time, before any decode work was spent on them.
    pub expired: u64,
    /// Requests failed with a structured error: their dispatch
    /// panicked (shard quarantine) or their net failed integrity
    /// verification (net quarantine).
    pub failed: u64,
    /// Backpressure events: a front-end held a request back because the
    /// shard would have shed it (see `Engine::note_deferral`).
    pub deferred: u64,
    /// Deepest queue backlog this shard ever held.
    pub peak_depth: usize,
    pub batches: u64,
    pub padded_rows: u64,
    /// Rows decoded fresh (cache misses or cache off).
    pub rows_decoded: u64,
    /// Rows served out of the decode cache.
    pub rows_from_cache: u64,
    /// Per-net conservation ledgers (accepted / served / shed).
    pub by_net: BTreeMap<String, NetLedger>,
    /// Virtual-clock queue latency (ns) — bounded accounting.
    pub latency_ns: Summary,
    /// Per-net, per-stage codeword utilization over the full codebook,
    /// computed once at hosting time from the same chunked unpack that
    /// validates the streams (arXiv 2309.17361) — surfaced through the
    /// TCP `/stats` verb.
    pub utilization: BTreeMap<String, Vec<Utilization>>,
}

/// One dispatch shard.
pub struct Shard {
    pub id: usize,
    pub router: Router,
    /// Hosted nets plus their shard-local numeric ids (the `Copy` cache
    /// key component — no per-row name clones on the lookup path).
    nets: BTreeMap<String, (u32, HostedNet)>,
    pub cache: DecodeCache,
    /// Streaming-decode destination, reused across batches — the
    /// `infer_hard` input staging buffer of this shard.
    staging: Vec<f32>,
    pub stats: ShardStats,
    /// Observability slice: stage histograms, per-net obs counters, and
    /// the flight recorder — plain fields, merged only at snapshot time
    /// (`Engine::metrics_snapshot`).
    pub obs: ShardObs,
    /// True once a dispatch failure quarantined this shard: its queues
    /// were drained into `failed`, it refuses admissions and never
    /// fires, until `Engine::revive_shard` clears the flag.
    quarantined: bool,
    /// Hosted nets whose packed streams failed integrity verification
    /// ([`Shard::verify_hosted`] or an injected
    /// [`FaultSite::CorruptWindow`]): quarantined individually — they
    /// refuse admissions and never serve a row — without taking the
    /// shard's healthy nets down with them.
    quarantined_nets: BTreeSet<String>,
    /// Hosting-time FNV-1a checksums of every net's packed streams (one
    /// per residual stage, `StagedCodes::checksums`) — the reference
    /// [`Shard::verify_hosted`] re-verifies against on demand.
    code_sums: BTreeMap<String, Vec<u64>>,
    /// Armed fault schedule (`None` = no faults).  Only consulted when
    /// the `fault-inject` feature is compiled in — without it every
    /// probe is a constant `false` (gated by the `faults_overhead`
    /// bench row).
    pub faults: Option<FaultPlan>,
    /// Virtual-clock stall accumulated by injected
    /// [`FaultSite::SlowOp`] firings; the engine drains it with
    /// [`Shard::take_stall_ns`] after each dispatch and advances its
    /// clock, so slow-op faults surface as real queue latency.
    stall_ns: u64,
}

impl Shard {
    pub fn new(
        id: usize,
        nets: Vec<HostedNet>,
        cache_bytes: usize,
        obs: ObsConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!nets.is_empty(), "shard {id} hosts no networks");
        let mut utilization: BTreeMap<String, Vec<Utilization>> = BTreeMap::new();
        let mut code_sums: BTreeMap<String, Vec<u64>> = BTreeMap::new();
        for n in &nets {
            anyhow::ensure!(n.codes_per_row > 0, "{:?}: codes_per_row must be positive", n.name);
            anyhow::ensure!(n.device_batch > 0, "{:?}: device_batch must be positive", n.name);
            anyhow::ensure!(
                n.stream_rows() > 0,
                "{:?}: staged streams of {} codes hold no rows of {}",
                n.name,
                n.codes.count(),
                n.codes_per_row
            );
            // One-time hosting validation, every stage: each packed code
            // must address a real codeword, whatever the pack width —
            // decode would panic mid-serve otherwise.  Chunked so
            // hosting a large stream needs no O(count) allocation; rides
            // the word-level unpack_range, so hosting big streams stays
            // cheap.  The same pass histograms the codes into the per-
            // stage utilization summary the `/stats` verb reports.
            let mut net_util = Vec::with_capacity(n.codes.stages());
            for (stage, p) in n.codes.stage_streams().iter().enumerate() {
                let mut counts = vec![0u64; n.codebook.k];
                let mut buf = [0u32; 512];
                let mut s = 0;
                while s < p.count {
                    let e = (s + buf.len()).min(p.count);
                    let chunk = &mut buf[..e - s];
                    unpack_range(p, s, e, chunk);
                    for &c in chunk.iter() {
                        anyhow::ensure!(
                            (c as usize) < n.codebook.k,
                            "{:?}: stage {stage} packed code {c} cannot address the k={} codebook",
                            n.name,
                            n.codebook.k
                        );
                        counts[c as usize] += 1;
                    }
                    s = e;
                }
                net_util.push(Utilization::from_counts(&counts));
            }
            utilization.insert(n.name.clone(), net_util);
            // Hosting-time integrity reference: the per-stage stream
            // checksums `verify_hosted` re-verifies against on demand.
            code_sums.insert(n.name.clone(), n.codes.checksums());
        }
        let names: Vec<&str> = nets.iter().map(|n| n.name.as_str()).collect();
        let router = Router::new(&names);
        // Ids follow hosting order — deterministic, never thread-derived.
        let map: BTreeMap<String, (u32, HostedNet)> = nets
            .into_iter()
            .enumerate()
            .map(|(i, n)| (n.name.clone(), (i as u32, n)))
            .collect();
        Ok(Shard {
            id,
            router,
            nets: map,
            cache: DecodeCache::new(cache_bytes),
            staging: Vec::new(),
            stats: ShardStats {
                utilization,
                ..ShardStats::default()
            },
            obs: ShardObs::new(obs),
            quarantined: false,
            quarantined_nets: BTreeSet::new(),
            code_sums,
            faults: None,
            stall_ns: 0,
        })
    }

    pub fn hosts(&self, net: &str) -> bool {
        self.nets.contains_key(net)
    }

    pub fn net(&self, net: &str) -> Option<&HostedNet> {
        self.nets.get(net).map(|(_, n)| n)
    }

    /// The shard-local numeric id of a hosted net (the cache-key
    /// component).
    pub fn net_id(&self, net: &str) -> Option<u32> {
        self.nets.get(net).map(|&(id, _)| id)
    }

    /// Hosted networks in deterministic (name) order.
    pub fn net_names(&self) -> impl Iterator<Item = &str> {
        self.nets.keys().map(|s| s.as_str())
    }

    /// Whether a dispatch failure quarantined this shard.
    pub fn is_quarantined(&self) -> bool {
        self.quarantined
    }

    /// Whether `net` individually failed integrity verification.
    pub fn net_quarantined(&self, net: &str) -> bool {
        self.quarantined_nets.contains(net)
    }

    /// Hosting-time per-stage checksums of a hosted net's packed
    /// streams (None if unknown).
    pub fn hosted_checksums(&self, net: &str) -> Option<&[u64]> {
        self.code_sums.get(net).map(|v| v.as_slice())
    }

    /// Clear the shard-level quarantine flag (`Engine::revive_shard`).
    /// Nets quarantined for integrity failures stay quarantined — their
    /// streams are still corrupt; only re-hosting fixes that.
    pub fn revive(&mut self) {
        self.quarantined = false;
    }

    /// Drain the virtual-clock stall accumulated by injected slow-op
    /// faults since the last call; the engine advances its clock by the
    /// returned amount.
    pub fn take_stall_ns(&mut self) -> u64 {
        std::mem::take(&mut self.stall_ns)
    }

    /// Consult the armed fault plan at `site`.  Compiled to a constant
    /// `false` without the `fault-inject` feature, so the default build
    /// never touches the plan on the hot path.
    #[cfg(feature = "fault-inject")]
    fn probe(&mut self, site: FaultSite) -> bool {
        match self.faults.as_mut() {
            Some(p) => p.should_fire(site),
            None => false,
        }
    }

    #[cfg(not(feature = "fault-inject"))]
    fn probe(&mut self, _site: FaultSite) -> bool {
        false
    }

    /// Record an injected firing on the flight recorder (`a` = site
    /// discriminant, `b` = cumulative firings at that site).
    fn note_fault(&mut self, site: FaultSite, net: &str, now_ns: u64) {
        let fired = self.faults.as_ref().map(|p| p.fired(site)).unwrap_or(0);
        self.obs.touch(now_ns);
        self.obs
            .note_event(EventKind::FaultInjected, net, site.index() as u64, fired);
    }

    /// Admission control: offer a (validated) request to this shard at
    /// `now_ns` under a queue-depth budget (`0` = unbounded).  Every
    /// offer counts as `accepted`; a full queue sheds the request (typed
    /// [`Admission::Rejected`], never enqueued — so no batch, and no
    /// padded row, can ever carry it to a decode or `infer_hard` run),
    /// otherwise it is enqueued under a fresh shard-local id.
    /// `deadline_ns` (0 = none) rides the queued request and is enforced
    /// at fire time: a lapsed request is ledgered `expired` and shed
    /// before decode.  The caller (`Engine::try_submit`) rejects
    /// submissions to quarantined shards/nets *before* this — those are
    /// errors, never accepted, so conservation is untouched.
    pub fn admit(
        &mut self,
        net: &str,
        row: usize,
        now_ns: u64,
        deadline_ns: u64,
        max_queue_depth: usize,
    ) -> Admission {
        let depth = self.router.total_pending();
        let shed = max_queue_depth > 0 && depth >= max_queue_depth;
        self.obs.touch(now_ns);
        let st = &mut self.stats;
        st.accepted += 1;
        let ledger = st.by_net.entry(net.to_string()).or_default();
        ledger.accepted += 1;
        if shed {
            ledger.shed += 1;
            st.shed += 1;
            self.obs.note_event(EventKind::Shed, net, row as u64, depth as u64);
            return Admission::Rejected {
                shard: self.id,
                depth,
            };
        }
        st.peak_depth = st.peak_depth.max(depth + 1);
        let id = self
            .router
            .submit_with_deadline(net, row, now_ns, deadline_ns)
            .expect("admit called for a net this shard hosts");
        Admission::Accepted { id }
    }

    /// Fire-selection: if any hosted queue should fire under `cfg` at
    /// `now_ns`, drain at most one device batch, form it, and record the
    /// serve-side counters (served / batches / padding / ledger /
    /// latency).  The decode and inference belong to the caller:
    /// [`Shard::dispatch_one`] (the standalone plane) streams the batch
    /// through this shard's cache, the front-ends stream it and then run
    /// the `infer_hard` artifact — one shared fire path either way.
    pub fn next_batch(&mut self, cfg: &BatcherConfig, now_ns: u64) -> Option<Batch> {
        // A quarantined shard never fires — and never serves a row —
        // until `Engine::revive_shard` clears it.
        if self.quarantined {
            return None;
        }
        if self.probe(FaultSite::ShardWedge) {
            // Transient stall: refuse to fire this round.
            self.note_fault(FaultSite::ShardWedge, "", now_ns);
            return None;
        }
        loop {
            let name = self.router.next_fireable(cfg, now_ns)?.to_string();
            // Deadline check at fire time: lapsed requests are ledgered
            // `expired` and shed *before* any decode work is spent on
            // them — they never occupy a batch slot.
            let lapsed = self.router.expire_net(&name, now_ns);
            if !lapsed.is_empty() {
                self.obs.touch(now_ns);
                let st = &mut self.stats;
                st.expired += lapsed.len() as u64;
                st.by_net.entry(name.clone()).or_default().expired += lapsed.len() as u64;
                for r in &lapsed {
                    self.obs
                        .note_event(EventKind::DeadlineExpired, &name, r.row as u64, r.deadline_ns);
                }
                if self.router.depth(&name) == 0 {
                    // Expiry emptied the selected queue — rescan.
                    continue;
                }
            }
            if self.probe(FaultSite::SlowOp) {
                // The fire still happens — slowly.  The stall surfaces on
                // the engine clock (`take_stall_ns`) as real latency.
                self.stall_ns += self.faults.as_ref().map(|p| p.slow_ns).unwrap_or(0);
                self.note_fault(FaultSite::SlowOp, &name, now_ns);
            }
            let device_batch = self
                .nets
                .get(&name)
                .expect("router queue without hosted net")
                .1
                .device_batch;
            // Never drain more than one device batch can carry —
            // leftovers stay queued instead of being dropped.
            let reqs = self.router.drain_net(&name, cfg.max_batch.min(device_batch));
            let batch = Batch::form(&name, reqs, device_batch);
            self.obs.touch(now_ns);
            let st = &mut self.stats;
            st.served += batch.requests.len() as u64;
            st.batches += 1;
            st.padded_rows += batch.padded as u64;
            st.by_net.entry(name).or_default().served += batch.requests.len() as u64;
            for r in &batch.requests {
                // One admit→fire span sample per dispatched request, on
                // the engine clock — so `queue_ns.count() == dispatched`
                // is part of the snapshot reconciliation contract in
                // fault-free operation (a failed batch keeps its spans;
                // see `Shard::fail_batch`).
                let wait = now_ns.saturating_sub(r.arrived_ns);
                st.latency_ns.push(wait as f64);
                self.obs.note_queue_wait(&batch.net, wait);
            }
            return Some(batch);
        }
    }

    /// Cache-aware streaming decode of `rows` of `net` into `dst`
    /// (`dst.len() == rows.len() * row_stride`).  This is the raw decode
    /// plane (caller-provided buffer); batch-serving callers use
    /// [`Shard::stream_batch`].
    pub fn decode_rows_into(
        &mut self,
        net: &str,
        rows: &[usize],
        dst: &mut [f32],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<RowServe> {
        self.ensure_serving(net)?;
        let (net_id, n) = self
            .nets
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("shard {}: unknown network {net:?}", self.id))?;
        serve_rows_into(n, *net_id, &mut self.cache, rows, dst, pool)
    }

    /// The never-serves-a-row guard every decode entry point shares: a
    /// quarantined shard or net refuses with a structured error instead
    /// of serving (possibly corrupt) rows.
    fn ensure_serving(&self, net: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.quarantined,
            "shard {}: quarantined after a dispatch failure (Engine::revive_shard restores it)",
            self.id
        );
        anyhow::ensure!(
            !self.quarantined_nets.contains(net),
            "shard {}: {net:?} is quarantined after a code-stream integrity failure",
            self.id
        );
        Ok(())
    }

    /// Cache-aware streaming decode of a dispatched batch's weight rows
    /// into this shard's own staging buffer, mapping caller rows onto
    /// the packed stream cyclically (safe for geometries where the
    /// request-row space exceeds the stream).  The one decode call the
    /// dispatch path makes per batch — standalone plane and front-ends
    /// alike — so the per-shard row counters are maintained here.
    pub fn stream_batch(
        &mut self,
        net: &str,
        rows: &[usize],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<RowServe> {
        self.ensure_serving(net)?;
        if self.probe(FaultSite::CorruptWindow) {
            // An injected integrity failure: exactly what a real
            // checksum mismatch does — quarantine the net (hosting-error
            // event) instead of serving garbage, and fail the batch.
            self.note_fault(FaultSite::CorruptWindow, net, 0);
            self.quarantine_net(net, 0, 0);
            anyhow::bail!(
                "shard {}: {net:?} code stream failed integrity verification",
                self.id
            );
        }
        if self.probe(FaultSite::DecodePanic) {
            self.note_fault(FaultSite::DecodePanic, net, 0);
            // The fire decision is taken here, *before* the parallel
            // section, so serial and pooled runs fail identically.  With
            // a real pool the panic rides a worker to exercise the
            // ThreadPool recovery path end to end.
            if let Some(tp) = pool {
                if tp.threads() > 1 {
                    let r = tp.parallel_for(1, 1, |_, _| panic!("injected decode panic"));
                    debug_assert!(r.is_err(), "pool must surface the injected panic");
                }
            }
            anyhow::bail!("shard {}: decode worker panicked serving {net:?}", self.id);
        }
        let (net_id, n) = self
            .nets
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("shard {}: unknown network {net:?}", self.id))?;
        let srows = n.stream_rows();
        let mapped: Vec<usize> = rows.iter().map(|r| r % srows).collect();
        let stride = n.row_stride();
        self.staging.resize(mapped.len() * stride, 0.0);
        let evictions_before = self.cache.stats.evictions;
        let serve = serve_rows_into(n, *net_id, &mut self.cache, &mapped, &mut self.staging, pool)?;
        self.stats.rows_from_cache += serve.hits as u64;
        self.stats.rows_decoded += serve.misses as u64;
        if self.obs.enabled() {
            let row_bytes = super::stream::row_window_bytes(&n.codes, n.codes_per_row) as u64;
            let evicted = self.cache.stats.evictions - evictions_before;
            let cache_bytes = self.cache.bytes() as u64;
            self.obs
                .note_batch_rows(net, serve.hits as u64, serve.misses as u64, serve.misses as u64 * row_bytes);
            if evicted > 0 {
                self.obs.note_event(EventKind::Eviction, net, evicted, cache_bytes);
            }
        }
        Ok(serve)
    }

    /// Fire at most one batch if any hosted queue should; returns the
    /// number of real requests served (0 if nothing fired).  The decode
    /// streams through the cache into the shard's staging buffer.
    ///
    /// Failure handling: a batch whose decode fails is moved from
    /// `served` to `failed` ([`Shard::fail_batch`]), and — unless the
    /// failure was a per-net integrity quarantine — the whole shard is
    /// quarantined ([`Shard::quarantine`]): its remaining queued
    /// requests are failed with a structured error and counted, so the
    /// conservation identity
    /// `accepted == dispatched + shed + expired + failed` closes even
    /// through the failure.
    pub fn dispatch_one(
        &mut self,
        cfg: &BatcherConfig,
        now_ns: u64,
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<usize> {
        let Some(batch) = self.next_batch(cfg, now_ns) else {
            return Ok(0);
        };
        // Submitted rows were validated < stream_rows, so the cyclic
        // mapping inside stream_batch is the identity here.
        match self.stream_batch(&batch.net, &batch.rows, pool) {
            Ok(_) => Ok(batch.requests.len()),
            Err(err) => {
                let in_flight = self.fail_batch(&batch, now_ns);
                if self.net_quarantined(&batch.net) {
                    // Integrity failure: only the net is down (the
                    // HostingError event was already recorded); the
                    // shard keeps serving its healthy nets.
                    return Err(err);
                }
                let drained = self.quarantine(now_ns);
                self.obs.note_event(
                    EventKind::Quarantined,
                    &batch.net,
                    self.id as u64,
                    in_flight + drained,
                );
                Err(err)
            }
        }
    }

    /// A dispatched batch failed before serving: roll its requests from
    /// `served` into `failed` (and the router's dispatched counter back)
    /// with one `RequestFailed` event each.  Returns how many.  Their
    /// fire-time latency spans are retained — `queue_ns.count() ==
    /// dispatched + failed-in-flight` under faults.
    pub fn fail_batch(&mut self, batch: &Batch, now_ns: u64) -> u64 {
        let n = batch.requests.len() as u64;
        self.obs.touch(now_ns);
        let st = &mut self.stats;
        st.served = st.served.saturating_sub(n);
        st.failed += n;
        let ledger = st.by_net.entry(batch.net.clone()).or_default();
        ledger.served = ledger.served.saturating_sub(n);
        ledger.failed += n;
        self.router.undispatch(n);
        for r in &batch.requests {
            self.obs
                .note_event(EventKind::RequestFailed, &batch.net, r.row as u64, self.id as u64);
        }
        n
    }

    /// Enter quarantine: stop admitting and firing, and fail every
    /// queued request with a structured error (counted per net, one
    /// `RequestFailed` event each).  Returns how many were failed.
    /// Idempotent; [`Shard::revive`] / `Engine::revive_shard` restores
    /// service.
    pub fn quarantine(&mut self, now_ns: u64) -> u64 {
        if self.quarantined {
            return 0;
        }
        self.quarantined = true;
        self.obs.touch(now_ns);
        let dropped = self.router.take_all();
        for r in &dropped {
            let st = &mut self.stats;
            st.failed += 1;
            st.by_net.entry(r.net.clone()).or_default().failed += 1;
            self.obs
                .note_event(EventKind::RequestFailed, &r.net, r.row as u64, self.id as u64);
        }
        dropped.len() as u64
    }

    /// Quarantine one net after a code-stream integrity failure: fail
    /// its queued requests (counted, one `RequestFailed` event each) and
    /// record a `HostingError` event (`a` = first mismatching stage,
    /// `b` = requests failed).  The shard's other nets keep serving.
    /// Idempotent per net; returns how many requests were failed.
    pub fn quarantine_net(&mut self, net: &str, now_ns: u64, stage: u64) -> u64 {
        if !self.quarantined_nets.insert(net.to_string()) {
            return 0;
        }
        self.obs.touch(now_ns);
        let dropped = self.router.take_net(net);
        for r in &dropped {
            let st = &mut self.stats;
            st.failed += 1;
            st.by_net.entry(net.to_string()).or_default().failed += 1;
            self.obs
                .note_event(EventKind::RequestFailed, net, r.row as u64, self.id as u64);
        }
        self.obs
            .note_event(EventKind::HostingError, net, stage, dropped.len() as u64);
        dropped.len() as u64
    }

    /// Re-verify every hosted net's packed streams against the
    /// hosting-time checksums.  A mismatching net is quarantined (its
    /// queued requests failed, `HostingError` event) and the call
    /// errors naming every bad net — corrupted packed bytes are always
    /// caught here or at hosting, never served.
    pub fn verify_hosted(&mut self, now_ns: u64) -> anyhow::Result<()> {
        let names: Vec<String> = self.nets.keys().cloned().collect();
        let mut bad: Vec<String> = Vec::new();
        for name in names {
            if self.quarantined_nets.contains(&name) {
                continue;
            }
            let expected = self.code_sums.get(&name).cloned().unwrap_or_default();
            let verdict = self.nets[&name].1.codes.verify_checksums(&expected);
            if let Err(e) = verdict {
                let got = self.nets[&name].1.codes.checksums();
                let stage = got
                    .iter()
                    .zip(&expected)
                    .position(|(g, w)| g != w)
                    .unwrap_or(0);
                self.quarantine_net(&name, now_ns, stage as u64);
                bad.push(format!("{name:?}: {e}"));
            }
        }
        anyhow::ensure!(
            bad.is_empty(),
            "shard {}: integrity failure: {}",
            self.id,
            bad.join("; ")
        );
        Ok(())
    }

    /// Chaos hook (`fault-inject` builds only): flip one bit of a
    /// hosted net's packed stage bytes so [`Shard::verify_hosted`] has
    /// real corruption to catch.  Returns false for unknown
    /// nets/stages/offsets.
    #[cfg(feature = "fault-inject")]
    pub fn corrupt_net_byte(&mut self, net: &str, stage: usize, byte: usize) -> bool {
        match self.nets.get_mut(net) {
            Some((_, n)) if stage < n.codes.stages() => {
                let p = n.codes.stage_mut(stage);
                if byte < p.data.len() {
                    p.data[byte] ^= 1;
                    true
                } else {
                    false
                }
            }
            _ => false,
        }
    }
}

/// The cache-aware serve kernel: hits copy the cached block into `dst`,
/// misses decode fresh (pooled over the miss list, disjoint windows) and
/// then populate the cache **in row order** — so serial and pooled runs
/// leave bit-identical cache state and output.
fn serve_rows_into(
    net: &HostedNet,
    net_id: u32,
    cache: &mut DecodeCache,
    rows: &[usize],
    dst: &mut [f32],
    pool: Option<&ThreadPool>,
) -> anyhow::Result<RowServe> {
    let stride = net.row_stride();
    anyhow::ensure!(
        dst.len() == rows.len() * stride,
        "serve_rows_into: dst holds {} f32s, {} rows of stride {stride} need {}",
        dst.len(),
        rows.len(),
        rows.len() * stride
    );
    let stream_rows = net.stream_rows();
    for &row in rows {
        anyhow::ensure!(
            row < stream_rows,
            "row {row} out of range: {:?} holds {stream_rows} rows",
            net.name
        );
    }
    let cpr = net.codes_per_row;
    let window = |row: usize| RowWindow {
        net: net_id,
        start: row * cpr,
        end: (row + 1) * cpr,
    };

    // Phase 1 — cache lookups in row order; hits stream straight to dst.
    let mut misses: Vec<usize> = Vec::new();
    for (i, &row) in rows.iter().enumerate() {
        match cache.get(&window(row)) {
            Some(block) => dst[i * stride..(i + 1) * stride].copy_from_slice(block),
            None => misses.push(i),
        }
    }

    // Phase 2 — decode each distinct missed window once (pooled over
    // disjoint dst windows).  Duplicate rows — `Batch::form` padding
    // clones real rows — are back-filled from their first occurrence
    // with a memcpy instead of re-decoding the same window.
    let mut first_pos: BTreeMap<usize, usize> = BTreeMap::new();
    let mut primary: Vec<usize> = Vec::new();
    let mut dups: Vec<(usize, usize)> = Vec::new(); // (dst pos, src pos)
    for &i in &misses {
        match first_pos.get(&rows[i]) {
            Some(&src) => dups.push((i, src)),
            None => {
                first_pos.insert(rows[i], i);
                primary.push(i);
            }
        }
    }
    let kernel = |i: usize, out: &mut [f32]| {
        let row = rows[i];
        net.codebook
            .decode_staged_packed_into(&net.codes, row * cpr, (row + 1) * cpr, out);
    };
    match pool {
        Some(tp) if tp.threads() > 1 && primary.len() > 1 => {
            let ptr = SyncPtr::new(dst);
            // A panicking decode worker is a *failure*, not an abort:
            // the pool recovers (util::threadpool) and the error
            // propagates so the dispatch path can quarantine the shard.
            tp.parallel_for(primary.len(), 1, |start, end| {
                for m in start..end {
                    let i = primary[m];
                    // SAFETY: primary positions are distinct rows, so
                    // their dst windows are disjoint.
                    let out = unsafe { ptr.slice(i * stride, stride) };
                    kernel(i, out);
                }
            })
            .map_err(|e| {
                anyhow::anyhow!("shard decode pool failed serving {:?}: {e}", net.name)
            })?;
        }
        _ => {
            for &i in &primary {
                kernel(i, &mut dst[i * stride..(i + 1) * stride]);
            }
        }
    }
    for &(i, src) in &dups {
        dst.copy_within(src * stride..(src + 1) * stride, i * stride);
    }

    // Phase 3 — populate the cache in row order (deterministic LRU; one
    // insert per distinct window — duplicates carry identical bits).
    for &i in &primary {
        cache.insert(window(rows[i]), &dst[i * stride..(i + 1) * stride]);
    }
    Ok(RowServe {
        hits: rows.len() - misses.len(),
        misses: misses.len(),
    })
}
