//! One engine shard: a disjoint subset of the hosted networks with its
//! own router queue set, decode cache, and reusable streaming-decode
//! staging buffer.  Shards share no mutable state, so the engine can fan
//! them across the worker pool — and because each shard's behavior
//! depends only on its own queues and the virtual clock, results and
//! cache state are bit-identical at every thread count.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::serving::batcher::{Batch, BatcherConfig};
use crate::serving::obs::{EventKind, ObsConfig, ShardObs};
use crate::util::stats::Summary;
use crate::util::threadpool::{SyncPtr, ThreadPool};
use crate::vq::assign::Utilization;
use crate::vq::codebook::Codebook;
use crate::vq::pack::{unpack_range, StagedCodes};

use super::cache::{DecodeCache, RowWindow};
use super::router::Router;
use super::Admission;

/// One network hosted on the decode plane: its staged assignment
/// streams (one packed stream per residual stage — `stages == 1` is the
/// legacy single-stream format), the shared (ROM-resident) universal
/// codebook, and the row geometry — row `r` covers codes
/// `[r * codes_per_row, (r + 1) * codes_per_row)` of every stage.
#[derive(Clone, Debug)]
pub struct HostedNet {
    pub name: String,
    pub codes: StagedCodes,
    /// Shared universal codebook (one `Arc` across every hosted net and
    /// every residual stage — the §3.2 premise).
    pub codebook: Arc<Codebook>,
    pub codes_per_row: usize,
    /// Fixed device batch its `infer_hard` artifact was lowered at.
    pub device_batch: usize,
}

impl HostedNet {
    /// Rows the staged streams hold at this geometry.
    pub fn stream_rows(&self) -> usize {
        self.codes.count() / self.codes_per_row
    }

    /// Decoded f32s per row.
    pub fn row_stride(&self) -> usize {
        self.codes_per_row * self.codebook.d
    }
}

/// Cache-aware row serve accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RowServe {
    /// Rows copied straight out of the decode cache.
    pub hits: usize,
    /// Rows decoded fresh from the packed stream.
    pub misses: usize,
}

/// Per-net conservation ledger: every validated submission lands in
/// `accepted`, and then in exactly one of `served` (dispatched through a
/// batch) or `shed` (rejected at admission) — so after a drain
/// `accepted == served + shed` holds per net, per shard, and engine-wide
/// (property-tested in `rust/tests/prop_substrate.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetLedger {
    pub accepted: u64,
    pub served: u64,
    pub shed: u64,
}

/// Per-shard serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Validated submissions offered to this shard (admitted + shed).
    pub accepted: u64,
    pub served: u64,
    /// Submissions rejected at admission (queue depth at budget).
    pub shed: u64,
    /// Backpressure events: a front-end held a request back because the
    /// shard would have shed it (see `Engine::note_deferral`).
    pub deferred: u64,
    /// Deepest queue backlog this shard ever held.
    pub peak_depth: usize,
    pub batches: u64,
    pub padded_rows: u64,
    /// Rows decoded fresh (cache misses or cache off).
    pub rows_decoded: u64,
    /// Rows served out of the decode cache.
    pub rows_from_cache: u64,
    /// Per-net conservation ledgers (accepted / served / shed).
    pub by_net: BTreeMap<String, NetLedger>,
    /// Virtual-clock queue latency (ns) — bounded accounting.
    pub latency_ns: Summary,
    /// Per-net, per-stage codeword utilization over the full codebook,
    /// computed once at hosting time from the same chunked unpack that
    /// validates the streams (arXiv 2309.17361) — surfaced through the
    /// TCP `/stats` verb.
    pub utilization: BTreeMap<String, Vec<Utilization>>,
}

/// One dispatch shard.
pub struct Shard {
    pub id: usize,
    pub router: Router,
    /// Hosted nets plus their shard-local numeric ids (the `Copy` cache
    /// key component — no per-row name clones on the lookup path).
    nets: BTreeMap<String, (u32, HostedNet)>,
    pub cache: DecodeCache,
    /// Streaming-decode destination, reused across batches — the
    /// `infer_hard` input staging buffer of this shard.
    staging: Vec<f32>,
    pub stats: ShardStats,
    /// Observability slice: stage histograms, per-net obs counters, and
    /// the flight recorder — plain fields, merged only at snapshot time
    /// (`Engine::metrics_snapshot`).
    pub obs: ShardObs,
}

impl Shard {
    pub fn new(
        id: usize,
        nets: Vec<HostedNet>,
        cache_bytes: usize,
        obs: ObsConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!nets.is_empty(), "shard {id} hosts no networks");
        let mut utilization: BTreeMap<String, Vec<Utilization>> = BTreeMap::new();
        for n in &nets {
            anyhow::ensure!(n.codes_per_row > 0, "{:?}: codes_per_row must be positive", n.name);
            anyhow::ensure!(n.device_batch > 0, "{:?}: device_batch must be positive", n.name);
            anyhow::ensure!(
                n.stream_rows() > 0,
                "{:?}: staged streams of {} codes hold no rows of {}",
                n.name,
                n.codes.count(),
                n.codes_per_row
            );
            // One-time hosting validation, every stage: each packed code
            // must address a real codeword, whatever the pack width —
            // decode would panic mid-serve otherwise.  Chunked so
            // hosting a large stream needs no O(count) allocation; rides
            // the word-level unpack_range, so hosting big streams stays
            // cheap.  The same pass histograms the codes into the per-
            // stage utilization summary the `/stats` verb reports.
            let mut net_util = Vec::with_capacity(n.codes.stages());
            for (stage, p) in n.codes.stage_streams().iter().enumerate() {
                let mut counts = vec![0u64; n.codebook.k];
                let mut buf = [0u32; 512];
                let mut s = 0;
                while s < p.count {
                    let e = (s + buf.len()).min(p.count);
                    let chunk = &mut buf[..e - s];
                    unpack_range(p, s, e, chunk);
                    for &c in chunk.iter() {
                        anyhow::ensure!(
                            (c as usize) < n.codebook.k,
                            "{:?}: stage {stage} packed code {c} cannot address the k={} codebook",
                            n.name,
                            n.codebook.k
                        );
                        counts[c as usize] += 1;
                    }
                    s = e;
                }
                net_util.push(Utilization::from_counts(&counts));
            }
            utilization.insert(n.name.clone(), net_util);
        }
        let names: Vec<&str> = nets.iter().map(|n| n.name.as_str()).collect();
        let router = Router::new(&names);
        // Ids follow hosting order — deterministic, never thread-derived.
        let map: BTreeMap<String, (u32, HostedNet)> = nets
            .into_iter()
            .enumerate()
            .map(|(i, n)| (n.name.clone(), (i as u32, n)))
            .collect();
        Ok(Shard {
            id,
            router,
            nets: map,
            cache: DecodeCache::new(cache_bytes),
            staging: Vec::new(),
            stats: ShardStats {
                utilization,
                ..ShardStats::default()
            },
            obs: ShardObs::new(obs),
        })
    }

    pub fn hosts(&self, net: &str) -> bool {
        self.nets.contains_key(net)
    }

    pub fn net(&self, net: &str) -> Option<&HostedNet> {
        self.nets.get(net).map(|(_, n)| n)
    }

    /// The shard-local numeric id of a hosted net (the cache-key
    /// component).
    pub fn net_id(&self, net: &str) -> Option<u32> {
        self.nets.get(net).map(|&(id, _)| id)
    }

    /// Hosted networks in deterministic (name) order.
    pub fn net_names(&self) -> impl Iterator<Item = &str> {
        self.nets.keys().map(|s| s.as_str())
    }

    /// Admission control: offer a (validated) request to this shard at
    /// `now_ns` under a queue-depth budget (`0` = unbounded).  Every
    /// offer counts as `accepted`; a full queue sheds the request (typed
    /// [`Admission::Rejected`], never enqueued — so no batch, and no
    /// padded row, can ever carry it to a decode or `infer_hard` run),
    /// otherwise it is enqueued under a fresh shard-local id.
    pub fn admit(
        &mut self,
        net: &str,
        row: usize,
        now_ns: u64,
        max_queue_depth: usize,
    ) -> Admission {
        let depth = self.router.total_pending();
        let shed = max_queue_depth > 0 && depth >= max_queue_depth;
        self.obs.touch(now_ns);
        let st = &mut self.stats;
        st.accepted += 1;
        let ledger = st.by_net.entry(net.to_string()).or_default();
        ledger.accepted += 1;
        if shed {
            ledger.shed += 1;
            st.shed += 1;
            self.obs.note_event(EventKind::Shed, net, row as u64, depth as u64);
            return Admission::Rejected {
                shard: self.id,
                depth,
            };
        }
        st.peak_depth = st.peak_depth.max(depth + 1);
        let id = self
            .router
            .submit(net, row, now_ns)
            .expect("admit called for a net this shard hosts");
        Admission::Accepted { id }
    }

    /// Fire-selection: if any hosted queue should fire under `cfg` at
    /// `now_ns`, drain at most one device batch, form it, and record the
    /// serve-side counters (served / batches / padding / ledger /
    /// latency).  The decode and inference belong to the caller:
    /// [`Shard::dispatch_one`] (the standalone plane) streams the batch
    /// through this shard's cache, the front-ends stream it and then run
    /// the `infer_hard` artifact — one shared fire path either way.
    pub fn next_batch(&mut self, cfg: &BatcherConfig, now_ns: u64) -> Option<Batch> {
        let name = self.router.next_fireable(cfg, now_ns)?.to_string();
        let device_batch = self
            .nets
            .get(&name)
            .expect("router queue without hosted net")
            .1
            .device_batch;
        // Never drain more than one device batch can carry — leftovers
        // stay queued instead of being dropped.
        let reqs = self.router.drain_net(&name, cfg.max_batch.min(device_batch));
        let batch = Batch::form(&name, reqs, device_batch);
        self.obs.touch(now_ns);
        let st = &mut self.stats;
        st.served += batch.requests.len() as u64;
        st.batches += 1;
        st.padded_rows += batch.padded as u64;
        st.by_net.entry(name).or_default().served += batch.requests.len() as u64;
        for r in &batch.requests {
            // One admit→fire span sample per dispatched request, on the
            // engine clock — so `queue_ns.count() == dispatched` is part
            // of the snapshot reconciliation contract.
            let wait = now_ns.saturating_sub(r.arrived_ns);
            st.latency_ns.push(wait as f64);
            self.obs.note_queue_wait(&batch.net, wait);
        }
        Some(batch)
    }

    /// Cache-aware streaming decode of `rows` of `net` into `dst`
    /// (`dst.len() == rows.len() * row_stride`).  This is the raw decode
    /// plane (caller-provided buffer); batch-serving callers use
    /// [`Shard::stream_batch`].
    pub fn decode_rows_into(
        &mut self,
        net: &str,
        rows: &[usize],
        dst: &mut [f32],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<RowServe> {
        let (net_id, n) = self
            .nets
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("shard {}: unknown network {net:?}", self.id))?;
        serve_rows_into(n, *net_id, &mut self.cache, rows, dst, pool)
    }

    /// Cache-aware streaming decode of a dispatched batch's weight rows
    /// into this shard's own staging buffer, mapping caller rows onto
    /// the packed stream cyclically (safe for geometries where the
    /// request-row space exceeds the stream).  The one decode call the
    /// dispatch path makes per batch — standalone plane and front-ends
    /// alike — so the per-shard row counters are maintained here.
    pub fn stream_batch(
        &mut self,
        net: &str,
        rows: &[usize],
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<RowServe> {
        let (net_id, n) = self
            .nets
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("shard {}: unknown network {net:?}", self.id))?;
        let srows = n.stream_rows();
        let mapped: Vec<usize> = rows.iter().map(|r| r % srows).collect();
        let stride = n.row_stride();
        self.staging.resize(mapped.len() * stride, 0.0);
        let evictions_before = self.cache.stats.evictions;
        let serve = serve_rows_into(n, *net_id, &mut self.cache, &mapped, &mut self.staging, pool)?;
        self.stats.rows_from_cache += serve.hits as u64;
        self.stats.rows_decoded += serve.misses as u64;
        if self.obs.enabled() {
            let row_bytes = super::stream::row_window_bytes(&n.codes, n.codes_per_row) as u64;
            let evicted = self.cache.stats.evictions - evictions_before;
            let cache_bytes = self.cache.bytes() as u64;
            self.obs
                .note_batch_rows(net, serve.hits as u64, serve.misses as u64, serve.misses as u64 * row_bytes);
            if evicted > 0 {
                self.obs.note_event(EventKind::Eviction, net, evicted, cache_bytes);
            }
        }
        Ok(serve)
    }

    /// Fire at most one batch if any hosted queue should; returns the
    /// number of real requests served (0 if nothing fired).  The decode
    /// streams through the cache into the shard's staging buffer.
    pub fn dispatch_one(
        &mut self,
        cfg: &BatcherConfig,
        now_ns: u64,
        pool: Option<&ThreadPool>,
    ) -> anyhow::Result<usize> {
        let Some(batch) = self.next_batch(cfg, now_ns) else {
            return Ok(0);
        };
        // Submitted rows were validated < stream_rows, so the cyclic
        // mapping inside stream_batch is the identity here.
        self.stream_batch(&batch.net, &batch.rows, pool)?;
        Ok(batch.requests.len())
    }
}

/// The cache-aware serve kernel: hits copy the cached block into `dst`,
/// misses decode fresh (pooled over the miss list, disjoint windows) and
/// then populate the cache **in row order** — so serial and pooled runs
/// leave bit-identical cache state and output.
fn serve_rows_into(
    net: &HostedNet,
    net_id: u32,
    cache: &mut DecodeCache,
    rows: &[usize],
    dst: &mut [f32],
    pool: Option<&ThreadPool>,
) -> anyhow::Result<RowServe> {
    let stride = net.row_stride();
    anyhow::ensure!(
        dst.len() == rows.len() * stride,
        "serve_rows_into: dst holds {} f32s, {} rows of stride {stride} need {}",
        dst.len(),
        rows.len(),
        rows.len() * stride
    );
    let stream_rows = net.stream_rows();
    for &row in rows {
        anyhow::ensure!(
            row < stream_rows,
            "row {row} out of range: {:?} holds {stream_rows} rows",
            net.name
        );
    }
    let cpr = net.codes_per_row;
    let window = |row: usize| RowWindow {
        net: net_id,
        start: row * cpr,
        end: (row + 1) * cpr,
    };

    // Phase 1 — cache lookups in row order; hits stream straight to dst.
    let mut misses: Vec<usize> = Vec::new();
    for (i, &row) in rows.iter().enumerate() {
        match cache.get(&window(row)) {
            Some(block) => dst[i * stride..(i + 1) * stride].copy_from_slice(block),
            None => misses.push(i),
        }
    }

    // Phase 2 — decode each distinct missed window once (pooled over
    // disjoint dst windows).  Duplicate rows — `Batch::form` padding
    // clones real rows — are back-filled from their first occurrence
    // with a memcpy instead of re-decoding the same window.
    let mut first_pos: BTreeMap<usize, usize> = BTreeMap::new();
    let mut primary: Vec<usize> = Vec::new();
    let mut dups: Vec<(usize, usize)> = Vec::new(); // (dst pos, src pos)
    for &i in &misses {
        match first_pos.get(&rows[i]) {
            Some(&src) => dups.push((i, src)),
            None => {
                first_pos.insert(rows[i], i);
                primary.push(i);
            }
        }
    }
    let kernel = |i: usize, out: &mut [f32]| {
        let row = rows[i];
        net.codebook
            .decode_staged_packed_into(&net.codes, row * cpr, (row + 1) * cpr, out);
    };
    match pool {
        Some(tp) if tp.threads() > 1 && primary.len() > 1 => {
            let ptr = SyncPtr::new(dst);
            tp.parallel_for(primary.len(), 1, |start, end| {
                for m in start..end {
                    let i = primary[m];
                    // SAFETY: primary positions are distinct rows, so
                    // their dst windows are disjoint.
                    let out = unsafe { ptr.slice(i * stride, stride) };
                    kernel(i, out);
                }
            })
            .expect("shard decode worker panicked");
        }
        _ => {
            for &i in &primary {
                kernel(i, &mut dst[i * stride..(i + 1) * stride]);
            }
        }
    }
    for &(i, src) in &dups {
        dst.copy_within(src * stride..(src + 1) * stride, i * stride);
    }

    // Phase 3 — populate the cache in row order (deterministic LRU; one
    // insert per distinct window — duplicates carry identical bits).
    for &i in &primary {
        cache.insert(window(rows[i]), &dst[i * stride..(i + 1) * stride]);
    }
    Ok(RowServe {
        hits: rows.len() - misses.len(),
        misses: misses.len(),
    })
}
