//! Streaming decode: unpack + codebook-decode a batch's packed weight
//! rows **directly into a caller-provided buffer** (the `infer_hard`
//! input staging area), eliminating the intermediate weights allocation
//! on the serving hot path.
//!
//! Row addressing and determinism contract are identical to
//! [`crate::serving::switchsim::decode_batch`], which now delegates here:
//! row `r` covers codes `[r * codes_per_row, (r + 1) * codes_per_row)`,
//! rows are independent (disjoint output windows over a shared read-only
//! stream), and every row runs through the fused
//! [`Codebook::decode_packed_into`] kernel — so serial and pooled runs
//! are bit-identical at every thread count.
//!
//! §Perf: `decode_packed_into` is the specialized kernel pair — the
//! word-level `vq::pack::unpack_range` (one `u64` window load per code)
//! fused with the small-`d` monomorphized gather — so every serving
//! decode, cache miss, and `stream_batch` call rides it; the hotpath
//! bench's `fused_decode` row and the engine summary's absolute
//! `rows_per_sec` / `codes_per_sec` keys track it.

use crate::serving::batcher::Batch;
use crate::util::threadpool::{SyncPtr, ThreadPool};
use crate::vq::codebook::Codebook;
use crate::vq::pack::PackedCodes;

/// Accounting for one streamed decode — [`crate::serving::switchsim::BatchDecode`]
/// minus the weights buffer, which lives with the caller.
#[derive(Clone, Copy, Debug)]
pub struct DecodeStats {
    /// Codes unpacked, padded rows included.
    pub codes_unpacked: usize,
    /// Packed bytes touched (per-row windows, rounded up to bytes).
    pub packed_bytes_read: usize,
    /// Real-request fraction of the decoded rows (`Batch::utilization`).
    pub utilization: f64,
}

/// Decode a formed batch's rows out of a packed assignment stream
/// straight into `dst` (`dst.len() == batch.rows.len() * codes_per_row *
/// cb.d`, row-major in `Batch::rows` order, padded rows included).
pub fn decode_into(
    batch: &Batch,
    packed: &PackedCodes,
    cb: &Codebook,
    codes_per_row: usize,
    dst: &mut [f32],
    pool: Option<&ThreadPool>,
) -> anyhow::Result<DecodeStats> {
    decode_rows_into(&batch.rows, packed, cb, codes_per_row, dst, pool)?;
    Ok(DecodeStats {
        codes_unpacked: batch.rows.len() * codes_per_row,
        packed_bytes_read: batch.rows.len() * (codes_per_row * packed.bits as usize).div_ceil(8),
        utilization: batch.utilization(),
    })
}

/// Row-list core of [`decode_into`] — also the cache-miss decode the
/// engine shards drive: stream `rows[i]`'s window into
/// `dst[i * stride .. (i + 1) * stride]`.
pub fn decode_rows_into(
    rows: &[usize],
    packed: &PackedCodes,
    cb: &Codebook,
    codes_per_row: usize,
    dst: &mut [f32],
    pool: Option<&ThreadPool>,
) -> anyhow::Result<()> {
    anyhow::ensure!(codes_per_row > 0, "codes_per_row must be positive");
    // `row < count / codes_per_row` is equivalent to
    // `(row + 1) * codes_per_row <= count` but cannot overflow — rows
    // arrive off the wire (serving::tcp), so huge values must error, not
    // wrap around and silently decode the wrong window.
    let stream_rows = packed.count / codes_per_row;
    for &row in rows {
        anyhow::ensure!(
            row < stream_rows,
            "row {row} out of range: the {}-code stream holds {stream_rows} rows of {codes_per_row}",
            packed.count
        );
    }
    let stride = codes_per_row * cb.d;
    anyhow::ensure!(
        dst.len() == rows.len() * stride,
        "decode_rows_into: dst holds {} f32s, {} rows of stride {stride} need {}",
        dst.len(),
        rows.len(),
        rows.len() * stride
    );

    let kernel = |i: usize, out: &mut [f32]| {
        let row = rows[i];
        cb.decode_packed_into(packed, row * codes_per_row, (row + 1) * codes_per_row, out);
    };

    match pool {
        Some(tp) if tp.threads() > 1 && rows.len() > 1 => {
            let ptr = SyncPtr::new(dst);
            tp.note_read(rows);
            tp.note_read(&packed.data);
            tp.note_read(&cb.words);
            tp.parallel_for(rows.len(), 1, |start, end| {
                for i in start..end {
                    // SAFETY: each row position owns a disjoint dst window.
                    let out = unsafe { ptr.slice(i * stride, stride) };
                    kernel(i, out);
                }
            })
            .expect("streaming decode worker panicked");
        }
        _ => {
            for i in 0..rows.len() {
                kernel(i, &mut dst[i * stride..(i + 1) * stride]);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::engine::router::Request;
    use crate::serving::switchsim::decode_batch;
    use crate::util::rng::Rng;
    use crate::vq::pack::pack_codes;

    fn req(id: u64, row: usize) -> Request {
        Request {
            id,
            net: "a".into(),
            row,
            arrived_ns: 0,
        }
    }

    #[test]
    fn streamed_decode_matches_allocating_decode_batch() {
        let mut rng = Rng::new(41);
        let mut words = vec![0.0f32; 32 * 4];
        rng.fill_normal(&mut words);
        let cb = Codebook::new(32, 4, words);
        let (device_rows, cpr) = (8usize, 23usize);
        let codes: Vec<u32> = (0..device_rows * cpr).map(|_| rng.below(32) as u32).collect();
        let packed = pack_codes(&codes, 5);
        let batch = Batch::form("a", vec![req(0, 5), req(1, 2), req(2, 5)], device_rows);

        let alloc = decode_batch(&batch, &packed, &cb, cpr, None).unwrap();
        let mut dst = vec![0.0f32; batch.rows.len() * cpr * cb.d];
        let s = decode_into(&batch, &packed, &cb, cpr, &mut dst, None).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dst), bits(&alloc.weights));
        assert_eq!(s.codes_unpacked, alloc.codes_unpacked);
        assert_eq!(s.packed_bytes_read, alloc.packed_bytes_read);
        assert!((s.utilization - alloc.utilization).abs() < 1e-12);
    }

    #[test]
    fn rejects_wrong_dst_size_and_oob_rows() {
        let cb = Codebook::new(2, 2, vec![0., 0., 1., 1.]);
        let packed = pack_codes(&[0u32, 1, 1, 0], 1); // 2 rows of 2 codes
        let mut small = vec![0.0f32; 3];
        assert!(decode_rows_into(&[0], &packed, &cb, 2, &mut small, None).is_err());
        let mut ok = vec![0.0f32; 4];
        assert!(decode_rows_into(&[2], &packed, &cb, 2, &mut ok, None).is_err());
        assert!(decode_rows_into(&[usize::MAX / 2], &packed, &cb, 2, &mut ok, None).is_err());
        assert!(decode_rows_into(&[1], &packed, &cb, 2, &mut ok, None).is_ok());
        assert_eq!(ok, vec![1., 1., 0., 0.]);
    }
}
