//! Streaming decode: unpack + codebook-decode a batch's packed weight
//! rows **directly into a caller-provided buffer** (the `infer_hard`
//! input staging area), eliminating the intermediate weights allocation
//! on the serving hot path.
//!
//! Row addressing and determinism contract are identical to
//! [`crate::serving::switchsim::decode_batch`], which now delegates here:
//! row `r` covers codes `[r * codes_per_row, (r + 1) * codes_per_row)`
//! of every residual stage, rows are independent (disjoint output
//! windows over shared read-only streams), and every row runs through
//! the fused staged kernel [`Codebook::decode_staged_packed_into`] — so
//! serial and pooled runs are bit-identical at every thread count and
//! stage count.
//!
//! §Perf: `decode_staged_packed_into` is the specialized kernel pair —
//! the word-level `vq::pack::unpack_range` (one `u64` window load per
//! code) fused with the small-`d` monomorphized gather, once per stage
//! (stage 0 writes, later stages accumulate) — so every serving decode,
//! cache miss, and `stream_batch` call rides it; the hotpath bench's
//! `fused_decode` / `staged_decode` rows and the engine summary's
//! absolute `rows_per_sec` / `codes_per_sec` keys track it.

use crate::serving::batcher::Batch;
use crate::util::threadpool::{SyncPtr, ThreadPool};
use crate::vq::codebook::Codebook;
use crate::vq::pack::StagedCodes;

/// Accounting for one streamed decode — [`crate::serving::switchsim::BatchDecode`]
/// minus the weights buffer, which lives with the caller.
#[derive(Clone, Copy, Debug)]
pub struct DecodeStats {
    /// Codes unpacked, padded rows and all residual stages included.
    pub codes_unpacked: usize,
    /// Packed bytes touched (per-row windows, rounded up to bytes,
    /// summed over stages).
    pub packed_bytes_read: usize,
    /// Real-request fraction of the decoded rows (`Batch::utilization`).
    pub utilization: f64,
}

/// Decode a formed batch's rows out of a staged assignment stream
/// straight into `dst` (`dst.len() == batch.rows.len() * codes_per_row *
/// cb.d`, row-major in `Batch::rows` order, padded rows included).
pub fn decode_into(
    batch: &Batch,
    staged: &StagedCodes,
    cb: &Codebook,
    codes_per_row: usize,
    dst: &mut [f32],
    pool: Option<&ThreadPool>,
) -> anyhow::Result<DecodeStats> {
    decode_rows_into(&batch.rows, staged, cb, codes_per_row, dst, pool)?;
    let window_bytes = row_window_bytes(staged, codes_per_row);
    Ok(DecodeStats {
        codes_unpacked: batch.rows.len() * codes_per_row * staged.stages(),
        packed_bytes_read: batch.rows.len() * window_bytes,
        utilization: batch.utilization(),
    })
}

/// Packed bytes one row's code windows span, summed across every
/// residual stage (per-stage windows round up to whole bytes) — the
/// cache-miss read volume per decoded row.  Shared by [`decode_into`]'s
/// accounting and the obs plane's `decoded_bytes_read` counter.
pub fn row_window_bytes(staged: &StagedCodes, codes_per_row: usize) -> usize {
    staged
        .stage_streams()
        .iter()
        .map(|p| (codes_per_row * p.bits as usize).div_ceil(8))
        .sum()
}

/// Row-list core of [`decode_into`] — also the cache-miss decode the
/// engine shards drive: stream `rows[i]`'s window (every stage) into
/// `dst[i * stride .. (i + 1) * stride]`.
pub fn decode_rows_into(
    rows: &[usize],
    staged: &StagedCodes,
    cb: &Codebook,
    codes_per_row: usize,
    dst: &mut [f32],
    pool: Option<&ThreadPool>,
) -> anyhow::Result<()> {
    anyhow::ensure!(codes_per_row > 0, "codes_per_row must be positive");
    // `row < count / codes_per_row` is equivalent to
    // `(row + 1) * codes_per_row <= count` but cannot overflow — rows
    // arrive off the wire (serving::tcp), so huge values must error, not
    // wrap around and silently decode the wrong window.
    let stream_rows = staged.count() / codes_per_row;
    for &row in rows {
        anyhow::ensure!(
            row < stream_rows,
            "row {row} out of range: the {}-code stream holds {stream_rows} rows of {codes_per_row}",
            staged.count()
        );
    }
    let stride = codes_per_row * cb.d;
    anyhow::ensure!(
        dst.len() == rows.len() * stride,
        "decode_rows_into: dst holds {} f32s, {} rows of stride {stride} need {}",
        dst.len(),
        rows.len(),
        rows.len() * stride
    );

    let kernel = |i: usize, out: &mut [f32]| {
        let row = rows[i];
        cb.decode_staged_packed_into(staged, row * codes_per_row, (row + 1) * codes_per_row, out);
    };

    match pool {
        Some(tp) if tp.threads() > 1 && rows.len() > 1 => {
            let ptr = SyncPtr::new(dst);
            tp.note_read(rows);
            for p in staged.stage_streams() {
                tp.note_read(&p.data);
            }
            tp.note_read(&cb.words);
            tp.parallel_for(rows.len(), 1, |start, end| {
                for i in start..end {
                    // SAFETY: each row position owns a disjoint dst window.
                    let out = unsafe { ptr.slice(i * stride, stride) };
                    kernel(i, out);
                }
            })
            .map_err(|e| anyhow::anyhow!("streaming decode pool failed: {e}"))?;
        }
        _ => {
            for i in 0..rows.len() {
                kernel(i, &mut dst[i * stride..(i + 1) * stride]);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::engine::router::Request;
    use crate::serving::switchsim::decode_batch;
    use crate::util::rng::Rng;
    use crate::vq::pack::pack_codes;

    fn req(id: u64, row: usize) -> Request {
        Request {
            id,
            net: "a".into(),
            row,
            arrived_ns: 0,
            deadline_ns: 0,
        }
    }

    #[test]
    fn streamed_decode_matches_allocating_decode_batch() {
        let mut rng = Rng::new(41);
        let mut words = vec![0.0f32; 32 * 4];
        rng.fill_normal(&mut words);
        let cb = Codebook::new(32, 4, words);
        let (device_rows, cpr) = (8usize, 23usize);
        let codes: Vec<u32> = (0..device_rows * cpr).map(|_| rng.below(32) as u32).collect();
        let staged = StagedCodes::single(pack_codes(&codes, 5));
        let batch = Batch::form("a", vec![req(0, 5), req(1, 2), req(2, 5)], device_rows);

        let alloc = decode_batch(&batch, &staged, &cb, cpr, None).unwrap();
        let mut dst = vec![0.0f32; batch.rows.len() * cpr * cb.d];
        let s = decode_into(&batch, &staged, &cb, cpr, &mut dst, None).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dst), bits(&alloc.weights));
        assert_eq!(s.codes_unpacked, alloc.codes_unpacked);
        assert_eq!(s.packed_bytes_read, alloc.packed_bytes_read);
        assert!((s.utilization - alloc.utilization).abs() < 1e-12);
    }

    /// A 2-stage stream through the streaming path must equal the
    /// stage-summed direct decode, and the byte/code accounting must
    /// scale with the stage count.
    #[test]
    fn streamed_decode_handles_residual_stages() {
        let mut rng = Rng::new(43);
        let mut words = vec![0.0f32; 32 * 3];
        rng.fill_normal(&mut words);
        let cb = Codebook::new(32, 3, words);
        let (device_rows, cpr) = (6usize, 11usize);
        let mk = |rng: &mut Rng, bits: u32| {
            let codes: Vec<u32> =
                (0..device_rows * cpr).map(|_| rng.below(16) as u32).collect();
            pack_codes(&codes, bits)
        };
        let staged = StagedCodes::new(vec![mk(&mut rng, 5), mk(&mut rng, 4)]);
        let batch = Batch::form("a", vec![req(0, 3), req(1, 1)], device_rows);

        let mut dst = vec![0.0f32; batch.rows.len() * cpr * cb.d];
        let s = decode_into(&batch, &staged, &cb, cpr, &mut dst, None).unwrap();
        assert_eq!(s.codes_unpacked, batch.rows.len() * cpr * 2);
        assert_eq!(
            s.packed_bytes_read,
            batch.rows.len() * ((cpr * 5).div_ceil(8) + (cpr * 4).div_ceil(8))
        );
        let mut direct = vec![0.0f32; cpr * cb.d];
        for (i, &row) in batch.rows.iter().enumerate() {
            cb.decode_staged_packed_into(&staged, row * cpr, (row + 1) * cpr, &mut direct);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&dst[i * cpr * cb.d..(i + 1) * cpr * cb.d]), bits(&direct));
        }
    }

    #[test]
    fn rejects_wrong_dst_size_and_oob_rows() {
        let cb = Codebook::new(2, 2, vec![0., 0., 1., 1.]);
        let staged = StagedCodes::single(pack_codes(&[0u32, 1, 1, 0], 1)); // 2 rows of 2 codes
        let mut small = vec![0.0f32; 3];
        assert!(decode_rows_into(&[0], &staged, &cb, 2, &mut small, None).is_err());
        let mut ok = vec![0.0f32; 4];
        assert!(decode_rows_into(&[2], &staged, &cb, 2, &mut ok, None).is_err());
        assert!(decode_rows_into(&[usize::MAX / 2], &staged, &cb, 2, &mut ok, None).is_err());
        assert!(decode_rows_into(&[1], &staged, &cb, 2, &mut ok, None).is_ok());
        assert_eq!(ok, vec![1., 1., 0., 0.]);
    }
}
