//! Deterministic fault injection for the serving plane.
//!
//! A [`FaultPlan`] is a seeded, replayable schedule of failures: every
//! fire decision is a pure function of `(seed, site, probe index)` —
//! never of wall time or thread interleaving — so the same plan drives
//! bit-identical failure sequences serial vs pooled, and the same seed
//! reproduces the same ledgers and flight-recorder events across runs.
//!
//! The plan is armed on an [`crate::serving::Engine`] (which forks one
//! deterministic sub-plan per shard, exactly like the chunked RNG
//! streams in the construction paths) and consulted at the existing
//! choke points:
//!
//! * [`FaultSite::DecodePanic`]   — a dispatch's decode job panics on
//!   the worker pool (exercising ThreadPool recovery + shard
//!   quarantine);
//! * [`FaultSite::SlowOp`]        — the fire path stalls the virtual
//!   clock by [`FaultPlan::slow_ns`] before forming the batch;
//! * [`FaultSite::CorruptWindow`] — a hosted net's packed stream is
//!   treated as failing its integrity check (the checksum path), so the
//!   batch fails and the net is quarantined instead of serving garbage;
//! * [`FaultSite::ShardWedge`]    — the shard refuses to fire this
//!   round (a transient stall);
//! * [`FaultSite::SocketDrop`]    — the TCP reader (or a client helper
//!   under test) drops the connection mid-request.
//!
//! The probes live behind the `fault-inject` cargo feature; without it
//! they compile to a constant `false` and the plan is never consulted
//! (the `faults_overhead` bench row gates that this stays free).

use crate::util::rng::Rng;

/// Where a fault can fire.  The discriminant doubles as the `a` payload
/// of the [`crate::serving::EventKind::FaultInjected`] flight-recorder
/// event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Decode job panics on the worker pool during dispatch.
    DecodePanic,
    /// Fire path stalls the virtual clock before forming a batch.
    SlowOp,
    /// A packed code window fails its integrity check.
    CorruptWindow,
    /// The shard refuses to fire this round.
    ShardWedge,
    /// A TCP connection drops mid-request.
    SocketDrop,
}

/// Every site, in discriminant order (index == [`FaultSite::index`]).
pub const ALL_SITES: [FaultSite; 5] = [
    FaultSite::DecodePanic,
    FaultSite::SlowOp,
    FaultSite::CorruptWindow,
    FaultSite::ShardWedge,
    FaultSite::SocketDrop,
];

impl FaultSite {
    /// Stable index (and event payload / wire discriminant).
    pub fn index(&self) -> usize {
        match self {
            FaultSite::DecodePanic => 0,
            FaultSite::SlowOp => 1,
            FaultSite::CorruptWindow => 2,
            FaultSite::ShardWedge => 3,
            FaultSite::SocketDrop => 4,
        }
    }

    /// Stable wire name (the fault-plan format in README and the
    /// `/trace` explanation of `fault_injected` events).
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultSite::DecodePanic => "decode_panic",
            FaultSite::SlowOp => "slow_op",
            FaultSite::CorruptWindow => "corrupt_window",
            FaultSite::ShardWedge => "shard_wedge",
            FaultSite::SocketDrop => "socket_drop",
        }
    }
}

/// A seeded, deterministic fault schedule.
///
/// Each site carries a firing rate in permille (0 = never, 1000 =
/// every probe).  The decision for the `i`-th probe of a site is a pure
/// function of `(seed, site, i)`; per-site probe counters are the only
/// mutable state, so a plan forked per shard stays deterministic as
/// long as each shard's probe sequence is deterministic — which it is,
/// because every probe site runs on the single-threaded dispatch path
/// (the pooled decode keys its faults off a decision taken *before*
/// the parallel section).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    rates: [u16; 5],
    probes: [u64; 5],
    fired: [u64; 5],
    /// Virtual-clock stall injected when [`FaultSite::SlowOp`] fires.
    pub slow_ns: u64,
}

impl FaultPlan {
    /// A plan that never fires (all rates zero).
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            rates: [0; 5],
            probes: [0; 5],
            fired: [0; 5],
            slow_ns: 1_000_000,
        }
    }

    /// Set one site's firing rate in permille (clamped to 1000).
    pub fn with_rate(mut self, site: FaultSite, permille: u16) -> Self {
        self.rates[site.index()] = permille.min(1000);
        self
    }

    /// Arm every site at the same permille rate.
    pub fn arm_all(seed: u64, permille: u16) -> Self {
        let mut p = FaultPlan::new(seed);
        for s in ALL_SITES {
            p = p.with_rate(s, permille);
        }
        p
    }

    /// Set the [`FaultSite::SlowOp`] stall.
    pub fn with_slow_ns(mut self, ns: u64) -> Self {
        self.slow_ns = ns;
        self
    }

    /// Derive an independent sub-plan (per shard / per connection) with
    /// the same rates and fresh counters.  Deterministic in `(self.seed,
    /// tag)` — the same fork of the same plan replays identically.
    pub fn fork(&self, tag: u64) -> Self {
        FaultPlan {
            seed: self.seed ^ tag.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            rates: self.rates,
            probes: [0; 5],
            fired: [0; 5],
            slow_ns: self.slow_ns,
        }
    }

    /// Configured rate for a site (permille).
    pub fn rate(&self, site: FaultSite) -> u16 {
        self.rates[site.index()]
    }

    /// Probes taken at a site so far.
    pub fn probes(&self, site: FaultSite) -> u64 {
        self.probes[site.index()]
    }

    /// Faults fired at a site so far.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.fired[site.index()]
    }

    /// Take the next probe at `site`: advance the site's counter and
    /// decide — purely from `(seed, site, probe index)` — whether the
    /// fault fires.
    pub fn should_fire(&mut self, site: FaultSite) -> bool {
        let idx = site.index();
        let i = self.probes[idx];
        self.probes[idx] += 1;
        let rate = self.rates[idx];
        if rate == 0 {
            return false;
        }
        let mut r = Rng::new(
            self.seed
                ^ ((idx as u64 + 1).wrapping_mul(0xD6E8_FEB8_6659_FD93))
                ^ i.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let fire = r.below(1000) < rate as usize;
        if fire {
            self.fired[idx] += 1;
        }
        fire
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_plan_never_fires() {
        let mut p = FaultPlan::new(7);
        for _ in 0..1000 {
            for s in ALL_SITES {
                assert!(!p.should_fire(s));
            }
        }
        for s in ALL_SITES {
            assert_eq!(p.fired(s), 0);
            assert_eq!(p.probes(s), 1000, "probes counted even when unarmed");
        }
    }

    #[test]
    fn same_seed_same_schedule() {
        let mut a = FaultPlan::arm_all(42, 250);
        let mut b = FaultPlan::arm_all(42, 250);
        for _ in 0..500 {
            for s in ALL_SITES {
                assert_eq!(a.should_fire(s), b.should_fire(s));
            }
        }
        for s in ALL_SITES {
            assert_eq!(a.fired(s), b.fired(s));
            assert!(a.fired(s) > 0, "site {:?} should fire at 250 permille", s);
        }
    }

    #[test]
    fn schedule_is_probe_indexed_not_order_dependent() {
        // Interleaving probes across sites must not change any site's
        // own schedule: decisions depend only on (seed, site, index).
        let mut interleaved = FaultPlan::arm_all(9, 300);
        let mut sequential = FaultPlan::arm_all(9, 300);
        let mut got_inter = vec![];
        for _ in 0..200 {
            for s in ALL_SITES {
                got_inter.push((s, interleaved.should_fire(s)));
            }
        }
        let mut got_seq = vec![];
        for s in ALL_SITES {
            for _ in 0..200 {
                got_seq.push((s, sequential.should_fire(s)));
            }
        }
        for s in ALL_SITES {
            let a: Vec<bool> = got_inter
                .iter()
                .filter(|(x, _)| *x == s)
                .map(|(_, f)| *f)
                .collect();
            let b: Vec<bool> = got_seq
                .iter()
                .filter(|(x, _)| *x == s)
                .map(|(_, f)| *f)
                .collect();
            assert_eq!(a, b, "site {:?} schedule shifted under interleaving", s);
        }
    }

    #[test]
    fn forks_are_deterministic_and_distinct() {
        let base = FaultPlan::arm_all(5, 500);
        let mut f0a = base.fork(0);
        let mut f0b = base.fork(0);
        let mut f1 = base.fork(1);
        let a: Vec<bool> = (0..100).map(|_| f0a.should_fire(FaultSite::SlowOp)).collect();
        let b: Vec<bool> = (0..100).map(|_| f0b.should_fire(FaultSite::SlowOp)).collect();
        let c: Vec<bool> = (0..100).map(|_| f1.should_fire(FaultSite::SlowOp)).collect();
        assert_eq!(a, b, "same fork tag replays identically");
        assert_ne!(a, c, "different tags give unrelated schedules");
    }

    #[test]
    fn rate_extremes() {
        let mut never = FaultPlan::new(1).with_rate(FaultSite::DecodePanic, 0);
        let mut always = FaultPlan::new(1).with_rate(FaultSite::DecodePanic, 1000);
        for _ in 0..100 {
            assert!(!never.should_fire(FaultSite::DecodePanic));
            assert!(always.should_fire(FaultSite::DecodePanic));
        }
    }

    #[test]
    fn site_names_and_indices_are_stable() {
        let names = [
            "decode_panic",
            "slow_op",
            "corrupt_window",
            "shard_wedge",
            "socket_drop",
        ];
        for (i, s) in ALL_SITES.iter().enumerate() {
            assert_eq!(s.index(), i);
            assert_eq!(s.as_str(), names[i]);
        }
    }
}
