//! Multi-network serving layer — the deployment story of §3.2: many
//! compressed networks resident on one platform, fast task switching
//! because the universal codebook never reloads.
//!
//! * [`batcher`]   — dynamic batcher: coalesces requests per network up
//!   to a batch size / linger deadline; [`Batch::decode_rows_into`]
//!   streams a batch's weight rows into a caller-provided buffer.
//! * [`router`]    — routes requests to per-network queues, tracks
//!   fairness and queue depths (name-keyed, incl. [`Router::drain_net`]).
//! * [`engine`]    — the sharded, cache-aware decode plane: worker
//!   shards each owning a disjoint subset of the hosted networks with
//!   their own router queue set, an LRU decode cache keyed on
//!   `(net, row window)` with byte-budget eviction, and the streaming
//!   decode path ([`engine::decode_into`]) that unpacks + decodes
//!   straight into `infer_hard` staging buffers.  `server`/`tcp`
//!   consume the plane per batch via `Engine::stream_batch` (cache +
//!   streaming decode); the sharded dispatch loop
//!   (`Engine::submit`/`dispatch_round`/`drain`) is the standalone
//!   plane — exercised by `benches/hotpath.rs` and the conservation
//!   property tests, and the target for moving the front-end routers
//!   onto (see ROADMAP).
//! * [`server`]    — thread-driven serving loop gluing router + batcher
//!   to the `infer_hard` artifacts (virtual clock); attaches an
//!   [`Engine`] as its decode plane.
//! * [`switchsim`] — task-switch cost simulator on top of `rom::memsim`
//!   (Table 1's I/O column at serving granularity), plus the batched
//!   packed-decode path ([`switchsim::decode_batch`]) that turns a
//!   formed [`Batch`] into real unpack + codebook-decode work on the
//!   worker pool.
//! * [`tcp`]       — newline-JSON TCP front-end (std::net; single PJRT
//!   dispatch thread + reader threads per connection, wall clock); also
//!   attaches an [`Engine`] decode plane.

pub mod batcher;
pub mod engine;
pub mod router;
pub mod server;
pub mod switchsim;
pub mod tcp;

pub use batcher::{Batch, BatcherConfig};
pub use engine::{DecodeCache, Engine, EngineConfig, HostedNet};
pub use router::{Request, Router};
pub use switchsim::{decode_batch, BatchDecode};
