//! Multi-network serving layer — the deployment story of §3.2: many
//! compressed networks resident on one platform, fast task switching
//! because the universal codebook never reloads.
//!
//! * [`batcher`]   — dynamic batcher: coalesces requests per network up
//!   to a batch size / linger deadline.
//! * [`router`]    — routes requests to per-network queues, tracks
//!   fairness and queue depths.
//! * [`server`]    — thread-driven serving loop gluing router + batcher
//!   to the `infer_hard` artifacts.
//! * [`switchsim`] — task-switch cost simulator on top of `rom::memsim`
//!   (Table 1's I/O column at serving granularity), plus the batched
//!   packed-decode path ([`switchsim::decode_batch`]) that turns a
//!   formed [`Batch`] into real unpack + codebook-decode work on the
//!   worker pool.

//! * [`tcp`]       — newline-JSON TCP front-end (std::net; single PJRT
//!   dispatch thread + reader threads per connection).

pub mod batcher;
pub mod router;
pub mod server;
pub mod switchsim;
pub mod tcp;

pub use batcher::{Batch, BatcherConfig};
pub use router::{Request, Router};
pub use switchsim::{decode_batch, BatchDecode};
