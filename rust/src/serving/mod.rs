//! Multi-network serving layer — the deployment story of §3.2: many
//! compressed networks resident on one platform, fast task switching
//! because the universal codebook never reloads.
//!
//! Since the planes were unified there is exactly **one routing/dispatch
//! path**, owned by [`engine`]:
//!
//! ```text
//!                     serving::server (virtual clock)
//!                     serving::tcp    (wall clock)
//!                               │ try_submit / would_admit
//!                               ▼
//!            ┌───────────── serving::engine ─────────────┐
//!            │ admission (max_queue_depth: shed | defer)  │
//!            │   → per-shard Router queue sets            │
//!            │   → fire-selection (Engine::next_batch)    │
//!            │   → cached/streamed decode (stream_batch)  │
//!            └────────────────────┬───────────────────────┘
//!                                 ▼
//!                       infer_hard artifacts
//! ```
//!
//! The front-ends no longer own a `Router` — the only router
//! construction sites are the engine's shards.  Both front-ends, the
//! benches, and the property tests drive the same admission → shard
//! queue → fire-selection → decode pipeline; the virtual-clock path
//! sheds over-budget submissions with a typed
//! [`engine::Admission::Rejected`], the TCP path probes
//! [`Engine::would_admit`] and defers (backpressure) instead.
//!
//! * [`batcher`]   — dynamic batcher: fire-on-size-or-linger policy
//!   ([`batcher::should_fire`]) plus [`Batch`] forming/padding;
//!   [`Batch::decode_rows_into`] streams a batch's weight rows into a
//!   caller-provided buffer.
//! * [`engine`]    — the sharded, cache-aware decode **and dispatch**
//!   plane: worker shards each owning a disjoint subset of the hosted
//!   networks with their own router queue set and admission budget, an
//!   LRU decode cache keyed on `(net, row window)` with byte-budget
//!   eviction, and the streaming decode path ([`engine::decode_into`])
//!   that unpacks + decodes straight into `infer_hard` staging buffers.
//! * [`faults`]    — deterministic fault-injection harness: a seeded
//!   [`FaultPlan`] (decode panic, slow op, corrupted code window, shard
//!   wedge, socket drop) consulted at the plane's choke points when the
//!   `fault-inject` feature is on; firings land in the flight recorder.
//! * [`obs`]       — unified observability plane: per-shard metrics
//!   registry (log2 latency histograms, counters, gauges) merged into
//!   one [`MetricsSnapshot`] by [`Engine::metrics_snapshot`],
//!   request-lifecycle stage tracing on the engine clock, Prometheus
//!   text exposition (the TCP `/metrics` verb), and a per-shard flight
//!   recorder of structured events (the `/trace` verb).
//! * [`server`]    — virtual-clock front-end gluing the plane to the
//!   `infer_hard` artifacts (deterministic serving benches).
//! * [`switchsim`] — task-switch cost simulator on top of `rom::memsim`
//!   (Table 1's I/O column at serving granularity), plus the batched
//!   staged-decode path ([`switchsim::decode_batch`], one packed stream
//!   per residual stage summed against the same universal codebook).
//! * [`tcp`]       — newline-JSON TCP front-end (std::net; single
//!   dispatch thread owning every session + the plane, reader threads
//!   per connection feeding a **bounded** channel, wall clock): when a
//!   shard is at its admission budget the dispatcher defers and stops
//!   pulling, the channel fills, and the kernel socket buffers
//!   backpressure the clients.
pub mod batcher;
pub mod engine;
pub mod faults;
pub mod obs;
pub mod server;
pub mod switchsim;
pub mod tcp;

pub use batcher::{Batch, BatcherConfig};
pub use engine::{
    Admission, DecodeCache, Engine, EngineConfig, HostedNet, NetLedger, Request, Router,
};
pub use faults::{FaultPlan, FaultSite};
pub use obs::{Event, EventKind, FlightRecorder, MetricsSnapshot, ObsConfig, ShardObs};
pub use switchsim::{decode_batch, BatchDecode};
