//! Exposition: render a [`MetricsSnapshot`] as Prometheus text format
//! (the TCP `/metrics` verb body) or as a JSON object (the JSON
//! snapshot verb and the serve examples' final dumps), plus the labeled
//! latency-summary shape that unifies the `latency_ns` (engine clock)
//! vs `latency_us` (wall clock) reporting mismatch.
//!
//! [`check_exposition`] is a deliberately small text-format validator —
//! enough for the integration test to *parse* what `/metrics` returns
//! (every required family declared and sampled, histogram buckets
//! cumulative and consistent with `_count`) without vendoring a
//! Prometheus client.

use std::fmt::Write as _;

use super::MetricsSnapshot;
use crate::util::json::Json;
use crate::util::stats::{Log2Hist, Summary};

/// Metric families every exposition must contain — the CI
/// seeded-violation step and [`check_exposition`] key off this list.
pub const REQUIRED_FAMILIES: &[&str] = &[
    "vq4all_requests_accepted_total",
    "vq4all_requests_dispatched_total",
    "vq4all_requests_shed_total",
    "vq4all_requests_expired_total",
    "vq4all_requests_failed_total",
    "vq4all_requests_deferred_total",
    "vq4all_batches_total",
    "vq4all_padded_rows_total",
    "vq4all_rows_from_cache_total",
    "vq4all_rows_decoded_total",
    "vq4all_cache_lookups_total",
    "vq4all_cache_hits_total",
    "vq4all_cache_misses_total",
    "vq4all_cache_evictions_total",
    "vq4all_decoded_bytes_total",
    "vq4all_obs_events_recorded_total",
    "vq4all_obs_events_dropped_total",
    "vq4all_shards",
    "vq4all_hosted_nets",
    "vq4all_pending_requests",
    "vq4all_decode_hidden_ratio",
    "vq4all_queue_wait_ns",
    "vq4all_decode_ns",
    "vq4all_infer_ns",
    "vq4all_respond_ns",
    "vq4all_decode_hit_ns",
    "vq4all_decode_miss_ns",
];

/// The histogram subset of [`REQUIRED_FAMILIES`].
pub const HISTOGRAM_FAMILIES: &[&str] = &[
    "vq4all_queue_wait_ns",
    "vq4all_decode_ns",
    "vq4all_infer_ns",
    "vq4all_respond_ns",
    "vq4all_decode_hit_ns",
    "vq4all_decode_miss_ns",
];

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

fn histogram(out: &mut String, name: &str, help: &str, h: &Log2Hist) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let cum = h.cumulative();
    for (i, c) in cum.iter().enumerate() {
        if i == Log2Hist::BUCKETS - 1 {
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {c}");
        } else {
            let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {c}", 1u64 << i);
        }
    }
    let _ = writeln!(out, "{name}_sum {}", h.sum());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Render the snapshot in Prometheus text exposition format
/// (`text/plain; version=0.0.4`).
pub fn prometheus_text(s: &MetricsSnapshot) -> String {
    let mut out = String::new();
    counter(&mut out, "vq4all_requests_accepted_total", "Requests admitted by the plane", s.accepted);
    counter(&mut out, "vq4all_requests_dispatched_total", "Requests fired into batches", s.dispatched);
    counter(&mut out, "vq4all_requests_shed_total", "Requests rejected at the admission budget", s.shed);
    counter(&mut out, "vq4all_requests_expired_total", "Requests whose deadline lapsed before their batch fired", s.expired);
    counter(&mut out, "vq4all_requests_failed_total", "Requests failed by a shard or net quarantine", s.failed);
    counter(&mut out, "vq4all_requests_deferred_total", "Requests deferred by front-end backpressure", s.deferred);
    counter(&mut out, "vq4all_batches_total", "Batches formed and served", s.batches);
    counter(&mut out, "vq4all_padded_rows_total", "Padding rows added to fill device batches", s.padded_rows);
    counter(&mut out, "vq4all_rows_from_cache_total", "Weight rows served from the decode cache", s.rows_from_cache);
    counter(&mut out, "vq4all_rows_decoded_total", "Weight rows decoded fresh on a cache miss", s.rows_decoded);
    counter(&mut out, "vq4all_cache_lookups_total", "Decode-cache window lookups", s.cache_lookups);
    counter(&mut out, "vq4all_cache_hits_total", "Decode-cache window hits", s.cache_hits);
    counter(&mut out, "vq4all_cache_misses_total", "Decode-cache window misses", s.cache_misses);
    counter(&mut out, "vq4all_cache_evictions_total", "Decode-cache windows evicted under byte pressure", s.cache_evictions);
    counter(&mut out, "vq4all_decoded_bytes_total", "Packed bytes read to decode cache misses", s.decoded_bytes_read);
    counter(&mut out, "vq4all_obs_events_recorded_total", "Flight-recorder events recorded", s.events_recorded);
    counter(&mut out, "vq4all_obs_events_dropped_total", "Flight-recorder events pushed out of the ring", s.events_dropped);
    gauge(&mut out, "vq4all_shards", "Engine shard count", s.shards as f64);
    gauge(&mut out, "vq4all_hosted_nets", "Networks hosted on the plane", s.hosted_nets as f64);
    gauge(&mut out, "vq4all_pending_requests", "Requests queued across all shards", s.pending as f64);
    gauge(&mut out, "vq4all_decode_hidden_ratio", "decode_ns_total / infer_ns_total", s.decode_hidden_ratio());
    histogram(&mut out, "vq4all_queue_wait_ns", "Admit-to-fire wait per dispatched request (engine clock, ns)", &s.queue_ns);
    histogram(&mut out, "vq4all_decode_ns", "Decode stage duration per batch (ns)", &s.decode_ns);
    histogram(&mut out, "vq4all_infer_ns", "Infer stage duration per batch (ns)", &s.infer_ns);
    histogram(&mut out, "vq4all_respond_ns", "Respond stage duration per batch (ns)", &s.respond_ns);
    histogram(&mut out, "vq4all_decode_hit_ns", "Decode stage duration, all-cache-hit batches (ns)", &s.decode_hit_ns);
    histogram(&mut out, "vq4all_decode_miss_ns", "Decode stage duration, batches with >=1 cache miss (ns)", &s.decode_miss_ns);
    if !s.per_net.is_empty() {
        let nets: Vec<(&String, &super::NetSnapshot)> = s.per_net.iter().collect();
        let labeled = |out: &mut String, name: &str, help: &str, ty: &str, f: &dyn Fn(&super::NetSnapshot) -> u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {ty}");
            for (net, n) in &nets {
                let _ = writeln!(out, "{name}{{net=\"{}\"}} {}", escape_label(net), f(n));
            }
        };
        labeled(&mut out, "vq4all_net_accepted_total", "Requests admitted per net", "counter", &|n| n.accepted);
        labeled(&mut out, "vq4all_net_served_total", "Requests served per net", "counter", &|n| n.served);
        labeled(&mut out, "vq4all_net_shed_total", "Requests shed per net", "counter", &|n| n.shed);
        labeled(&mut out, "vq4all_net_expired_total", "Deadline-expired requests per net", "counter", &|n| n.expired);
        labeled(&mut out, "vq4all_net_failed_total", "Quarantine-failed requests per net", "counter", &|n| n.failed);
        labeled(&mut out, "vq4all_net_pending", "Requests queued per net", "gauge", &|n| n.pending);
        labeled(&mut out, "vq4all_net_batches_total", "Batches streamed per net", "counter", &|n| n.batches);
        labeled(&mut out, "vq4all_net_rows_hit_total", "Cache-hit weight rows per net", "counter", &|n| n.rows_hit);
        labeled(&mut out, "vq4all_net_rows_missed_total", "Cache-miss weight rows per net", "counter", &|n| n.rows_missed);
        // Per-net queue wait as a summary (sum + count) — the full
        // bucket shape lives in the unlabeled engine-wide histogram.
        let _ = writeln!(out, "# HELP vq4all_net_queue_wait_ns Admit-to-fire wait per net (engine clock, ns)");
        let _ = writeln!(out, "# TYPE vq4all_net_queue_wait_ns summary");
        for (net, n) in &nets {
            let e = escape_label(net);
            let _ = writeln!(out, "vq4all_net_queue_wait_ns_sum{{net=\"{e}\"}} {}", n.queue_ns.sum());
            let _ = writeln!(out, "vq4all_net_queue_wait_ns_count{{net=\"{e}\"}} {}", n.queue_ns.count());
        }
    }
    out
}

/// Parse + validate a Prometheus text exposition: every line must be a
/// comment or a `name[{labels}] value` sample, every family in
/// [`REQUIRED_FAMILIES`] must be declared (`# TYPE`) and sampled, and
/// every required histogram must have cumulative buckets whose `+Inf`
/// count equals its `_count` sample.  Returns the number of sample
/// lines on success.
pub fn check_exposition(text: &str) -> anyhow::Result<usize> {
    let mut typed: Vec<String> = Vec::new();
    let mut sampled: Vec<String> = Vec::new();
    // (family, le value as f64 or +Inf, cumulative count) in order.
    let mut buckets: Vec<(String, f64, f64)> = Vec::new();
    let mut counts: Vec<(String, f64)> = Vec::new();
    let mut samples = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("").to_string();
            let ty = it.next().unwrap_or("");
            anyhow::ensure!(
                matches!(ty, "counter" | "gauge" | "histogram" | "summary"),
                "line {}: unknown metric type {ty:?}",
                ln + 1
            );
            typed.push(name);
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        // Sample line: name[{labels}] value
        let (head, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| anyhow::anyhow!("line {}: no value on sample line {line:?}", ln + 1))?;
        let value: f64 = value
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: unparsable value {value:?}", ln + 1))?;
        let (name, labels) = match head.split_once('{') {
            Some((n, l)) => {
                let l = l
                    .strip_suffix('}')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated labels", ln + 1))?;
                (n, Some(l))
            }
            None => (head, None),
        };
        anyhow::ensure!(
            !name.is_empty() && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "line {}: bad metric name {name:?}",
            ln + 1
        );
        samples += 1;
        if let Some(base) = name.strip_suffix("_bucket") {
            let le = labels
                .and_then(|l| l.strip_prefix("le=\""))
                .and_then(|l| l.strip_suffix('"'))
                .ok_or_else(|| anyhow::anyhow!("line {}: bucket without le label", ln + 1))?;
            let le = if le == "+Inf" { f64::INFINITY } else { le.parse::<f64>()? };
            buckets.push((base.to_string(), le, value));
            sampled.push(base.to_string());
        } else if let Some(base) = name.strip_suffix("_count") {
            counts.push((base.to_string(), value));
            sampled.push(base.to_string());
        } else if let Some(base) = name.strip_suffix("_sum") {
            sampled.push(base.to_string());
        } else {
            sampled.push(name.to_string());
        }
    }
    for fam in REQUIRED_FAMILIES {
        anyhow::ensure!(typed.iter().any(|t| t == fam), "missing # TYPE for required family {fam}");
        anyhow::ensure!(sampled.iter().any(|s| s == fam), "required family {fam} has no samples");
    }
    for fam in HISTOGRAM_FAMILIES {
        let fam_buckets: Vec<&(String, f64, f64)> =
            buckets.iter().filter(|(b, _, _)| b == fam).collect();
        anyhow::ensure!(!fam_buckets.is_empty(), "histogram {fam} has no buckets");
        for w in fam_buckets.windows(2) {
            anyhow::ensure!(
                w[0].1 < w[1].1 && w[0].2 <= w[1].2,
                "histogram {fam}: buckets must be le-ordered and cumulative"
            );
        }
        let last = fam_buckets.last().unwrap();
        anyhow::ensure!(last.1.is_infinite(), "histogram {fam}: last bucket must be +Inf");
        let count = counts
            .iter()
            .find(|(b, _)| b == fam)
            .ok_or_else(|| anyhow::anyhow!("histogram {fam} lacks _count"))?;
        anyhow::ensure!(
            count.1 == last.2,
            "histogram {fam}: _count {} != +Inf bucket {}",
            count.1,
            last.2
        );
    }
    Ok(samples)
}

fn hist_json(h: &Log2Hist) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count() as f64)),
        ("sum", Json::num(h.sum() as f64)),
    ])
}

/// JSON twin of [`prometheus_text`] — the `/metrics?format=json` verb
/// body and the serve examples' final snapshot dump.
pub fn snapshot_json(s: &MetricsSnapshot) -> Json {
    let per_net: Vec<(&str, Json)> = s
        .per_net
        .iter()
        .map(|(net, n)| {
            (
                net.as_str(),
                Json::obj(vec![
                    ("accepted", Json::num(n.accepted as f64)),
                    ("served", Json::num(n.served as f64)),
                    ("shed", Json::num(n.shed as f64)),
                    ("expired", Json::num(n.expired as f64)),
                    ("failed", Json::num(n.failed as f64)),
                    ("pending", Json::num(n.pending as f64)),
                    ("batches", Json::num(n.batches as f64)),
                    ("rows_hit", Json::num(n.rows_hit as f64)),
                    ("rows_missed", Json::num(n.rows_missed as f64)),
                    ("queue_wait_ns", hist_json(&n.queue_ns)),
                ]),
            )
        })
        .collect();
    Json::obj(vec![
        ("shards", Json::num(s.shards as f64)),
        ("hosted_nets", Json::num(s.hosted_nets as f64)),
        ("accepted", Json::num(s.accepted as f64)),
        ("dispatched", Json::num(s.dispatched as f64)),
        ("shed", Json::num(s.shed as f64)),
        ("expired", Json::num(s.expired as f64)),
        ("failed", Json::num(s.failed as f64)),
        ("deferred", Json::num(s.deferred as f64)),
        ("batches", Json::num(s.batches as f64)),
        ("padded_rows", Json::num(s.padded_rows as f64)),
        ("rows_from_cache", Json::num(s.rows_from_cache as f64)),
        ("rows_decoded", Json::num(s.rows_decoded as f64)),
        ("cache_lookups", Json::num(s.cache_lookups as f64)),
        ("cache_hits", Json::num(s.cache_hits as f64)),
        ("cache_misses", Json::num(s.cache_misses as f64)),
        ("cache_evictions", Json::num(s.cache_evictions as f64)),
        ("decoded_bytes_read", Json::num(s.decoded_bytes_read as f64)),
        ("pending", Json::num(s.pending as f64)),
        ("queue_wait_ns", hist_json(&s.queue_ns)),
        ("decode_ns", hist_json(&s.decode_ns)),
        ("infer_ns", hist_json(&s.infer_ns)),
        ("respond_ns", hist_json(&s.respond_ns)),
        ("decode_hit_ns", hist_json(&s.decode_hit_ns)),
        ("decode_miss_ns", hist_json(&s.decode_miss_ns)),
        ("decode_ns_total", Json::num(s.decode_ns_total as f64)),
        ("infer_ns_total", Json::num(s.infer_ns_total as f64)),
        ("decode_hidden_ratio", Json::num(s.decode_hidden_ratio())),
        ("events_recorded", Json::num(s.events_recorded as f64)),
        ("events_dropped", Json::num(s.events_dropped as f64)),
        ("per_net", Json::obj(per_net)),
    ])
}

/// One labeled latency shape for every report: the serving stack keeps
/// engine-clock nanosecond summaries (`latency_ns`) and wall-clock
/// microsecond summaries (`latency_us`); this tags each with its unit
/// and clock so the `/stats` verb and the examples' end-of-run reports
/// stop mixing bare numbers of different units.
pub fn latency_summary_json(s: &Summary, unit: &str, clock: &str) -> Json {
    Json::obj(vec![
        ("unit", Json::str(unit)),
        ("clock", Json::str(clock)),
        ("count", Json::num(s.count() as f64)),
        ("mean", Json::num(if s.is_empty() { 0.0 } else { s.mean() })),
        ("p50", Json::num(s.percentile(50.0))),
        ("p90", Json::num(s.percentile(90.0))),
        ("p99", Json::num(s.percentile(99.0))),
        ("max", Json::num(if s.is_empty() { 0.0 } else { s.max() })),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::obs::{NetSnapshot, ObsConfig, ShardObs};

    fn sample_snapshot() -> MetricsSnapshot {
        let mut o = ShardObs::new(ObsConfig::default());
        o.touch(1_000);
        for w in [3u64, 70, 900] {
            o.note_queue_wait("alpha", w);
        }
        o.note_batch_rows("alpha", 2, 1, 48);
        o.note_stages(120, 400, 9, true);
        let mut s = MetricsSnapshot {
            shards: 1,
            hosted_nets: 1,
            accepted: 4,
            dispatched: 3,
            shed: 1,
            deferred: 0,
            batches: 1,
            rows_from_cache: 2,
            rows_decoded: 1,
            cache_lookups: 3,
            cache_hits: 2,
            cache_misses: 1,
            pending: 1,
            ..MetricsSnapshot::default()
        };
        s.absorb_shard(&o);
        let n = s.per_net.entry("alpha".into()).or_default();
        n.accepted = 4;
        n.served = 3;
        n.shed = 1;
        n.pending = 1;
        s
    }

    #[test]
    fn exposition_round_trips_through_the_checker() {
        let s = sample_snapshot();
        let text = prometheus_text(&s);
        let samples = check_exposition(&text).expect("valid exposition");
        assert!(samples > 40, "histograms alone exceed 40 samples, got {samples}");
        assert!(text.contains("vq4all_requests_accepted_total 4"));
        assert!(text.contains("vq4all_queue_wait_ns_count 3"));
        assert!(text.contains("vq4all_net_served_total{net=\"alpha\"} 3"));
        assert!(text.contains("vq4all_net_queue_wait_ns_count{net=\"alpha\"} 3"));
    }

    #[test]
    fn checker_rejects_missing_family_and_broken_buckets() {
        let s = sample_snapshot();
        let text = prometheus_text(&s);
        // Drop one required family wholesale.
        let gutted: String = text
            .lines()
            .filter(|l| !l.contains("vq4all_cache_hits_total"))
            .map(|l| format!("{l}\n"))
            .collect();
        let err = check_exposition(&gutted).unwrap_err().to_string();
        assert!(err.contains("vq4all_cache_hits_total"), "err: {err}");
        // Corrupt a histogram count so buckets and _count disagree.
        let broken = text.replace("vq4all_queue_wait_ns_count 3", "vq4all_queue_wait_ns_count 99");
        assert!(check_exposition(&broken).is_err());
        // Garbage line.
        assert!(check_exposition("not a metric line at all\n").is_err());
    }

    #[test]
    fn label_escaping_survives_hostile_net_names() {
        let mut s = sample_snapshot();
        s.per_net.insert("we\"ird\\net".into(), NetSnapshot::default());
        let text = prometheus_text(&s);
        assert!(text.contains("net=\"we\\\"ird\\\\net\""));
        check_exposition(&text).expect("escaped labels still parse");
    }

    #[test]
    fn snapshot_json_carries_the_required_keys() {
        let s = sample_snapshot();
        let j = snapshot_json(&s);
        assert_eq!(j.req_usize("accepted").unwrap(), 4);
        assert_eq!(j.req_usize("dispatched").unwrap(), 3);
        assert_eq!(j.req_usize("cache_lookups").unwrap(), 3);
        assert!(j.req_f64("decode_hidden_ratio").unwrap() > 0.0);
        let net = j.req("per_net").unwrap().get("alpha").expect("net entry");
        assert_eq!(net.req_usize("served").unwrap(), 3);
        assert_eq!(net.req("queue_wait_ns").unwrap().req_usize("count").unwrap(), 3);
    }

    #[test]
    fn latency_shape_is_labeled_and_total() {
        let mut sum = Summary::new();
        for i in 1..=100 {
            sum.push(i as f64);
        }
        let j = latency_summary_json(&sum, "us", "wall");
        assert_eq!(j.req_str("unit").unwrap(), "us");
        assert_eq!(j.req_str("clock").unwrap(), "wall");
        assert_eq!(j.req_usize("count").unwrap(), 100);
        assert!(j.req_f64("p99").unwrap() >= j.req_f64("p50").unwrap());
        let empty = latency_summary_json(&Summary::new(), "ns", "engine");
        assert_eq!(empty.req_f64("mean").unwrap(), 0.0);
        assert_eq!(empty.req_f64("max").unwrap(), 0.0);
    }
}
