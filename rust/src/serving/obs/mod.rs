//! Unified observability plane for the serving engine.
//!
//! Three parts, one contract:
//!
//! 1. **Metrics registry** — counters, gauges, and fixed-bucket log2
//!    latency histograms ([`crate::util::stats::Log2Hist`]), accumulated
//!    in plain non-atomic per-shard fields ([`ShardObs`]) and merged at
//!    snapshot time by [`crate::serving::Engine::metrics_snapshot`].
//!    The hot path stays lock-free, and because every stamp uses the
//!    **engine clock** (virtual on `serving::server`, `set_now` wall
//!    time on `serving::tcp`), serial and pooled runs produce
//!    bit-identical snapshots — property-tested in `prop_substrate`.
//! 2. **Request-lifecycle tracing** — each admitted request's span is
//!    stamped admit → enqueue → fire (queue-wait histogram, recorded in
//!    `Shard::next_batch`) → decode (cache-hit vs miss split) → infer →
//!    respond (stage histograms fed by the front-ends through
//!    [`crate::serving::Engine::observe_batch`]), per shard and per
//!    net, plus derived keys like the decode-hidden ratio
//!    ([`MetricsSnapshot::decode_hidden_ratio`]).
//! 3. **Exposition + flight recorder** — [`expose::prometheus_text`]
//!    renders the snapshot as Prometheus text format (served by the TCP
//!    `/metrics` verb; [`expose::snapshot_json`] is the JSON twin), and
//!    each shard keeps a fixed-capacity [`recorder::FlightRecorder`]
//!    ring of recent structured events (shed / deferral / eviction /
//!    hosting / validation / out-of-range), dumped by the `/trace`
//!    verb.
//!
//! **Reconciliation contract:** [`MetricsSnapshot`] totals are
//! *defined* to equal the engine's existing conservation counters —
//! `accepted == dispatched + shed + expired + failed`, per-net ledger
//! sums, cache `hits + misses == lookups`, and (in fault-free
//! operation) `queue_ns.count() == dispatched` — one queue-wait sample
//! per dispatched request; a failed batch keeps its fire-time spans.
//! The `obs_overhead` bench row gates the instrumentation cost of the
//! `stream_batch` path at ≤ ~5% (`scripts/verify.sh`).

pub mod expose;
pub mod recorder;

use std::collections::BTreeMap;

use crate::util::stats::Log2Hist;
pub use recorder::{Event, EventKind, FlightRecorder};

/// Observability knobs, part of `EngineConfig` (so `Copy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ObsConfig {
    /// Master switch: `false` skips every histogram/ring update on the
    /// hot path (the `obs_overhead` bench's uninstrumented side).
    pub enabled: bool,
    /// Flight-recorder capacity per shard (0 disables the ring).
    pub ring_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: true,
            ring_capacity: 256,
        }
    }
}

/// Per-net slice of a shard's observability state.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetObs {
    /// Admit→fire wait per dispatched request (engine clock).
    pub queue_ns: Log2Hist,
    /// Batches streamed for this net.
    pub batches: u64,
    /// Weight rows served out of the decode cache / decoded fresh.
    pub rows_hit: u64,
    pub rows_missed: u64,
}

/// Per-shard observability state: plain fields, owned by exactly one
/// shard, merged only at snapshot time.  All methods are no-ops when
/// the plane is disabled.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardObs {
    enabled: bool,
    /// Engine clock at the last admit/fire on this shard — the
    /// timestamp source for events raised where no clock is in scope
    /// (e.g. cache evictions inside `stream_batch`).
    pub now_ns: u64,
    /// Admit→fire wait per dispatched request.
    pub queue_ns: Log2Hist,
    /// Front-end measured stage durations per batch.
    pub decode_ns: Log2Hist,
    pub infer_ns: Log2Hist,
    pub respond_ns: Log2Hist,
    /// Decode-stage duration split by cache outcome: batches whose rows
    /// all hit vs batches that decoded at least one miss.
    pub decode_hit_ns: Log2Hist,
    pub decode_miss_ns: Log2Hist,
    /// Stage-duration running totals (the decode-hidden ratio inputs).
    pub decode_ns_total: u64,
    pub infer_ns_total: u64,
    /// Packed bytes read to decode cache misses
    /// (`stream::row_window_bytes` per missed row).
    pub decoded_bytes_read: u64,
    pub by_net: BTreeMap<String, NetObs>,
    pub recorder: FlightRecorder,
}

impl ShardObs {
    pub fn new(cfg: ObsConfig) -> Self {
        ShardObs {
            enabled: cfg.enabled,
            now_ns: 0,
            queue_ns: Log2Hist::new(),
            decode_ns: Log2Hist::new(),
            infer_ns: Log2Hist::new(),
            respond_ns: Log2Hist::new(),
            decode_hit_ns: Log2Hist::new(),
            decode_miss_ns: Log2Hist::new(),
            decode_ns_total: 0,
            infer_ns_total: 0,
            decoded_bytes_read: 0,
            by_net: BTreeMap::new(),
            recorder: FlightRecorder::new(if cfg.enabled { cfg.ring_capacity } else { 0 }),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Advance the shard-local engine-clock mirror (monotone max, so
    /// out-of-order admit/fire interleavings cannot run it backwards).
    #[inline]
    pub fn touch(&mut self, now_ns: u64) {
        if self.enabled {
            self.now_ns = self.now_ns.max(now_ns);
        }
    }

    /// Borrow (create on first use) a net's obs slice without cloning
    /// the name on the hot path once the entry exists.
    fn net_mut(&mut self, net: &str) -> &mut NetObs {
        if !self.by_net.contains_key(net) {
            self.by_net.insert(net.to_string(), NetObs::default());
        }
        self.by_net.get_mut(net).expect("entry just ensured")
    }

    /// One dispatched request's admit→fire wait.
    #[inline]
    pub fn note_queue_wait(&mut self, net: &str, wait_ns: u64) {
        if !self.enabled {
            return;
        }
        self.queue_ns.record(wait_ns);
        self.net_mut(net).queue_ns.record(wait_ns);
    }

    /// One streamed batch's cache outcome (`stream_batch`).
    #[inline]
    pub fn note_batch_rows(&mut self, net: &str, hits: u64, misses: u64, miss_bytes: u64) {
        if !self.enabled {
            return;
        }
        self.decoded_bytes_read += miss_bytes;
        let n = self.net_mut(net);
        n.batches += 1;
        n.rows_hit += hits;
        n.rows_missed += misses;
    }

    /// Front-end measured stage durations for one responded batch.
    pub fn note_stages(
        &mut self,
        decode_ns: u64,
        infer_ns: u64,
        respond_ns: u64,
        had_miss: bool,
    ) {
        if !self.enabled {
            return;
        }
        self.decode_ns.record(decode_ns);
        self.infer_ns.record(infer_ns);
        self.respond_ns.record(respond_ns);
        if had_miss {
            self.decode_miss_ns.record(decode_ns);
        } else {
            self.decode_hit_ns.record(decode_ns);
        }
        self.decode_ns_total += decode_ns;
        self.infer_ns_total += infer_ns;
    }

    /// Raise a flight-recorder event at the shard's clock mirror.
    #[inline]
    pub fn note_event(&mut self, kind: EventKind, net: &str, a: u64, b: u64) {
        if self.enabled {
            self.recorder.record(self.now_ns, kind, net, a, b);
        }
    }
}

/// Per-net slice of a [`MetricsSnapshot`] — ledger counters plus the
/// obs-plane additions, reconciled against `NetLedger` by the property
/// tests.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetSnapshot {
    pub accepted: u64,
    pub served: u64,
    pub shed: u64,
    /// Requests whose deadline lapsed before their batch fired.
    pub expired: u64,
    /// Requests failed with a structured error by a quarantine.
    pub failed: u64,
    /// Requests sitting in this net's queue right now (gauge).
    pub pending: u64,
    pub queue_ns: Log2Hist,
    pub batches: u64,
    pub rows_hit: u64,
    pub rows_missed: u64,
}

/// One coherent, fully merged view of the engine's metrics.  All fields
/// are integers (or integer histograms) so the snapshot is `Eq` and the
/// serial-vs-pooled property can demand exact equality.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub shards: u64,
    pub hosted_nets: u64,
    // Admission conservation:
    // accepted == dispatched + shed + expired + failed.
    pub accepted: u64,
    pub dispatched: u64,
    pub shed: u64,
    /// Requests whose deadline lapsed before their batch fired.
    pub expired: u64,
    /// Requests failed with a structured error by a shard or net
    /// quarantine.
    pub failed: u64,
    pub deferred: u64,
    pub batches: u64,
    pub padded_rows: u64,
    // Decode plane: rows_from_cache + rows_decoded == cache lookups.
    pub rows_from_cache: u64,
    pub rows_decoded: u64,
    pub cache_lookups: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub decoded_bytes_read: u64,
    /// Requests queued across every shard right now (gauge).
    pub pending: u64,
    pub queue_ns: Log2Hist,
    pub decode_ns: Log2Hist,
    pub infer_ns: Log2Hist,
    pub respond_ns: Log2Hist,
    pub decode_hit_ns: Log2Hist,
    pub decode_miss_ns: Log2Hist,
    pub decode_ns_total: u64,
    pub infer_ns_total: u64,
    pub events_recorded: u64,
    pub events_dropped: u64,
    pub per_net: BTreeMap<String, NetSnapshot>,
}

impl MetricsSnapshot {
    /// Fraction of decode time hidden behind (divided by) infer time —
    /// the decode/execute-overlap headline the ROADMAP's device-path
    /// item will optimize.  0 when nothing was observed.
    pub fn decode_hidden_ratio(&self) -> f64 {
        if self.infer_ns_total == 0 {
            return 0.0;
        }
        self.decode_ns_total as f64 / self.infer_ns_total as f64
    }

    /// Fold one shard's view into the totals (snapshot-time merge).
    pub fn absorb_shard(&mut self, obs: &ShardObs) {
        self.queue_ns.merge(&obs.queue_ns);
        self.decode_ns.merge(&obs.decode_ns);
        self.infer_ns.merge(&obs.infer_ns);
        self.respond_ns.merge(&obs.respond_ns);
        self.decode_hit_ns.merge(&obs.decode_hit_ns);
        self.decode_miss_ns.merge(&obs.decode_miss_ns);
        self.decode_ns_total += obs.decode_ns_total;
        self.infer_ns_total += obs.infer_ns_total;
        self.decoded_bytes_read += obs.decoded_bytes_read;
        self.events_recorded += obs.recorder.recorded();
        self.events_dropped += obs.recorder.dropped();
        for (net, n) in &obs.by_net {
            let dst = self.per_net.entry(net.clone()).or_default();
            dst.queue_ns.merge(&n.queue_ns);
            dst.batches += n.batches;
            dst.rows_hit += n.rows_hit;
            dst.rows_missed += n.rows_missed;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_obs_records_nothing() {
        let mut o = ShardObs::new(ObsConfig {
            enabled: false,
            ring_capacity: 8,
        });
        o.touch(100);
        o.note_queue_wait("a", 5);
        o.note_batch_rows("a", 3, 1, 64);
        o.note_stages(10, 20, 1, true);
        o.note_event(EventKind::Shed, "a", 0, 0);
        assert_eq!(o.now_ns, 0);
        assert_eq!(o.queue_ns.count(), 0);
        assert!(o.by_net.is_empty());
        assert_eq!(o.decode_ns_total + o.infer_ns_total + o.decoded_bytes_read, 0);
        assert_eq!(o.recorder.recorded(), 0);
    }

    #[test]
    fn shard_merge_reconciles_into_snapshot() {
        let mk = |waits: &[u64], net: &str| {
            let mut o = ShardObs::new(ObsConfig::default());
            o.touch(50);
            for &w in waits {
                o.note_queue_wait(net, w);
            }
            o.note_batch_rows(net, waits.len() as u64, 1, 10);
            o.note_stages(4, 8, 1, true);
            o.note_event(EventKind::Eviction, net, 1, 0);
            o
        };
        let a = mk(&[1, 2, 3], "x");
        let b = mk(&[7], "y");
        let mut s = MetricsSnapshot::default();
        s.absorb_shard(&a);
        s.absorb_shard(&b);
        assert_eq!(s.queue_ns.count(), 4);
        assert_eq!(s.per_net.len(), 2);
        assert_eq!(s.per_net["x"].queue_ns.count(), 3);
        assert_eq!(s.per_net["x"].rows_hit, 3);
        assert_eq!(s.decode_ns_total, 8);
        assert_eq!(s.infer_ns_total, 16);
        assert_eq!(s.decoded_bytes_read, 20);
        assert_eq!(s.events_recorded, 2);
        assert!((s.decode_hidden_ratio() - 0.5).abs() < 1e-12);
        // Stage histograms saw one batch per shard, split by outcome.
        assert_eq!(s.decode_ns.count(), 2);
        assert_eq!(s.decode_miss_ns.count(), 2);
        assert_eq!(s.decode_hit_ns.count(), 0);
    }

    #[test]
    fn shard_clock_mirror_is_monotone() {
        let mut o = ShardObs::new(ObsConfig::default());
        o.touch(100);
        o.touch(40);
        assert_eq!(o.now_ns, 100);
        o.note_event(EventKind::Shed, "a", 0, 0);
        assert_eq!(o.recorder.events().next().unwrap().at_ns, 100);
    }
}
