//! Per-shard flight recorder: a fixed-capacity ring buffer of recent
//! structured events (sheds, deferrals, evictions, hosting/validation
//! errors, out-of-range rows), so a misbehaving burst is explainable
//! after the fact without log scraping.  The ring is plain per-shard
//! state — no locks, no atomics — and is dumped via the TCP `/trace`
//! verb or the engine's [`crate::serving::Engine::trace_events`].

use std::collections::VecDeque;

/// What happened.  The discriminants are stable wire names (see
/// [`EventKind::as_str`]) used by the `/trace` verb and the exposition
/// counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Admission rejected a request at the queue-depth budget
    /// (`a` = row, `b` = queue depth at rejection).
    Shed,
    /// A front-end deferred a request instead of shedding
    /// (`a` = queue depth at deferral, `b` = 0).
    Deferral,
    /// The decode cache evicted windows under byte pressure
    /// (`a` = evictions in this serve, `b` = cache bytes after).
    Eviction,
    /// A request named a network this plane does not host
    /// (`a` = row, `b` = 0).
    HostingError,
    /// A request's row fell outside the net's stream (`a` = row,
    /// `b` = stream rows).
    OutOfRangeRow,
    /// A request failed structural validation before admission
    /// (`a`/`b` free-form).
    ValidationError,
    /// A request's deadline lapsed before its batch fired; the work was
    /// shed pre-decode (`a` = row, `b` = deadline_ns).
    DeadlineExpired,
    /// A shard entered quarantine after a dispatch failure
    /// (`a` = shard id, `b` = requests failed with it).
    Quarantined,
    /// A queued request was failed with a structured error because its
    /// shard was quarantined (`a` = row, `b` = shard id).
    RequestFailed,
    /// An armed fault fired at an instrumented choke point
    /// (`a` = fault-site discriminant, `b` = firing index).
    FaultInjected,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Shed => "shed",
            EventKind::Deferral => "deferral",
            EventKind::Eviction => "eviction",
            EventKind::HostingError => "hosting_error",
            EventKind::OutOfRangeRow => "out_of_range_row",
            EventKind::ValidationError => "validation_error",
            EventKind::DeadlineExpired => "deadline_expired",
            EventKind::Quarantined => "quarantined",
            EventKind::RequestFailed => "request_failed",
            EventKind::FaultInjected => "fault_injected",
        }
    }
}

/// One recorded event.  `seq` is the shard-local sequence number (gaps
/// reveal how much the ring dropped between retained events); `at_ns`
/// is the engine clock at record time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Event {
    pub seq: u64,
    pub at_ns: u64,
    pub kind: EventKind,
    pub net: String,
    pub a: u64,
    pub b: u64,
}

/// Fixed-capacity ring of recent [`Event`]s.  When full, the oldest
/// event is dropped (and counted) — recording is O(1) and allocation-
/// free after the ring fills.  Capacity 0 disables recording entirely.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FlightRecorder {
    cap: usize,
    ring: VecDeque<Event>,
    next_seq: u64,
    dropped: u64,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> Self {
        FlightRecorder {
            cap,
            ring: VecDeque::with_capacity(cap),
            next_seq: 0,
            dropped: 0,
        }
    }

    pub fn record(&mut self, at_ns: u64, kind: EventKind, net: &str, a: u64, b: u64) {
        if self.cap == 0 {
            return;
        }
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(Event {
            seq: self.next_seq,
            at_ns,
            kind,
            net: net.to_string(),
            a,
            b,
        });
        self.next_seq += 1;
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Total events ever recorded (retained + dropped).
    pub fn recorded(&self) -> u64 {
        self.next_seq
    }

    /// Events pushed out of the ring by newer ones.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.ring.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut r = FlightRecorder::new(3);
        assert!(r.is_empty());
        for i in 0..5u64 {
            r.record(i * 10, EventKind::Shed, "a", i, 0);
        }
        assert_eq!(r.len(), 3, "ring stays at capacity");
        assert_eq!(r.recorded(), 5);
        assert_eq!(r.dropped(), 2, "two oldest pushed out");
        let seqs: Vec<u64> = r.events().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4], "oldest-first, newest retained");
        let first = r.events().next().unwrap();
        assert_eq!((first.at_ns, first.a), (20, 2), "payload rides along");
    }

    #[test]
    fn zero_capacity_disables_recording() {
        let mut r = FlightRecorder::new(0);
        r.record(1, EventKind::Eviction, "a", 0, 0);
        assert!(r.is_empty());
        assert_eq!(r.recorded(), 0, "disabled ring records nothing");
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn kind_names_are_stable() {
        // The /trace verb and exposition counters key on these strings.
        for (k, s) in [
            (EventKind::Shed, "shed"),
            (EventKind::Deferral, "deferral"),
            (EventKind::Eviction, "eviction"),
            (EventKind::HostingError, "hosting_error"),
            (EventKind::OutOfRangeRow, "out_of_range_row"),
            (EventKind::ValidationError, "validation_error"),
            (EventKind::DeadlineExpired, "deadline_expired"),
            (EventKind::Quarantined, "quarantined"),
            (EventKind::RequestFailed, "request_failed"),
            (EventKind::FaultInjected, "fault_injected"),
        ] {
            assert_eq!(k.as_str(), s);
        }
    }
}
