//! Serving loop: drives the sharded engine plane against the
//! `infer_hard` artifacts for a set of constructed networks.
//!
//! Single dispatch thread (the CPU PJRT client serializes execution
//! anyway); the interesting concurrency — request arrival vs dispatch —
//! is modeled with a virtual clock so the serving benches are
//! deterministic.
//!
//! **Breaking change (plane unification):** the server no longer owns a
//! `Router` or a `BatcherConfig` — routing, batching policy, admission
//! control, and the virtual clock all live on the mandatory
//! [`Engine`] plane ([`Server::new`] takes it by value).  `submit`
//! returns the plane's typed [`Admission`] outcome: over-budget
//! submissions are shed with [`Admission::Rejected`] instead of being
//! queued without bound.

use std::collections::BTreeMap;

use crate::coordinator::calib::gather_rows;
use crate::coordinator::session::NetSession;
use crate::tensor::Tensor;
use crate::util::stats::{Running, Summary};
use crate::util::threadpool::ThreadPool;

use super::engine::{Admission, Engine};

/// Latency/throughput accounting per network.  Latency is a bounded
/// [`Summary`] (running moments + percentile reservoir), so long serve
/// loops no longer grow memory linearly with traffic.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub served: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub latency_ns: Summary,
    /// Weight rows served out of the decode plane's cache.
    pub rows_from_cache: u64,
    /// Weight rows the decode plane decoded fresh.
    pub rows_decoded: u64,
}

/// The multi-network server: a virtual-clock front-end over the sharded
/// engine plane.
pub struct Server<'a> {
    pub sessions: BTreeMap<String, (&'a mut NetSession, Tensor)>, // (session, codes tensor)
    pub stats: BTreeMap<String, ServeStats>,
    /// Measured execute time per batch (feeds the virtual clock).
    pub exec_ns: Running,
    /// The sharded decode/dispatch plane — the single routing path:
    /// admission, per-shard queues, fire-selection, and the cached
    /// streaming decode all happen here.
    pub plane: Engine,
    /// Worker pool the plane's miss-decodes run on (None = serial).
    plane_pool: Option<ThreadPool>,
}

impl<'a> Server<'a> {
    /// Build the server on a plane whose hosted nets and the sessions
    /// match one-to-one, each hosted at the session's `eval_batch` (the
    /// fixed batch its `infer_hard` artifact was lowered at — the plane
    /// forms the batches now).  See [`Engine::validate_sessions`].
    pub fn new(
        sessions: Vec<(&'a mut NetSession, Tensor)>,
        plane: Engine,
        pool: Option<ThreadPool>,
    ) -> anyhow::Result<Self> {
        let mut map = BTreeMap::new();
        let mut stats = BTreeMap::new();
        for (s, codes) in sessions {
            let name = s.net.name.clone();
            stats.insert(name.clone(), ServeStats::default());
            anyhow::ensure!(
                map.insert(name.clone(), (s, codes)).is_none(),
                "server: duplicate session for {name:?}"
            );
        }
        plane.validate_sessions(
            "server",
            map.iter().map(|(n, (s, _))| (n.as_str(), s.net.eval_batch)),
        )?;
        Ok(Server {
            sessions: map,
            stats,
            exec_ns: Running::new(),
            plane,
            plane_pool: pool,
        })
    }

    /// Current virtual time (ns) — the plane's clock.
    pub fn now_ns(&self) -> u64 {
        self.plane.now_ns
    }

    /// Submit a request at the current virtual time; over-budget
    /// submissions come back as the typed [`Admission::Rejected`] shed.
    /// The plane validates `row` against the hosted packed stream; rows
    /// beyond the session's *input pool* fail loudly at dispatch
    /// (`gather_rows`), never remap silently.
    pub fn submit(&mut self, net: &str, row: usize) -> anyhow::Result<Admission> {
        self.plane.try_submit(net, row)
    }

    /// [`Server::submit`] with a deadline on the plane's virtual clock
    /// (`0` = none).  A request whose deadline lapses before its batch
    /// fires is counted `expired` and shed before decode — see
    /// [`Engine::try_submit_deadline`].
    pub fn submit_with_deadline(
        &mut self,
        net: &str,
        row: usize,
        deadline_ns: u64,
    ) -> anyhow::Result<Admission> {
        self.plane.try_submit_deadline(net, row, deadline_ns)
    }

    /// Advance virtual time.
    pub fn tick(&mut self, ns: u64) {
        self.plane.tick(ns);
    }

    /// Dispatch at most one batch if any shard queue should fire.
    /// Returns the served batch size (0 if nothing fired).
    pub fn dispatch_one(&mut self) -> anyhow::Result<usize> {
        let Some(batch) = self.plane.next_batch() else {
            return Ok(0);
        };
        let name = batch.net.clone();
        // Stream the batch's weight rows through the plane's decode
        // cache (fused unpack + decode) into the owning shard's staging
        // buffer — the host-side decode that precedes the artifact run.
        // Decode and infer are wall-timed separately here (the engine
        // never reads a clock itself) and reported back through
        // [`Engine::observe_batch`] — the stage histograms and the
        // decode-hidden ratio in [`Engine::metrics_snapshot`].  The
        // virtual clock advances by the *sum*, so latency accounting
        // sees the full host-side cost of the batch as before.
        let t_decode = std::time::Instant::now();
        // A decode failure (worker panic, integrity quarantine) must not
        // leave the batch's requests counted `dispatched` forever: hand
        // the batch back to the plane so the owning shard rolls the
        // rows into `failed` and quarantines, then surface the error.
        let row_serve = match self.plane.stream_batch(&name, &batch.rows, self.plane_pool.as_ref())
        {
            Ok(rs) => rs
                .ok_or_else(|| anyhow::anyhow!("plane fired a batch for unhosted net {name:?}"))?,
            Err(e) => {
                self.plane.fail_batch(&batch);
                return Err(e);
            }
        };
        let decode_ns = t_decode.elapsed().as_nanos() as u64;

        let (sess, codes) = self
            .sessions
            .get_mut(&name)
            .ok_or_else(|| anyhow::anyhow!("no session for {name:?}"))?;
        // Gather input rows from the network's test pool.  Rows beyond
        // the pool are a loud error here (as before the unification) —
        // never silently remapped to a different input row.
        let x = gather_rows(&sess.test_x, &batch.rows)?;
        let codes_t = codes.clone();
        let t0 = std::time::Instant::now();
        // infer_hard signature: codes, other:*, codebook, x
        let _out = sess.eval_infer(&codes_t, &[x])?;
        let dt = t0.elapsed().as_nanos() as u64;
        self.exec_ns.push(dt as f64);
        self.plane.tick(decode_ns + dt);
        self.plane.observe_batch(&name, row_serve, decode_ns, dt, 0);

        let st = self.stats.get_mut(&name).unwrap();
        st.served += batch.requests.len() as u64;
        st.batches += 1;
        st.padded_rows += batch.padded as u64;
        st.rows_from_cache += row_serve.hits as u64;
        st.rows_decoded += row_serve.misses as u64;
        for r in &batch.requests {
            st.latency_ns.push((self.plane.now_ns - r.arrived_ns) as f64);
        }
        Ok(batch.requests.len())
    }

    /// Drain everything still queued on the plane.  Tolerates bounded
    /// stalls (an injected shard wedge holds a fire back for a round or
    /// two) but still fails loudly if no progress happens for 64
    /// consecutive rounds.
    pub fn drain_all(&mut self) -> anyhow::Result<u64> {
        let mut total = 0u64;
        let mut stalled_rounds = 0u32;
        loop {
            // Force-fire partial batches once queues stop growing.
            let before = self.plane.total_pending();
            if before == 0 {
                break;
            }
            self.tick(self.plane.cfg.batcher.max_linger_ns + 1);
            let served = self.dispatch_one()?;
            total += served as u64;
            if served == 0 && self.plane.total_pending() == before {
                stalled_rounds += 1;
                anyhow::ensure!(
                    stalled_rounds < 64,
                    "server wedged with {before} pending requests"
                );
            } else {
                stalled_rounds = 0;
            }
        }
        Ok(total)
    }
}

impl NetSession {
    /// Serving-path forward: `infer_hard` with explicit codes + inputs.
    pub fn eval_infer(&mut self, codes: &Tensor, batch: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let lits = self.assemble_public("infer_hard", Some(codes), batch)?;
        self.exec("infer_hard")?.run_literals(&lits)
    }
}
