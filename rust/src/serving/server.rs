//! Serving loop: drives router + batcher against the `infer_hard`
//! artifacts for a set of constructed networks.
//!
//! Single dispatch thread (the CPU PJRT client serializes execution
//! anyway); the interesting concurrency — request arrival vs dispatch —
//! is modeled with a virtual clock so the serving benches are
//! deterministic.

use std::collections::BTreeMap;

use crate::coordinator::calib::gather_rows;
use crate::coordinator::session::NetSession;
use crate::tensor::Tensor;
use crate::util::stats::{Running, Summary};
use crate::util::threadpool::ThreadPool;

use super::batcher::{Batch, BatcherConfig};
use super::engine::Engine;
use super::router::Router;

/// Latency/throughput accounting per network.  Latency is a bounded
/// [`Summary`] (running moments + percentile reservoir), so long serve
/// loops no longer grow memory linearly with traffic.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub served: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub latency_ns: Summary,
    /// Weight rows served out of the attached decode plane's cache.
    pub rows_from_cache: u64,
    /// Weight rows the decode plane decoded fresh.
    pub rows_decoded: u64,
}

/// The multi-network server.
pub struct Server<'a> {
    pub sessions: BTreeMap<String, (&'a mut NetSession, Tensor)>, // (session, codes tensor)
    pub router: Router,
    pub cfg: BatcherConfig,
    pub stats: BTreeMap<String, ServeStats>,
    /// Virtual time (ns).
    pub now_ns: u64,
    /// Measured execute time per batch (feeds the virtual clock).
    pub exec_ns: Running,
    /// Optional sharded decode plane: when attached (and hosting the
    /// batch's net), every dispatched batch's weight rows are streamed
    /// through the plane's decode cache into the owning shard's staging
    /// buffer before the artifact runs — the host-side §3.2 decode work,
    /// now cache-aware.
    pub plane: Option<Engine>,
    /// Worker pool the plane's miss-decodes run on (None = serial).
    plane_pool: Option<ThreadPool>,
}

impl<'a> Server<'a> {
    pub fn new(
        sessions: Vec<(&'a mut NetSession, Tensor)>,
        cfg: BatcherConfig,
    ) -> Self {
        let names: Vec<String> = sessions.iter().map(|(s, _)| s.net.name.clone()).collect();
        let router = Router::new(&names.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        let mut map = BTreeMap::new();
        let mut stats = BTreeMap::new();
        for (s, codes) in sessions {
            stats.insert(s.net.name.clone(), ServeStats::default());
            map.insert(s.net.name.clone(), (s, codes));
        }
        Server {
            sessions: map,
            router,
            cfg,
            stats,
            now_ns: 0,
            exec_ns: Running::new(),
            plane: None,
            plane_pool: None,
        }
    }

    /// Attach a decode plane (`serving::engine`) the dispatch path
    /// streams every batch's weight rows through; `pool` parallelizes
    /// the plane's cache-miss decodes (None = serial).
    pub fn attach_plane(&mut self, plane: Engine, pool: Option<ThreadPool>) {
        self.plane = Some(plane);
        self.plane_pool = pool;
    }

    /// Submit a request at the current virtual time.
    pub fn submit(&mut self, net: &str, row: usize) -> anyhow::Result<u64> {
        self.router.submit(net, row, self.now_ns)
    }

    /// Advance virtual time.
    pub fn tick(&mut self, ns: u64) {
        self.now_ns += ns;
    }

    /// Dispatch at most one batch if any queue should fire.
    /// Returns the served batch size (0 if nothing fired).
    pub fn dispatch_one(&mut self) -> anyhow::Result<usize> {
        let fire = self
            .router
            .next_fireable(&self.cfg, self.now_ns)
            .map(|n| n.to_string());
        let Some(name) = fire else { return Ok(0) };
        let (sess, codes) = self
            .sessions
            .get_mut(&name)
            .ok_or_else(|| anyhow::anyhow!("no session for {name:?}"))?;
        let device_batch = sess.net.eval_batch;
        // Drain by name (the router's name-keyed API) and never take more
        // than one device batch can carry — leftovers stay queued.
        let reqs = self
            .router
            .drain_net(&name, self.cfg.max_batch.min(device_batch));
        let batch = Batch::form(&name, reqs, device_batch);

        // Stream the batch's weight rows through the decode plane (cache
        // + fused unpack) into the owning shard's staging buffer, when a
        // plane is attached and hosts this net — the host-side decode
        // that precedes the artifact run.
        let row_serve = match self.plane.as_mut() {
            Some(plane) => plane.stream_batch(&name, &batch.rows, self.plane_pool.as_ref())?,
            None => None,
        };

        // Gather input rows from the network's test pool and run infer.
        let x = gather_rows(&sess.test_x, &batch.rows)?;
        let codes_t = codes.clone();
        let t0 = std::time::Instant::now();
        // infer_hard signature: codes, other:*, codebook, x
        let _out = sess.eval_infer(&codes_t, &[x])?;
        let dt = t0.elapsed().as_nanos() as u64;
        self.exec_ns.push(dt as f64);
        self.now_ns += dt;

        let st = self.stats.get_mut(&name).unwrap();
        st.served += batch.requests.len() as u64;
        st.batches += 1;
        st.padded_rows += batch.padded as u64;
        if let Some(rs) = row_serve {
            st.rows_from_cache += rs.hits as u64;
            st.rows_decoded += rs.misses as u64;
        }
        for r in &batch.requests {
            st.latency_ns.push((self.now_ns - r.arrived_ns) as f64);
        }
        Ok(batch.requests.len())
    }

    /// Drain everything.
    pub fn drain_all(&mut self) -> anyhow::Result<u64> {
        let mut total = 0u64;
        loop {
            // Force-fire partial batches once queues stop growing.
            let before = self.router.total_pending();
            if before == 0 {
                break;
            }
            self.tick(self.cfg.max_linger_ns + 1);
            let served = self.dispatch_one()?;
            total += served as u64;
            if served == 0 && self.router.total_pending() == before {
                anyhow::bail!("server wedged with {before} pending requests");
            }
        }
        Ok(total)
    }
}

impl NetSession {
    /// Serving-path forward: `infer_hard` with explicit codes + inputs.
    pub fn eval_infer(&mut self, codes: &Tensor, batch: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let lits = self.assemble_public("infer_hard", Some(codes), batch)?;
        self.exec("infer_hard")?.run_literals(&lits)
    }
}
