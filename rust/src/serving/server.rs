//! Serving loop: drives router + batcher against the `infer_hard`
//! artifacts for a set of constructed networks.
//!
//! Single dispatch thread (the CPU PJRT client serializes execution
//! anyway); the interesting concurrency — request arrival vs dispatch —
//! is modeled with a virtual clock so the serving benches are
//! deterministic.

use std::collections::BTreeMap;

use crate::coordinator::calib::gather_rows;
use crate::coordinator::session::NetSession;
use crate::tensor::Tensor;
use crate::util::stats::Running;

use super::batcher::{should_fire, Batch, BatcherConfig};
use super::router::Router;

/// Latency/throughput accounting per network.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub served: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub latency_ns: Vec<f64>,
}

/// The multi-network server.
pub struct Server<'a> {
    pub sessions: BTreeMap<String, (&'a mut NetSession, Tensor)>, // (session, codes tensor)
    pub router: Router,
    pub cfg: BatcherConfig,
    pub stats: BTreeMap<String, ServeStats>,
    /// Virtual time (ns).
    pub now_ns: u64,
    /// Measured execute time per batch (feeds the virtual clock).
    pub exec_ns: Running,
}

impl<'a> Server<'a> {
    pub fn new(
        sessions: Vec<(&'a mut NetSession, Tensor)>,
        cfg: BatcherConfig,
    ) -> Self {
        let names: Vec<String> = sessions.iter().map(|(s, _)| s.net.name.clone()).collect();
        let router = Router::new(&names.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        let mut map = BTreeMap::new();
        let mut stats = BTreeMap::new();
        for (s, codes) in sessions {
            stats.insert(s.net.name.clone(), ServeStats::default());
            map.insert(s.net.name.clone(), (s, codes));
        }
        Server {
            sessions: map,
            router,
            cfg,
            stats,
            now_ns: 0,
            exec_ns: Running::new(),
        }
    }

    /// Submit a request at the current virtual time.
    pub fn submit(&mut self, net: &str, row: usize) -> anyhow::Result<u64> {
        self.router.submit(net, row, self.now_ns)
    }

    /// Advance virtual time.
    pub fn tick(&mut self, ns: u64) {
        self.now_ns += ns;
    }

    /// Dispatch at most one batch if any queue should fire.
    /// Returns the served batch size (0 if nothing fired).
    pub fn dispatch_one(&mut self) -> anyhow::Result<usize> {
        let names: Vec<String> = self.router.networks().iter().map(|s| s.to_string()).collect();
        // Find a fireable queue (deepest-first via router.pick semantics).
        let mut fire: Option<String> = None;
        for name in &names {
            let depth = self.router.depth(name);
            if depth == 0 {
                continue;
            }
            let oldest = self.router.oldest_arrival(name).unwrap_or(self.now_ns);
            if should_fire(&self.cfg, depth, oldest, self.now_ns) {
                fire = Some(name.clone());
                break;
            }
        }
        let Some(name) = fire else { return Ok(0) };
        let qi = names.iter().position(|n| n == &name).unwrap();
        let reqs = self.router.drain(qi, self.cfg.max_batch);
        let (sess, codes) = self
            .sessions
            .get_mut(&name)
            .ok_or_else(|| anyhow::anyhow!("no session for {name:?}"))?;
        let device_batch = sess.net.eval_batch;
        let take = reqs.len().min(device_batch);
        let batch = Batch::form(&name, reqs[..take].to_vec(), device_batch);

        // Gather input rows from the network's test pool and run infer.
        let x = gather_rows(&sess.test_x, &batch.rows)?;
        let codes_t = codes.clone();
        let t0 = std::time::Instant::now();
        // infer_hard signature: codes, other:*, codebook, x
        let _out = sess.eval_infer(&codes_t, &[x])?;
        let dt = t0.elapsed().as_nanos() as u64;
        self.exec_ns.push(dt as f64);
        self.now_ns += dt;

        let st = self.stats.get_mut(&name).unwrap();
        st.served += batch.requests.len() as u64;
        st.batches += 1;
        st.padded_rows += batch.padded as u64;
        for r in &batch.requests {
            st.latency_ns.push((self.now_ns - r.arrived_ns) as f64);
        }
        Ok(batch.requests.len())
    }

    /// Drain everything.
    pub fn drain_all(&mut self) -> anyhow::Result<u64> {
        let mut total = 0u64;
        loop {
            // Force-fire partial batches once queues stop growing.
            let before = self.router.total_pending();
            if before == 0 {
                break;
            }
            self.tick(self.cfg.max_linger_ns + 1);
            let served = self.dispatch_one()?;
            total += served as u64;
            if served == 0 && self.router.total_pending() == before {
                anyhow::bail!("server wedged with {before} pending requests");
            }
        }
        Ok(total)
    }
}

impl NetSession {
    /// Serving-path forward: `infer_hard` with explicit codes + inputs.
    pub fn eval_infer(&mut self, codes: &Tensor, batch: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let lits = self.assemble_public("infer_hard", Some(codes), batch)?;
        self.exec("infer_hard")?.run_literals(&lits)
    }
}
