//! Task-switch cost simulation at serving granularity — §3.2's claim
//! ("eliminates the need for repeated codebook loading during rapid task
//! switching") made measurable, on top of `rom::memsim` — plus the
//! actual decode work a formed batch drives: every batch row selects a
//! window of the network's staged assignment stream (one packed stream
//! per residual stage), which is unpacked and decoded against the
//! (ROM-resident) universal codebook through the worker pool
//! ([`decode_batch`]).

use crate::rom::memsim::{switch_storm, CodebookPlacement, MemSim, NetCodebooks, TrafficReport};
use crate::util::threadpool::ThreadPool;
use crate::vq::codebook::Codebook;
use crate::vq::pack::StagedCodes;

use super::batcher::Batch;
use super::engine::stream;

/// Workload description.
#[derive(Clone, Copy, Debug)]
pub struct SwitchWorkload {
    pub nets: usize,
    pub layers_per_net: usize,
    pub codebook_bytes_per_layer: usize,
    pub rounds: usize,
    pub inferences_per_activation: usize,
    pub sram_bytes: usize,
}

/// Compare per-layer-DRAM vs universal-ROM codebook traffic.
pub fn compare(w: &SwitchWorkload) -> (TrafficReport, TrafficReport) {
    let zoo: Vec<NetCodebooks> = (0..w.nets)
        .map(|i| NetCodebooks {
            name: format!("net{i}"),
            layer_codebooks: vec![w.codebook_bytes_per_layer; w.layers_per_net],
        })
        .collect();
    let mut per_layer = MemSim::new(
        CodebookPlacement::PerLayerDram {
            sram_bytes: w.sram_bytes,
        },
        zoo.clone(),
    );
    switch_storm(&mut per_layer, w.nets, w.rounds, w.inferences_per_activation);
    let mut rom = MemSim::new(CodebookPlacement::UniversalRom, zoo);
    switch_storm(&mut rom, w.nets, w.rounds, w.inferences_per_activation);
    (per_layer.report.clone(), rom.report.clone())
}

/// The I/O multiple (per-layer loads : ROM loads, with ROM clamped to 1
/// load representing the one-time tape-out — Table 1 normalizes the
/// universal column to 1x).
pub fn io_multiple(per_layer: &TrafficReport, rom: &TrafficReport) -> f64 {
    per_layer.codebook_loads as f64 / rom.codebook_loads.max(1) as f64
}

/// Accounting for one batched packed-decode ([`decode_batch`]).
#[derive(Clone, Debug)]
pub struct BatchDecode {
    /// Reconstructed weights, `(batch rows, codes_per_row * d)` row-major
    /// in `Batch::rows` order (padded rows included — the fixed-batch
    /// device decodes them too, which is exactly the waste the
    /// utilization metric prices).
    pub weights: Vec<f32>,
    /// Codes unpacked, padded rows and all residual stages included.
    pub codes_unpacked: usize,
    /// Packed bytes touched (per-row windows, rounded up to bytes,
    /// summed over residual stages).
    pub packed_bytes_read: usize,
    /// Real-request fraction of the decoded rows (`Batch::utilization`).
    pub utilization: f64,
}

/// Decode a formed batch's rows out of a staged assignment stream: row
/// `r` covers codes `[r * codes_per_row, (r + 1) * codes_per_row)` of
/// every residual stage. Rows are independent (disjoint output windows,
/// shared read-only streams), so the pooled path is bit-identical to
/// serial — this is the serving-side decode the batcher's utilization
/// metric measures.
///
/// Allocating wrapper over the streaming [`stream::decode_into`] path
/// (one kernel, one determinism contract): callers that can provide the
/// destination buffer should stream instead.
pub fn decode_batch(
    batch: &Batch,
    staged: &StagedCodes,
    cb: &Codebook,
    codes_per_row: usize,
    pool: Option<&ThreadPool>,
) -> anyhow::Result<BatchDecode> {
    anyhow::ensure!(codes_per_row > 0, "codes_per_row must be positive");
    let mut weights = vec![0.0f32; batch.rows.len() * codes_per_row * cb.d];
    let stats = stream::decode_into(batch, staged, cb, codes_per_row, &mut weights, pool)?;
    Ok(BatchDecode {
        weights,
        codes_unpacked: stats.codes_unpacked,
        packed_bytes_read: stats.packed_bytes_read,
        utilization: stats.utilization,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::engine::router::Request;
    use crate::util::rng::Rng;
    use crate::vq::pack::pack_codes;

    #[test]
    fn rom_wins_by_orders_of_magnitude() {
        let w = SwitchWorkload {
            nets: 5,
            layers_per_net: 20,
            codebook_bytes_per_layer: 64 * 1024,
            rounds: 10,
            inferences_per_activation: 5,
            // SRAM fits ~1.5 networks -> heavy thrash on switches
            sram_bytes: 30 * 64 * 1024,
        };
        let (pl, rom) = compare(&w);
        assert_eq!(rom.codebook_loads, 0);
        assert!(
            pl.codebook_loads > 500,
            "per-layer should thrash hundreds of loads, got {}",
            pl.codebook_loads
        );
        assert_eq!(pl.inferences, rom.inferences);
        // ROM loads clamp to 1, so the multiple equals the raw count.
        assert_eq!(io_multiple(&pl, &rom), pl.codebook_loads as f64);
    }

    #[test]
    fn generous_sram_still_pays_cold_loads() {
        let w = SwitchWorkload {
            nets: 3,
            layers_per_net: 10,
            codebook_bytes_per_layer: 4096,
            rounds: 4,
            inferences_per_activation: 8,
            sram_bytes: 1 << 30,
        };
        let (pl, rom) = compare(&w);
        assert_eq!(pl.codebook_loads, 30, "one cold load per codebook");
        assert_eq!(rom.codebook_loads, 0);
    }

    /// Regression for the `_rom`-ignoring bug: when the ROM side really
    /// records loads (> 1), the multiple must be the *ratio*, not the raw
    /// per-layer count.
    #[test]
    fn io_multiple_divides_by_rom_loads() {
        let pl = TrafficReport {
            codebook_loads: 500,
            ..TrafficReport::default()
        };
        let rom = TrafficReport {
            codebook_loads: 2,
            ..TrafficReport::default()
        };
        assert_eq!(io_multiple(&pl, &rom), 250.0);
        // Zero ROM loads clamp to the one-time tape-out load.
        let rom0 = TrafficReport::default();
        assert_eq!(io_multiple(&pl, &rom0), 500.0);
    }

    fn req(id: u64, row: usize) -> Request {
        Request {
            id,
            net: "a".into(),
            row,
            arrived_ns: 0,
            deadline_ns: 0,
        }
    }

    fn test_codebook(rng: &mut Rng, k: usize, d: usize) -> Codebook {
        let mut words = vec![0.0f32; k * d];
        rng.fill_normal(&mut words);
        Codebook::new(k, d, words)
    }

    #[test]
    fn batched_decode_matches_direct_row_decode() {
        let mut rng = Rng::new(5);
        let cb = test_codebook(&mut rng, 16, 3);
        let (device_rows, codes_per_row) = (6usize, 20usize);
        let codes: Vec<u32> = (0..device_rows * codes_per_row)
            .map(|_| rng.below(16) as u32)
            .collect();
        let staged = StagedCodes::single(pack_codes(&codes, 4));
        let batch = Batch::form("a", vec![req(0, 3), req(1, 0)], 4);
        let r = decode_batch(&batch, &staged, &cb, codes_per_row, None).unwrap();
        assert_eq!(r.weights.len(), 4 * codes_per_row * cb.d);
        assert_eq!(r.codes_unpacked, 4 * codes_per_row);
        // Per-row byte rounding: 20 codes @4b = 10 bytes per row.
        assert_eq!(r.packed_bytes_read, 4 * (codes_per_row * 4).div_ceil(8));
        assert!((r.utilization - 0.5).abs() < 1e-12);
        // Every decoded row equals the direct decode of its stream window,
        // and padded rows replicate their source rows exactly.
        let stride = codes_per_row * cb.d;
        for (pos, &row) in batch.rows.iter().enumerate() {
            let direct = cb.decode_vec(&codes[row * codes_per_row..(row + 1) * codes_per_row]);
            assert_eq!(&r.weights[pos * stride..(pos + 1) * stride], &direct[..]);
        }
        assert_eq!(batch.rows, vec![3, 0, 3, 0], "padding repeats real rows");
    }

    #[test]
    fn batched_decode_parallel_bit_identical_to_serial() {
        let mut rng = Rng::new(6);
        let cb = test_codebook(&mut rng, 32, 4);
        let (device_rows, codes_per_row) = (16usize, 257usize);
        let codes: Vec<u32> = (0..device_rows * codes_per_row)
            .map(|_| rng.below(32) as u32)
            .collect();
        let staged = StagedCodes::single(pack_codes(&codes, 5));
        let reqs: Vec<Request> = (0..9).map(|i| req(i, (i as usize * 5) % device_rows)).collect();
        let batch = Batch::form("a", reqs, device_rows);
        let pool = ThreadPool::new(4);
        let serial = decode_batch(&batch, &staged, &cb, codes_per_row, None).unwrap();
        let par = decode_batch(&batch, &staged, &cb, codes_per_row, Some(&pool)).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&serial.weights), bits(&par.weights));
        assert_eq!(serial.codes_unpacked, par.codes_unpacked);
        assert_eq!(serial.packed_bytes_read, par.packed_bytes_read);
    }

    #[test]
    fn batched_decode_rejects_out_of_stream_rows() {
        let mut rng = Rng::new(7);
        let cb = test_codebook(&mut rng, 4, 2);
        let staged = StagedCodes::single(pack_codes(&[0u32, 1, 2, 3], 2)); // one row of 4 codes
        let batch = Batch::form("a", vec![req(0, 1)], 1); // row 1 doesn't exist
        assert!(decode_batch(&batch, &staged, &cb, 4, None).is_err());
        // Wire-sized garbage rows must error, not wrap around (the bounds
        // check is overflow-free even in release builds).
        let huge = Batch::form("a", vec![req(0, usize::MAX / 2)], 1);
        assert!(decode_batch(&huge, &staged, &cb, 4, None).is_err());
    }
}
