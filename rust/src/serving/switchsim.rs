//! Task-switch cost simulation at serving granularity — §3.2's claim
//! ("eliminates the need for repeated codebook loading during rapid task
//! switching") made measurable, on top of `rom::memsim`.

use crate::rom::memsim::{switch_storm, CodebookPlacement, MemSim, NetCodebooks, TrafficReport};

/// Workload description.
#[derive(Clone, Copy, Debug)]
pub struct SwitchWorkload {
    pub nets: usize,
    pub layers_per_net: usize,
    pub codebook_bytes_per_layer: usize,
    pub rounds: usize,
    pub inferences_per_activation: usize,
    pub sram_bytes: usize,
}

/// Compare per-layer-DRAM vs universal-ROM codebook traffic.
pub fn compare(w: &SwitchWorkload) -> (TrafficReport, TrafficReport) {
    let zoo: Vec<NetCodebooks> = (0..w.nets)
        .map(|i| NetCodebooks {
            name: format!("net{i}"),
            layer_codebooks: vec![w.codebook_bytes_per_layer; w.layers_per_net],
        })
        .collect();
    let mut per_layer = MemSim::new(
        CodebookPlacement::PerLayerDram {
            sram_bytes: w.sram_bytes,
        },
        zoo.clone(),
    );
    switch_storm(&mut per_layer, w.nets, w.rounds, w.inferences_per_activation);
    let mut rom = MemSim::new(CodebookPlacement::UniversalRom, zoo);
    switch_storm(&mut rom, w.nets, w.rounds, w.inferences_per_activation);
    (per_layer.report.clone(), rom.report.clone())
}

/// The I/O multiple (per-layer loads : ROM loads, with ROM clamped to 1
/// load representing the one-time tape-out — Table 1 normalizes the
/// universal column to 1x).
pub fn io_multiple(per_layer: &TrafficReport, _rom: &TrafficReport) -> f64 {
    per_layer.codebook_loads.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rom_wins_by_orders_of_magnitude() {
        let w = SwitchWorkload {
            nets: 5,
            layers_per_net: 20,
            codebook_bytes_per_layer: 64 * 1024,
            rounds: 10,
            inferences_per_activation: 5,
            // SRAM fits ~1.5 networks -> heavy thrash on switches
            sram_bytes: 30 * 64 * 1024,
        };
        let (pl, rom) = compare(&w);
        assert_eq!(rom.codebook_loads, 0);
        assert!(
            pl.codebook_loads > 500,
            "per-layer should thrash hundreds of loads, got {}",
            pl.codebook_loads
        );
        assert_eq!(pl.inferences, rom.inferences);
    }

    #[test]
    fn generous_sram_still_pays_cold_loads() {
        let w = SwitchWorkload {
            nets: 3,
            layers_per_net: 10,
            codebook_bytes_per_layer: 4096,
            rounds: 4,
            inferences_per_activation: 8,
            sram_bytes: 1 << 30,
        };
        let (pl, rom) = compare(&w);
        assert_eq!(pl.codebook_loads, 30, "one cold load per codebook");
        assert_eq!(rom.codebook_loads, 0);
    }
}
