//! TCP serving front-end: newline-delimited JSON over `std::net`.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"net": "mini_mlp", "row": 5}
//! <- {"ok": true, "net": "mini_mlp", "row": 5, "argmax": 3,
//!     "batch": 4, "latency_us": 812.0}
//! <- {"ok": false, "error": "router: unknown network \"ghost\""}
//! ```
//!
//! Threading model: PJRT executables are not thread-safe to share, so
//! **one dispatch thread owns every session** and runs the dynamic
//! batcher against a real clock; each connection gets a reader thread
//! that parses lines into an mpsc queue and a writer handle the
//! dispatcher answers through.  This is the same router/batcher policy
//! as [`super::server`], with wall-clock linger instead of virtual time.
//! (`tokio` is not vendored in this build environment; the std::net +
//! channel design keeps the same structure an async runtime would.)

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::calib::gather_rows;
use crate::coordinator::session::NetSession;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::util::stats::Summary;
use crate::util::threadpool::ThreadPool;

use super::batcher::BatcherConfig;
use super::engine::Engine;

/// One parsed in-flight request.
struct InFlight {
    conn: u64,
    net: String,
    row: usize,
    arrived: Instant,
}

/// Per-network serving statistics (mirrors `server::ServeStats`,
/// including the bounded wall-clock latency summary).
#[derive(Clone, Debug, Default)]
pub struct TcpStats {
    pub served: u64,
    pub batches: u64,
    pub errors: u64,
    /// Wall-clock request latency (µs) — bounded accounting.
    pub latency_us: Summary,
    /// Weight rows served out of the attached decode plane's cache.
    pub rows_from_cache: u64,
    /// Weight rows the decode plane decoded fresh.
    pub rows_decoded: u64,
}

/// Shared handle for shutting the server down from another thread.
#[derive(Clone)]
pub struct Shutdown(Arc<AtomicBool>);

impl Default for Shutdown {
    fn default() -> Self {
        Self::new()
    }
}

impl Shutdown {
    pub fn new() -> Self {
        Shutdown(Arc::new(AtomicBool::new(false)))
    }
    pub fn trigger(&self) {
        self.0.store(true, Ordering::SeqCst);
    }
    pub fn is_set(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Parse one request line. Returns (net, row).
pub fn parse_request(line: &str) -> anyhow::Result<(String, usize)> {
    let v = json::parse(line)?;
    let net = v.req_str("net")?.to_string();
    let row = v.req_usize("row")?;
    Ok((net, row))
}

/// Render a success response.
pub fn ok_response(net: &str, row: usize, argmax: usize, batch: usize, latency_us: f64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("net", Json::str(net.to_string())),
        ("row", Json::num(row as f64)),
        ("argmax", Json::num(argmax as f64)),
        ("batch", Json::num(batch as f64)),
        ("latency_us", Json::num(latency_us)),
    ])
    .to_string()
}

/// Render an error response.
pub fn err_response(msg: &str) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg.to_string())),
    ])
    .to_string()
}

/// The TCP server. Owns the constructed sessions + their hard codes.
pub struct TcpServer {
    sessions: BTreeMap<String, (NetSession, Tensor)>,
    pub cfg: BatcherConfig,
    pub stats: BTreeMap<String, TcpStats>,
    /// Optional sharded decode plane (see `server::Server::plane`) —
    /// same engine, wall clock instead of virtual time.
    pub plane: Option<Engine>,
    /// Worker pool the plane's miss-decodes run on (None = serial).
    plane_pool: Option<ThreadPool>,
}

impl TcpServer {
    pub fn new(sessions: Vec<(NetSession, Tensor)>, cfg: BatcherConfig) -> Self {
        let mut map = BTreeMap::new();
        let mut stats = BTreeMap::new();
        for (s, codes) in sessions {
            stats.insert(s.net.name.clone(), TcpStats::default());
            map.insert(s.net.name.clone(), (s, codes));
        }
        TcpServer {
            sessions: map,
            cfg,
            stats,
            plane: None,
            plane_pool: None,
        }
    }

    /// Attach a decode plane the dispatch path streams every batch's
    /// weight rows through; `pool` parallelizes the plane's cache-miss
    /// decodes (None = serial).
    pub fn attach_plane(&mut self, plane: Engine, pool: Option<ThreadPool>) {
        self.plane = Some(plane);
        self.plane_pool = pool;
    }

    /// Serve until `shutdown` triggers.  Blocks the calling thread (it
    /// becomes the dispatch thread).  `max_requests` (if nonzero) stops
    /// the server after that many served requests — used by tests and
    /// the example's `--requests` bound.
    pub fn serve(
        &mut self,
        listener: TcpListener,
        shutdown: Shutdown,
        max_requests: u64,
    ) -> anyhow::Result<u64> {
        listener.set_nonblocking(true)?;
        let (tx, rx): (Sender<InFlight>, Receiver<InFlight>) = channel();
        let conn_seq = Arc::new(AtomicU64::new(0));
        // Writers: dispatch thread sends rendered lines per connection.
        let writers: Arc<std::sync::Mutex<BTreeMap<u64, TcpStream>>> =
            Arc::new(std::sync::Mutex::new(BTreeMap::new()));

        // Accept loop on a helper thread.
        let accept_shutdown = shutdown.clone();
        let accept_writers = writers.clone();
        let accept_tx = tx.clone();
        let acceptor = std::thread::spawn(move || {
            while !accept_shutdown.is_set() {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let id = conn_seq.fetch_add(1, Ordering::SeqCst);
                        let ws = stream.try_clone().expect("clone stream");
                        accept_writers.lock().unwrap().insert(id, ws);
                        let tx2 = accept_tx.clone();
                        let wmap = accept_writers.clone();
                        std::thread::spawn(move || {
                            let reader = BufReader::new(stream);
                            for line in reader.lines() {
                                let Ok(line) = line else { break };
                                if line.trim().is_empty() {
                                    continue;
                                }
                                match parse_request(&line) {
                                    Ok((net, row)) => {
                                        if tx2
                                            .send(InFlight {
                                                conn: id,
                                                net,
                                                row,
                                                arrived: Instant::now(),
                                            })
                                            .is_err()
                                        {
                                            break;
                                        }
                                    }
                                    Err(e) => {
                                        if let Some(w) = wmap.lock().unwrap().get_mut(&id) {
                                            let _ = writeln!(w, "{}", err_response(&e.to_string()));
                                        }
                                    }
                                }
                            }
                            wmap.lock().unwrap().remove(&id);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });

        // Dispatch loop (this thread): batch per network with linger.
        let mut pending: BTreeMap<String, Vec<InFlight>> = BTreeMap::new();
        let mut served = 0u64;
        let linger = Duration::from_nanos(self.cfg.max_linger_ns);
        while !shutdown.is_set() {
            match rx.recv_timeout(linger.max(Duration::from_millis(1))) {
                Ok(req) => pending.entry(req.net.clone()).or_default().push(req),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            // Fire every queue that is full or has lingered.
            let names: Vec<String> = pending.keys().cloned().collect();
            for name in names {
                let q = pending.get_mut(&name).unwrap();
                if q.is_empty() {
                    continue;
                }
                let full = q.len() >= self.cfg.max_batch;
                let lingered = q[0].arrived.elapsed() >= linger;
                if !(full || lingered) {
                    continue;
                }
                // Never drain more than the artifact's fixed batch can
                // carry — leftovers stay queued for the next firing
                // (mirrors server::dispatch_one).  Unknown nets drain at
                // max_batch; dispatch answers them all with errors.
                let cap = match self.sessions.get(&name) {
                    Some((s, _)) => self.cfg.max_batch.min(s.net.eval_batch),
                    None => self.cfg.max_batch,
                };
                let reqs: Vec<InFlight> = q.drain(..q.len().min(cap.max(1))).collect();
                served += self.dispatch(&name, reqs, &writers)?;
            }
            if max_requests > 0 && served >= max_requests {
                shutdown.trigger();
            }
        }
        drop(tx);
        let _ = acceptor.join();
        Ok(served)
    }

    /// Execute one batch and answer every requester.
    fn dispatch(
        &mut self,
        name: &str,
        reqs: Vec<InFlight>,
        writers: &Arc<std::sync::Mutex<BTreeMap<u64, TcpStream>>>,
    ) -> anyhow::Result<u64> {
        let Some((sess, codes)) = self.sessions.get_mut(name) else {
            let msg = err_response(&format!("unknown network {name:?}"));
            let mut w = writers.lock().unwrap();
            for r in &reqs {
                if let Some(ws) = w.get_mut(&r.conn) {
                    let _ = writeln!(ws, "{msg}");
                }
            }
            let st = self.stats.entry(name.to_string()).or_default();
            st.errors += reqs.len() as u64;
            return Ok(0);
        };
        let device_batch = sess.net.eval_batch;
        let pool_rows = sess.test_x.shape[0];
        let mut rows: Vec<usize> = reqs.iter().map(|r| r.row % pool_rows).collect();
        let real = rows.len();
        for i in 0..device_batch.saturating_sub(real) {
            rows.push(rows[i % real]); // pad with real rows
        }
        // Stream the batch's weight rows through the decode plane (cache
        // + fused unpack) into the owning shard's staging buffer, when a
        // plane is attached and hosts this net — decode precedes the
        // artifact run, mirroring server::dispatch_one.
        let row_serve = match self.plane.as_mut() {
            Some(plane) => plane.stream_batch(name, &rows, self.plane_pool.as_ref())?,
            None => None,
        };

        let x = gather_rows(&sess.test_x, &rows)?;
        let codes_t = codes.clone();
        let out = sess.eval_infer(&codes_t, &[x])?;
        let logits = out[0].as_f32()?;
        let classes = out[0].shape.get(1).copied().unwrap_or(1);

        let st = self.stats.entry(name.to_string()).or_default();
        let mut w = writers.lock().unwrap();
        for (i, r) in reqs.iter().enumerate() {
            let seg = &logits[i * classes..(i + 1) * classes];
            let argmax = seg
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            let latency = r.arrived.elapsed().as_micros() as f64;
            st.latency_us.push(latency);
            if let Some(ws) = w.get_mut(&r.conn) {
                let _ = writeln!(ws, "{}", ok_response(name, r.row, argmax, real, latency));
            }
        }
        st.served += real as u64;
        st.batches += 1;
        if let Some(rs) = row_serve {
            st.rows_from_cache += rs.hits as u64;
            st.rows_decoded += rs.misses as u64;
        }
        Ok(real as u64)
    }
}

/// Blocking client helper (examples + tests): send one request, read
/// one response line.
pub fn client_request(stream: &mut TcpStream, net: &str, row: usize) -> anyhow::Result<Json> {
    let req = Json::obj(vec![
        ("net", Json::str(net.to_string())),
        ("row", Json::num(row as f64)),
    ]);
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(&line)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_parses() {
        let (net, row) = parse_request(r#"{"net": "mini_mlp", "row": 7}"#).unwrap();
        assert_eq!(net, "mini_mlp");
        assert_eq!(row, 7);
        assert!(parse_request(r#"{"row": 7}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn responses_are_valid_json() {
        let ok = ok_response("a", 3, 9, 4, 120.5);
        let v = json::parse(&ok).unwrap();
        assert_eq!(v.req_str("net").unwrap(), "a");
        assert_eq!(v.req_usize("argmax").unwrap(), 9);
        let err = err_response("boom");
        let v = json::parse(&err).unwrap();
        assert_eq!(v.req_str("error").unwrap(), "boom");
    }

    #[test]
    fn shutdown_flag_is_shared() {
        let s = Shutdown::new();
        let s2 = s.clone();
        assert!(!s.is_set());
        s2.trigger();
        assert!(s.is_set());
    }
}
