//! TCP serving front-end: newline-delimited JSON over `std::net`.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"net": "mini_mlp", "row": 5}
//! -> {"net": "mini_mlp", "row": 6, "deadline_ms": 250}
//! <- {"ok": true, "net": "mini_mlp", "row": 5, "argmax": 3,
//!     "batch": 4, "latency_us": 812.0}
//! <- {"ok": false, "error": "unknown network \"ghost\""}
//! <- {"ok": false, "error": "row 999 out of range: \"mini_mlp\" serves rows 0..64"}
//! <- {"ok": false, "error": "deadline expired after 250 ms before the batch fired"}
//! -> {"stats": true}
//! <- {"ok": true, "stats": true, "accepted": 10, "dispatched": 10,
//!     "shed": 0, "deferred": 0, "peak_depth": 4, "rows_decoded": 40,
//!     "rows_from_cache": 24, "cache_hit_rate": 0.375,
//!     "queue_wait": {"unit": "ns", "clock": "engine", "count": 10,
//!                    "p50": ..., "p90": ..., "p99": ...},
//!     "per_net": {...}}
//! -> {"metrics": true}
//! <- {"ok": true, "metrics": true,
//!     "content_type": "text/plain; version=0.0.4",
//!     "body": "# HELP vq4all_requests_accepted_total ...\n..."}
//! -> {"metrics": true, "format": "json"}
//! <- {"ok": true, "metrics": true, "format": "json", "snapshot": {...}}
//! -> {"trace": true}
//! <- {"ok": true, "trace": true, "recorded": 3, "dropped": 0,
//!     "events": [{"shard": 0, "seq": 0, "at_ns": 10, "kind": "shed",
//!                 "net": "a", "a": 5, "b": 2}, ...]}
//! ```
//!
//! The `/stats`, `/metrics`, and `/trace` verbs are answered by the
//! dispatch thread (a consistent snapshot of the plane it owns) and
//! ride the same reader channel as row requests, so they observe the
//! protocol's ordering — including waiting behind backpressure like any
//! other line.  `/metrics` carries the Prometheus exposition as an
//! escaped string under `"body"` because the wire protocol is
//! newline-framed: one JSON object per line, however many lines the
//! text format itself has.
//!
//! The servable row space is `0..min(stream_rows, input_pool_rows)` —
//! bounded by the hosted packed stream and the session's input pool;
//! out-of-range rows are answered with a structured error rather than
//! silently wrapped onto a different row.
//!
//! Threading model: PJRT executables are not thread-safe to share, so
//! **one dispatch thread owns every session and the engine plane**; each
//! connection gets a reader thread that parses lines into a **bounded**
//! mpsc queue and a writer handle the dispatcher answers through.
//! Routing, batching, and admission all happen on the same sharded
//! [`Engine`] plane as [`super::server`], driven by a wall clock
//! ([`Engine::set_now`]) instead of virtual time.
//!
//! **Framing:** one frame is one `\n`-terminated line, hard-capped at
//! [`MAX_FRAME_BYTES`].  An oversized frame, a stream that ends
//! mid-frame, and non-UTF-8 bytes are all answered with a structured
//! error instead of silently killing the reader thread; only the
//! errors that lose framing (oversized, truncated) close the
//! connection.
//!
//! **Deadlines:** a row request may carry `"deadline_ms"` (relative,
//! from arrival at the dispatcher).  The engine enforces it at fire
//! time — an expired request is ledgered `expired` and shed before any
//! decode — and the dispatcher answers the waiting connection with a
//! structured error so no client hangs on a request that will never
//! fire.
//!
//! **Backpressure (wall-clock admission policy):** where the
//! virtual-clock front-end sheds over-budget submissions, the TCP
//! dispatcher *defers* — it probes [`Engine::would_admit`], parks the
//! request in a local FIFO, and stops pulling from the reader channel
//! until the shard drains.  The bounded channel then fills, reader
//! threads block on `send`, and the kernel socket buffers throttle the
//! clients; each parked request counts one deferral on the owning
//! shard ([`Engine::note_deferral`]).  (`tokio` is not vendored in this
//! build environment; the std::net + channel design keeps the same
//! structure an async runtime would.)

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::calib::gather_rows;
use crate::coordinator::session::NetSession;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::threadpool::ThreadPool;

use super::batcher::Batch;
use super::engine::{Admission, Engine};
use super::faults::{FaultPlan, FaultSite};
use super::obs::{expose, EventKind};

/// Hard cap on one newline-delimited frame.  A peer that streams more
/// than this without a `\n` gets a structured error and loses the
/// connection (framing is unrecoverable) instead of growing an
/// unbounded line buffer on the reader thread.
pub const MAX_FRAME_BYTES: usize = 64 * 1024;

/// Outcome of pulling one frame off the wire — every way a read can
/// end, so the reader loop can answer each with a structured error
/// rather than dying silently.
#[derive(Debug, PartialEq, Eq)]
pub enum Frame {
    /// A complete line, newline stripped.
    Line(String),
    /// Peer closed cleanly at a frame boundary.
    Eof,
    /// The frame exceeded the cap before its newline arrived.
    Oversized { read: usize },
    /// The stream ended mid-frame (bytes but no trailing newline).
    Truncated { read: usize },
    /// A complete line that was not valid UTF-8.  Framing is intact
    /// (the newline was consumed), so the connection can continue.
    BadUtf8,
}

/// Read one bounded frame.  Never allocates more than `max + one
/// BufRead chunk`; consumes through the terminating newline on
/// success and on `BadUtf8`, and stops consuming as soon as the cap
/// is exceeded on `Oversized`.
pub fn read_frame<R: BufRead>(r: &mut R, max: usize) -> std::io::Result<Frame> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                return Ok(if buf.is_empty() {
                    Frame::Eof
                } else {
                    Frame::Truncated { read: buf.len() }
                });
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    buf.extend_from_slice(&chunk[..pos]);
                    (true, pos + 1)
                }
                None => {
                    buf.extend_from_slice(chunk);
                    (false, chunk.len())
                }
            }
        };
        r.consume(used);
        if buf.len() > max {
            return Ok(Frame::Oversized { read: buf.len() });
        }
        if done {
            return Ok(match String::from_utf8(buf) {
                Ok(s) => Frame::Line(s),
                Err(_) => Frame::BadUtf8,
            });
        }
    }
}

/// One parsed in-flight request.
struct InFlight {
    conn: u64,
    net: String,
    row: usize,
    arrived: Instant,
    /// Relative deadline in ms (0 = none), converted onto the engine
    /// clock at enqueue time.
    deadline_ms: u64,
}

/// One line pulled off a reader channel: a row request, or a control
/// verb the dispatch thread answers directly.
enum Inbound {
    Request(InFlight),
    /// `{"stats": true}` — dump the plane's admission + throughput
    /// counters to this connection.
    Stats { conn: u64 },
    /// `{"metrics": true}` — dump the unified metrics snapshot
    /// (Prometheus text by default, `"format": "json"` for the raw
    /// snapshot object).
    Metrics { conn: u64, json: bool },
    /// `{"trace": true}` — dump every shard's retained flight-recorder
    /// events.
    Trace { conn: u64 },
}

/// Per-connection writer handles the dispatch thread answers through.
type Writers = Arc<Mutex<BTreeMap<u64, TcpStream>>>;

/// (conn, arrival, engine-clock deadline_ns) for every enqueued
/// request, keyed by (net, shard-local request id) — ids are unique per
/// net because a net lives on exactly one shard router.  The deadline
/// rides along so the dispatcher can answer a connection whose request
/// the engine expired (the engine sheds it from the queue; the client
/// still needs a response line).
type InFlightMap = BTreeMap<(String, u64), (u64, Instant, u64)>;

/// Per-network serving statistics (mirrors `server::ServeStats`,
/// including the bounded wall-clock latency summary).
#[derive(Clone, Debug, Default)]
pub struct TcpStats {
    pub served: u64,
    pub batches: u64,
    pub errors: u64,
    /// Wall-clock request latency (µs) — bounded accounting.
    pub latency_us: Summary,
    /// Weight rows served out of the decode plane's cache.
    pub rows_from_cache: u64,
    /// Weight rows the decode plane decoded fresh.
    pub rows_decoded: u64,
}

/// Shared handle for shutting the server down from another thread.
#[derive(Clone)]
pub struct Shutdown(Arc<AtomicBool>);

impl Default for Shutdown {
    fn default() -> Self {
        Self::new()
    }
}

impl Shutdown {
    pub fn new() -> Self {
        Shutdown(Arc::new(AtomicBool::new(false)))
    }
    pub fn trigger(&self) {
        self.0.store(true, Ordering::SeqCst);
    }
    pub fn is_set(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// One parsed inbound line of the wire protocol.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Verb {
    /// `{"net": ..., "row": ...}` — serve one row.  The optional
    /// `"deadline_ms"` key (relative, 0 = none) bounds how long the
    /// request may wait for its batch to fire.
    Infer { net: String, row: usize, deadline_ms: u64 },
    /// `{"stats": true}` — report the plane's admission and decode
    /// throughput counters (ROADMAP: surfacing the admission counters
    /// over a `/stats` TCP verb).
    Stats,
    /// `{"metrics": true}` — the unified observability snapshot, as
    /// Prometheus text (default) or the raw snapshot object
    /// (`"format": "json"`).
    Metrics { json: bool },
    /// `{"trace": true}` — the per-shard flight recorders' retained
    /// structured events.
    Trace,
}

/// Parse one protocol line into a [`Verb`].
pub fn parse_verb(line: &str) -> anyhow::Result<Verb> {
    let v = json::parse(line)?;
    if let Some(s) = v.get("stats") {
        anyhow::ensure!(
            s.as_bool() == Some(true),
            "the \"stats\" key must be `true` when present"
        );
        return Ok(Verb::Stats);
    }
    if let Some(m) = v.get("metrics") {
        anyhow::ensure!(
            m.as_bool() == Some(true),
            "the \"metrics\" key must be `true` when present"
        );
        let json = match v.get("format").and_then(|f| f.as_str()) {
            None | Some("prometheus") | Some("text") => false,
            Some("json") => true,
            Some(other) => anyhow::bail!(
                "unknown metrics format {other:?} (expected \"prometheus\" or \"json\")"
            ),
        };
        return Ok(Verb::Metrics { json });
    }
    if let Some(t) = v.get("trace") {
        anyhow::ensure!(
            t.as_bool() == Some(true),
            "the \"trace\" key must be `true` when present"
        );
        return Ok(Verb::Trace);
    }
    let net = v.req_str("net")?.to_string();
    let row = v.req_usize("row")?;
    let deadline_ms = match v.get("deadline_ms") {
        None => 0,
        Some(d) => d
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("\"deadline_ms\" must be a nonnegative integer"))?
            as u64,
    };
    Ok(Verb::Infer { net, row, deadline_ms })
}

/// Parse one request line. Returns (net, row).  Row-request-only wrapper
/// around [`parse_verb`], kept for callers that never speak verbs.
pub fn parse_request(line: &str) -> anyhow::Result<(String, usize)> {
    match parse_verb(line)? {
        Verb::Infer { net, row, .. } => Ok((net, row)),
        Verb::Stats | Verb::Metrics { .. } | Verb::Trace => {
            anyhow::bail!("expected a row request, got a control verb")
        }
    }
}

/// Render a success response.
pub fn ok_response(net: &str, row: usize, argmax: usize, batch: usize, latency_us: f64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("net", Json::str(net.to_string())),
        ("row", Json::num(row as f64)),
        ("argmax", Json::num(argmax as f64)),
        ("batch", Json::num(batch as f64)),
        ("latency_us", Json::num(latency_us)),
    ])
    .to_string()
}

/// Render an error response.
pub fn err_response(msg: &str) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg.to_string())),
    ])
    .to_string()
}

/// Render the `/stats` verb response: the plane's admission counters
/// (accepted / dispatched / shed / expired / failed / deferred / peak
/// queue depth, plus the quarantined-shard gauge), decode
/// throughput counters (rows decoded fresh vs served from cache, cache
/// hit rate and evictions), and per-net serve counts plus the hosting
/// audit's per-stage codeword utilization (fraction of the universal
/// codebook a net's assignment stream actually addresses, and the
/// empirical code entropy in bits — the collapse/under-use diagnostics
/// of arXiv 2309.17361, computed once at hosting time).
pub fn stats_response(plane: &Engine, stats: &BTreeMap<String, TcpStats>) -> String {
    let t = plane.totals();
    let cs = plane.cache_stats();
    let per_net: BTreeMap<String, Json> = stats
        .iter()
        .map(|(n, s)| {
            // One object per residual stage, stage order; empty for nets
            // the plane does not host (stats entries can outlive hosting
            // in principle — never invent counters for them).
            let utilization = Json::Arr(
                plane
                    .net_utilization(n)
                    .unwrap_or(&[])
                    .iter()
                    .map(|u| {
                        Json::obj(vec![
                            ("k", Json::num(u.k as f64)),
                            ("codes", Json::num(u.total as f64)),
                            ("used", Json::num(u.used as f64)),
                            ("used_fraction", Json::num(u.used_fraction())),
                            ("entropy_bits", Json::num(u.entropy_bits)),
                        ])
                    })
                    .collect(),
            );
            (
                n.clone(),
                Json::obj(vec![
                    ("served", Json::num(s.served as f64)),
                    ("batches", Json::num(s.batches as f64)),
                    ("errors", Json::num(s.errors as f64)),
                    ("rows_from_cache", Json::num(s.rows_from_cache as f64)),
                    ("rows_decoded", Json::num(s.rows_decoded as f64)),
                    // Wall-clock request latency, reservoir percentiles —
                    // same labeled shape as the engine-clock `queue_wait`
                    // below so the two latency families read uniformly.
                    ("latency", expose::latency_summary_json(&s.latency_us, "us", "wall")),
                    ("utilization", utilization),
                ]),
            )
        })
        .collect();
    // Plane-wide queue-wait summary on the engine clock: exact moments,
    // reservoir percentiles, merged across shards at snapshot time.
    let mut queue_wait = Summary::new();
    for sh in plane.shards() {
        queue_wait.absorb(&sh.stats.latency_ns);
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("stats", Json::Bool(true)),
        ("accepted", Json::num(t.accepted as f64)),
        ("dispatched", Json::num(t.served as f64)),
        ("shed", Json::num(t.shed as f64)),
        ("expired", Json::num(t.expired as f64)),
        ("failed", Json::num(t.failed as f64)),
        (
            "quarantined_shards",
            Json::num(plane.shards().iter().filter(|s| s.is_quarantined()).count() as f64),
        ),
        ("deferred", Json::num(t.deferred as f64)),
        ("peak_depth", Json::num(t.peak_depth as f64)),
        ("pending", Json::num(plane.total_pending() as f64)),
        ("batches", Json::num(t.batches as f64)),
        ("padded_rows", Json::num(t.padded_rows as f64)),
        ("rows_decoded", Json::num(t.rows_decoded as f64)),
        ("rows_from_cache", Json::num(t.rows_from_cache as f64)),
        ("cache_hit_rate", Json::num(cs.hit_rate())),
        ("cache_evictions", Json::num(cs.evictions as f64)),
        ("max_queue_depth", Json::num(plane.cfg.max_queue_depth as f64)),
        ("shards", Json::num(plane.shard_count() as f64)),
        ("queue_wait", expose::latency_summary_json(&queue_wait, "ns", "engine")),
        ("per_net", Json::Obj(per_net)),
    ])
    .to_string()
}

/// Render the `/metrics` verb response.  The Prometheus exposition is
/// multi-line text, but the wire protocol is one JSON object per line —
/// so the text rides as an escaped string under `"body"`, next to the
/// `content_type` a gateway would serve it with.  `"format": "json"`
/// returns the raw [`MetricsSnapshot`] object instead.
pub fn metrics_response(plane: &Engine, json_format: bool) -> String {
    let snap = plane.metrics_snapshot();
    if json_format {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", Json::Bool(true)),
            ("format", Json::str("json".to_string())),
            ("snapshot", expose::snapshot_json(&snap)),
        ])
        .to_string()
    } else {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", Json::Bool(true)),
            ("content_type", Json::str("text/plain; version=0.0.4".to_string())),
            ("body", Json::str(expose::prometheus_text(&snap))),
        ])
        .to_string()
    }
}

/// Render the `/trace` verb response: every shard's retained
/// flight-recorder events, oldest first within a shard, plus the
/// lifetime recorded/dropped counters so a reader knows how much
/// history the rings have already shed.
pub fn trace_response(plane: &Engine) -> String {
    let events: Vec<Json> = plane
        .trace_events()
        .iter()
        .map(|(shard, e)| {
            Json::obj(vec![
                ("shard", Json::num(*shard as f64)),
                ("seq", Json::num(e.seq as f64)),
                ("at_ns", Json::num(e.at_ns as f64)),
                ("kind", Json::str(e.kind.as_str().to_string())),
                ("net", Json::str(e.net.clone())),
                ("a", Json::num(e.a as f64)),
                ("b", Json::num(e.b as f64)),
            ])
        })
        .collect();
    let (recorded, dropped) = plane.shards().iter().fold((0u64, 0u64), |(r, d), s| {
        (r + s.obs.recorder.recorded(), d + s.obs.recorder.dropped())
    });
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("trace", Json::Bool(true)),
        ("recorded", Json::num(recorded as f64)),
        ("dropped", Json::num(dropped as f64)),
        ("events", Json::Arr(events)),
    ])
    .to_string()
}

/// The TCP server. Owns the constructed sessions + their hard codes and
/// the engine plane that routes every request.
pub struct TcpServer {
    sessions: BTreeMap<String, (NetSession, Tensor)>,
    pub stats: BTreeMap<String, TcpStats>,
    /// The sharded decode/dispatch plane (see `server::Server::plane`) —
    /// same engine, wall clock instead of virtual time.
    pub plane: Engine,
    /// Worker pool the plane's miss-decodes run on (None = serial).
    plane_pool: Option<ThreadPool>,
    /// Chaos-suite socket faults: when armed (and the `fault-inject`
    /// feature is on), every reader thread probes
    /// [`FaultSite::SocketDrop`] per frame and severs its connection
    /// when the plan fires — the fault the client retry helpers are
    /// tested against.  `None` (the default) never drops anything.
    pub socket_faults: Option<FaultPlan>,
}

impl TcpServer {
    /// Build the server on a plane whose hosted nets and the sessions
    /// match one-to-one, each hosted at the session's `eval_batch` (the
    /// plane forms the batches now).  See [`Engine::validate_sessions`].
    pub fn new(
        sessions: Vec<(NetSession, Tensor)>,
        plane: Engine,
        pool: Option<ThreadPool>,
    ) -> anyhow::Result<Self> {
        let mut map = BTreeMap::new();
        let mut stats = BTreeMap::new();
        for (s, codes) in sessions {
            let name = s.net.name.clone();
            stats.insert(name.clone(), TcpStats::default());
            anyhow::ensure!(
                map.insert(name.clone(), (s, codes)).is_none(),
                "tcp: duplicate session for {name:?}"
            );
        }
        plane.validate_sessions(
            "tcp",
            map.iter().map(|(n, (s, _))| (n.as_str(), s.net.eval_batch)),
        )?;
        Ok(TcpServer {
            sessions: map,
            stats,
            plane,
            plane_pool: pool,
            socket_faults: None,
        })
    }

    /// Serve until `shutdown` triggers.  Blocks the calling thread (it
    /// becomes the dispatch thread).  `max_requests` (if nonzero) stops
    /// the server after that many served requests — used by tests and
    /// the example's `--requests` bound.
    pub fn serve(
        &mut self,
        listener: TcpListener,
        shutdown: Shutdown,
        max_requests: u64,
    ) -> anyhow::Result<u64> {
        listener.set_nonblocking(true)?;
        // Bounded reader channel: sized to the plane's admission budget
        // so blocked readers (not an unbounded queue) absorb overload.
        let cap = match self.plane.cfg.max_queue_depth {
            0 => 1024,
            d => (d * self.plane.shard_count()).max(1),
        };
        crate::log_info!(
            "serving::tcp",
            "dispatch loop up: {} shard(s), reader channel capacity {cap}",
            self.plane.shard_count()
        );
        let (tx, rx): (SyncSender<Inbound>, Receiver<Inbound>) = sync_channel(cap);
        let conn_seq = Arc::new(AtomicU64::new(0));
        // Writers: dispatch thread sends rendered lines per connection.
        let writers: Writers = Arc::new(Mutex::new(BTreeMap::new()));

        // Chaos-suite socket faults: consulted per frame by every reader
        // thread, but only when the `fault-inject` feature armed them —
        // the `cfg!` keeps both paths compiled so the release build
        // carries no dead cfg branches.
        let socket_faults: Option<FaultPlan> = if cfg!(feature = "fault-inject") {
            self.socket_faults.clone()
        } else {
            None
        };

        // Accept loop on a helper thread.
        let accept_shutdown = shutdown.clone();
        let accept_writers = writers.clone();
        let accept_tx = tx.clone();
        let acceptor = std::thread::spawn(move || {
            while !accept_shutdown.is_set() {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        let id = conn_seq.fetch_add(1, Ordering::SeqCst);
                        crate::log_debug!("serving::tcp", "conn {id} accepted from {peer}");
                        let ws = stream.try_clone().expect("clone stream");
                        accept_writers.lock().unwrap().insert(id, ws);
                        let tx2 = accept_tx.clone();
                        let wmap = accept_writers.clone();
                        // Each connection forks the plan by its id, so a
                        // seeded run drops the same connections at the
                        // same frames every time.
                        let mut plan = socket_faults.clone().map(|p| p.fork(id));
                        std::thread::spawn(move || {
                            let mut reader = BufReader::new(stream);
                            loop {
                                if let Some(p) = plan.as_mut() {
                                    if p.should_fire(FaultSite::SocketDrop) {
                                        crate::log_debug!(
                                            "serving::tcp",
                                            "conn {id}: injected socket drop"
                                        );
                                        break;
                                    }
                                }
                                let frame = match read_frame(&mut reader, MAX_FRAME_BYTES) {
                                    Ok(f) => f,
                                    Err(_) => break,
                                };
                                let line = match frame {
                                    Frame::Eof => break,
                                    Frame::Oversized { read } => {
                                        // Framing is lost: answer, then
                                        // close rather than guess where
                                        // the next frame starts.
                                        if let Some(w) = wmap.lock().unwrap().get_mut(&id) {
                                            let _ = writeln!(
                                                w,
                                                "{}",
                                                err_response(&format!(
                                                    "frame exceeds {MAX_FRAME_BYTES} bytes \
                                                     ({read} read with no newline); closing \
                                                     connection"
                                                ))
                                            );
                                        }
                                        break;
                                    }
                                    Frame::Truncated { read } => {
                                        if let Some(w) = wmap.lock().unwrap().get_mut(&id) {
                                            let _ = writeln!(
                                                w,
                                                "{}",
                                                err_response(&format!(
                                                    "connection closed mid-frame after {read} \
                                                     bytes (missing trailing newline)"
                                                ))
                                            );
                                        }
                                        break;
                                    }
                                    Frame::BadUtf8 => {
                                        // The newline was consumed, so the
                                        // framing survives this one.
                                        if let Some(w) = wmap.lock().unwrap().get_mut(&id) {
                                            let _ = writeln!(
                                                w,
                                                "{}",
                                                err_response("frame is not valid UTF-8")
                                            );
                                        }
                                        continue;
                                    }
                                    Frame::Line(l) => l,
                                };
                                if line.trim().is_empty() {
                                    continue;
                                }
                                match parse_verb(&line) {
                                    Ok(Verb::Infer { net, row, deadline_ms }) => {
                                        // Blocks when the channel is full
                                        // — the backpressure edge.
                                        if tx2
                                            .send(Inbound::Request(InFlight {
                                                conn: id,
                                                net,
                                                row,
                                                arrived: Instant::now(),
                                                deadline_ms,
                                            }))
                                            .is_err()
                                        {
                                            break;
                                        }
                                    }
                                    // Control verbs ride the same channel,
                                    // so they observe the dispatcher's
                                    // ordering (and wait behind a parked
                                    // request like any other line).
                                    Ok(Verb::Stats) => {
                                        if tx2.send(Inbound::Stats { conn: id }).is_err() {
                                            break;
                                        }
                                    }
                                    Ok(Verb::Metrics { json }) => {
                                        if tx2.send(Inbound::Metrics { conn: id, json }).is_err() {
                                            break;
                                        }
                                    }
                                    Ok(Verb::Trace) => {
                                        if tx2.send(Inbound::Trace { conn: id }).is_err() {
                                            break;
                                        }
                                    }
                                    Err(e) => {
                                        if let Some(w) = wmap.lock().unwrap().get_mut(&id) {
                                            let _ = writeln!(w, "{}", err_response(&e.to_string()));
                                        }
                                    }
                                }
                            }
                            wmap.lock().unwrap().remove(&id);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });

        // Dispatch loop (this thread): the engine plane owns the queues
        // and the batching policy; this loop feeds admission and fires.
        // At most ONE request is ever parked for backpressure (the pull
        // below is gated on the slot being empty), so an Option slot —
        // not a queue — states the invariant.
        let t0 = Instant::now();
        let elapsed_ns = |t0: &Instant| t0.elapsed().as_nanos() as u64;
        let linger = Duration::from_nanos(self.plane.cfg.batcher.max_linger_ns);
        let mut parked: Option<InFlight> = None;
        let mut inflight: InFlightMap = BTreeMap::new();
        let mut served = 0u64;
        while !shutdown.is_set() {
            self.plane.set_now(elapsed_ns(&t0));

            // Re-admit the parked request first — its shard may have
            // drained since it was deferred.  Re-validate too: a
            // quarantine may have hit while it waited, and a request
            // parked on a shard that will never serve it must be
            // answered, not held forever.
            if let Some(req) = parked.take() {
                if let Some(err) = self.reject_reason(&req) {
                    if let Some(w) = writers.lock().unwrap().get_mut(&req.conn) {
                        let _ = writeln!(w, "{}", err_response(&err));
                    }
                    self.stats.entry(req.net.clone()).or_default().errors += 1;
                } else if self.plane.would_admit(&req.net) {
                    self.enqueue(req, &mut inflight)?;
                } else {
                    parked = Some(req);
                }
            }

            // Pull from the wire only when nothing is parked: the
            // channel fills behind us and blocks the readers.
            if parked.is_none() {
                match rx.recv_timeout(linger.max(Duration::from_millis(1))) {
                    Ok(Inbound::Stats { conn }) => {
                        // Answered inline by the dispatch thread — it owns
                        // the plane, so the counters are a consistent
                        // snapshot with no extra synchronization.
                        if let Some(w) = writers.lock().unwrap().get_mut(&conn) {
                            let _ = writeln!(w, "{}", stats_response(&self.plane, &self.stats));
                        }
                    }
                    Ok(Inbound::Metrics { conn, json }) => {
                        if let Some(w) = writers.lock().unwrap().get_mut(&conn) {
                            let _ = writeln!(w, "{}", metrics_response(&self.plane, json));
                        }
                    }
                    Ok(Inbound::Trace { conn }) => {
                        if let Some(w) = writers.lock().unwrap().get_mut(&conn) {
                            let _ = writeln!(w, "{}", trace_response(&self.plane));
                        }
                    }
                    Ok(Inbound::Request(req)) => {
                        self.plane.set_now(elapsed_ns(&t0));
                        // Validate BEFORE the defer decision: a request
                        // that can never occupy a queue slot (unknown
                        // net, out-of-range row) is answered right away
                        // instead of head-of-line-blocking the channel
                        // behind a full shard.
                        if let Some(err) = self.reject_reason(&req) {
                            if let Some(w) = writers.lock().unwrap().get_mut(&req.conn) {
                                let _ = writeln!(w, "{}", err_response(&err));
                            }
                            self.stats.entry(req.net.clone()).or_default().errors += 1;
                        } else if !self.plane.would_admit(&req.net) {
                            self.plane.note_deferral(&req.net);
                            parked = Some(req);
                        } else {
                            self.enqueue(req, &mut inflight)?;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            } else {
                // The parked request waits on the plane, not the channel.
                std::thread::sleep(Duration::from_millis(1));
            }

            // Fire every batch the plane says is due (size or linger).
            loop {
                self.plane.set_now(elapsed_ns(&t0));
                let Some(batch) = self.plane.next_batch() else { break };
                served += self.dispatch(batch, &mut inflight, &writers)?;
            }

            // Answer the connections whose requests expired.  The
            // engine sheds expired requests from its queues at fire
            // time (ledgered `expired`), but the waiting client still
            // needs a response line; both sides compare the same
            // engine-clock deadline, so a request answered here is
            // never also served later (the clock only advances).
            if !inflight.is_empty() {
                let now = elapsed_ns(&t0);
                let lapsed: Vec<(String, u64)> = inflight
                    .iter()
                    .filter(|(_, &(_, _, dl))| dl != 0 && now > dl)
                    .map(|(k, _)| k.clone())
                    .collect();
                for key in lapsed {
                    let Some((conn, arrived, _)) = inflight.remove(&key) else { continue };
                    self.stats.entry(key.0.clone()).or_default().errors += 1;
                    if let Some(w) = writers.lock().unwrap().get_mut(&conn) {
                        let _ = writeln!(
                            w,
                            "{}",
                            err_response(&format!(
                                "deadline expired after {} ms before the batch fired",
                                arrived.elapsed().as_millis()
                            ))
                        );
                    }
                }
            }
            if max_requests > 0 && served >= max_requests {
                shutdown.trigger();
            }
        }
        // Drop both channel ends before joining so blocked readers
        // unblock with a send error and exit.
        drop(rx);
        drop(tx);
        let _ = acceptor.join();
        crate::log_info!("serving::tcp", "dispatch loop stopped after {served} served requests");
        Ok(served)
    }

    /// Why `req` can never be served — unknown net, or a row outside
    /// the servable range (the hosted packed stream AND the session's
    /// input pool both bound it; silently wrapping onto a different row
    /// would answer the wrong question while echoing the asked one).
    /// `None` means the request is admissible in principle and may be
    /// enqueued or deferred.  Every refusal also lands in the flight
    /// recorder ([`Engine::note_rejected`]) so `/trace` shows the
    /// requests that never reached a queue, not just the shed ones.
    fn reject_reason(&mut self, req: &InFlight) -> Option<String> {
        let Some(hosted) = self.plane.hosted(&req.net) else {
            self.plane
                .note_rejected(&req.net, EventKind::HostingError, req.row as u64, 0);
            return Some(format!("unknown network {:?}", req.net));
        };
        // A quarantined shard/net refuses submissions outright —
        // answering here keeps the request out of the defer slot, where
        // it would otherwise park forever behind a shard that will
        // never drain.
        if self.plane.quarantined(&req.net) {
            self.plane
                .note_rejected(&req.net, EventKind::RequestFailed, req.row as u64, 0);
            return Some(format!(
                "{:?} is quarantined (shard fault or code-stream integrity failure)",
                req.net
            ));
        }
        let (sess, _) = self
            .sessions
            .get(&req.net)
            .expect("every hosted net has a session (validated at construction)");
        let max_row = hosted.stream_rows().min(sess.test_x.shape[0]);
        if req.row >= max_row {
            self.plane.note_rejected(
                &req.net,
                EventKind::OutOfRangeRow,
                req.row as u64,
                max_row as u64,
            );
            return Some(format!(
                "row {} out of range: {:?} serves rows 0..{max_row}",
                req.row, req.net
            ));
        }
        None
    }

    /// Enqueue a validated, admissible request on the plane and record
    /// it in-flight so the dispatch can answer the right connection.
    /// A relative `deadline_ms` lands on the engine clock here, where
    /// submission time is known.
    fn enqueue(&mut self, req: InFlight, inflight: &mut InFlightMap) -> anyhow::Result<()> {
        let deadline_ns = match req.deadline_ms {
            0 => 0,
            ms => self.plane.now_ns.saturating_add(ms.saturating_mul(1_000_000)),
        };
        match self.plane.try_submit_deadline(&req.net, req.row, deadline_ns)? {
            Admission::Accepted { id } => {
                inflight.insert((req.net, id), (req.conn, req.arrived, deadline_ns));
                Ok(())
            }
            // Both call sites gate on would_admit and this thread is the
            // only submitter, so a shed here is a logic bug — fail loud
            // rather than dropping the request silently.
            Admission::Rejected { shard, depth } => anyhow::bail!(
                "plane shed a request the would_admit probe approved \
                 ({:?}, shard {shard}, depth {depth})",
                req.net
            ),
        }
    }

    /// Execute one plane-fired batch and answer every requester.
    fn dispatch(
        &mut self,
        batch: Batch,
        inflight: &mut InFlightMap,
        writers: &Writers,
    ) -> anyhow::Result<u64> {
        let name = batch.net.clone();
        // Stream the batch's weight rows through the plane's decode
        // cache into the owning shard's staging buffer — decode precedes
        // the artifact run, mirroring server::dispatch_one.  Each stage
        // is wall-timed here (the engine never reads a clock itself) and
        // reported back through `Engine::observe_batch`, which is what
        // feeds the decode/infer/respond stage histograms and the
        // decode-hidden ratio.
        let t_decode = Instant::now();
        // A decode failure (injected panic, integrity quarantine) takes
        // out this batch, not the server: hand the batch back to the
        // plane so the owning shard ledgers its rows `failed` and
        // quarantines, answer every waiting connection with a
        // structured error, and keep dispatching for the healthy
        // shards.
        let row_serve = match self.plane.stream_batch(&name, &batch.rows, self.plane_pool.as_ref())
        {
            Ok(rs) => rs
                .ok_or_else(|| anyhow::anyhow!("plane fired a batch for unhosted net {name:?}"))?,
            Err(e) => {
                self.plane.fail_batch(&batch);
                let msg = err_response(&format!("request failed: {e}"));
                let st = self.stats.entry(name.clone()).or_default();
                let mut w = writers.lock().unwrap();
                for r in &batch.requests {
                    st.errors += 1;
                    if let Some((conn, _, _)) = inflight.remove(&(name.clone(), r.id)) {
                        if let Some(ws) = w.get_mut(&conn) {
                            let _ = writeln!(ws, "{msg}");
                        }
                    }
                }
                return Ok(0);
            }
        };
        let decode_ns = t_decode.elapsed().as_nanos() as u64;

        let (sess, codes) = self
            .sessions
            .get_mut(&name)
            .expect("every hosted net has a session (validated at construction)");
        // Admission validated every row against both the stream and the
        // input pool, so the batch rows gather directly — no remapping.
        let x = gather_rows(&sess.test_x, &batch.rows)?;
        let codes_t = codes.clone();
        let t_infer = Instant::now();
        let out = sess.eval_infer(&codes_t, &[x])?;
        let infer_ns = t_infer.elapsed().as_nanos() as u64;
        let logits = out[0].as_f32()?;
        let classes = out[0].shape.get(1).copied().unwrap_or(1);

        let real = batch.requests.len();
        let st = self.stats.entry(name.clone()).or_default();
        st.rows_from_cache += row_serve.hits as u64;
        st.rows_decoded += row_serve.misses as u64;
        let t_respond = Instant::now();
        let mut w = writers.lock().unwrap();
        for (i, r) in batch.requests.iter().enumerate() {
            let seg = &logits[i * classes..(i + 1) * classes];
            let argmax = seg
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            let Some((conn, arrived, _)) = inflight.remove(&(name.clone(), r.id)) else {
                continue;
            };
            let latency = arrived.elapsed().as_micros() as f64;
            st.latency_us.push(latency);
            if let Some(ws) = w.get_mut(&conn) {
                let _ = writeln!(ws, "{}", ok_response(&name, r.row, argmax, real, latency));
            }
        }
        st.served += real as u64;
        st.batches += 1;
        drop(w);
        let respond_ns = t_respond.elapsed().as_nanos() as u64;
        self.plane
            .observe_batch(&name, row_serve, decode_ns, infer_ns, respond_ns);
        Ok(real as u64)
    }
}

/// Blocking client helper (examples + tests): send one request, read
/// one response line.
pub fn client_request(stream: &mut TcpStream, net: &str, row: usize) -> anyhow::Result<Json> {
    client_request_deadline(stream, net, row, 0)
}

/// [`client_request`] with a relative deadline (`deadline_ms`, 0 =
/// none): the request carries `"deadline_ms"`, and a request that
/// cannot fire in time comes back as a structured
/// `{"ok": false, "error": "deadline expired ..."}` line instead of
/// hanging the reader.
pub fn client_request_deadline(
    stream: &mut TcpStream,
    net: &str,
    row: usize,
    deadline_ms: u64,
) -> anyhow::Result<Json> {
    let mut req = vec![
        ("net", Json::str(net.to_string())),
        ("row", Json::num(row as f64)),
    ];
    if deadline_ms > 0 {
        req.push(("deadline_ms", Json::num(deadline_ms as f64)));
    }
    writeln!(stream, "{}", Json::obj(req))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(&line)
}

/// Blocking client helper for the `/stats` verb: send `{"stats": true}`,
/// read the counter snapshot.
pub fn client_stats(stream: &mut TcpStream) -> anyhow::Result<Json> {
    writeln!(stream, "{}", Json::obj(vec![("stats", Json::Bool(true))]))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(&line)
}

/// Blocking client helper for the `/metrics` verb.  `json` selects the
/// raw-snapshot format; the default is the Prometheus text exposition
/// (returned inside the JSON envelope under `"body"`).
pub fn client_metrics(stream: &mut TcpStream, json_format: bool) -> anyhow::Result<Json> {
    let mut req = vec![("metrics", Json::Bool(true))];
    if json_format {
        req.push(("format", Json::str("json".to_string())));
    }
    writeln!(stream, "{}", Json::obj(req))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(&line)
}

/// Blocking client helper for the `/trace` verb: send `{"trace": true}`,
/// read the flight-recorder dump.
pub fn client_trace(stream: &mut TcpStream) -> anyhow::Result<Json> {
    writeln!(stream, "{}", Json::obj(vec![("trace", Json::Bool(true))]))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(&line)
}

/// Client-side retry policy: exponential backoff with deterministic
/// jitter (seeded through [`Rng`], so a test run retries on the same
/// schedule every time), capped per delay and — optionally — by a
/// wall-clock deadline across all attempts.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total tries, the first included.  Must be at least 1.
    pub max_attempts: u32,
    /// Delay before the first retry; doubles per subsequent retry.
    pub base_backoff: Duration,
    /// Per-delay cap (before jitter).
    pub max_backoff: Duration,
    /// Wall-clock budget across every attempt and delay; `None` means
    /// only `max_attempts` bounds the loop.
    pub deadline: Option<Duration>,
    /// Seed for the jitter sequence — same seed, same schedule.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(200),
            deadline: Some(Duration::from_secs(5)),
            jitter_seed: 0x7C15,
        }
    }
}

impl RetryPolicy {
    /// The full delay schedule (`max_attempts - 1` entries), computed
    /// up front so it is a pure function of the policy: exponential
    /// doubling from `base_backoff`, capped at `max_backoff`, plus up
    /// to 25% deterministic jitter so synchronized clients spread out.
    pub fn backoff_schedule(&self) -> Vec<Duration> {
        let mut rng = Rng::new(self.jitter_seed);
        (1..self.max_attempts)
            .map(|a| {
                let exp = self.base_backoff.saturating_mul(1u32 << (a - 1).min(16));
                let capped = exp.min(self.max_backoff);
                let jitter_span = (capped.as_nanos() as u64 / 4).max(1) as usize;
                capped + Duration::from_nanos(rng.below(jitter_span) as u64)
            })
            .collect()
    }
}

/// Run `op` under `policy`: retry on `Err`, sleeping the scheduled
/// backoff between attempts, until it succeeds, attempts run out, or
/// the next delay would cross the deadline.  `op` receives the
/// zero-based attempt index.
pub fn with_retry<T>(
    policy: &RetryPolicy,
    mut op: impl FnMut(u32) -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    anyhow::ensure!(policy.max_attempts > 0, "retry policy allows zero attempts");
    let schedule = policy.backoff_schedule();
    let t0 = Instant::now();
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..policy.max_attempts {
        if attempt > 0 {
            let delay = schedule[(attempt - 1) as usize];
            if let Some(cap) = policy.deadline {
                if t0.elapsed() + delay >= cap {
                    let e = last.take().expect("a retry always follows a failure");
                    return Err(anyhow::anyhow!(
                        "gave up after {attempt} attempt(s): the next backoff would cross the \
                         {cap:?} deadline: {e}"
                    ));
                }
            }
            std::thread::sleep(delay);
        }
        match op(attempt) {
            Ok(v) => return Ok(v),
            Err(e) => {
                crate::log_debug!("serving::tcp", "attempt {attempt} failed: {e}");
                last = Some(e);
            }
        }
    }
    let e = last.expect("loop ran at least once");
    Err(anyhow::anyhow!("all {} attempt(s) failed: {e}", policy.max_attempts))
}

/// [`client_request`] with reconnect-and-retry under `policy` — a
/// dropped socket (the injected [`FaultSite::SocketDrop`], a restarting
/// server) fails one attempt, not the request.  Each attempt dials a
/// fresh connection: after a drop the old stream is unusable.
pub fn client_request_with_retry(
    addr: &str,
    net: &str,
    row: usize,
    policy: &RetryPolicy,
) -> anyhow::Result<Json> {
    with_retry(policy, |_| {
        let mut stream = TcpStream::connect(addr)?;
        client_request(&mut stream, net, row)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_parses() {
        let (net, row) = parse_request(r#"{"net": "mini_mlp", "row": 7}"#).unwrap();
        assert_eq!(net, "mini_mlp");
        assert_eq!(row, 7);
        assert!(parse_request(r#"{"row": 7}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn verb_parses_stats_and_rejects_malformed() {
        assert_eq!(parse_verb(r#"{"stats": true}"#).unwrap(), Verb::Stats);
        assert_eq!(
            parse_verb(r#"{"net": "a", "row": 3}"#).unwrap(),
            Verb::Infer { net: "a".into(), row: 3, deadline_ms: 0 }
        );
        assert!(parse_verb(r#"{"stats": false}"#).is_err());
        assert!(parse_verb(r#"{"stats": 1}"#).is_err());
        // The request-only wrapper refuses the verb.
        assert!(parse_request(r#"{"stats": true}"#).is_err());
    }

    #[test]
    fn verb_parses_optional_deadline() {
        assert_eq!(
            parse_verb(r#"{"net": "a", "row": 3, "deadline_ms": 250}"#).unwrap(),
            Verb::Infer { net: "a".into(), row: 3, deadline_ms: 250 }
        );
        // Absent means none; malformed is a loud error, not a silent 0.
        assert_eq!(
            parse_verb(r#"{"net": "a", "row": 3}"#).unwrap(),
            Verb::Infer { net: "a".into(), row: 3, deadline_ms: 0 }
        );
        assert!(parse_verb(r#"{"net": "a", "row": 3, "deadline_ms": "soon"}"#).is_err());
        assert!(parse_verb(r#"{"net": "a", "row": 3, "deadline_ms": true}"#).is_err());
        // The request-only wrapper still strips it down to (net, row).
        assert_eq!(
            parse_request(r#"{"net": "a", "row": 3, "deadline_ms": 9}"#).unwrap(),
            ("a".to_string(), 3)
        );
    }

    #[test]
    fn read_frame_bounds_and_classifies_every_ending() {
        use std::io::Cursor;
        let mut c = Cursor::new(b"{\"stats\": true}\nrest\n".to_vec());
        assert_eq!(
            read_frame(&mut c, 64).unwrap(),
            Frame::Line("{\"stats\": true}".into())
        );
        assert_eq!(read_frame(&mut c, 64).unwrap(), Frame::Line("rest".into()));
        assert_eq!(read_frame(&mut c, 64).unwrap(), Frame::Eof);

        // Oversized: the cap triggers even before any newline shows up.
        let big = vec![b'x'; 200];
        let mut c = Cursor::new(big);
        assert!(matches!(
            read_frame(&mut c, 64).unwrap(),
            Frame::Oversized { read } if read > 64
        ));

        // Truncated: bytes, then EOF with no newline.
        let mut c = Cursor::new(b"{\"net\": \"a\"".to_vec());
        assert_eq!(
            read_frame(&mut c, 64).unwrap(),
            Frame::Truncated { read: 11 }
        );

        // Bad UTF-8 inside a complete line: framing survives, the next
        // frame still parses.
        let mut bytes = vec![0xff, 0xfe, b'\n'];
        bytes.extend_from_slice(b"ok\n");
        let mut c = Cursor::new(bytes);
        assert_eq!(read_frame(&mut c, 64).unwrap(), Frame::BadUtf8);
        assert_eq!(read_frame(&mut c, 64).unwrap(), Frame::Line("ok".into()));
    }

    #[test]
    fn retry_schedule_is_deterministic_and_capped() {
        let policy = RetryPolicy {
            max_attempts: 6,
            base_backoff: Duration::from_micros(100),
            max_backoff: Duration::from_micros(800),
            deadline: None,
            jitter_seed: 11,
        };
        let a = policy.backoff_schedule();
        let b = policy.backoff_schedule();
        assert_eq!(a, b, "same policy, same schedule");
        assert_eq!(a.len(), 5);
        for (i, d) in a.iter().enumerate() {
            // Exponential base, +25% jitter ceiling, hard cap.
            let base = Duration::from_micros(100 * (1 << i)).min(Duration::from_micros(800));
            assert!(*d >= base, "delay {i} below its base: {d:?} < {base:?}");
            assert!(*d < base + base / 4 + Duration::from_nanos(1), "delay {i} over-jittered");
        }
        // A different seed shifts the jitter.
        let other = RetryPolicy { jitter_seed: 12, ..policy.clone() };
        assert_ne!(a, other.backoff_schedule());
    }

    #[test]
    fn with_retry_returns_first_success_and_gives_up_loudly() {
        let policy = RetryPolicy {
            max_attempts: 5,
            base_backoff: Duration::from_micros(10),
            max_backoff: Duration::from_micros(40),
            deadline: None,
            jitter_seed: 3,
        };
        let mut calls = 0u32;
        let v = with_retry(&policy, |attempt| {
            calls += 1;
            anyhow::ensure!(attempt >= 2, "injected failure");
            Ok(attempt)
        })
        .unwrap();
        assert_eq!(v, 2);
        assert_eq!(calls, 3);

        let res: anyhow::Result<u32> = with_retry(&policy, |_| anyhow::bail!("always down"));
        let err = res.unwrap_err().to_string();
        assert!(err.contains("5 attempt(s)"), "err: {err}");
        assert!(err.contains("always down"), "err: {err}");

        // A zero deadline stops the loop at the first retry boundary.
        let strict = RetryPolicy { deadline: Some(Duration::ZERO), ..policy };
        let mut tries = 0u32;
        let res: anyhow::Result<u32> = with_retry(&strict, |_| {
            tries += 1;
            anyhow::bail!("down")
        });
        let err = res.unwrap_err().to_string();
        assert_eq!(tries, 1, "no retry once the deadline is spent");
        assert!(err.contains("deadline"), "err: {err}");
    }

    /// End-to-end client resilience against the injected socket-drop
    /// fault: a listener severs the first two connections exactly as a
    /// seeded [`FaultPlan`] dictates, and the retry helper dials until
    /// it gets a real answer.
    #[test]
    fn retry_recovers_from_injected_socket_drops() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // Rate 1000 = every probe fires; the loop stops consulting
            // the plan after two drops so the third dial is served.
            let mut plan = FaultPlan::new(9).with_rate(FaultSite::SocketDrop, 1000);
            let mut drops = 0u64;
            for stream in listener.incoming() {
                let Ok(mut s) = stream else { break };
                if drops < 2 && plan.should_fire(FaultSite::SocketDrop) {
                    drops += 1;
                    drop(s); // sever before answering — the injected fault
                    continue;
                }
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                let _ = r.read_line(&mut line);
                let (net, row) = parse_request(line.trim()).unwrap();
                let _ = writeln!(s, "{}", ok_response(&net, row, 1, 1, 5.0));
                break;
            }
            (drops, plan.fired(FaultSite::SocketDrop))
        });
        let policy = RetryPolicy {
            max_attempts: 8,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(5),
            deadline: Some(Duration::from_secs(10)),
            jitter_seed: 17,
        };
        let resp = client_request_with_retry(&addr, "a", 3, &policy).unwrap();
        assert!(resp.req_bool("ok").unwrap());
        assert_eq!(resp.req_usize("row").unwrap(), 3);
        let (drops, fired) = server.join().unwrap();
        assert_eq!(drops, 2, "the plan dropped the first two connections");
        assert_eq!(fired, 2, "the plan's own firing counter agrees");
    }

    #[test]
    fn verb_parses_metrics_and_trace() {
        assert_eq!(
            parse_verb(r#"{"metrics": true}"#).unwrap(),
            Verb::Metrics { json: false }
        );
        assert_eq!(
            parse_verb(r#"{"metrics": true, "format": "prometheus"}"#).unwrap(),
            Verb::Metrics { json: false }
        );
        assert_eq!(
            parse_verb(r#"{"metrics": true, "format": "json"}"#).unwrap(),
            Verb::Metrics { json: true }
        );
        assert_eq!(parse_verb(r#"{"trace": true}"#).unwrap(), Verb::Trace);
        assert!(parse_verb(r#"{"metrics": false}"#).is_err());
        assert!(parse_verb(r#"{"metrics": true, "format": "xml"}"#).is_err());
        assert!(parse_verb(r#"{"trace": 0}"#).is_err());
        assert!(parse_request(r#"{"metrics": true}"#).is_err());
        assert!(parse_request(r#"{"trace": true}"#).is_err());
    }

    /// The stats snapshot must reflect the plane's admission + decode
    /// counters — driven end to end on a standalone engine (no PJRT
    /// artifacts needed).
    #[test]
    fn stats_response_reports_plane_counters() {
        use crate::serving::batcher::BatcherConfig;
        use crate::serving::engine::{EngineConfig, HostedNet};
        use crate::util::rng::Rng;
        use crate::vq::pack::{pack_codes, StagedCodes};
        use crate::vq::Codebook;
        use std::sync::Arc;

        let mut rng = Rng::new(51);
        let mut words = vec![0.0f32; 8 * 2];
        rng.fill_normal(&mut words);
        let cb = Arc::new(Codebook::new(8, 2, words));
        let codes: Vec<u32> = (0..24).map(|_| rng.below(8) as u32).collect();
        let net = HostedNet {
            name: "a".into(),
            codes: StagedCodes::single(pack_codes(&codes, 3)),
            codebook: cb,
            codes_per_row: 4,
            device_batch: 2,
        };
        let mut plane = Engine::new(
            EngineConfig {
                shards: 1,
                cache_bytes: 1 << 16,
                max_queue_depth: 5,
                batcher: BatcherConfig {
                    max_batch: 2,
                    max_linger_ns: 10,
                },
                obs: Default::default(),
            },
            vec![net],
        )
        .unwrap();
        for row in [0usize, 1, 0] {
            plane.submit("a", row).unwrap();
        }
        plane.drain(None).unwrap();

        let mut stats: BTreeMap<String, TcpStats> = BTreeMap::new();
        stats.entry("a".into()).or_default().served = 3;
        let parsed = json::parse(&stats_response(&plane, &stats)).unwrap();
        assert!(parsed.req_bool("ok").unwrap());
        assert!(parsed.req_bool("stats").unwrap());
        assert_eq!(parsed.req_usize("accepted").unwrap(), 3);
        assert_eq!(parsed.req_usize("dispatched").unwrap(), 3);
        assert_eq!(parsed.req_usize("shed").unwrap(), 0);
        assert_eq!(parsed.req_usize("expired").unwrap(), 0);
        assert_eq!(parsed.req_usize("failed").unwrap(), 0);
        assert_eq!(parsed.req_usize("quarantined_shards").unwrap(), 0);
        assert_eq!(parsed.req_usize("pending").unwrap(), 0);
        assert_eq!(parsed.req_usize("max_queue_depth").unwrap(), 5);
        let t = plane.totals();
        assert_eq!(
            parsed.req_usize("rows_decoded").unwrap() as u64,
            t.rows_decoded,
            "decode counter surfaced"
        );
        assert_eq!(
            parsed.req_usize("rows_from_cache").unwrap() as u64,
            t.rows_from_cache
        );
        let per_net = parsed.req("per_net").unwrap().get("a").expect("per-net entry");
        assert_eq!(per_net.req_usize("served").unwrap(), 3);
        // The hosting-time utilization audit rides along: one entry per
        // residual stage, matching the engine's own accounting.
        let util = per_net
            .get("utilization")
            .and_then(|u| u.as_arr())
            .expect("utilization array");
        assert_eq!(util.len(), 1, "single-stage net reports one stage");
        let expected = plane.net_utilization("a").expect("hosted net has utilization");
        assert_eq!(util[0].req_usize("k").unwrap(), expected[0].k);
        assert_eq!(util[0].req_usize("codes").unwrap(), expected[0].total);
        assert_eq!(util[0].req_usize("used").unwrap(), expected[0].used);
        assert!(util[0].req("entropy_bits").is_ok());
        // The unified latency shape: engine-clock queue wait at the top
        // level, wall-clock per-net latency — both labeled with their
        // unit and clock so readers never guess which family they hold.
        let qw = parsed.req("queue_wait").unwrap();
        assert_eq!(qw.req_str("unit").unwrap(), "ns");
        assert_eq!(qw.req_str("clock").unwrap(), "engine");
        assert_eq!(
            qw.req_usize("count").unwrap(),
            3,
            "one queue-wait sample per dispatched request"
        );
        assert!(qw.req_f64("p99").unwrap() >= qw.req_f64("p50").unwrap());
        let lat = per_net.req("latency").unwrap();
        assert_eq!(lat.req_str("unit").unwrap(), "us");
        assert_eq!(lat.req_str("clock").unwrap(), "wall");
        assert_eq!(lat.req_usize("count").unwrap(), 0, "no wall samples pushed here");
    }

    /// `/metrics` (both formats) and `/trace` driven end to end on a
    /// standalone engine: the Prometheus body parses under the repo's
    /// own exposition checker, the JSON snapshot carries the
    /// conservation counters, and the flight recorder surfaces the shed
    /// with its payload convention.
    #[test]
    fn metrics_and_trace_responses_expose_the_plane() {
        use crate::serving::batcher::BatcherConfig;
        use crate::serving::engine::{EngineConfig, HostedNet};
        use crate::util::rng::Rng;
        use crate::vq::pack::{pack_codes, StagedCodes};
        use crate::vq::Codebook;
        use std::sync::Arc;

        let mut rng = Rng::new(52);
        let mut words = vec![0.0f32; 8 * 2];
        rng.fill_normal(&mut words);
        let cb = Arc::new(Codebook::new(8, 2, words));
        let codes: Vec<u32> = (0..24).map(|_| rng.below(8) as u32).collect();
        let net = HostedNet {
            name: "a".into(),
            codes: StagedCodes::single(pack_codes(&codes, 3)),
            codebook: cb,
            codes_per_row: 4,
            device_batch: 2,
        };
        let mut plane = Engine::new(
            EngineConfig {
                shards: 1,
                cache_bytes: 1 << 16,
                max_queue_depth: 2,
                batcher: BatcherConfig {
                    max_batch: 2,
                    max_linger_ns: 10,
                },
                obs: Default::default(),
            },
            vec![net],
        )
        .unwrap();
        // Two admits fill the depth-2 budget; the third sheds — one
        // flight-recorder event for `/trace`.
        for row in [0usize, 1, 0] {
            let _ = plane.try_submit("a", row).unwrap();
        }
        plane.drain(None).unwrap();

        let prom = json::parse(&metrics_response(&plane, false)).unwrap();
        assert!(prom.req_bool("ok").unwrap());
        assert!(prom.req_bool("metrics").unwrap());
        assert_eq!(
            prom.req_str("content_type").unwrap(),
            "text/plain; version=0.0.4"
        );
        let body = prom.req_str("body").unwrap();
        let samples = expose::check_exposition(body).expect("valid exposition");
        assert!(samples > 0);
        assert!(body.contains("vq4all_requests_shed_total 1"));

        let js = json::parse(&metrics_response(&plane, true)).unwrap();
        assert_eq!(js.req_str("format").unwrap(), "json");
        let snap = js.req("snapshot").unwrap();
        assert_eq!(snap.req_usize("accepted").unwrap(), 3);
        assert_eq!(snap.req_usize("dispatched").unwrap(), 2);
        assert_eq!(snap.req_usize("shed").unwrap(), 1);

        let tr = json::parse(&trace_response(&plane)).unwrap();
        assert!(tr.req_bool("trace").unwrap());
        assert_eq!(tr.req_usize("recorded").unwrap(), 1);
        assert_eq!(tr.req_usize("dropped").unwrap(), 0);
        let events = tr.req("events").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].req_str("kind").unwrap(), "shed");
        assert_eq!(events[0].req_str("net").unwrap(), "a");
        assert_eq!(events[0].req_usize("shard").unwrap(), 0);
    }

    #[test]
    fn responses_are_valid_json() {
        let ok = ok_response("a", 3, 9, 4, 120.5);
        let v = json::parse(&ok).unwrap();
        assert_eq!(v.req_str("net").unwrap(), "a");
        assert_eq!(v.req_usize("argmax").unwrap(), 9);
        let err = err_response("boom");
        let v = json::parse(&err).unwrap();
        assert_eq!(v.req_str("error").unwrap(), "boom");
    }

    #[test]
    fn shutdown_flag_is_shared() {
        let s = Shutdown::new();
        let s2 = s.clone();
        assert!(!s.is_set());
        s2.trigger();
        assert!(s.is_set());
    }
}
