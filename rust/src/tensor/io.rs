//! `.vqt` tensor-file codec — the Rust half of the interchange format.
//!
//! Mirrors `python/compile/tensorio.py` byte for byte:
//!
//! ```text
//! magic  4B   b"VQT1"
//! dtype  u32  0=f32 1=i32 2=u32 3=f64 4=i64 5=u8
//! ndim   u32
//! dims   ndim * u64
//! data   raw little-endian row-major payload
//! ```

use super::{DType, Storage, Tensor};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"VQT1";

/// Read a `.vqt` file into a host [`Tensor`].
pub fn read_tensor(path: &Path) -> anyhow::Result<Tensor> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {path:?}: {e}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        anyhow::bail!("{path:?}: bad magic {magic:?}");
    }
    let tag = read_u32(&mut f)?;
    let ndim = read_u32(&mut f)? as usize;
    if ndim > 16 {
        anyhow::bail!("{path:?}: implausible ndim {ndim}");
    }
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(read_u64(&mut f)? as usize);
    }
    let dtype = DType::from_tag(tag)?;
    let count: usize = shape.iter().product();
    let mut payload = vec![0u8; count * dtype.size_bytes()];
    f.read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("{path:?}: truncated payload: {e}"))?;
    let data = decode(dtype, &payload);
    Ok(Tensor { shape, data })
}

/// Write a host [`Tensor`] as a `.vqt` file.
pub fn write_tensor(path: &Path, t: &Tensor) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).map_err(|e| anyhow::anyhow!("create {path:?}: {e}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&t.dtype().tag().to_le_bytes())?;
    f.write_all(&(t.rank() as u32).to_le_bytes())?;
    for &d in &t.shape {
        f.write_all(&(d as u64).to_le_bytes())?;
    }
    match &t.data {
        Storage::F32(v) => write_slice(&mut f, v, |x| x.to_le_bytes())?,
        Storage::I32(v) => write_slice(&mut f, v, |x| x.to_le_bytes())?,
        Storage::U32(v) => write_slice(&mut f, v, |x| x.to_le_bytes())?,
        Storage::F64(v) => write_slice(&mut f, v, |x| x.to_le_bytes())?,
        Storage::I64(v) => write_slice(&mut f, v, |x| x.to_le_bytes())?,
        Storage::U8(v) => f.write_all(v)?,
    }
    Ok(())
}

fn read_u32(f: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_slice<T: Copy, const N: usize>(
    f: &mut impl Write,
    v: &[T],
    enc: impl Fn(T) -> [u8; N],
) -> anyhow::Result<()> {
    // Chunked to keep the buffer bounded on multi-MB tensors.
    let mut buf = Vec::with_capacity(8192 * N);
    for chunk in v.chunks(8192) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&enc(x));
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

fn decode(dtype: DType, payload: &[u8]) -> Storage {
    macro_rules! dec {
        ($ty:ty, $variant:ident, $w:expr) => {{
            let v: Vec<$ty> = payload
                .chunks_exact($w)
                .map(|c| <$ty>::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Storage::$variant(v)
        }};
    }
    match dtype {
        DType::F32 => dec!(f32, F32, 4),
        DType::I32 => dec!(i32, I32, 4),
        DType::U32 => dec!(u32, U32, 4),
        DType::F64 => dec!(f64, F64, 8),
        DType::I64 => dec!(i64, I64, 8),
        DType::U8 => Storage::U8(payload.to_vec()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("vq4all_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, f32::MIN, f32::MAX]);
        let p = tmp("a.vqt");
        write_tensor(&p, &t).unwrap();
        assert_eq!(read_tensor(&p).unwrap(), t);
    }

    #[test]
    fn roundtrip_i32_and_scalar() {
        let t = Tensor::from_i32(&[4], vec![i32::MIN, -1, 0, i32::MAX]);
        let p = tmp("b.vqt");
        write_tensor(&p, &t).unwrap();
        assert_eq!(read_tensor(&p).unwrap(), t);

        // 0-dim scalar
        let s = Tensor {
            shape: vec![],
            data: Storage::F32(vec![42.0]),
        };
        let p = tmp("c.vqt");
        write_tensor(&p, &s).unwrap();
        let back = read_tensor(&p).unwrap();
        assert_eq!(back.shape, Vec::<usize>::new());
        assert_eq!(back.as_f32().unwrap(), &[42.0]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let p = tmp("bad.vqt");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(read_tensor(&p).is_err());

        let t = Tensor::from_f32(&[10], vec![0.0; 10]);
        let p2 = tmp("trunc.vqt");
        write_tensor(&p2, &t).unwrap();
        let bytes = std::fs::read(&p2).unwrap();
        std::fs::write(&p2, &bytes[..bytes.len() - 4]).unwrap();
        assert!(read_tensor(&p2).is_err());
    }

    /// Cross-language fixture: python writes, rust must read identically.
    /// (The reverse direction is covered by python/tests/test_aot.py.)
    #[test]
    fn python_compatible_layout() {
        // Hand-assembled file equal to python's write_tensor output for
        // np.array([[1.0, 2.0]], np.float32).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"VQT1");
        bytes.extend_from_slice(&0u32.to_le_bytes()); // f32
        bytes.extend_from_slice(&2u32.to_le_bytes()); // ndim
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        bytes.extend_from_slice(&2.0f32.to_le_bytes());
        let p = tmp("pyfix.vqt");
        std::fs::write(&p, &bytes).unwrap();
        let t = read_tensor(&p).unwrap();
        assert_eq!(t.shape, vec![1, 2]);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
    }
}
