//! Host tensors: a small dense ndarray, the `.vqt` file codec, and the
//! host math the substrates need (matmul, softmax, argmax/top-k).
//!
//! This is deliberately *not* a general tensor library — it covers
//! exactly what the L3 coordinator touches on the host side: marshalling
//! buffers in and out of PJRT literals, decoding VQ weights, computing
//! MSE/top-k for the analyses, and reading the artifacts python wrote.

pub mod io;
pub mod ops;

use std::fmt;

/// Element type of a [`Tensor`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
    F64,
    I64,
    U8,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::F64 | DType::I64 => 8,
            DType::U8 => 1,
        }
    }

    pub fn tag(self) -> u32 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U32 => 2,
            DType::F64 => 3,
            DType::I64 => 4,
            DType::U8 => 5,
        }
    }

    pub fn from_tag(tag: u32) -> anyhow::Result<Self> {
        Ok(match tag {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            3 => DType::F64,
            4 => DType::I64,
            5 => DType::U8,
            _ => anyhow::bail!("unknown dtype tag {tag}"),
        })
    }

    /// Parse the manifest's dtype strings.
    pub fn from_str_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            "f64" => DType::F64,
            "i64" => DType::I64,
            "u8" => DType::U8,
            _ => anyhow::bail!("unknown dtype {s:?}"),
        })
    }
}

/// Typed storage.
#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    F64(Vec<f64>),
    I64(Vec<i64>),
    U8(Vec<u8>),
}

impl Storage {
    pub fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::U32(v) => v.len(),
            Storage::F64(v) => v.len(),
            Storage::I64(v) => v.len(),
            Storage::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Storage::F32(_) => DType::F32,
            Storage::I32(_) => DType::I32,
            Storage::U32(_) => DType::U32,
            Storage::F64(_) => DType::F64,
            Storage::I64(_) => DType::I64,
            Storage::U8(_) => DType::U8,
        }
    }
}

/// Dense row-major host tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Storage,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Tensor<{:?}>{:?} ({} elems)",
            self.data.dtype(),
            self.shape,
            self.len()
        )
    }
}

impl Tensor {
    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape: shape.to_vec(),
            data: Storage::F32(data),
        }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape: shape.to_vec(),
            data: Storage::I32(data),
        }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Tensor::from_f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn zeros_i32(shape: &[usize]) -> Self {
        Tensor::from_i32(shape, vec![0; shape.iter().product()])
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Borrow as f32 slice (error if not f32).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match &self.data {
            Storage::F32(v) => Ok(v),
            other => anyhow::bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> anyhow::Result<&mut [f32]> {
        match &mut self.data {
            Storage::F32(v) => Ok(v),
            other => anyhow::bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match &self.data {
            Storage::I32(v) => Ok(v),
            other => anyhow::bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32_mut(&mut self) -> anyhow::Result<&mut [i32]> {
        match &mut self.data {
            Storage::I32(v) => Ok(v),
            other => anyhow::bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    /// Convert any numeric storage to f32 (labels, codes, ...).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match &self.data {
            Storage::F32(v) => v.clone(),
            Storage::I32(v) => v.iter().map(|&x| x as f32).collect(),
            Storage::U32(v) => v.iter().map(|&x| x as f32).collect(),
            Storage::F64(v) => v.iter().map(|&x| x as f32).collect(),
            Storage::I64(v) => v.iter().map(|&x| x as f32).collect(),
            Storage::U8(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn to_i32_vec(&self) -> Vec<i32> {
        match &self.data {
            Storage::F32(v) => v.iter().map(|&x| x as i32).collect(),
            Storage::I32(v) => v.clone(),
            Storage::U32(v) => v.iter().map(|&x| x as i32).collect(),
            Storage::F64(v) => v.iter().map(|&x| x as i32).collect(),
            Storage::I64(v) => v.iter().map(|&x| x as i32).collect(),
            Storage::U8(v) => v.iter().map(|&x| x as i32).collect(),
        }
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> anyhow::Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.len() {
            anyhow::bail!("reshape {:?} -> {shape:?}: element count mismatch", self.shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Rows `[start, end)` of a rank-2 tensor.
    pub fn slice_rows(&self, start: usize, end: usize) -> anyhow::Result<Tensor> {
        if self.rank() < 1 || start > end || end > self.shape[0] {
            anyhow::bail!("slice_rows({start}, {end}) on shape {:?}", self.shape);
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = end - start;
        let data = match &self.data {
            Storage::F32(v) => Storage::F32(v[start * row..end * row].to_vec()),
            Storage::I32(v) => Storage::I32(v[start * row..end * row].to_vec()),
            Storage::U32(v) => Storage::U32(v[start * row..end * row].to_vec()),
            Storage::F64(v) => Storage::F64(v[start * row..end * row].to_vec()),
            Storage::I64(v) => Storage::I64(v[start * row..end * row].to_vec()),
            Storage::U8(v) => Storage::U8(v[start * row..end * row].to_vec()),
        };
        Ok(Tensor { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.rank(), 2);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i32().is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        Tensor::from_f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros_f32(&[4, 2]);
        assert!(t.clone().reshape(&[2, 4]).is_ok());
        assert!(t.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn slice_rows_rank2() {
        let t = Tensor::from_f32(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let s = t.slice_rows(1, 3).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.as_f32().unwrap(), &[10., 11., 20., 21.]);
        assert!(t.slice_rows(2, 4).is_err());
    }

    #[test]
    fn dtype_conversions() {
        let t = Tensor::from_i32(&[3], vec![1, 2, 3]);
        assert_eq!(t.to_f32_vec(), vec![1.0, 2.0, 3.0]);
        assert_eq!(DType::from_str_name("i32").unwrap(), DType::I32);
        assert!(DType::from_str_name("bf16").is_err());
        for d in [DType::F32, DType::I32, DType::U32, DType::F64, DType::I64, DType::U8] {
            assert_eq!(DType::from_tag(d.tag()).unwrap(), d);
        }
    }
}
