//! Host math: the small set of numeric ops the coordinator and the
//! pure-Rust substrates need (no PJRT round-trip for these).
//!
//! Everything operates on plain slices; shapes are passed explicitly.
//! The k-means/Table-1 hot loops live in `vq::` and call into these.
//!
//! §Canonical summation order: for slices of `len >= vq::simd::LANES`
//! (8), [`sq_dist`] and [`sq_dist_pruned`] are *defined* by the
//! lane-tree accumulation of `vq::simd` (eight lane accumulators plus a
//! fixed combine tree — the order the AVX2/NEON arms compute natively),
//! and dispatch to the runtime-selected arm; below 8 they keep the
//! sequential left-to-right order.  Every naive/reference scan in the
//! crate sums through these same entry points, so specialized and
//! reference paths share one order and all the bit-identity contracts
//! hold unchanged.

use crate::vq::simd;

/// `c[m, n] = sum_k a[m, k] * b[k, n]` — naive blocked matmul, f32.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(out.len(), m * n, "out shape");
    out.fill(0.0);
    // i-k-j loop order: streams b rows, vectorizes the j loop.
    for i in 0..m {
        for kk in 0..k {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
}

/// Row-wise softmax in place over a `(rows, cols)` buffer.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the `n` smallest values, ascending (partial selection).
/// Ties break toward the smaller index, so the result is a pure function
/// of the values — the pruned top-n scan in `vq::assign` is proven
/// bit-identical against exactly this ordering.
pub fn argmin_n(xs: &[f32], n: usize) -> Vec<usize> {
    assert!(n <= xs.len(), "argmin_n: n {n} > len {}", xs.len());
    let key = |&a: &usize, &b: &usize| {
        xs[a]
            .partial_cmp(&xs[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    };
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.select_nth_unstable_by(n.saturating_sub(1), key);
    let mut head = idx[..n].to_vec();
    head.sort_by(key);
    head
}

/// Squared Euclidean distance between two equal-length slices.
///
/// At `len >= vq::simd::LANES` this is the canonical lane-tree sum (see
/// the module docs), computed by the process-wide dispatched arm
/// ([`simd::active`]); below that, the sequential left-to-right sum.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if a.len() >= simd::LANES {
        simd::sq_dist_lanes(simd::active(), a, b)
    } else {
        sq_dist_seq(a, b)
    }
}

/// The sequential (left-to-right) accumulation used below the lane
/// threshold — also the canonical order for those short widths.
#[inline]
fn sq_dist_seq(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// [`sq_dist`] with an explicit dispatch arm — the pruned sweeps probe
/// the level once per scan and thread it through here.
#[inline]
fn sq_dist_at(level: simd::SimdLevel, a: &[f32], b: &[f32]) -> f32 {
    if a.len() >= simd::LANES {
        simd::sq_dist_lanes(level, a, b)
    } else {
        sq_dist_seq(a, b)
    }
}

/// Minimum sub-vector width at which the pruned nearest-codeword scans
/// ([`nearest_pruned`], the Euclid top-n scan in `vq::assign`) pay off:
/// at this width [`sq_dist_pruned`] enters the lane-order scan (bail
/// check once per 8-lane block), and below it a bail could skip at most
/// a ragged tail — not enough to cover the compare/branch and norm-seed
/// overhead.  Callers dispatch to the retained naive scan below this
/// threshold — both paths are bit-identical, so where the line sits is
/// purely a perf knob.
pub const PRUNE_MIN_D: usize = 8;

/// The pruned-scan dispatch predicate: `d >= PRUNE_MIN_D`.  Every call
/// site ([`crate::vq::Codebook::encode_nearest_with`], the staged
/// encoder, the k-means assign sweep, the Euclid candidate sweep) gates
/// on this helper, so the boundary is testable in one place — d = 7
/// takes the naive scan, d = 8 the pruned one.
#[inline]
pub fn prunes_at(d: usize) -> bool {
    d >= PRUNE_MIN_D
}

/// Partial-distance squared Euclidean scan: accumulates `(a[i]-b[i])^2`
/// in exactly the summation order of [`sq_dist`], bailing with `None`
/// as soon as a running prefix exceeds `limit` **strictly** — so the
/// result is `Some(full sq_dist)` iff that full sum is `<= limit`.
///
/// At `len >= vq::simd::LANES` this is the lane-order pruned scan of
/// the dispatched arm (checks once per 8-lane block); below that, the
/// sequential scan with checks every 4 lanes.
///
/// Exactness: every term is nonnegative, and for nonnegative f32 `x, t`
/// round-to-nearest gives `fl(x + t) >= fl(x) = x` (rounding is
/// monotone), so the running sums never decrease — a prefix above
/// `limit` proves the full sum is above it too.  Conversely a candidate
/// whose full distance is `<= limit` never bails (all its prefixes are
/// below the final sum), so `Some(v)` carries the bit-exact [`sq_dist`]
/// value, and the observable result is a pure function of
/// `(a, b, limit)` — independent of where the intermediate checks sit
/// (see `vq::simd` for the lane-order version of the argument).  The
/// strict comparison keeps distance-equals-bound candidates alive,
/// which is what lets callers prove first-index tie-breaks unchanged.
#[inline]
pub fn sq_dist_pruned(a: &[f32], b: &[f32], limit: f32) -> Option<f32> {
    sq_dist_pruned_at(simd::active(), a, b, limit)
}

/// [`sq_dist_pruned`] with an explicit dispatch arm.
#[inline]
fn sq_dist_pruned_at(level: simd::SimdLevel, a: &[f32], b: &[f32], limit: f32) -> Option<f32> {
    debug_assert_eq!(a.len(), b.len());
    if a.len() >= simd::LANES {
        return simd::sq_dist_pruned_lanes(level, a, b, limit);
    }
    let n = a.len();
    let mut acc = 0.0f32;
    let mut i = 0;
    while i < n {
        let e = (i + 4).min(n);
        while i < e {
            let d = a[i] - b[i];
            acc += d * d;
            i += 1;
        }
        if acc > limit {
            return None;
        }
    }
    Some(acc)
}

/// Pruned first-index argmin of squared distances from `sub` to the `k`
/// rows of `words` (`norms[c]` = precomputed squared norm of row `c`).
/// Returns `(best_index, best_dist)` **bit-identical** to the naive
/// reference scan
///
/// ```text
/// for c in 0..k { d = sq_dist(sub, word(c)); if d < best_d { best = c; ... } }
/// ```
///
/// including argmin tie-breaks (first min wins) and the f32 bits of
/// `best_dist` (the winning candidate always runs to completion in
/// [`sq_dist`]'s accumulation order).  Two exact pruning devices:
///
/// * **Seed bound** — the codeword whose squared norm is closest to
///   `|sub|^2` is fully evaluated up front; its distance `B` bounds the
///   final minimum (`m <= B`, the seed is one of the candidates).  The
///   scan still visits *every* index in order, so the seed choice only
///   affects speed, never the result.
/// * **Partial-distance bail** — each candidate accumulates through
///   [`sq_dist_pruned`] with `limit = min(best_d, B)`; strict-bail
///   semantics mean a candidate with distance exactly `limit` completes
///   and ties resolve exactly as in the naive scan.
pub fn nearest_pruned(sub: &[f32], words: &[f32], norms: &[f32]) -> (usize, f32) {
    nearest_pruned_at(simd::active(), sub, words, norms)
}

/// [`nearest_pruned`] with an explicit SIMD dispatch arm, threaded
/// through every distance it computes.  The benches and property tests
/// use this to pit a forced-scalar scan against the dispatched one in a
/// single process; production call sites go through [`nearest_pruned`],
/// which probes [`simd::active`] once per scan.
pub fn nearest_pruned_at(
    level: simd::SimdLevel,
    sub: &[f32],
    words: &[f32],
    norms: &[f32],
) -> (usize, f32) {
    let d = sub.len();
    let k = norms.len();
    debug_assert_eq!(words.len(), k * d);
    debug_assert!(k > 0);
    let q = dot(sub, sub);
    let mut seed = 0usize;
    let mut seed_gap = f32::INFINITY;
    for (c, &nc) in norms.iter().enumerate() {
        let gap = (nc - q).abs();
        if gap < seed_gap {
            seed_gap = gap;
            seed = c;
        }
    }
    let bound = sq_dist_at(level, sub, &words[seed * d..(seed + 1) * d]);
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for c in 0..k {
        let limit = if best_d < bound { best_d } else { bound };
        if let Some(dist) = sq_dist_pruned_at(level, sub, &words[c * d..(c + 1) * d], limit) {
            if dist < best_d {
                best_d = dist;
                best = c;
            }
        }
    }
    (best, best_d)
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// L2 norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity (0 when either vector is ~zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    if na < 1e-20 || nb < 1e-20 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// 2x2 symmetric-matrix sqrt trace term for the 2-D Fréchet distance:
/// `tr((S1 S2)^{1/2})` via the closed form for 2x2 PSD products.
/// Used by the Table-4 "FID-like" metric on the diffusion samples.
pub fn frechet_distance_2d(
    mu1: [f32; 2],
    cov1: [[f32; 2]; 2],
    mu2: [f32; 2],
    cov2: [[f32; 2]; 2],
) -> f64 {
    let dm0 = (mu1[0] - mu2[0]) as f64;
    let dm1 = (mu1[1] - mu2[1]) as f64;
    let mean_term = dm0 * dm0 + dm1 * dm1;
    // product P = cov1 * cov2
    let p = [
        [
            cov1[0][0] as f64 * cov2[0][0] as f64 + cov1[0][1] as f64 * cov2[1][0] as f64,
            cov1[0][0] as f64 * cov2[0][1] as f64 + cov1[0][1] as f64 * cov2[1][1] as f64,
        ],
        [
            cov1[1][0] as f64 * cov2[0][0] as f64 + cov1[1][1] as f64 * cov2[1][0] as f64,
            cov1[1][0] as f64 * cov2[0][1] as f64 + cov1[1][1] as f64 * cov2[1][1] as f64,
        ],
    ];
    // For a 2x2 matrix M with trace t and det d, tr(sqrt(M)) = sqrt(t + 2 sqrt(d)).
    let t = p[0][0] + p[1][1];
    let d = (p[0][0] * p[1][1] - p[0][1] * p[1][0]).max(0.0);
    let tr_sqrt = (t + 2.0 * d.sqrt()).max(0.0).sqrt();
    let tr1 = (cov1[0][0] + cov1[1][1]) as f64;
    let tr2 = (cov2[0][0] + cov2[1][1]) as f64;
    (mean_term + tr1 + tr2 - 2.0 * tr_sqrt).max(0.0)
}

/// Sample mean and covariance of `(n, 2)` points.
pub fn mean_cov_2d(pts: &[f32]) -> ([f32; 2], [[f32; 2]; 2]) {
    let n = pts.len() / 2;
    assert!(n > 1, "need >= 2 points");
    let mut mu = [0.0f64; 2];
    for i in 0..n {
        mu[0] += pts[2 * i] as f64;
        mu[1] += pts[2 * i + 1] as f64;
    }
    mu[0] /= n as f64;
    mu[1] /= n as f64;
    let mut c = [[0.0f64; 2]; 2];
    for i in 0..n {
        let dx = pts[2 * i] as f64 - mu[0];
        let dy = pts[2 * i + 1] as f64 - mu[1];
        c[0][0] += dx * dx;
        c[0][1] += dx * dy;
        c[1][0] += dy * dx;
        c[1][1] += dy * dy;
    }
    let denom = (n - 1) as f64;
    (
        [mu[0] as f32, mu[1] as f32],
        [
            [(c[0][0] / denom) as f32, (c[0][1] / denom) as f32],
            [(c[1][0] / denom) as f32, (c[1][1] / denom) as f32],
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = same
        let a = [1., 2., 3., 4.];
        let b = [1., 0., 0., 1.];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, a);
        // known product
        let b2 = [1., 1., 1., 1.];
        matmul(&a, &b2, 2, 2, 2, &mut out);
        assert_eq!(out, [3., 3., 7., 7.]);
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut x = vec![0.0, 1.0, 2.0, -5.0, 0.0, 5.0];
        softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x[5] > 0.99, "dominant logit wins");
    }

    #[test]
    fn argmin_n_sorted_and_correct() {
        let xs = [5.0, 1.0, 4.0, 0.5, 3.0];
        assert_eq!(argmin_n(&xs, 3), vec![3, 1, 4]);
        assert_eq!(argmin_n(&xs, 5), vec![3, 1, 4, 2, 0]);
        assert_eq!(argmax(&xs), 0);
    }

    #[test]
    fn argmin_n_breaks_ties_by_index() {
        // Duplicated minima and a duplicated threshold value: the smaller
        // index must win in both the selection and the output order.
        let xs = [2.0, 1.0, 2.0, 1.0, 0.5, 2.0];
        assert_eq!(argmin_n(&xs, 1), vec![4]);
        assert_eq!(argmin_n(&xs, 2), vec![4, 1]);
        assert_eq!(argmin_n(&xs, 4), vec![4, 1, 3, 0]);
        assert_eq!(argmin_n(&xs, 6), vec![4, 1, 3, 0, 2, 5]);
    }

    #[test]
    fn sq_dist_pruned_exact_or_bails() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.0f32; 6];
        let full = sq_dist(&a, &b);
        // Generous limit: exact full value, bit for bit.
        assert_eq!(sq_dist_pruned(&a, &b, f32::INFINITY).unwrap().to_bits(), full.to_bits());
        // Limit exactly the full distance: strict bail keeps it alive.
        assert_eq!(sq_dist_pruned(&a, &b, full).unwrap().to_bits(), full.to_bits());
        // The first 4-lane prefix is 1+4+9+16 = 30: anything below bails.
        assert_eq!(sq_dist_pruned(&a, &b, 29.0), None);
        // A limit above the first prefix but below the total also bails
        // (at the final check).
        assert_eq!(sq_dist_pruned(&a, &b, full - 1.0), None);
    }

    #[test]
    fn nearest_pruned_matches_naive_scan_with_ties() {
        // k=4, d=8; words 1 and 3 are identical — the naive scan keeps
        // the first of an exact tie, and so must the pruned scan.
        let d = 8;
        let mut words = vec![0.0f32; 4 * d];
        for j in 0..d {
            words[j] = j as f32; // word 0
            words[d + j] = 1.5; // word 1
            words[2 * d + j] = -3.0; // word 2
            words[3 * d + j] = 1.5; // word 3 == word 1
        }
        let norms: Vec<f32> = words.chunks_exact(d).map(|w| dot(w, w)).collect();
        let sub = vec![1.5f32; d];
        let naive = |sub: &[f32]| {
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..4 {
                let dist = sq_dist(sub, &words[c * d..(c + 1) * d]);
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            (best, best_d)
        };
        assert_eq!(nearest_pruned(&sub, &words, &norms), naive(&sub));
        assert_eq!(nearest_pruned(&sub, &words, &norms).0, 1, "first of the tie wins");
        let far = vec![-2.9f32; d];
        assert_eq!(nearest_pruned(&far, &words, &norms), naive(&far));
    }

    #[test]
    fn prunes_at_boundary_is_exactly_prune_min_d() {
        assert!(!prunes_at(PRUNE_MIN_D - 1), "d = 7 must take the naive scan");
        assert!(prunes_at(PRUNE_MIN_D), "d = 8 must take the pruned scan");
        assert!(!prunes_at(1));
        assert!(prunes_at(16));
    }

    #[test]
    fn sq_dist_uses_the_lane_order_at_and_above_lanes() {
        let mut rng = crate::util::rng::Rng::new(0x5EED_0401);
        for n in [8usize, 9, 12, 16, 23, 32] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            let want = simd::sq_dist_lanes_reference(&a, &b);
            assert_eq!(
                sq_dist(&a, &b).to_bits(),
                want.to_bits(),
                "sq_dist must be the canonical lane-tree sum at n = {n}"
            );
        }
        // Below the threshold the sequential order stays in force.
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let b = [0.25f32; 7];
        assert_eq!(sq_dist(&a, &b).to_bits(), sq_dist_seq(&a, &b).to_bits());
    }

    #[test]
    fn sq_dist_pruned_lane_path_is_exact_or_bails() {
        let mut rng = crate::util::rng::Rng::new(0x5EED_0402);
        let n = 12;
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let full = sq_dist(&a, &b);
        for level in simd::available_levels() {
            let ok = sq_dist_pruned_at(level, &a, &b, f32::INFINITY).unwrap();
            assert_eq!(ok.to_bits(), full.to_bits(), "{}", level.name());
            // Limit exactly the full sum: strict bail keeps it alive.
            let tie = sq_dist_pruned_at(level, &a, &b, full).unwrap();
            assert_eq!(tie.to_bits(), full.to_bits(), "{}", level.name());
            // Any limit strictly below the full sum rejects.
            assert_eq!(sq_dist_pruned_at(level, &a, &b, full * 0.999), None);
            assert_eq!(sq_dist_pruned_at(level, &a, &b, 0.0), None);
        }
    }

    #[test]
    fn frechet_identical_is_zero() {
        let mu = [0.3, -0.2];
        let cov = [[1.0, 0.2], [0.2, 0.5]];
        assert!(frechet_distance_2d(mu, cov, mu, cov) < 1e-9);
    }

    #[test]
    fn frechet_mean_shift() {
        let cov = [[1.0, 0.0], [0.0, 1.0]];
        let d = frechet_distance_2d([0.0, 0.0], cov, [3.0, 4.0], cov);
        assert!((d - 25.0).abs() < 1e-6, "pure mean term = |dmu|^2, got {d}");
    }

    #[test]
    fn mean_cov_of_known_points() {
        // points: (0,0), (2,0), (0,2), (2,2) -> mean (1,1), cov diag 4/3
        let pts = [0., 0., 2., 0., 0., 2., 2., 2.];
        let (mu, cov) = mean_cov_2d(&pts);
        assert_eq!(mu, [1.0, 1.0]);
        assert!((cov[0][0] - 4.0 / 3.0).abs() < 1e-6);
        assert!((cov[1][1] - 4.0 / 3.0).abs() < 1e-6);
        assert!(cov[0][1].abs() < 1e-6);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
    }
}
