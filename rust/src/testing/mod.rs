//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! A deterministic, seed-sweeping property runner with typed generators.
//! No shrinking — instead every failure reports the seed and iteration,
//! which reproduces the exact case (generators are pure functions of the
//! RNG stream).
//!
//! ```ignore
//! proptest(|g| {
//!     let codes = g.vec_u32(1..=500, 0..8);
//!     let p = pack_codes(&codes, 3);
//!     prop_assert_eq!(unpack_codes(&p), codes);
//! });
//! ```

use crate::util::rng::Rng;

/// Generator context handed to each property iteration.
pub struct Gen {
    pub rng: Rng,
    pub iteration: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        assert!(hi_incl >= lo);
        lo + self.rng.below(hi_incl - lo + 1)
    }

    pub fn u32_below(&mut self, n: u32) -> u32 {
        self.rng.below(n as usize) as u32
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of u32 codes with length in `len` and values below `below`.
    pub fn vec_u32(&mut self, len: std::ops::RangeInclusive<usize>, below: u32) -> Vec<u32> {
        let n = self.usize_in(*len.start(), *len.end());
        (0..n).map(|_| self.u32_below(below.max(1))).collect()
    }

    /// Vector of standard-normal f32s.
    pub fn vec_normal(&mut self, len: std::ops::RangeInclusive<usize>) -> Vec<f32> {
        let n = self.usize_in(*len.start(), *len.end());
        let mut v = vec![0.0f32; n];
        self.rng.fill_normal(&mut v);
        v
    }

    /// Vector of uniform f32s in [lo, hi).
    pub fn vec_uniform(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        lo: f32,
        hi: f32,
    ) -> Vec<f32> {
        let n = self.usize_in(*len.start(), *len.end());
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }
}

/// Number of iterations per property (override with `VQ4ALL_PROP_ITERS`).
pub fn prop_iters() -> usize {
    std::env::var("VQ4ALL_PROP_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run a property across seeded iterations.  The closure returns
/// `Err(msg)` (or panics) to fail; failures report the reproducing seed.
pub fn proptest<F>(mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed: u64 = std::env::var("VQ4ALL_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xBADC0FFE);
    for it in 0..prop_iters() {
        let seed = base_seed.wrapping_add(it as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(seed),
            iteration: it,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at iteration {it} (reproduce with VQ4ALL_PROP_SEED={base_seed}): {msg}"
            );
        }
    }
}

/// Assertion helpers that produce `Result<(), String>` for [`proptest`].
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($msg:tt)+) => {
        if !$cond {
            return Err(format!($($msg)+));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({a:?} vs {b:?})",
                stringify!($a),
                stringify!($b)
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn properties_run_and_pass() {
        let mut count = 0;
        proptest(|g| {
            count += 1;
            let v = g.vec_u32(0..=10, 5);
            prop_assert!(v.iter().all(|&x| x < 5), "range respected");
            Ok(())
        });
        assert_eq!(count, prop_iters());
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failures_panic_with_seed() {
        proptest(|g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x < 1000, "sanity");
            prop_assert!(g.iteration != 10, "deterministic failure at iter 10 (x={x})");
            Ok(())
        });
    }

    #[test]
    fn generators_cover_ranges() {
        proptest(|g| {
            let a = g.usize_in(3, 7);
            prop_assert!((3..=7).contains(&a), "usize_in out of range: {a}");
            let f = g.f32_in(-1.0, 1.0);
            prop_assert!((-1.0..1.0).contains(&f), "f32_in out of range: {f}");
            Ok(())
        });
    }
}
