//! Tiny declarative CLI parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, and auto-generated `--help`.  Used by the
//! `vq4all` binary and every example/bench driver.

use std::collections::BTreeMap;

/// Declared option for help text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.values.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v:?} is not an integer: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v:?} is not a number: {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v:?} is not an integer: {e}")),
        }
    }

    /// Comma-separated list option.
    pub fn list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Serving-engine knobs from the conventional `--shards` /
    /// `--cache-kb` options (declared with [`Cli::engine_opts`]); unset
    /// values fall back to `EngineKnobs::default()`.
    pub fn engine_knobs(&self) -> anyhow::Result<crate::util::config::EngineKnobs> {
        self.engine_knobs_with(crate::util::config::EngineKnobs::default())
    }

    /// Full driver-side resolution: optional config file (`[engine]`
    /// section) overlaid by the CLI options — CLI > config > defaults.
    /// Pass `self.get("config")` (an empty/unset path means no file).
    pub fn engine_knobs_from_config(
        &self,
        config_path: Option<&str>,
    ) -> anyhow::Result<crate::util::config::EngineKnobs> {
        let base = match config_path {
            Some(p) if !p.is_empty() => crate::util::config::EngineKnobs::from_raw(
                &crate::util::config::RawConfig::load(std::path::Path::new(p))?,
            )?,
            _ => crate::util::config::EngineKnobs::default(),
        };
        self.engine_knobs_with(base)
    }

    /// Like [`Args::engine_knobs`] but with an explicit fallback —
    /// drivers that load a config file pass
    /// `EngineKnobs::from_raw(&raw)?` here, so the precedence is
    /// CLI > config file > defaults (mirroring `CampaignConfig`).
    pub fn engine_knobs_with(
        &self,
        base: crate::util::config::EngineKnobs,
    ) -> anyhow::Result<crate::util::config::EngineKnobs> {
        let shards = match self.get("shards") {
            None | Some("") => base.shards,
            Some(v) => v
                .parse::<usize>()
                .map_err(|e| anyhow::anyhow!("--shards={v:?} is not an integer: {e}"))?,
        };
        let cache_kb = match self.get("cache-kb") {
            None | Some("") => base.cache_kb,
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--cache-kb={v:?} is not an integer: {e}"))?,
        };
        let max_queue = match self.get("max-queue") {
            None | Some("") => base.max_queue,
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--max-queue={v:?} is not an integer: {e}"))?,
        };
        Ok(crate::util::config::EngineKnobs {
            shards: shards.max(1),
            cache_kb,
            max_queue,
        })
    }

    /// Parallelism selection from the conventional `--threads` option
    /// (0 = all cores, 1 = serial; unset = 0).  Drivers declare the
    /// option with [`Cli::threads_opt`] and read it here.
    pub fn parallelism(&self) -> anyhow::Result<crate::util::config::Parallelism> {
        let threads = match self.get("threads") {
            None | Some("") => 0,
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--threads={v:?} is not an integer: {e}"))?,
        };
        Ok(crate::util::config::Parallelism::new(threads))
    }
}

/// A subcommand-aware parser.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli {
            program,
            about,
            opts: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
        });
        self
    }

    /// The conventional serving-engine options (`--shards`,
    /// `--cache-kb`, `--max-queue`) the serving drivers expose.
    /// Defaults are empty so unset values fall back to the base knobs
    /// (config-file values via [`Args::engine_knobs_with`], or
    /// `EngineKnobs::default()` via [`Args::engine_knobs`]).
    pub fn engine_opts(self) -> Self {
        self.opt(
            "shards",
            "",
            "decode-plane shards, each owning a subset of the hosted nets (unset = 1)",
        )
        .opt(
            "cache-kb",
            "",
            "per-shard decode-cache budget in KiB (0 = off, unset = 1024)",
        )
        .opt(
            "max-queue",
            "",
            "per-shard admission budget: queue depth that sheds (virtual clock) or \
             backpressures (TCP) further requests (0 = unbounded, the default)",
        )
    }

    /// The conventional `--threads` option every hot-path driver exposes.
    /// The default is empty (not "0") so drivers can distinguish "unset"
    /// from an explicit request and let config-file values win.
    pub fn threads_opt(self) -> Self {
        self.opt(
            "threads",
            "",
            "worker threads for host hot paths (0 = all cores, 1 = serial)",
        )
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let v = if o.takes_value { " <value>" } else { "" };
            let d = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{v}\n      {}{d}\n", o.name, o.help));
        }
        s
    }

    /// Parse an iterator of arguments (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                out.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", self.help_text()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?,
                    };
                    out.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        anyhow::bail!("--{name} does not take a value");
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn parse(&self) -> anyhow::Result<Args> {
        self.parse_from(std::env::args().skip(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("alpha", "0.9999", "freeze threshold")
            .opt("nets", "", "subset")
            .flag("verbose", "chatty")
    }

    fn args(v: &[&str]) -> Args {
        cli()
            .parse_from(v.iter().map(|s| s.to_string()))
            .unwrap()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = args(&[]);
        assert_eq!(a.get("alpha"), Some("0.9999"));
        let a = args(&["--alpha", "0.9"]);
        assert_eq!(a.f64_or("alpha", 0.0).unwrap(), 0.9);
        let a = args(&["--alpha=0.95"]);
        assert_eq!(a.f64_or("alpha", 0.0).unwrap(), 0.95);
    }

    #[test]
    fn flags_and_positionals() {
        let a = args(&["run", "--verbose", "thing"]);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["run", "thing"]);
    }

    #[test]
    fn list_parsing() {
        let a = args(&["--nets", "a, b,c"]);
        assert_eq!(a.list("nets").unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli()
            .parse_from(vec!["--bogus".to_string()])
            .is_err());
    }

    #[test]
    fn typed_errors() {
        let a = args(&["--alpha", "zzz"]);
        assert!(a.f64_or("alpha", 0.0).is_err());
    }

    #[test]
    fn engine_opts_parse_knobs() {
        let cli = Cli::new("t", "test").engine_opts();
        let a = cli.parse_from(Vec::<String>::new()).unwrap();
        let k = a.engine_knobs().unwrap();
        assert_eq!(k.shards, 1, "unset falls back to defaults");
        assert_eq!(k.cache_kb, 1024);
        assert_eq!(k.max_queue, 0, "unbounded admission by default");
        let a = cli
            .parse_from(vec![
                "--shards=4".to_string(),
                "--cache-kb=0".to_string(),
                "--max-queue=32".to_string(),
            ])
            .unwrap();
        let k = a.engine_knobs().unwrap();
        assert_eq!(k.shards, 4);
        assert_eq!(k.cache_kb, 0, "explicit 0 disables the cache");
        assert_eq!(k.max_queue, 32);
        let a = cli.parse_from(vec!["--shards=0".to_string()]).unwrap();
        assert_eq!(a.engine_knobs().unwrap().shards, 1, "0 clamps to 1");
        let a = cli.parse_from(vec!["--shards=zzz".to_string()]).unwrap();
        assert!(a.engine_knobs().is_err());
        let a = cli.parse_from(vec!["--max-queue=zzz".to_string()]).unwrap();
        assert!(a.engine_knobs().is_err());
        // Config-file precedence: unset CLI values take the base, set
        // CLI values override it.
        let base = crate::util::config::EngineKnobs {
            shards: 3,
            cache_kb: 64,
            max_queue: 16,
        };
        let a = cli.parse_from(Vec::<String>::new()).unwrap();
        assert_eq!(a.engine_knobs_with(base).unwrap(), base);
        let a = cli.parse_from(vec!["--shards=8".to_string()]).unwrap();
        let k = a.engine_knobs_with(base).unwrap();
        assert_eq!(k.shards, 8, "CLI beats config");
        assert_eq!(k.cache_kb, 64, "unset CLI keeps config value");
        assert_eq!(k.max_queue, 16, "unset CLI keeps config value");
    }

    #[test]
    fn engine_knobs_from_config_overlays_file() {
        let cli = Cli::new("t", "test").engine_opts();
        let p = std::env::temp_dir().join("vq4all_engine_knobs_test.toml");
        std::fs::write(&p, "[engine]\nshards = 5\ncache_kb = 32\nmax_queue = 9\n").unwrap();
        let path = p.to_string_lossy().to_string();
        let a = cli.parse_from(Vec::<String>::new()).unwrap();
        let k = a.engine_knobs_from_config(Some(&path)).unwrap();
        assert_eq!(
            (k.shards, k.cache_kb, k.max_queue),
            (5, 32, 9),
            "config file wins over defaults"
        );
        let a = cli.parse_from(vec!["--cache-kb=8".to_string()]).unwrap();
        let k = a.engine_knobs_from_config(Some(&path)).unwrap();
        assert_eq!((k.shards, k.cache_kb, k.max_queue), (5, 8, 9), "CLI wins over config");
        let k = a.engine_knobs_from_config(None).unwrap();
        assert_eq!(k.shards, 1, "no file falls back to defaults");
        assert!(a.engine_knobs_from_config(Some("/no/such/file.toml")).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn threads_opt_parses_parallelism() {
        let cli = Cli::new("t", "test").threads_opt();
        let a = cli.parse_from(Vec::<String>::new()).unwrap();
        assert_eq!(a.parallelism().unwrap().threads, 0, "unset means all cores");
        let a = cli
            .parse_from(vec!["--threads".to_string(), "1".to_string()])
            .unwrap();
        assert!(a.parallelism().unwrap().pool().is_none(), "1 = serial");
        let a = cli.parse_from(vec!["--threads=2".to_string()]).unwrap();
        assert_eq!(a.parallelism().unwrap().threads, 2);
        let a = cli.parse_from(vec!["--threads=zzz".to_string()]).unwrap();
        assert!(a.parallelism().is_err());
    }
}
