//! Tiny declarative CLI parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, and auto-generated `--help`.  Used by the
//! `vq4all` binary and every example/bench driver.

use std::collections::BTreeMap;

/// Declared option for help text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.values.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v:?} is not an integer: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v:?} is not a number: {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v:?} is not an integer: {e}")),
        }
    }

    /// Comma-separated list option.
    pub fn list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Parallelism selection from the conventional `--threads` option
    /// (0 = all cores, 1 = serial; unset = 0).  Drivers declare the
    /// option with [`Cli::threads_opt`] and read it here.
    pub fn parallelism(&self) -> anyhow::Result<crate::util::config::Parallelism> {
        let threads = match self.get("threads") {
            None | Some("") => 0,
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--threads={v:?} is not an integer: {e}"))?,
        };
        Ok(crate::util::config::Parallelism::new(threads))
    }
}

/// A subcommand-aware parser.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli {
            program,
            about,
            opts: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
        });
        self
    }

    /// The conventional `--threads` option every hot-path driver exposes.
    /// The default is empty (not "0") so drivers can distinguish "unset"
    /// from an explicit request and let config-file values win.
    pub fn threads_opt(self) -> Self {
        self.opt(
            "threads",
            "",
            "worker threads for host hot paths (0 = all cores, 1 = serial)",
        )
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let v = if o.takes_value { " <value>" } else { "" };
            let d = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{v}\n      {}{d}\n", o.name, o.help));
        }
        s
    }

    /// Parse an iterator of arguments (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                out.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", self.help_text()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?,
                    };
                    out.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        anyhow::bail!("--{name} does not take a value");
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn parse(&self) -> anyhow::Result<Args> {
        self.parse_from(std::env::args().skip(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("alpha", "0.9999", "freeze threshold")
            .opt("nets", "", "subset")
            .flag("verbose", "chatty")
    }

    fn args(v: &[&str]) -> Args {
        cli()
            .parse_from(v.iter().map(|s| s.to_string()))
            .unwrap()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = args(&[]);
        assert_eq!(a.get("alpha"), Some("0.9999"));
        let a = args(&["--alpha", "0.9"]);
        assert_eq!(a.f64_or("alpha", 0.0).unwrap(), 0.9);
        let a = args(&["--alpha=0.95"]);
        assert_eq!(a.f64_or("alpha", 0.0).unwrap(), 0.95);
    }

    #[test]
    fn flags_and_positionals() {
        let a = args(&["run", "--verbose", "thing"]);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["run", "thing"]);
    }

    #[test]
    fn list_parsing() {
        let a = args(&["--nets", "a, b,c"]);
        assert_eq!(a.list("nets").unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli()
            .parse_from(vec!["--bogus".to_string()])
            .is_err());
    }

    #[test]
    fn typed_errors() {
        let a = args(&["--alpha", "zzz"]);
        assert!(a.f64_or("alpha", 0.0).is_err());
    }

    #[test]
    fn threads_opt_parses_parallelism() {
        let cli = Cli::new("t", "test").threads_opt();
        let a = cli.parse_from(Vec::<String>::new()).unwrap();
        assert_eq!(a.parallelism().unwrap().threads, 0, "unset means all cores");
        let a = cli
            .parse_from(vec!["--threads".to_string(), "1".to_string()])
            .unwrap();
        assert!(a.parallelism().unwrap().pool().is_none(), "1 = serial");
        let a = cli.parse_from(vec!["--threads=2".to_string()]).unwrap();
        assert_eq!(a.parallelism().unwrap().threads, 2);
        let a = cli.parse_from(vec!["--threads=zzz".to_string()]).unwrap();
        assert!(a.parallelism().is_err());
    }
}
