//! Campaign configuration: a typed config struct + a TOML-subset loader.
//!
//! The launcher reads `configs/*.toml` (sections, `key = value`, strings,
//! numbers, booleans, comments) — enough of TOML for flat experiment
//! configs without an external crate.  CLI options override file values,
//! file values override defaults.

use std::collections::BTreeMap;

use crate::util::threadpool::ThreadPool;

/// Worker-thread selection for the pure-Rust hot paths (k-means sweeps,
/// candidate assignment, KDE sampling, PNC scans).
///
/// `0` means "all available cores", `1` is the fully serial path, any
/// other value is an explicit worker count.  Results are bit-identical
/// at every setting: the chunked schedules derive all per-chunk state
/// from chunk indices, never from thread interleaving (see
/// `util::threadpool::ThreadPool::parallel_for`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    pub threads: usize,
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism { threads: 0 }
    }
}

impl Parallelism {
    pub fn new(threads: usize) -> Self {
        Parallelism { threads }
    }

    pub fn serial() -> Self {
        Parallelism { threads: 1 }
    }

    /// Resolved worker count (`0` -> available cores).
    pub fn effective_threads(self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            self.threads
        }
    }

    /// Spin up a pool, or `None` for the serial path — callers pass the
    /// result straight to the `*_with(..., pool)` hot-path entry points.
    pub fn pool(self) -> Option<ThreadPool> {
        if self.effective_threads() <= 1 {
            None
        } else {
            Some(ThreadPool::new(self.threads))
        }
    }
}

/// Serving-engine knobs (the `[engine]` config section / `--shards`,
/// `--cache-kb`, `--max-queue` CLI options): decode-plane shard count,
/// per-shard decode-cache budget, and per-shard admission budget for
/// `serving::engine`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineKnobs {
    /// Decode-plane worker shards (each owns a disjoint subset of the
    /// hosted networks); clamped to >= 1.
    pub shards: usize,
    /// Per-shard decode-cache budget in KiB (0 disables the cache).
    pub cache_kb: usize,
    /// Per-shard admission budget: queue depth at which further
    /// submissions are shed (virtual-clock front-end) or deferred with
    /// backpressure (TCP front-end).  0 = unbounded.
    pub max_queue: usize,
}

impl Default for EngineKnobs {
    fn default() -> Self {
        EngineKnobs {
            shards: 1,
            cache_kb: 1024,
            max_queue: 0,
        }
    }
}

impl EngineKnobs {
    /// Overlay `[engine]` keys from a RawConfig.
    pub fn from_raw(raw: &RawConfig) -> anyhow::Result<Self> {
        let d = EngineKnobs::default();
        Ok(EngineKnobs {
            shards: raw.usize("engine.shards", d.shards)?.max(1),
            cache_kb: raw.usize("engine.cache_kb", d.cache_kb)?,
            max_queue: raw.usize("engine.max_queue", d.max_queue)?,
        })
    }

    pub fn cache_bytes(&self) -> usize {
        self.cache_kb * 1024
    }
}

/// Parsed flat config: `section.key -> raw string value`.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    values: BTreeMap<String, String>,
}

impl RawConfig {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut out = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            out.insert(key, unquote(v.trim()).to_string());
        }
        Ok(RawConfig { values: out })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {path:?}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("config {key} = {v:?}: {e}")),
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("config {key} = {v:?}: {e}")),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => anyhow::bail!("config {key} = {v:?} is not a bool"),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> &str {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(v)
}

/// Campaign-level settings consumed by `coordinator::campaign`.
#[derive(Clone, Debug)]
pub struct CampaignConfig {
    /// PNC freeze threshold alpha (Eq. 14).  The paper uses 0.9999 over
    /// a ~50k-step schedule; at this repo's scaled 200-400-step schedule
    /// the max-ratio distribution reaches the same *tail shape* around
    /// 0.99 (measured in python/tools/tune_probe.py: ~75-92% of groups
    /// cross 0.99 by step 150-200, none cross 0.9999), so 0.99 is the
    /// schedule-equivalent default.  Figure 4's alpha sweep regenerates
    /// the paper's sensitivity curve around it.
    pub alpha: f64,
    /// Construction steps per network.
    pub steps: usize,
    /// How often (steps) the PNC scheduler scans ratios for freezing.
    pub pnc_interval: usize,
    /// Evaluate soft accuracy every `eval_interval` steps (0 = only at end).
    pub eval_interval: usize,
    /// Disable PNC entirely (the DKM-style ablation of Table 5 / Fig. 3).
    pub disable_pnc: bool,
    /// Loss-term toggles (Table 5 ablations).
    pub use_task_loss: bool,
    pub use_kd_loss: bool,
    pub use_ratio_reg: bool,
    /// Continuous loss weights `[w_t, w_kd, w_r]` (Eq. 12 is all-ones).
    /// When set, overrides the boolean toggles.  The denoiser campaign
    /// uses a KD-dominant weighting (see `for_task`): at the scaled
    /// schedule the eps-MSE task gradient is batch-noise-dominated and
    /// drifts assignments toward generation-biased codes — the paper's
    /// SD run reflects the same fragility via a 100x smaller lr (§5.3).
    pub loss_weights: Option<[f32; 3]>,
    /// Emulate a smaller candidate count n' <= n by masking the logits
    /// of slots >= n' to -inf (Table 5's n ablation).
    pub candidate_mask: Option<usize>,
    /// §5.1 special-layer pass: quantize the output head with a small
    /// *private* (k, d) codebook after construction (the paper's
    /// 2^8 x 4 at 2-bit).  None = heads stay float (EWGS-comparable
    /// configuration of Table 3).
    pub output_codebook: Option<(usize, usize)>,
    /// RNG seed for batching.
    pub seed: u64,
    /// Worker threads for the host hot paths (0 = all cores, 1 = serial).
    pub threads: usize,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            alpha: 0.99,
            steps: 200,
            pnc_interval: 10,
            eval_interval: 0,
            disable_pnc: false,
            use_task_loss: true,
            use_kd_loss: true,
            use_ratio_reg: true,
            loss_weights: None,
            candidate_mask: None,
            output_codebook: None,
            seed: 0xC0DE,
            threads: 0,
        }
    }
}

impl CampaignConfig {
    /// Overlay `[campaign]` keys from a RawConfig.
    pub fn from_raw(raw: &RawConfig) -> anyhow::Result<Self> {
        let d = CampaignConfig::default();
        Ok(CampaignConfig {
            alpha: raw.f64("campaign.alpha", d.alpha)?,
            steps: raw.usize("campaign.steps", d.steps)?,
            pnc_interval: raw.usize("campaign.pnc_interval", d.pnc_interval)?,
            eval_interval: raw.usize("campaign.eval_interval", d.eval_interval)?,
            disable_pnc: raw.bool("campaign.disable_pnc", d.disable_pnc)?,
            use_task_loss: raw.bool("campaign.use_task_loss", d.use_task_loss)?,
            use_kd_loss: raw.bool("campaign.use_kd_loss", d.use_kd_loss)?,
            use_ratio_reg: raw.bool("campaign.use_ratio_reg", d.use_ratio_reg)?,
            loss_weights: {
                let wt = raw.f64("campaign.w_t", f64::NAN)?;
                let wkd = raw.f64("campaign.w_kd", f64::NAN)?;
                let wr = raw.f64("campaign.w_r", f64::NAN)?;
                if wt.is_nan() && wkd.is_nan() && wr.is_nan() {
                    None
                } else {
                    Some([
                        if wt.is_nan() { 1.0 } else { wt as f32 },
                        if wkd.is_nan() { 1.0 } else { wkd as f32 },
                        if wr.is_nan() { 1.0 } else { wr as f32 },
                    ])
                }
            },
            candidate_mask: match raw.usize("campaign.candidate_mask", 0)? {
                0 => None,
                m => Some(m),
            },
            output_codebook: match (
                raw.usize("campaign.output_codebook_k", 0)?,
                raw.usize("campaign.output_codebook_d", 0)?,
            ) {
                (0, _) | (_, 0) => None,
                (k, dd) => Some((k, dd)),
            },
            seed: raw.usize("campaign.seed", d.seed as usize)? as u64,
            threads: raw.usize("campaign.threads", d.threads)?,
        })
    }

    /// The campaign's parallelism selection.
    pub fn parallelism(&self) -> Parallelism {
        Parallelism::new(self.threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_types() {
        let cfg = RawConfig::parse(
            r#"
            # top comment
            top = 1
            [campaign]
            alpha = 0.99   # inline comment
            steps = 50
            disable_pnc = true
            name = "hello # not a comment"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.usize("top", 0).unwrap(), 1);
        assert_eq!(cfg.f64("campaign.alpha", 0.0).unwrap(), 0.99);
        assert!(cfg.bool("campaign.disable_pnc", false).unwrap());
        assert_eq!(cfg.get("campaign.name"), Some("hello # not a comment"));
    }

    #[test]
    fn campaign_overlay() {
        let raw = RawConfig::parse("[campaign]\nalpha = 0.9\nsteps = 7\nthreads = 3\n").unwrap();
        let c = CampaignConfig::from_raw(&raw).unwrap();
        assert_eq!(c.alpha, 0.9);
        assert_eq!(c.steps, 7);
        assert_eq!(c.threads, 3);
        assert!(c.use_kd_loss, "untouched fields keep defaults");
    }

    #[test]
    fn parallelism_resolves_and_pools() {
        assert!(Parallelism::serial().pool().is_none(), "threads=1 is serial");
        assert_eq!(Parallelism::new(1).effective_threads(), 1);
        assert!(Parallelism::new(0).effective_threads() >= 1);
        let p = Parallelism::new(3).pool().expect("explicit 3 threads pools");
        assert_eq!(p.threads(), 3);
        assert_eq!(CampaignConfig::default().parallelism(), Parallelism::new(0));
    }

    #[test]
    fn engine_knobs_overlay_and_defaults() {
        let d = EngineKnobs::default();
        assert_eq!(d.shards, 1);
        assert_eq!(d.cache_bytes(), 1024 * 1024);
        assert_eq!(d.max_queue, 0, "unbounded admission by default");
        let raw = RawConfig::parse("[engine]\nshards = 4\ncache_kb = 256\nmax_queue = 64\n").unwrap();
        let k = EngineKnobs::from_raw(&raw).unwrap();
        assert_eq!(k.shards, 4);
        assert_eq!(k.cache_bytes(), 256 * 1024);
        assert_eq!(k.max_queue, 64);
        // shards = 0 clamps to 1; cache_kb = 0 disables the cache.
        let raw = RawConfig::parse("[engine]\nshards = 0\ncache_kb = 0\n").unwrap();
        let k = EngineKnobs::from_raw(&raw).unwrap();
        assert_eq!(k.shards, 1);
        assert_eq!(k.cache_bytes(), 0);
        assert!(EngineKnobs::from_raw(
            &RawConfig::parse("[engine]\nshards = banana\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn bad_types_error() {
        let raw = RawConfig::parse("[campaign]\nalpha = banana\n").unwrap();
        assert!(CampaignConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn bad_syntax_errors() {
        assert!(RawConfig::parse("[unterminated\n").is_err());
        assert!(RawConfig::parse("novalue\n").is_err());
    }
}
