//! Minimal JSON codec (serde is unavailable offline).
//!
//! Full JSON data model with a recursive-descent parser and a compact
//! writer.  Used for `artifacts/manifest.json`, checkpoints, and the
//! experiment reports.  Numbers are stored as `f64` (the manifest's
//! integers are all well under 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are ordered (BTreeMap) so emission is
/// deterministic — checkpoints diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null-safe Option.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key {key:?} is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_f64()
            .map(|x| x as usize)
            .ok_or_else(|| anyhow::anyhow!("JSON key {key:?} is not a number"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("JSON key {key:?} is not a number"))
    }

    pub fn req_bool(&self, key: &str) -> anyhow::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("JSON key {key:?} is not a bool"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("JSON key {key:?} is not an array"))
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

// ------------------------------------------------------------------ parse

/// Parse a JSON document (strict; trailing garbage is an error).
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!(
                "expected {:?} at byte {} found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}' found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected ',' or ']' found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: best-effort (manifest is ASCII).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number {s:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ------------------------------------------------------------------- emit

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e1}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.path(&["c", "d"]).unwrap().as_f64(), Some(-25.0));
        let emitted = v.to_string();
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("café ✓"));
        let emitted = v.to_string();
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn nested_path_access() {
        let v = parse(r#"{"networks":[{"name":"mini_mlp","s_total":57344}]}"#).unwrap();
        let net = &v.req_arr("networks").unwrap()[0];
        assert_eq!(net.req_str("name").unwrap(), "mini_mlp");
        assert_eq!(net.req_usize("s_total").unwrap(), 57344);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
