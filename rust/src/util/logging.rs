//! Structured logging with levels and elapsed-time stamps.
//!
//! The coordinator runs multi-minute campaigns; the log format is
//! `[  12.345s INFO  campaign] message` so progress is scannable and
//! the experiment harnesses can keep stdout for their table rows.
//! Level is process-global, settable via `VQ4ALL_LOG` (error..trace) or
//! the CLI's `-v` flags.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start, for elapsed stamps.
fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Initialize from the environment (`VQ4ALL_LOG=debug` etc.).
pub fn init_from_env() {
    let _ = start();
    if let Ok(v) = std::env::var("VQ4ALL_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        });
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Core emit; use via the `log!`-style macros below.
pub fn emit(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let elapsed = start().elapsed().as_secs_f64();
    eprintln!("[{elapsed:9.3}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_error { ($t:expr, $($a:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($t:expr, $($a:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($t:expr, $($a:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($t:expr, $($a:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, $t, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
