//! In-house substrates.
//!
//! The build is fully offline: the only dependencies are the in-tree
//! `vendor/anyhow` shim and the host-only `vendor/xla` stub, so
//! everything a framework normally pulls from crates.io is implemented
//! here: a deterministic PRNG, a JSON codec, a CLI parser, a TOML-subset
//! config reader, a scoped thread pool, structured logging, and running
//! statistics.  Each module is small, tested, and dependency-free.

pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threadpool;
