//! In-house substrates.
//!
//! Only `xla` and `anyhow` resolve in the build image (vendored, offline),
//! so everything a framework normally pulls from crates.io is implemented
//! here: a deterministic PRNG, a JSON codec, a CLI parser, a TOML-subset
//! config reader, a scoped thread pool, structured logging, and running
//! statistics.  Each module is small, tested, and dependency-free.

pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threadpool;
