//! Deterministic PRNG (xoshiro256**), with the distributions the system
//! needs: uniforms, integer ranges, permutations, and Gaussians
//! (Box–Muller) for KDE sampling and diffusion noise.
//!
//! Determinism matters here: the PNC campaign, dataset batching, and all
//! experiment harnesses must be reproducible from a seed so EXPERIMENTS.md
//! numbers can be regenerated exactly.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-thread / per-network rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Chunk-indexed stream derivation for the chunked parallel schedules
    /// (`ThreadPool::parallel_for`): per-chunk randomness depends only on
    /// `(base, chunk_idx)` — never on thread interleaving — which is what
    /// makes the parallel hot paths bit-identical to the serial path.
    /// The splitmix-style spread keeps nearby chunk streams unrelated.
    pub fn chunk_stream(base: u64, chunk_idx: usize) -> Rng {
        Rng::new(base ^ ((chunk_idx as u64).wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Unbiased integer in `[0, n)` (Lemire rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// N(mu, sigma) as f32.
    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal() as f32
    }

    /// Fill with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// `count` distinct indices from `0..n` (swap-sampling; O(count)).
    pub fn sample_without_replacement(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n, "sample {count} from {n}");
        // Partial Fisher–Yates over a lazily materialized range.
        let mut map = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(count);
        for i in 0..count {
            let j = i + self.below(n - i);
            let vi = *map.get(&i).unwrap_or(&i);
            let vj = *map.get(&j).unwrap_or(&j);
            out.push(vj);
            map.insert(j, vi);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let mut p = r.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_without_replacement(50, 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn chunk_streams_deterministic_and_distinct() {
        let mut a = Rng::chunk_stream(42, 0);
        let mut b = Rng::chunk_stream(42, 0);
        let mut c = Rng::chunk_stream(42, 1);
        let x = a.next_u64();
        assert_eq!(x, b.next_u64(), "same (base, idx) -> same stream");
        assert_ne!(x, c.next_u64(), "adjacent chunks get unrelated streams");
    }
}
