//! Running statistics and small numeric helpers shared by the bench
//! harness, the ROM simulator, and the experiment reports.

use crate::util::rng::Rng;

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Running {
    fn default() -> Self {
        Running::new()
    }
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Bounded metric summary: Welford running moments plus a fixed-capacity
/// reservoir (Vitter's Algorithm R, deterministic seed) for percentile
/// estimates.  Replaces the unbounded `Vec<f64>` latency logs in the
/// serving stats so long-running serve loops stay O(1) in memory
/// regardless of traffic.
#[derive(Clone, Debug)]
pub struct Summary {
    running: Running,
    samples: Vec<f64>,
    cap: usize,
    rng: Rng,
}

impl Default for Summary {
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    /// Default reservoir capacity — 4096 f64s (32 KiB) bounds the memory
    /// while keeping p99 estimates tight at serving volumes.
    pub const DEFAULT_CAP: usize = 4096;

    pub fn new() -> Self {
        Self::with_capacity(Self::DEFAULT_CAP)
    }

    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "summary reservoir needs capacity");
        Summary {
            running: Running::new(),
            samples: Vec::new(),
            cap,
            rng: Rng::new(0x5EED_5A3E),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.running.push(x);
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Algorithm R: item i replaces a reservoir slot with
            // probability cap/i, keeping a uniform sample of the stream.
            let j = self.rng.below(self.running.count() as usize);
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.running.count()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    pub fn mean(&self) -> f64 {
        self.running.mean()
    }

    pub fn std(&self) -> f64 {
        self.running.std()
    }

    pub fn min(&self) -> f64 {
        self.running.min()
    }

    pub fn max(&self) -> f64 {
        self.running.max()
    }

    /// Percentile estimate from the reservoir (exact while the stream
    /// fits in it); 0.0 for an empty summary.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        percentile(&self.samples, p)
    }

    /// The retained sample (exact stream prefix until `cap` is hit).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Percentile over a copy of the data (nearest-rank).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Mean squared error between two slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "mse length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Histogram with fixed bin count over [lo, hi); counts out-of-range into
/// the edge bins.  Used by the Figure-3/Figure-5 distribution reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
        }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let frac = (x - self.lo) / (self.hi - self.lo);
        let idx = ((frac * n as f64).floor() as i64).clamp(0, n as i64 - 1) as usize;
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// Fraction of mass in each bin.
    pub fn normalized(&self) -> Vec<f64> {
        let t = self.total().max(1) as f64;
        self.bins.iter().map(|&b| b as f64 / t).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0_f64).powi(2)).sum::<f64>() / 4.0;
        assert!((r.var() - direct_var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 10.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn summary_exact_below_capacity_and_bounded_above() {
        let mut s = Summary::with_capacity(8);
        assert_eq!(s.percentile(50.0), 0.0, "empty summary percentiles are 0");
        for i in 1..=6 {
            s.push(i as f64);
        }
        // Below capacity the reservoir is the exact stream.
        assert_eq!(s.count(), 6);
        assert_eq!(s.samples(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!((s.mean() - 3.5).abs() < 1e-12);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 6.0);

        for i in 7..=10_000 {
            s.push(i as f64);
        }
        assert_eq!(s.count(), 10_000);
        assert_eq!(s.samples().len(), 8, "reservoir stays bounded");
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10_000.0);
        // Running moments see the full stream, not just the reservoir.
        assert!((s.mean() - 5000.5).abs() < 1e-9);
        // The reservoir is a sample of the stream, so percentiles stay
        // inside the observed range.
        let p50 = s.percentile(50.0);
        assert!((1.0..=10_000.0).contains(&p50));
    }

    #[test]
    fn summary_is_deterministic() {
        let run = || {
            let mut s = Summary::with_capacity(16);
            for i in 0..5000 {
                s.push((i * 7 % 113) as f64);
            }
            (s.samples().to_vec(), s.percentile(99.0))
        };
        assert_eq!(run(), run(), "fixed-seed reservoir must reproduce");
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.push(0.05);
        h.push(0.95);
        h.push(-5.0); // clamps into bin 0
        h.push(7.0); // clamps into last bin
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total(), 4);
        let norm = h.normalized();
        assert!((norm.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
