//! Running statistics and small numeric helpers shared by the bench
//! harness, the ROM simulator, and the experiment reports.

use crate::util::rng::Rng;

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Running {
    fn default() -> Self {
        Running::new()
    }
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Bounded metric summary: Welford running moments plus a fixed-capacity
/// reservoir (Vitter's Algorithm R, deterministic seed) for percentile
/// estimates.  Replaces the unbounded `Vec<f64>` latency logs in the
/// serving stats so long-running serve loops stay O(1) in memory
/// regardless of traffic.
#[derive(Clone, Debug)]
pub struct Summary {
    running: Running,
    samples: Vec<f64>,
    cap: usize,
    rng: Rng,
}

impl Default for Summary {
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    /// Default reservoir capacity — 4096 f64s (32 KiB) bounds the memory
    /// while keeping p99 estimates tight at serving volumes.
    pub const DEFAULT_CAP: usize = 4096;

    pub fn new() -> Self {
        Self::with_capacity(Self::DEFAULT_CAP)
    }

    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "summary reservoir needs capacity");
        Summary {
            running: Running::new(),
            samples: Vec::new(),
            cap,
            rng: Rng::new(0x5EED_5A3E),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.running.push(x);
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Algorithm R: item i replaces a reservoir slot with
            // probability cap/i, keeping a uniform sample of the stream.
            let j = self.rng.below(self.running.count() as usize);
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.running.count()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    pub fn mean(&self) -> f64 {
        self.running.mean()
    }

    pub fn std(&self) -> f64 {
        self.running.std()
    }

    pub fn min(&self) -> f64 {
        self.running.min()
    }

    pub fn max(&self) -> f64 {
        self.running.max()
    }

    /// Percentile estimate from the reservoir (exact while the stream
    /// fits in it); 0.0 for an empty summary.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        percentile(&self.samples, p)
    }

    /// The retained sample (exact stream prefix until `cap` is hit).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Fold `other` into `self` — snapshot-time aggregation across
    /// shards (the `/stats` verb's plane-wide latency shape).  The
    /// moments combine exactly (Chan et al. parallel Welford:
    /// count/mean/std/min/max are as if every sample hit one summary);
    /// the percentile reservoir concatenates the retained samples and,
    /// past capacity, keeps a deterministic random subsample — so
    /// percentiles stay estimates while the counts stay exact.
    pub fn absorb(&mut self, other: &Summary) {
        if other.running.n == 0 {
            return;
        }
        if self.running.n == 0 {
            self.running = other.running.clone();
        } else {
            let (na, nb) = (self.running.n as f64, other.running.n as f64);
            let delta = other.running.mean - self.running.mean;
            self.running.mean += delta * nb / (na + nb);
            self.running.m2 += other.running.m2 + delta * delta * na * nb / (na + nb);
            self.running.n += other.running.n;
            self.running.min = self.running.min.min(other.running.min);
            self.running.max = self.running.max.max(other.running.max);
        }
        let mut seen = self.samples.len();
        for &x in &other.samples {
            seen += 1;
            if self.samples.len() < self.cap {
                self.samples.push(x);
            } else {
                let j = self.rng.below(seen);
                if j < self.cap {
                    self.samples[j] = x;
                }
            }
        }
    }
}

/// Percentile over a copy of the data (nearest-rank).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Mean squared error between two slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "mse length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Histogram with fixed bin count over [lo, hi); counts out-of-range into
/// the edge bins.  Used by the Figure-3/Figure-5 distribution reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
        }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let frac = (x - self.lo) / (self.hi - self.lo);
        let idx = ((frac * n as f64).floor() as i64).clamp(0, n as i64 - 1) as usize;
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// Fraction of mass in each bin.
    pub fn normalized(&self) -> Vec<f64> {
        let t = self.total().max(1) as f64;
        self.bins.iter().map(|&b| b as f64 / t).collect()
    }
}

/// Fixed-bucket log2 histogram for nanosecond durations — the
/// observability plane's latency shape.  Bucket `i` counts values
/// `v <= 2^i` not already counted lower (upper bound `2^i` ns, so the
/// buckets cover 1 ns .. ~2^38 ns ≈ 4.6 min); the last bucket is the
/// +Inf overflow.  Plain non-atomic fields: each shard owns one and the
/// engine merges at snapshot time, keeping the hot path lock-free and
/// the serial-vs-pooled snapshots bit-identical.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Log2Hist {
    counts: [u64; Log2Hist::BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for Log2Hist {
    fn default() -> Self {
        Log2Hist::new()
    }
}

impl Log2Hist {
    /// Bucket count, overflow included.
    pub const BUCKETS: usize = 40;

    pub fn new() -> Self {
        Log2Hist {
            counts: [0; Self::BUCKETS],
            count: 0,
            sum: 0,
        }
    }

    /// Bucket index for `v`: the smallest `i` with `v <= 2^i`, clamped
    /// into the overflow bucket.
    pub fn bucket_of(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            ((64 - (v - 1).leading_zeros()) as usize).min(Self::BUCKETS - 1)
        }
    }

    /// Upper bound of bucket `i` (`2^i`; +Inf for the overflow bucket).
    pub fn upper_bound(i: usize) -> f64 {
        if i >= Self::BUCKETS - 1 {
            f64::INFINITY
        } else {
            (1u64 << i) as f64
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded values (saturating — a century of ns fits u64).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn counts(&self) -> &[u64; Self::BUCKETS] {
        &self.counts
    }

    /// Cumulative (`le`-style) counts, Prometheus histogram semantics:
    /// entry `i` counts every value `<= 2^i`; the last entry equals
    /// `count()`.
    pub fn cumulative(&self) -> [u64; Self::BUCKETS] {
        let mut out = [0u64; Self::BUCKETS];
        let mut acc = 0u64;
        for (o, &c) in out.iter_mut().zip(self.counts.iter()) {
            acc += c;
            *o = acc;
        }
        out
    }

    /// Fold `other` into `self` (snapshot-time per-shard merge).
    pub fn merge(&mut self, other: &Log2Hist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0_f64).powi(2)).sum::<f64>() / 4.0;
        assert!((r.var() - direct_var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 10.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn summary_exact_below_capacity_and_bounded_above() {
        let mut s = Summary::with_capacity(8);
        assert_eq!(s.percentile(50.0), 0.0, "empty summary percentiles are 0");
        for i in 1..=6 {
            s.push(i as f64);
        }
        // Below capacity the reservoir is the exact stream.
        assert_eq!(s.count(), 6);
        assert_eq!(s.samples(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!((s.mean() - 3.5).abs() < 1e-12);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 6.0);

        for i in 7..=10_000 {
            s.push(i as f64);
        }
        assert_eq!(s.count(), 10_000);
        assert_eq!(s.samples().len(), 8, "reservoir stays bounded");
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10_000.0);
        // Running moments see the full stream, not just the reservoir.
        assert!((s.mean() - 5000.5).abs() < 1e-9);
        // The reservoir is a sample of the stream, so percentiles stay
        // inside the observed range.
        let p50 = s.percentile(50.0);
        assert!((1.0..=10_000.0).contains(&p50));
    }

    #[test]
    fn summary_is_deterministic() {
        let run = || {
            let mut s = Summary::with_capacity(16);
            for i in 0..5000 {
                s.push((i * 7 % 113) as f64);
            }
            (s.samples().to_vec(), s.percentile(99.0))
        };
        assert_eq!(run(), run(), "fixed-seed reservoir must reproduce");
    }

    #[test]
    fn summary_absorb_combines_moments_exactly() {
        let mut whole = Summary::with_capacity(64);
        let mut a = Summary::with_capacity(64);
        let mut b = Summary::with_capacity(64);
        for i in 0..40 {
            let x = (i * 13 % 29) as f64;
            whole.push(x);
            if i % 3 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
        }
        a.absorb(&b);
        assert_eq!(a.count(), whole.count(), "counts add exactly");
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.std() - whole.std()).abs() < 1e-9);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        // Under capacity the merged reservoir is the exact union, so
        // percentiles match the single-stream summary too.
        let mut got = a.samples().to_vec();
        let mut want = whole.samples().to_vec();
        got.sort_by(f64::total_cmp);
        want.sort_by(f64::total_cmp);
        assert_eq!(got, want);

        // Absorbing into/out of an empty summary is the identity.
        let mut empty = Summary::with_capacity(8);
        empty.absorb(&a);
        assert_eq!(empty.count(), a.count());
        a.absorb(&Summary::with_capacity(8));
        assert_eq!(a.count(), whole.count());

        // Over capacity the reservoir stays bounded but the moments
        // still combine exactly.
        let mut big = Summary::with_capacity(4);
        let mut tail = Summary::with_capacity(4);
        for i in 0..100 {
            big.push(i as f64);
            tail.push((100 + i) as f64);
        }
        let (n0, m0) = (big.count(), big.mean());
        big.absorb(&tail);
        assert_eq!(big.count(), 200);
        assert_eq!(big.samples().len(), 4, "reservoir stays bounded");
        assert!((big.mean() - (m0 * n0 as f64 + tail.mean() * 100.0) / 200.0).abs() < 1e-9);
        assert_eq!(big.max(), 199.0);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn log2_hist_bucket_edges() {
        // Power-of-two edges land in the `le 2^i` bucket, one past the
        // edge rolls into the next.
        assert_eq!(Log2Hist::bucket_of(0), 0);
        assert_eq!(Log2Hist::bucket_of(1), 0);
        assert_eq!(Log2Hist::bucket_of(2), 1);
        assert_eq!(Log2Hist::bucket_of(3), 2);
        assert_eq!(Log2Hist::bucket_of(4), 2);
        assert_eq!(Log2Hist::bucket_of(5), 3);
        for i in 1..Log2Hist::BUCKETS - 1 {
            let edge = 1u64 << i;
            assert_eq!(Log2Hist::bucket_of(edge), i, "2^{i} belongs to bucket {i}");
            assert_eq!(Log2Hist::bucket_of(edge + 1), i + 1, "2^{i}+1 spills over");
        }
        // Far past the covered range clamps into the overflow bucket.
        assert_eq!(Log2Hist::bucket_of(u64::MAX), Log2Hist::BUCKETS - 1);
        assert_eq!(Log2Hist::upper_bound(0), 1.0);
        assert_eq!(Log2Hist::upper_bound(3), 8.0);
        assert!(Log2Hist::upper_bound(Log2Hist::BUCKETS - 1).is_infinite());
    }

    #[test]
    fn log2_hist_counts_cumulative_and_merge() {
        let mut h = Log2Hist::new();
        for v in [0u64, 1, 2, 4, 5, 1 << 20, u64::MAX] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.counts()[0], 2, "0 and 1 share the first bucket");
        assert_eq!(h.counts()[Log2Hist::BUCKETS - 1], 1, "overflow counted");
        let cum = h.cumulative();
        assert_eq!(cum[Log2Hist::BUCKETS - 1], h.count(), "le +Inf == count");
        for w in cum.windows(2) {
            assert!(w[0] <= w[1], "cumulative counts are monotone");
        }
        assert_eq!(h.sum(), u64::MAX, "sum saturates instead of wrapping");

        let mut a = Log2Hist::new();
        let mut b = Log2Hist::new();
        let mut whole = Log2Hist::new();
        for (i, v) in [3u64, 9, 17, 100, 4096].iter().enumerate() {
            if i % 2 == 0 {
                a.record(*v);
            } else {
                b.record(*v);
            }
            whole.record(*v);
        }
        a.merge(&b);
        assert_eq!(a, whole, "merge equals recording the union");
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.push(0.05);
        h.push(0.95);
        h.push(-5.0); // clamps into bin 0
        h.push(7.0); // clamps into last bin
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total(), 4);
        let norm = h.normalized();
        assert!((norm.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
