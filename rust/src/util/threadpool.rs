//! Scoped thread pool (tokio/rayon are unavailable offline).
//!
//! A fixed pool of workers executing boxed jobs from a shared queue, plus
//! a `scope`-style `parallel_for` used by the pure-Rust hot paths
//! (k-means assignment sweeps, Table-1 MSE scans) and the serving
//! batcher tests.  Shutdown is explicit and panic-safe: a panicking job
//! poisons the pool and surfaces as an error on `join`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    panicked: Arc<AtomicBool>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// `threads = 0` means "number of available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            threads
        };
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let panicked = Arc::new(AtomicBool::new(false));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let panicked = Arc::clone(&panicked);
            let in_flight = Arc::clone(&in_flight);
            handles.push(
                thread::Builder::new()
                    .name(format!("vq4all-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panicked.store(true, Ordering::SeqCst);
                                }
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Ok(Msg::Stop) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx,
            handles,
            panicked,
            in_flight,
        }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    /// Busy-wait (with yields) until all enqueued jobs finished.
    pub fn wait_idle(&self) -> anyhow::Result<()> {
        while self.in_flight.load(Ordering::SeqCst) != 0 {
            thread::yield_now();
        }
        if self.panicked.load(Ordering::SeqCst) {
            anyhow::bail!("a pool job panicked");
        }
        Ok(())
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Chunked parallel map over `0..n`: calls `f(start, end)` on worker
/// threads with disjoint ranges covering `0..n`, blocking until done.
/// `f` must be `Sync` (typically writes through disjoint `&mut` chunks
/// obtained via `split_at_mut` outside).
pub fn parallel_ranges<F>(pool: &ThreadPool, n: usize, min_chunk: usize, f: F) -> anyhow::Result<()>
where
    F: Fn(usize, usize) + Send + Sync + 'static,
{
    if n == 0 {
        return Ok(());
    }
    let chunks = pool.threads().max(1);
    let chunk = ((n + chunks - 1) / chunks).max(min_chunk.max(1));
    let f = Arc::new(f);
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        let f = Arc::clone(&f);
        pool.execute(move || f(start, end));
        start = end;
    }
    pool.wait_idle()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_ranges_cover_exactly() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new((0..1000).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
        let h2 = Arc::clone(&hits);
        parallel_ranges(&pool, 1000, 1, move |s, e| {
            for i in s..e {
                h2[i].fetch_add(1, Ordering::SeqCst);
            }
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn panic_is_reported() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        assert!(pool.wait_idle().is_err());
    }

    #[test]
    fn zero_jobs_ok() {
        let pool = ThreadPool::new(2);
        pool.wait_idle().unwrap();
        parallel_ranges(&pool, 0, 1, |_, _| {}).unwrap();
    }
}
