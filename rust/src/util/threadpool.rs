//! Scoped thread pool (tokio/rayon are unavailable offline).
//!
//! A fixed pool of workers executing boxed jobs from a shared queue, plus
//! a `scope`-style `parallel_for` used by the pure-Rust hot paths
//! (k-means assignment sweeps, Table-1 MSE scans) and the serving
//! batcher tests.  Shutdown is explicit and panic-safe: a panicking job
//! surfaces as an error on `join`.
//!
//! # Panic recovery
//!
//! A panicking job is caught on the worker (`catch_unwind`), the poison
//! flag is set, and the next join reports `Err` — then, by default, the
//! pool **recovers**: the flag is cleared after it is reported, any
//! worker thread that actually died is respawned, and subsequent runs
//! proceed normally.  A long-lived engine can therefore quarantine the
//! failing shard and keep serving on the same pool.  Tests that want
//! the old poisoned-until-acknowledged semantics opt in via
//! [`ThreadPool::set_sticky_poison`] + [`ThreadPool::acknowledge_panic`].
//!
//! # `race-audit` feature
//!
//! With `--features race-audit` every [`ThreadPool::parallel_for`] run
//! keeps a shadow write-set: each [`SyncPtr::slice`] call records the
//! byte range it hands out, attributed to the chunk that asked, and the
//! join asserts (a) pairwise disjointness of the ranges across chunks
//! and (b) disjointness against every shared input registered via
//! [`ThreadPool::note_read`].  A violation surfaces as `Err` from
//! `parallel_for` — turning "the chunks never overlap" from a comment
//! into a checked contract.  The feature is for tests/CI only: recording
//! takes a mutex per `slice` call, so release builds leave it off (every
//! hook compiles to nothing).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    /// Behind a mutex so a `&self` join can respawn dead workers.
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
    /// Target worker count (== handles.len(); cached lock-free for the
    /// `parallel_for` inline-path decision).
    threads: usize,
    /// Receiver end kept for respawning replacement workers.
    rx: Arc<Mutex<mpsc::Receiver<Msg>>>,
    panicked: Arc<AtomicBool>,
    in_flight: Arc<AtomicUsize>,
    /// When true, a reported panic is NOT cleared at the join — the pool
    /// stays poisoned until [`ThreadPool::acknowledge_panic`].
    sticky_poison: AtomicBool,
    #[cfg(feature = "race-audit")]
    audit: Arc<race_audit::AuditState>,
}

fn spawn_worker(
    i: usize,
    rx: Arc<Mutex<mpsc::Receiver<Msg>>>,
    panicked: Arc<AtomicBool>,
    in_flight: Arc<AtomicUsize>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("vq4all-worker-{i}"))
        .spawn(move || loop {
            let msg = { rx.lock().unwrap().recv() };
            match msg {
                Ok(Msg::Run(job)) => {
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        panicked.store(true, Ordering::SeqCst);
                    }
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                }
                Ok(Msg::Stop) | Err(_) => break,
            }
        })
        .expect("spawn worker")
}

impl ThreadPool {
    /// `threads = 0` means "number of available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            threads
        };
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let panicked = Arc::new(AtomicBool::new(false));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            handles.push(spawn_worker(
                i,
                Arc::clone(&rx),
                Arc::clone(&panicked),
                Arc::clone(&in_flight),
            ));
        }
        ThreadPool {
            tx,
            handles: Mutex::new(handles),
            threads,
            rx,
            panicked,
            in_flight,
            sticky_poison: AtomicBool::new(false),
            #[cfg(feature = "race-audit")]
            audit: Arc::new(race_audit::AuditState::default()),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Opt into poisoned-until-acknowledged semantics: after a panic is
    /// reported, every subsequent join keeps failing until
    /// [`ThreadPool::acknowledge_panic`] clears the flag.  Off by
    /// default (the pool recovers at the reporting join).
    pub fn set_sticky_poison(&self, sticky: bool) {
        self.sticky_poison.store(sticky, Ordering::SeqCst);
    }

    /// Clear the poison flag; returns whether it was set.  Only needed
    /// under [`ThreadPool::set_sticky_poison`] — the default mode clears
    /// the flag itself when the failing join reports.
    pub fn acknowledge_panic(&self) -> bool {
        self.panicked.swap(false, Ordering::SeqCst)
    }

    /// Join + respawn any worker threads that actually died.  The worker
    /// loop catches job panics, so in practice workers survive — this
    /// guards the pathological exits (e.g. a poisoned queue mutex) so a
    /// recovered pool is guaranteed its full complement of workers.
    fn respawn_dead_workers(&self) {
        let mut handles = self.handles.lock().unwrap();
        for i in 0..handles.len() {
            if handles[i].is_finished() {
                let dead = std::mem::replace(
                    &mut handles[i],
                    spawn_worker(
                        i,
                        Arc::clone(&self.rx),
                        Arc::clone(&self.panicked),
                        Arc::clone(&self.in_flight),
                    ),
                );
                let _ = dead.join();
            }
        }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    /// Busy-wait (with yields) until all enqueued jobs finished.  A
    /// panicked job surfaces as `Err` here; by default the pool then
    /// recovers (flag cleared, dead workers respawned) so the next run
    /// starts clean — under sticky poisoning the flag stays set until
    /// [`ThreadPool::acknowledge_panic`].
    pub fn wait_idle(&self) -> anyhow::Result<()> {
        while self.in_flight.load(Ordering::SeqCst) != 0 {
            thread::yield_now();
        }
        let poisoned = if self.sticky_poison.load(Ordering::SeqCst) {
            self.panicked.load(Ordering::SeqCst)
        } else {
            self.panicked.swap(false, Ordering::SeqCst)
        };
        if poisoned {
            self.respawn_dead_workers();
            anyhow::bail!("a pool job panicked");
        }
        Ok(())
    }

    /// Register `slice` as a shared read-only input of the next
    /// [`ThreadPool::parallel_for`] run: under `race-audit` the join
    /// fails if any chunk's [`SyncPtr::slice`] write range overlaps it.
    /// Without the feature this compiles to nothing.
    #[cfg(feature = "race-audit")]
    pub fn note_read<T>(&self, slice: &[T]) {
        let start = slice.as_ptr() as usize;
        self.audit.note_read(start, start + std::mem::size_of_val(slice));
    }

    /// `race-audit`-only hook; a no-op in normal builds.
    #[cfg(not(feature = "race-audit"))]
    #[inline(always)]
    pub fn note_read<T>(&self, _slice: &[T]) {}

    /// Join-time audit: always drain the shadow write/read sets, then
    /// report the join error (a panicked chunk) ahead of any overlap.
    #[cfg(feature = "race-audit")]
    fn finish_audit(&self, joined: anyhow::Result<()>) -> anyhow::Result<()> {
        let audit = self.audit.check_and_clear();
        joined.and(audit)
    }

    #[cfg(not(feature = "race-audit"))]
    #[inline(always)]
    fn finish_audit(&self, joined: anyhow::Result<()>) -> anyhow::Result<()> {
        joined
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        let mut handles = self.handles.lock().unwrap();
        for _ in handles.iter() {
            let _ = self.tx.send(Msg::Stop);
        }
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl ThreadPool {
    /// Scoped chunked parallel-for over `0..n`: calls `f(start, end)` for
    /// every fixed-size chunk `[i*chunk, min((i+1)*chunk, n))`, blocking
    /// until all chunks completed.  Unlike [`parallel_ranges`] the closure
    /// may borrow from the caller's stack (no `'static` bound).
    ///
    /// **Determinism contract:** the chunk decomposition depends only on
    /// `(n, chunk)` — never on the worker count or scheduling — so any
    /// per-chunk state (RNG streams seeded by chunk index, per-chunk
    /// float accumulators reduced in chunk order) produces bit-identical
    /// results at every thread count, including the serial `threads = 1`
    /// path.  Every parallelized hot path in `vq::` relies on this.
    ///
    /// A panicking chunk surfaces as `Err` from the final join instead
    /// of hanging (the worker's `catch_unwind` always decrements the
    /// in-flight count); the pool recovers at that join unless sticky
    /// poisoning is on — see the module docs.
    pub fn parallel_for<F>(&self, n: usize, chunk: usize, f: F) -> anyhow::Result<()>
    where
        F: Fn(usize, usize) + Send + Sync,
    {
        let chunk = chunk.max(1);
        if n == 0 {
            return self.finish_audit(self.wait_idle());
        }
        if self.threads() <= 1 || n <= chunk {
            // Inline path: same decomposition, no cross-thread dispatch.
            // Chunks still enter the race audit so the overlap contract
            // is checked even on serial runs (and negative tests can
            // exercise a bad write plan without a real data race).
            let mut start = 0;
            while start < n {
                let end = (start + chunk).min(n);
                #[cfg(feature = "race-audit")]
                let _guard = race_audit::ChunkGuard::enter(Arc::clone(&self.audit), start / chunk);
                f(start, end);
                start = end;
            }
            return self.finish_audit(self.wait_idle());
        }
        let f_ref: &(dyn Fn(usize, usize) + Send + Sync) = &f;
        // SAFETY: every job enqueued below decrements `in_flight` exactly
        // once (panics are caught by the worker loop), and `wait_idle`
        // blocks until the count reaches zero — so no job can observe `f`
        // after this frame returns, making the lifetime erasure sound.
        let f_static: &'static (dyn Fn(usize, usize) + Send + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            #[cfg(feature = "race-audit")]
            {
                let audit = Arc::clone(&self.audit);
                let index = start / chunk;
                self.execute(move || {
                    let _guard = race_audit::ChunkGuard::enter(audit, index);
                    f_static(start, end)
                });
            }
            #[cfg(not(feature = "race-audit"))]
            self.execute(move || f_static(start, end));
            start = end;
        }
        self.finish_audit(self.wait_idle())
    }
}

/// Raw-pointer wrapper for writing *disjoint* ranges of one slice from
/// multiple pool jobs (the chunks handed out by [`ThreadPool::parallel_for`]
/// never overlap, so each job owns its range exclusively).  Under the
/// `race-audit` feature every `slice` call is bounds-checked against the
/// source slice and recorded in the pool's shadow write-set.
#[derive(Clone, Copy)]
pub struct SyncPtr<T> {
    ptr: *mut T,
    #[cfg(feature = "race-audit")]
    len: usize,
}

// SAFETY: SyncPtr is only a capability to re-derive `&mut [T]` windows;
// callers uphold disjointness per `slice`'s contract (checked at join
// under `race-audit`), so sending/sharing the pointer itself is sound
// whenever `T: Send` (the data may move across threads, never aliased).
unsafe impl<T: Send> Send for SyncPtr<T> {}
// SAFETY: as above — `&SyncPtr<T>` only exposes `slice`, whose contract
// forbids overlapping ranges across concurrent users.
unsafe impl<T: Send> Sync for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    pub fn new(slice: &mut [T]) -> Self {
        SyncPtr {
            ptr: slice.as_mut_ptr(),
            #[cfg(feature = "race-audit")]
            len: slice.len(),
        }
    }

    /// Reborrow `[start, start + len)` mutably.
    ///
    /// # Safety
    /// The range must lie inside the original slice and must not overlap
    /// any range concurrently handed to another job.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        #[cfg(feature = "race-audit")]
        {
            assert!(
                start.checked_add(len).is_some_and(|e| e <= self.len),
                "race-audit: slice [{start}, {start}+{len}) outside the {}-element source",
                self.len
            );
            let base = self.ptr as usize;
            race_audit::note_write(
                base + start * std::mem::size_of::<T>(),
                base + (start + len) * std::mem::size_of::<T>(),
            );
        }
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Shadow write-set race detector behind the `race-audit` feature — see
/// the module docs for the contract it enforces.
#[cfg(feature = "race-audit")]
pub mod race_audit {
    use std::cell::RefCell;
    use std::sync::{Arc, Mutex};

    /// One recorded write: byte range `[start, end)` claimed via
    /// [`super::SyncPtr::slice`] by chunk `chunk` of the current run.
    #[derive(Clone, Copy, Debug)]
    struct WriteRec {
        chunk: usize,
        start: usize,
        end: usize,
    }

    /// Per-pool shadow sets, drained at every `parallel_for` join.
    #[derive(Default)]
    pub struct AuditState {
        writes: Mutex<Vec<WriteRec>>,
        reads: Mutex<Vec<(usize, usize)>>,
    }

    thread_local! {
        /// The (pool, chunk index) a `slice` call on this thread should
        /// be attributed to; `None` outside a `parallel_for` chunk.
        static CURRENT: RefCell<Option<(Arc<AuditState>, usize)>> = const { RefCell::new(None) };
    }

    /// RAII marker: while alive, `SyncPtr::slice` calls on this thread
    /// are attributed to chunk `index` of `state`.  `parallel_for` holds
    /// one around every chunk call, on both the inline and pooled paths.
    pub struct ChunkGuard;

    impl ChunkGuard {
        pub fn enter(state: Arc<AuditState>, index: usize) -> ChunkGuard {
            CURRENT.with(|c| *c.borrow_mut() = Some((state, index)));
            ChunkGuard
        }
    }

    impl Drop for ChunkGuard {
        fn drop(&mut self) {
            CURRENT.with(|c| *c.borrow_mut() = None);
        }
    }

    /// Record a byte-range write for the current chunk (no-op outside a
    /// `parallel_for` chunk — e.g. plain `execute` jobs).
    pub fn note_write(start: usize, end: usize) {
        CURRENT.with(|c| {
            if let Some((state, chunk)) = c.borrow().as_ref() {
                state.writes.lock().unwrap().push(WriteRec {
                    chunk: *chunk,
                    start,
                    end,
                });
            }
        });
    }

    impl AuditState {
        pub(super) fn note_read(&self, start: usize, end: usize) {
            if start < end {
                self.reads.lock().unwrap().push((start, end));
            }
        }

        /// Drain the shadow sets and check the disjointness contract.
        /// Always drains — a failed run must not poison the next one.
        pub(super) fn check_and_clear(&self) -> anyhow::Result<()> {
            let mut writes = std::mem::take(&mut *self.writes.lock().unwrap());
            let reads = std::mem::take(&mut *self.reads.lock().unwrap());
            // Coalesce each chunk's own ranges first: a chunk re-slicing
            // its window is sequential with itself and perfectly legal.
            writes.sort_by_key(|w| (w.chunk, w.start));
            let mut merged: Vec<WriteRec> = Vec::with_capacity(writes.len());
            for w in writes {
                if w.start >= w.end {
                    continue;
                }
                match merged.last_mut() {
                    Some(m) if m.chunk == w.chunk && w.start <= m.end => m.end = m.end.max(w.end),
                    _ => merged.push(w),
                }
            }
            // Cross-chunk sweep in address order.  `max1` is the
            // furthest-reaching interval so far; `alt_end` bounds the
            // furthest end among *other* chunks than `max1`'s (it may
            // conservatively include `max1.chunk` entries — harmless,
            // since post-coalescing a chunk never starts before its own
            // earlier end, so those can't trip the comparison).
            merged.sort_by_key(|w| (w.start, w.end));
            let mut max1: Option<(usize, usize)> = None; // (end, chunk)
            let mut alt_end = 0usize;
            for w in &merged {
                let other_end = match max1 {
                    Some((_, chunk)) if chunk == w.chunk => alt_end,
                    Some((end, _)) => end,
                    None => 0,
                };
                if w.start < other_end {
                    anyhow::bail!(
                        "race-audit: chunk {} write [{:#x}, {:#x}) overlaps another \
                         chunk's write ending at {:#x}",
                        w.chunk,
                        w.start,
                        w.end,
                        other_end
                    );
                }
                match &mut max1 {
                    Some((end, chunk)) if *chunk == w.chunk => *end = (*end).max(w.end),
                    Some((end, chunk)) => {
                        if w.end >= *end {
                            alt_end = alt_end.max(*end);
                            *end = w.end;
                            *chunk = w.chunk;
                        } else {
                            alt_end = alt_end.max(w.end);
                        }
                    }
                    None => max1 = Some((w.end, w.chunk)),
                }
            }
            // Shared inputs: no chunk may write into a registered read
            // range (reads are few — a linear scan per read is fine).
            for &(rs, re) in &reads {
                for w in &merged {
                    if w.start < re && rs < w.end {
                        anyhow::bail!(
                            "race-audit: chunk {} write [{:#x}, {:#x}) overlaps shared \
                             read range [{:#x}, {:#x})",
                            w.chunk,
                            w.start,
                            w.end,
                            rs,
                            re
                        );
                    }
                }
            }
            Ok(())
        }
    }
}

/// Chunked parallel map over `0..n`: calls `f(start, end)` on worker
/// threads with disjoint ranges covering `0..n`, blocking until done.
/// `f` must be `Sync` (typically writes through disjoint `&mut` chunks
/// obtained via `split_at_mut` outside).
pub fn parallel_ranges<F>(pool: &ThreadPool, n: usize, min_chunk: usize, f: F) -> anyhow::Result<()>
where
    F: Fn(usize, usize) + Send + Sync + 'static,
{
    if n == 0 {
        return Ok(());
    }
    let chunks = pool.threads().max(1);
    let chunk = n.div_ceil(chunks).max(min_chunk.max(1));
    let f = Arc::new(f);
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        let f = Arc::clone(&f);
        pool.execute(move || f(start, end));
        start = end;
    }
    pool.wait_idle()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_ranges_cover_exactly() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new((0..1000).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
        let h2 = Arc::clone(&hits);
        parallel_ranges(&pool, 1000, 1, move |s, e| {
            for i in s..e {
                h2[i].fetch_add(1, Ordering::SeqCst);
            }
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn panic_is_reported() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        assert!(pool.wait_idle().is_err());
    }

    #[test]
    fn zero_jobs_ok() {
        let pool = ThreadPool::new(2);
        pool.wait_idle().unwrap();
        parallel_ranges(&pool, 0, 1, |_, _| {}).unwrap();
    }

    /// Every `[start, end)` pair handed out by `parallel_for` must tile
    /// `0..n` exactly once, independent of the worker count.
    fn assert_covers_exactly(threads: usize, n: usize, chunk: usize) {
        let pool = ThreadPool::new(threads);
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, chunk, |s, e| {
            assert!(s < e && e <= n, "bad range [{s}, {e}) for n={n}");
            assert_eq!(s % chunk, 0, "chunk start not aligned");
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::SeqCst);
            }
        })
        .unwrap();
        assert!(
            hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
            "threads={threads} n={n} chunk={chunk}: uneven coverage"
        );
    }

    #[test]
    fn parallel_for_zero_items() {
        let pool = ThreadPool::new(4);
        let ran = AtomicU64::new(0);
        pool.parallel_for(0, 16, |_, _| {
            ran.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 0, "no chunks for n = 0");
    }

    #[test]
    fn parallel_for_one_item() {
        assert_covers_exactly(4, 1, 16);
        assert_covers_exactly(1, 1, 1);
    }

    #[test]
    fn parallel_for_items_far_fewer_than_threads() {
        // 3 items over 8 workers with chunk 1: three 1-element chunks.
        assert_covers_exactly(8, 3, 1);
        // Fewer chunks than threads after rounding.
        assert_covers_exactly(8, 10, 4);
    }

    #[test]
    fn parallel_for_covers_all_thread_counts() {
        for threads in [1, 2, 3, 7] {
            assert_covers_exactly(threads, 1000, 64);
        }
    }

    #[test]
    fn parallel_for_can_borrow_stack_state() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..500).collect();
        let total = AtomicU64::new(0);
        pool.parallel_for(data.len(), 32, |s, e| {
            let part: u64 = data[s..e].iter().sum();
            total.fetch_add(part, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(total.load(Ordering::SeqCst), 500 * 499 / 2);
    }

    #[test]
    fn parallel_for_panic_surfaces_as_error_not_hang() {
        let pool = ThreadPool::new(3);
        let err = pool
            .parallel_for(100, 4, |s, _| {
                if s == 48 {
                    panic!("chunk bomb");
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "got: {err}");
        // Recovery: the failure is reported exactly once, then the pool
        // is clean — the next run succeeds and actually does its work.
        let ran = AtomicU64::new(0);
        pool.parallel_for(8, 4, |_, _| {
            ran.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 2, "recovered pool runs all chunks");
        assert_eq!(pool.threads(), 3, "full worker complement after recovery");
    }

    #[test]
    fn sticky_poison_holds_until_acknowledged() {
        let pool = ThreadPool::new(2);
        pool.set_sticky_poison(true);
        assert!(pool
            .parallel_for(8, 2, |s, _| {
                if s == 2 {
                    panic!("sticky bomb");
                }
            })
            .is_err());
        // Sticky mode: later joins keep reporting the old failure.
        assert!(pool.parallel_for(4, 4, |_, _| {}).is_err());
        assert!(pool.acknowledge_panic(), "flag was set");
        assert!(!pool.acknowledge_panic(), "ack clears it");
        pool.parallel_for(4, 4, |_, _| {}).unwrap();
    }

    #[test]
    fn execute_after_recovered_panic_runs() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        assert!(pool.wait_idle().is_err());
        let c = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&c);
        pool.execute(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle().unwrap();
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn sync_ptr_disjoint_chunk_writes() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0u64; 777];
        let n = out.len();
        let ptr = SyncPtr::new(&mut out);
        pool.parallel_for(n, 10, |s, e| {
            // SAFETY: parallel_for ranges are disjoint.
            let chunk = unsafe { ptr.slice(s, e - s) };
            for (off, v) in chunk.iter_mut().enumerate() {
                *v = (s + off) as u64;
            }
        })
        .unwrap();
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64));
    }

    #[cfg(feature = "race-audit")]
    mod race_audit_detection {
        use super::*;

        #[test]
        fn overlapping_chunk_writes_trip_the_audit() {
            // One worker forces the inline path, so the chunks with the
            // deliberately-overlapping write plan run *sequentially* —
            // no real data race happens, only the recorded plan is bad,
            // which is exactly what the join must reject.
            let pool = ThreadPool::new(1);
            let mut out = vec![0u32; 64];
            let ptr = SyncPtr::new(&mut out);
            let err = pool
                .parallel_for(64, 16, |s, _| {
                    // SAFETY: in-bounds and sequential on the inline
                    // path; the cross-chunk overlap is the point.
                    let w = unsafe { ptr.slice(0, 8) };
                    w[0] = s as u32;
                })
                .unwrap_err();
            assert!(err.to_string().contains("race-audit"), "got: {err}");
            // The audit drains at the join: the pool is not poisoned and
            // a following disjoint run passes clean.
            let ok = pool.parallel_for(64, 16, |s, e| {
                // SAFETY: parallel_for ranges are disjoint.
                let w = unsafe { ptr.slice(s, e - s) };
                w.fill(1);
            });
            assert!(ok.is_ok(), "clean run after violation: {ok:?}");
        }

        #[test]
        fn disjoint_writes_pass_under_audit_on_the_pooled_path() {
            let pool = ThreadPool::new(4);
            let mut out = vec![0u8; 501];
            let n = out.len();
            let ptr = SyncPtr::new(&mut out);
            pool.parallel_for(n, 32, |s, e| {
                // SAFETY: parallel_for ranges are disjoint.
                unsafe { ptr.slice(s, e - s) }.fill(7);
            })
            .unwrap();
            assert!(out.iter().all(|&v| v == 7));
        }

        #[test]
        fn write_into_registered_read_range_trips_the_audit() {
            let pool = ThreadPool::new(1);
            let mut buf = vec![0u32; 32];
            let ptr = SyncPtr::new(&mut buf);
            // Register the same buffer as a shared read-only input, then
            // write it from chunks: disjoint across chunks, but a
            // read/write race against the registered range.
            pool.note_read(&buf);
            let err = pool
                .parallel_for(2, 1, |s, _| {
                    // SAFETY: in-bounds, disjoint across chunks, and
                    // sequential on the inline path; the conflict with
                    // the registered read range is the point.
                    unsafe { ptr.slice(s, 1) }[0] = 1;
                })
                .unwrap_err();
            assert!(err.to_string().contains("read range"), "got: {err}");
        }

        #[test]
        #[should_panic(expected = "race-audit")]
        fn out_of_bounds_slice_asserts() {
            let mut buf = vec![0u8; 8];
            let ptr = SyncPtr::new(&mut buf);
            // SAFETY: never reached — the bounds assertion fires first.
            let _ = unsafe { ptr.slice(4, 8) };
        }
    }
}
