//! Scoped thread pool (tokio/rayon are unavailable offline).
//!
//! A fixed pool of workers executing boxed jobs from a shared queue, plus
//! a `scope`-style `parallel_for` used by the pure-Rust hot paths
//! (k-means assignment sweeps, Table-1 MSE scans) and the serving
//! batcher tests.  Shutdown is explicit and panic-safe: a panicking job
//! poisons the pool and surfaces as an error on `join`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    panicked: Arc<AtomicBool>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// `threads = 0` means "number of available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            threads
        };
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let panicked = Arc::new(AtomicBool::new(false));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let panicked = Arc::clone(&panicked);
            let in_flight = Arc::clone(&in_flight);
            handles.push(
                thread::Builder::new()
                    .name(format!("vq4all-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panicked.store(true, Ordering::SeqCst);
                                }
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Ok(Msg::Stop) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx,
            handles,
            panicked,
            in_flight,
        }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    /// Busy-wait (with yields) until all enqueued jobs finished.
    pub fn wait_idle(&self) -> anyhow::Result<()> {
        while self.in_flight.load(Ordering::SeqCst) != 0 {
            thread::yield_now();
        }
        if self.panicked.load(Ordering::SeqCst) {
            anyhow::bail!("a pool job panicked");
        }
        Ok(())
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl ThreadPool {
    /// Scoped chunked parallel-for over `0..n`: calls `f(start, end)` for
    /// every fixed-size chunk `[i*chunk, min((i+1)*chunk, n))`, blocking
    /// until all chunks completed.  Unlike [`parallel_ranges`] the closure
    /// may borrow from the caller's stack (no `'static` bound).
    ///
    /// **Determinism contract:** the chunk decomposition depends only on
    /// `(n, chunk)` — never on the worker count or scheduling — so any
    /// per-chunk state (RNG streams seeded by chunk index, per-chunk
    /// float accumulators reduced in chunk order) produces bit-identical
    /// results at every thread count, including the serial `threads = 1`
    /// path.  Every parallelized hot path in `vq::` relies on this.
    ///
    /// A panicking chunk poisons the pool and surfaces as `Err` from the
    /// final join instead of hanging (the worker's `catch_unwind` always
    /// decrements the in-flight count).
    pub fn parallel_for<F>(&self, n: usize, chunk: usize, f: F) -> anyhow::Result<()>
    where
        F: Fn(usize, usize) + Send + Sync,
    {
        let chunk = chunk.max(1);
        if n == 0 {
            return self.wait_idle();
        }
        if self.threads() <= 1 || n <= chunk {
            // Inline path: same decomposition, no cross-thread dispatch.
            let mut start = 0;
            while start < n {
                let end = (start + chunk).min(n);
                f(start, end);
                start = end;
            }
            return self.wait_idle();
        }
        // SAFETY: every job enqueued below decrements `in_flight` exactly
        // once (panics are caught by the worker loop), and `wait_idle`
        // blocks until the count reaches zero — so no job can observe `f`
        // after this frame returns, making the lifetime erasure sound.
        let f_ref: &(dyn Fn(usize, usize) + Send + Sync) = &f;
        let f_static: &'static (dyn Fn(usize, usize) + Send + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            self.execute(move || f_static(start, end));
            start = end;
        }
        self.wait_idle()
    }
}

/// Raw-pointer wrapper for writing *disjoint* ranges of one slice from
/// multiple pool jobs (the chunks handed out by [`ThreadPool::parallel_for`]
/// never overlap, so each job owns its range exclusively).
#[derive(Clone, Copy)]
pub struct SyncPtr<T>(*mut T);

unsafe impl<T: Send> Send for SyncPtr<T> {}
unsafe impl<T: Send> Sync for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    pub fn new(slice: &mut [T]) -> Self {
        SyncPtr(slice.as_mut_ptr())
    }

    /// Reborrow `[start, start + len)` mutably.
    ///
    /// # Safety
    /// The range must lie inside the original slice and must not overlap
    /// any range concurrently handed to another job.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

/// Chunked parallel map over `0..n`: calls `f(start, end)` on worker
/// threads with disjoint ranges covering `0..n`, blocking until done.
/// `f` must be `Sync` (typically writes through disjoint `&mut` chunks
/// obtained via `split_at_mut` outside).
pub fn parallel_ranges<F>(pool: &ThreadPool, n: usize, min_chunk: usize, f: F) -> anyhow::Result<()>
where
    F: Fn(usize, usize) + Send + Sync + 'static,
{
    if n == 0 {
        return Ok(());
    }
    let chunks = pool.threads().max(1);
    let chunk = ((n + chunks - 1) / chunks).max(min_chunk.max(1));
    let f = Arc::new(f);
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        let f = Arc::clone(&f);
        pool.execute(move || f(start, end));
        start = end;
    }
    pool.wait_idle()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_ranges_cover_exactly() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new((0..1000).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
        let h2 = Arc::clone(&hits);
        parallel_ranges(&pool, 1000, 1, move |s, e| {
            for i in s..e {
                h2[i].fetch_add(1, Ordering::SeqCst);
            }
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn panic_is_reported() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        assert!(pool.wait_idle().is_err());
    }

    #[test]
    fn zero_jobs_ok() {
        let pool = ThreadPool::new(2);
        pool.wait_idle().unwrap();
        parallel_ranges(&pool, 0, 1, |_, _| {}).unwrap();
    }

    /// Every `[start, end)` pair handed out by `parallel_for` must tile
    /// `0..n` exactly once, independent of the worker count.
    fn assert_covers_exactly(threads: usize, n: usize, chunk: usize) {
        let pool = ThreadPool::new(threads);
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, chunk, |s, e| {
            assert!(s < e && e <= n, "bad range [{s}, {e}) for n={n}");
            assert_eq!(s % chunk, 0, "chunk start not aligned");
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::SeqCst);
            }
        })
        .unwrap();
        assert!(
            hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
            "threads={threads} n={n} chunk={chunk}: uneven coverage"
        );
    }

    #[test]
    fn parallel_for_zero_items() {
        let pool = ThreadPool::new(4);
        let ran = AtomicU64::new(0);
        pool.parallel_for(0, 16, |_, _| {
            ran.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 0, "no chunks for n = 0");
    }

    #[test]
    fn parallel_for_one_item() {
        assert_covers_exactly(4, 1, 16);
        assert_covers_exactly(1, 1, 1);
    }

    #[test]
    fn parallel_for_items_far_fewer_than_threads() {
        // 3 items over 8 workers with chunk 1: three 1-element chunks.
        assert_covers_exactly(8, 3, 1);
        // Fewer chunks than threads after rounding.
        assert_covers_exactly(8, 10, 4);
    }

    #[test]
    fn parallel_for_covers_all_thread_counts() {
        for threads in [1, 2, 3, 7] {
            assert_covers_exactly(threads, 1000, 64);
        }
    }

    #[test]
    fn parallel_for_can_borrow_stack_state() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..500).collect();
        let total = AtomicU64::new(0);
        pool.parallel_for(data.len(), 32, |s, e| {
            let part: u64 = data[s..e].iter().sum();
            total.fetch_add(part, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(total.load(Ordering::SeqCst), 500 * 499 / 2);
    }

    #[test]
    fn parallel_for_panic_surfaces_as_error_not_hang() {
        let pool = ThreadPool::new(3);
        let err = pool
            .parallel_for(100, 4, |s, _| {
                if s == 48 {
                    panic!("chunk bomb");
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "got: {err}");
        // The pool stays poisoned: later joins keep reporting the failure.
        assert!(pool.parallel_for(4, 4, |_, _| {}).is_err());
    }

    #[test]
    fn sync_ptr_disjoint_chunk_writes() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0u64; 777];
        let n = out.len();
        let ptr = SyncPtr::new(&mut out);
        pool.parallel_for(n, 10, |s, e| {
            // SAFETY: parallel_for ranges are disjoint.
            let chunk = unsafe { ptr.slice(s, e - s) };
            for (off, v) in chunk.iter_mut().enumerate() {
                *v = (s + off) as u64;
            }
        })
        .unwrap();
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64));
    }
}
