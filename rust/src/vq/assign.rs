//! Candidate-assignment search (Eq. 5) and ratio-logit init (Eq. 7).
//!
//! The AOT `init_assign` artifact does this on the device path (Pallas
//! distance kernel); this host implementation backs the pure-Rust
//! baselines, the Table-7 initialization ablation (random / cosine /
//! Euclidean), and the coordinator's unit tests.

use crate::tensor::ops;
use crate::util::rng::Rng;

use super::codebook::Codebook;

/// Candidate-initialization strategy (Table 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignInit {
    /// Uniformly random codewords (Table 7 col 1 — the failure mode).
    Random,
    /// Top-n by cosine similarity (Table 7 col 2).
    Cosine,
    /// Top-n by Euclidean distance (Table 7 col 3 — the paper's choice).
    Euclid,
}

/// Candidate table + distances for `(s, d)` sub-vectors.
#[derive(Clone, Debug)]
pub struct Candidates {
    pub n: usize,
    /// `(s, n)` codeword indices, best first.
    pub assign: Vec<u32>,
    /// `(s, n)` squared distances (Euclid) or 1-cos (Cosine); random
    /// init stores Euclidean distances of the random picks.
    pub dist: Vec<f32>,
}

/// Build the candidate table (Eq. 5 generalized per Table 7).
pub fn candidates(
    flat: &[f32],
    cb: &Codebook,
    n: usize,
    init: AssignInit,
    rng: &mut Rng,
) -> Candidates {
    assert_eq!(flat.len() % cb.d, 0);
    let s = flat.len() / cb.d;
    assert!(n >= 1 && n <= cb.k, "n={n} out of range for k={}", cb.k);
    let mut assign = vec![0u32; s * n];
    let mut dist = vec![0.0f32; s * n];
    let mut scratch = vec![0.0f32; cb.k];

    for g in 0..s {
        let sub = &flat[g * cb.d..(g + 1) * cb.d];
        match init {
            AssignInit::Random => {
                for m in 0..n {
                    let c = rng.below(cb.k);
                    assign[g * n + m] = c as u32;
                    dist[g * n + m] = ops::sq_dist(sub, cb.word(c));
                }
            }
            AssignInit::Euclid | AssignInit::Cosine => {
                for c in 0..cb.k {
                    scratch[c] = match init {
                        AssignInit::Euclid => ops::sq_dist(sub, cb.word(c)),
                        AssignInit::Cosine => 1.0 - ops::cosine(sub, cb.word(c)),
                        AssignInit::Random => unreachable!(),
                    };
                }
                for (m, &c) in ops::argmin_n(&scratch, n).iter().enumerate() {
                    assign[g * n + m] = c as u32;
                    dist[g * n + m] = scratch[c];
                }
            }
        }
    }
    Candidates { n, assign, dist }
}

/// Eq. 7: logits `z_m = ln(d_last / d_m)` so softmax(z) ∝ 1/d.
pub fn init_ratio_logits(cand: &Candidates) -> Vec<f32> {
    let n = cand.n;
    let s = cand.dist.len() / n;
    let mut z = vec![0.0f32; s * n];
    for g in 0..s {
        let row = &cand.dist[g * n..(g + 1) * n];
        let last = row[n - 1].max(1e-12);
        for m in 0..n {
            z[g * n + m] = (last / row[m].max(1e-12)).ln();
        }
    }
    z
}

/// Equal-initialization alternative (supplementary §10's comparison):
/// all logits zero -> uniform ratios.
pub fn equal_ratio_logits(s: usize, n: usize) -> Vec<f32> {
    vec![0.0; s * n]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cb() -> Codebook {
        Codebook::new(4, 2, vec![0., 0., 1., 0., 0., 1., 5., 5.])
    }

    #[test]
    fn euclid_orders_by_distance() {
        let mut rng = Rng::new(1);
        let flat = [0.9f32, 0.1]; // nearest (1,0), then (0,0), then (0,1)
        let c = candidates(&flat, &cb(), 3, AssignInit::Euclid, &mut rng);
        assert_eq!(c.assign[0], 1);
        assert_eq!(c.assign[1], 0);
        assert_eq!(c.assign[2], 2);
        assert!(c.dist[0] <= c.dist[1] && c.dist[1] <= c.dist[2]);
    }

    #[test]
    fn cosine_differs_from_euclid_on_scaled_words() {
        // (5,5) has perfect cosine with (0.1,0.1) but large distance.
        let mut rng = Rng::new(2);
        let flat = [0.1f32, 0.1];
        let e = candidates(&flat, &cb(), 1, AssignInit::Euclid, &mut rng);
        let c = candidates(&flat, &cb(), 1, AssignInit::Cosine, &mut rng);
        assert_eq!(e.assign[0], 0, "euclid picks the origin");
        assert_eq!(c.assign[0], 3, "cosine picks the aligned word");
    }

    #[test]
    fn random_within_range_and_deterministic() {
        let mut rng = Rng::new(3);
        let flat = [0.0f32; 20];
        let a = candidates(&flat, &cb(), 4, AssignInit::Random, &mut rng);
        assert!(a.assign.iter().all(|&c| (c as usize) < 4));
        let mut rng2 = Rng::new(3);
        let b = candidates(&flat, &cb(), 4, AssignInit::Random, &mut rng2);
        assert_eq!(a.assign, b.assign);
    }

    #[test]
    fn ratio_logits_inverse_proportional() {
        let cand = Candidates {
            n: 3,
            assign: vec![0, 1, 2],
            dist: vec![0.5, 1.0, 2.0],
        };
        let z = init_ratio_logits(&cand);
        // softmax(z) proportional to 1/d: check r0/r1 = d1/d0 = 2.
        let e: Vec<f64> = z.iter().map(|&x| (x as f64).exp()).collect();
        assert!((e[0] / e[1] - 2.0).abs() < 1e-6);
        assert!((e[1] / e[2] - 2.0).abs() < 1e-6);
        assert!((z[2]).abs() < 1e-7, "last logit is 0 by construction");
    }

    #[test]
    fn n_bounds_checked() {
        let mut rng = Rng::new(4);
        let flat = [0.0f32, 0.0];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            candidates(&flat, &cb(), 5, AssignInit::Euclid, &mut rng)
        }));
        assert!(res.is_err());
    }
}
